//! Quickstart: decompose a small synthetic rating tensor with the full
//! cuFasterTucker algorithm and watch test RMSE fall.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use fastertucker::algo::Algo;
use fastertucker::config::TrainConfig;
use fastertucker::coordinator::Session;
use fastertucker::data::split::{filter_cold, train_test};
use fastertucker::data::synthetic::{recommender, RecommenderSpec};

fn main() -> anyhow::Result<()> {
    // 1. a (user × item × time) rating tensor with power-law activity
    let tensor = recommender(&RecommenderSpec::tiny(), 42);
    println!(
        "tensor: dims {:?}, {} observed ratings (density {:.2e})",
        tensor.dims(),
        tensor.nnz(),
        tensor.density()
    );

    // 2. hold out 10% for evaluation
    let (train, test) = train_test(&tensor, 0.1, 7);
    let test = filter_cold(&test, &train);

    // 3. configure: rank-16 factors, rank-16 core matrices
    let cfg = TrainConfig {
        order: train.order(),
        dims: train.dims().to_vec(),
        j: 16,
        r: 16,
        lr_a: 0.01,
        lr_b: 1e-4,
        workers: 4,
        ..TrainConfig::default()
    };

    // 4. train with the paper's full algorithm (B-CSF + both intermediate
    //    reuse strategies); the session stages its storages once up front
    let mut session = Session::new(Algo::FasterTucker, cfg, &train)?;
    let report = session.run(15, Some(&test));

    for rec in &report.convergence.records {
        println!(
            "epoch {:>2}  RMSE {:.4}  MAE {:.4}  ({:.1} ms)",
            rec.epoch,
            rec.rmse,
            rec.mae,
            rec.seconds * 1e3
        );
    }
    assert!(report.convergence.improved(), "training should reduce RMSE");
    println!("final test RMSE: {:.4}", report.last_rmse());
    Ok(())
}
