//! High-order tensors (the paper's §V-D claim): FasterTucker's per-epoch
//! cost grows far slower with tensor order than FastTucker's, because the
//! chain products come from the C tables (`N−2` multiplies) instead of
//! fresh `J·R` dot products per mode.
//!
//! ```sh
//! cargo run --release --example high_order
//! ```

use fastertucker::algo::Algo;
use fastertucker::config::TrainConfig;
use fastertucker::coordinator::Session;
use fastertucker::data::synthetic::order_sweep;

fn main() -> anyhow::Result<()> {
    let dim = 200;
    let nnz = 60_000;
    println!("order | cuFastTucker s/iter | cuFasterTucker s/iter | ratio");
    for order in 3..=7 {
        let data = order_sweep(order, dim, nnz, 11 + order as u64);
        let mut times = Vec::new();
        for algo in [Algo::FastTucker, Algo::FasterTucker] {
            let cfg = TrainConfig {
                order,
                dims: data.dims().to_vec(),
                j: 16,
                r: 16,
                ..TrainConfig::default()
            };
            let mut session = Session::new(algo, cfg, &data)?;
            session.epoch(); // warmup
            let t = std::time::Instant::now();
            session.epoch();
            times.push(t.elapsed().as_secs_f64());
        }
        println!(
            "{order:>5} | {:>19.4} | {:>21.4} | {:>5.2}x",
            times[0],
            times[1],
            times[0] / times[1]
        );
    }
    Ok(())
}
