//! END-TO-END driver: proves all three layers compose on a real workload.
//!
//! * **L1/L2** — loads the AOT-compiled JAX/Pallas artifacts
//!   (`make artifacts`) and runs the dense kernels through PJRT from the
//!   training hot path (`--compute pjrt` equivalent).
//! * **L3** — generates a Netflix-shaped sparse tensor, builds B-CSF,
//!   trains all four FastTucker-family variants with the worker-parallel
//!   SGD executor, and reports the paper's headline metric: per-iteration
//!   speedup of cuFasterTucker over cuFastTucker (Table V shape), plus the
//!   convergence curves (Fig. 3 shape).
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use fastertucker::algo::Algo;
use fastertucker::config::{Compute, TrainConfig};
use fastertucker::coordinator::Trainer;
use fastertucker::data::split::{filter_cold, train_test};
use fastertucker::data::synthetic::{recommender, RecommenderSpec};
use fastertucker::runtime::{default_artifacts_dir, PjrtRuntime};

fn main() -> anyhow::Result<()> {
    let nnz: usize = std::env::var("FT_E2E_NNZ")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400_000);
    let epochs: usize = std::env::var("FT_E2E_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);

    println!("=== end-to-end: data ===");
    let tensor = recommender(&RecommenderSpec::netflix_like(nnz), 2026);
    let (train, test) = train_test(&tensor, 0.1, 5);
    let test = filter_cold(&test, &train);
    println!(
        "netflix-like tensor: dims {:?}, {} train nnz, {} test nnz",
        train.dims(),
        train.nnz(),
        test.nnz()
    );

    println!("\n=== end-to-end: PJRT artifacts (L1/L2) ===");
    let artifacts = default_artifacts_dir();
    let runtime = match PjrtRuntime::load(&artifacts) {
        Ok(rt) => {
            println!(
                "loaded {} artifacts on platform '{}' from {}",
                rt.num_artifacts(),
                rt.platform(),
                artifacts.display()
            );
            Some(rt)
        }
        Err(e) => {
            println!(
                "artifacts unavailable ({e}); continuing with the Rust engine \
                 (run `make artifacts` for the full three-layer path)"
            );
            None
        }
    };

    println!("\n=== end-to-end: training all variants (L3, Rust engine) ===");
    let variants = [
        Algo::FastTucker,
        Algo::FasterTuckerCoo,
        Algo::FasterTuckerBcsf,
        Algo::FasterTucker,
    ];
    let mut mean_iters = Vec::new();
    for algo in variants {
        let cfg = TrainConfig {
            order: 3,
            dims: train.dims().to_vec(),
            j: 32,
            r: 32,
            lr_a: 1e-3,
            lr_b: 2e-5,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(algo, cfg.clone(), &train)?;
        let report = trainer.run(epochs, Some(&test));
        println!(
            "{:<22} {:.4}s/iter (factor {:.4}s, core {:.4}s)  final RMSE {:.4}",
            algo.name(),
            report.mean_epoch_seconds(),
            report.convergence.mean_factor_seconds(),
            report.convergence.mean_core_seconds(),
            report.last_rmse()
        );
        for rec in &report.convergence.records {
            println!(
                "    epoch {:>2}: {:.3}s  RMSE {:.4}  MAE {:.4}",
                rec.epoch, rec.seconds, rec.rmse, rec.mae
            );
        }
        assert!(
            report.convergence.improved(),
            "{} failed to converge",
            algo.name()
        );
        mean_iters.push((
            algo.name(),
            report.convergence.mean_factor_seconds(),
            report.convergence.mean_core_seconds(),
        ));
    }

    println!("\n=== end-to-end: headline (Table V shape) ===");
    let base_f = mean_iters[0].1;
    let base_c = mean_iters[0].2;
    for (name, f, c) in &mean_iters {
        println!(
            "{name:<22} Factor {f:.4}s ({:.2}X)   Core {c:.4}s ({:.2}X)",
            base_f / f,
            base_c / c
        );
    }
    let full = mean_iters.last().unwrap();
    assert!(
        base_f / full.1 > 1.5,
        "expected cuFasterTucker factor speedup > 1.5x over cuFastTucker"
    );

    // Demonstrate the full three-layer path: the same training loop with the
    // dense kernels (C-table refresh, batched eval) served by the AOT
    // JAX/Pallas artifacts through PJRT. On this CPU plugin the PJRT call
    // overhead makes it slower than the in-crate GEMM — on a real
    // accelerator plugin this is the offload path; numerics must agree.
    if let Some(rt) = runtime {
        println!("\n=== end-to-end: cuFasterTucker via PJRT artifacts (L1+L2+L3) ===");
        let cfg = TrainConfig {
            order: 3,
            dims: train.dims().to_vec(),
            j: 32,
            r: 32,
            lr_a: 1e-3,
            lr_b: 2e-5,
            compute: Compute::Pjrt,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(Algo::FasterTucker, cfg, &train)?.with_runtime(rt);
        assert!(trainer.pjrt_active());
        let report = trainer.run(2, Some(&test));
        println!(
            "PJRT-engine run: {:.4}s/iter, RMSE {:.4} (Rust-engine RMSE at same epoch: see above)",
            report.mean_epoch_seconds(),
            report.last_rmse()
        );
    }
    println!("\nend-to-end OK: all layers composed, speedup shape reproduced");
    Ok(())
}
