//! END-TO-END driver: proves the `Dataset → PreparedStorage → Session`
//! stack composes on a real workload.
//!
//! * **Dataset** — generates a Netflix-shaped sparse tensor, round-trips it
//!   through a FROSTT-style `.tns` text file, and drives the whole run from
//!   the file-backed dataset (streamed loading, deterministic split).
//! * **PreparedStorage** — every session stages its `(storage, chain)`
//!   structures exactly once; the staging/sweep split is printed like the
//!   paper's Table V.
//! * **Session** — trains all four FastTucker-family variants with the
//!   worker-parallel SGD executor, reports the paper's headline metric
//!   (per-iteration speedup of cuFasterTucker over cuFastTucker), then
//!   demonstrates checkpoint → warm-start resumption. With PJRT artifacts
//!   present (`make artifacts`), the dense kernels run through the AOT
//!   JAX/Pallas path as well.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use fastertucker::algo::Algo;
use fastertucker::config::{Compute, TrainConfig};
use fastertucker::coordinator::Session;
use fastertucker::data::dataset::{Dataset, SyntheticSpec};
use fastertucker::data::synthetic::RecommenderSpec;
use fastertucker::runtime::{default_artifacts_dir, PjrtRuntime};
use fastertucker::tensor::io;

fn main() -> anyhow::Result<()> {
    let nnz: usize = std::env::var("FT_E2E_NNZ")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400_000);
    let epochs: usize = std::env::var("FT_E2E_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);

    println!("=== end-to-end: Dataset layer ===");
    let synthetic = Dataset::Synthetic {
        spec: SyntheticSpec::Recommender(RecommenderSpec::netflix_like(nnz)),
        seed: 2026,
    };
    let tensor = synthetic.load()?;
    // round-trip through FROSTT-style text and drive everything below from
    // the file-backed dataset — the production ingestion path
    let tns_path =
        std::env::temp_dir().join(format!("ft_e2e_{}.tns", std::process::id()));
    io::write_text(&tensor, &tns_path, true)?;
    // dims are declared rather than inferred: a sampled tensor need not
    // touch the last index of every mode
    let dataset = Dataset::File {
        path: tns_path.clone(),
        one_based: true,
        dims: Some(tensor.dims().to_vec()),
    };
    let reloaded = dataset.load()?;
    assert_eq!(reloaded.nnz(), tensor.nnz(), ".tns round-trip lost elements");
    assert_eq!(reloaded.dims(), tensor.dims(), ".tns round-trip changed dims");
    let (train, test) = dataset.load_split(0.1, 5)?;
    let test = test.expect("test split requested");
    println!(
        "{}: dims {:?}, {} train nnz, {} test nnz (via .tns round-trip)",
        dataset.name(),
        train.dims(),
        train.nnz(),
        test.nnz()
    );

    println!("\n=== end-to-end: PJRT artifacts ===");
    let artifacts = default_artifacts_dir();
    let runtime = match PjrtRuntime::load(&artifacts) {
        Ok(rt) => {
            println!(
                "loaded {} artifacts on platform '{}' from {}",
                rt.num_artifacts(),
                rt.platform(),
                artifacts.display()
            );
            Some(rt)
        }
        Err(e) => {
            println!(
                "artifacts unavailable ({e}); continuing with the Rust engine \
                 (run `make artifacts` for the full three-layer path)"
            );
            None
        }
    };

    println!("\n=== end-to-end: Sessions over cached PreparedStorage ===");
    let variants = [
        Algo::FastTucker,
        Algo::FasterTuckerCoo,
        Algo::FasterTuckerBcsf,
        Algo::FasterTucker,
    ];
    let cfg_for = |_algo: Algo| TrainConfig {
        order: 3,
        dims: train.dims().to_vec(),
        j: 32,
        r: 32,
        lr_a: 1e-3,
        lr_b: 2e-5,
        ..TrainConfig::default()
    };
    let mut mean_iters = Vec::new();
    for algo in variants {
        let mut session = Session::new(algo, cfg_for(algo), &train)?;
        let prep = session.prep_stats().clone();
        assert_eq!(prep.builds, 1, "storages must be staged exactly once");
        let report = session.run(epochs, Some(&test));
        assert_eq!(
            session.prep_stats().builds,
            1,
            "epoch loop must not restage storages"
        );
        println!(
            "{:<22} prep {:.3}s (shuffle {:.3}s, B-CSF {:.3}s) | {:.4}s/iter \
             (factor {:.4}s, core {:.4}s)  final RMSE {:.4}",
            algo.name(),
            prep.total_seconds,
            prep.shuffle_seconds,
            prep.bcsf_seconds,
            report.mean_epoch_seconds(),
            report.convergence.mean_factor_seconds(),
            report.convergence.mean_core_seconds(),
            report.last_rmse()
        );
        for rec in &report.convergence.records {
            println!(
                "    epoch {:>2}: {:.3}s  RMSE {:.4}  MAE {:.4}",
                rec.epoch, rec.seconds, rec.rmse, rec.mae
            );
        }
        assert!(
            report.convergence.improved(),
            "{} failed to converge",
            algo.name()
        );
        mean_iters.push((
            algo.name(),
            report.convergence.mean_factor_seconds(),
            report.convergence.mean_core_seconds(),
        ));
    }

    println!("\n=== end-to-end: headline (Table V shape) ===");
    let base_f = mean_iters[0].1;
    let base_c = mean_iters[0].2;
    for (name, f, c) in &mean_iters {
        println!(
            "{name:<22} Factor {f:.4}s ({:.2}X)   Core {c:.4}s ({:.2}X)",
            base_f / f,
            base_c / c
        );
    }
    let full = mean_iters.last().unwrap();
    assert!(
        base_f / full.1 > 1.5,
        "expected cuFasterTucker factor speedup > 1.5x over cuFastTucker"
    );

    println!("\n=== end-to-end: checkpoint → warm-started Session ===");
    let ckpt =
        std::env::temp_dir().join(format!("ft_e2e_{}.ckpt", std::process::id()));
    let mut head = Session::new(Algo::FasterTucker, cfg_for(Algo::FasterTucker), &train)?;
    head.run(2, Some(&test));
    head.save_checkpoint(&ckpt)?;
    let mut resumed = Session::resume(
        Algo::FasterTucker,
        cfg_for(Algo::FasterTucker),
        &train,
        &ckpt,
        head.epochs_completed(),
    )?;
    let resumed_report = resumed.run(1, Some(&test));
    let last = resumed_report.convergence.records.last().unwrap();
    println!(
        "resumed at epoch {}, continued to epoch {}: RMSE {:.4}",
        resumed_report.start_epoch, last.epoch, last.rmse
    );
    assert_eq!(last.epoch, 2, "warm start must continue global numbering");
    std::fs::remove_file(&ckpt).ok();

    // Demonstrate the full three-layer path: the same training loop with the
    // dense kernels (C-table refresh, batched eval) served by the AOT
    // JAX/Pallas artifacts through PJRT. On this CPU plugin the PJRT call
    // overhead makes it slower than the in-crate GEMM — on a real
    // accelerator plugin this is the offload path; numerics must agree.
    if let Some(rt) = runtime {
        println!("\n=== end-to-end: cuFasterTucker via PJRT artifacts ===");
        let cfg = TrainConfig {
            compute: Compute::Pjrt,
            ..cfg_for(Algo::FasterTucker)
        };
        let mut session =
            Session::new(Algo::FasterTucker, cfg, &train)?.with_runtime(rt);
        assert!(session.pjrt_active());
        let report = session.run(2, Some(&test));
        println!(
            "PJRT-engine run: {:.4}s/iter, RMSE {:.4} (Rust-engine RMSE at same epoch: see above)",
            report.mean_epoch_seconds(),
            report.last_rmse()
        );
    }
    std::fs::remove_file(&tns_path).ok();
    println!("\nend-to-end OK: Dataset → PreparedStorage → Session composed, speedup shape reproduced");
    Ok(())
}
