//! Recommender-system scenario (the paper's §I motivation), now on the
//! serving stack: a `SessionRegistry` owns two rating tensors at once on
//! one shared worker pool, and a `ServingHandle` answers batched top-k
//! queries from a reader thread *while the session trains* — readers always
//! see the last completed epoch, never a torn mid-pass state.
//!
//! ```sh
//! cargo run --release --example recommender [-- nnz]
//! ```

use fastertucker::algo::Algo;
use fastertucker::config::TrainConfig;
use fastertucker::coordinator::{SessionRegistry, TopKQuery};
use fastertucker::data::synthetic::{recommender, RecommenderSpec};
use fastertucker::tensor::coo::CooTensor;

fn cfg_for(train: &CooTensor) -> TrainConfig {
    TrainConfig {
        order: 3,
        dims: train.dims().to_vec(),
        j: 16,
        r: 16,
        lr_a: 5e-3,
        lr_b: 5e-5,
        ..TrainConfig::default()
    }
}

fn main() -> anyhow::Result<()> {
    let nnz: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150_000);

    // two tenants in one process: a Netflix-shaped tensor and a small one,
    // sharing a worker pool and a 256 MiB prepared-cache budget
    let movies = recommender(&RecommenderSpec::netflix_like(nnz), 1);
    let tiny = recommender(&RecommenderSpec::tiny(), 2);
    let mut registry = SessionRegistry::new(0, 256 << 20);
    registry.open("movies", Algo::FasterTucker, cfg_for(&movies), &movies)?;
    registry.open("tiny", Algo::FasterTucker, cfg_for(&tiny), &tiny)?;
    println!(
        "registry: sessions {:?}, {} MiB resident prepared caches, {} workers",
        registry.names(),
        registry.resident_bytes() >> 20,
        registry.executor().workers()
    );

    // pick the busiest user of the big tensor to serve recommendations for
    let mut counts = vec![0u32; movies.dims()[0]];
    for (c, _) in movies.iter() {
        counts[c[0] as usize] += 1;
    }
    let user = counts
        .iter()
        .enumerate()
        .max_by_key(|(_, &c)| c)
        .map(|(i, _)| i as u32)
        .unwrap();
    let time = (movies.dims()[2] - 1) as u32;

    // serve top-k from a reader thread while the registry trains: every
    // answer is labelled with the completed epoch it was computed against.
    // The reader exits on a flag (set even if training errors), never on a
    // hard-coded epoch count, so a failed step cannot deadlock the join.
    let handle = registry.serving_handle("movies")?;
    let query = TopKQuery { mode: 1, fixed: vec![user, time], k: 5 };
    let done = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|scope| -> anyhow::Result<()> {
        use std::sync::atomic::Ordering;
        let reader = {
            let handle = handle.clone();
            let query = query.clone();
            let done = &done;
            scope.spawn(move || {
                let mut seen = Vec::new();
                loop {
                    let res = handle.top_k(&query).expect("valid query");
                    if seen.last() != Some(&res.epoch) {
                        seen.push(res.epoch);
                    }
                    if done.load(Ordering::Acquire) {
                        return seen;
                    }
                    std::thread::yield_now();
                }
            })
        };
        let trained = (|| -> anyhow::Result<()> {
            for _ in 0..10 {
                registry.step("movies", None)?;
                registry.step("tiny", None)?; // the other tenant trains too
            }
            Ok(())
        })();
        done.store(true, Ordering::Release);
        let epochs_seen = reader.join().expect("reader thread");
        trained?;
        println!("reader observed epoch snapshots {epochs_seen:?} during training");
        Ok(())
    })?;

    let report = registry.get("movies").unwrap().report();
    println!(
        "movies: trained {} epochs, {:.3}s/iter, self-eval RMSE {:.4}",
        report.epochs_completed,
        report.mean_epoch_seconds(),
        report.last_rmse()
    );
    println!(
        "shared executor ran {} passes across both sessions; {} evictions",
        registry.executor().passes_executed(),
        registry.evictions()
    );

    let top = handle.top_k(&query)?;
    println!(
        "top-5 recommendations for user {user} (rated {} items), epoch {}:",
        counts[user as usize], top.epoch
    );
    for (item, score) in &top.items {
        println!("  item {item:>6}  predicted rating {score:.2}");
    }
    assert!(top.items[0].1 >= top.items[4].1);
    Ok(())
}
