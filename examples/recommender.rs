//! Recommender-system scenario (the paper's §I motivation): factorize a
//! Netflix-shaped rating tensor, then use the factor/core matrices to score
//! unseen (user, item, time) cells and produce top-k recommendations.
//!
//! ```sh
//! cargo run --release --example recommender [-- nnz]
//! ```

use fastertucker::algo::Algo;
use fastertucker::config::TrainConfig;
use fastertucker::coordinator::{Session, SessionModel};
use fastertucker::data::split::{filter_cold, train_test};
use fastertucker::data::synthetic::{recommender, RecommenderSpec};

fn main() -> anyhow::Result<()> {
    let nnz: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150_000);
    let spec = RecommenderSpec::netflix_like(nnz);
    let tensor = recommender(&spec, 1);
    let (train, test) = train_test(&tensor, 0.1, 3);
    let test = filter_cold(&test, &train);
    println!(
        "ratings: {} train / {} test over {:?} users×items×times",
        train.nnz(),
        test.nnz(),
        train.dims()
    );

    let cfg = TrainConfig {
        order: 3,
        dims: train.dims().to_vec(),
        j: 16,
        r: 16,
        lr_a: 5e-3,
        lr_b: 5e-5,
        ..TrainConfig::default()
    };
    let mut session = Session::new(Algo::FasterTucker, cfg, &train)?;
    let report = session.run(10, Some(&test));
    println!(
        "trained 10 epochs, {:.3}s/iter, test RMSE {:.4} MAE {:.4}",
        report.mean_epoch_seconds(),
        report.convergence.last_rmse(),
        report.convergence.last_mae()
    );

    // score all items for a busy user at the most recent time step
    let model = match &session.model {
        SessionModel::Fast(m) => m,
        _ => unreachable!(),
    };
    // pick the user with the most training ratings
    let mut counts = vec![0u32; train.dims()[0]];
    for (c, _) in train.iter() {
        counts[c[0] as usize] += 1;
    }
    let user = counts
        .iter()
        .enumerate()
        .max_by_key(|(_, &c)| c)
        .map(|(i, _)| i as u32)
        .unwrap();
    let time = (train.dims()[2] - 1) as u32;
    let mut scores: Vec<(u32, f32)> = (0..train.dims()[1] as u32)
        .map(|item| (item, model.predict(&[user, item, time])))
        .collect();
    scores.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!(
        "top-5 recommendations for user {user} (rated {} items):",
        counts[user as usize]
    );
    for (item, score) in scores.iter().take(5) {
        println!("  item {item:>6}  predicted rating {score:.2}");
    }
    assert!(scores[0].1 >= scores[4].1);
    Ok(())
}
