//! Failure-injection tests: corrupted inputs, hostile files, and boundary
//! configurations must produce clean errors, never panics or silent
//! misbehaviour.

use fastertucker::config::toml::Doc;
use fastertucker::model::ModelState;
use fastertucker::runtime::manifest::Manifest;
use fastertucker::runtime::PjrtRuntime;
use fastertucker::tensor::io;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ft_failinj");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{}", std::process::id(), name))
}

// ---------------------------------------------------------------- tensor IO

#[test]
fn tensor_header_fuzzing_never_panics() {
    // random byte soups with a valid magic prefix must error, not panic
    let mut state = 0xF00Du64;
    for trial in 0..50 {
        let mut bytes = b"FTNS".to_vec();
        let len = (trial * 7) % 200;
        for _ in 0..len {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            bytes.push((state >> 33) as u8);
        }
        let p = tmp(&format!("fuzz_{trial}.ftns"));
        std::fs::write(&p, &bytes).unwrap();
        let _ = io::read_binary(&p); // must return, Err or Ok, without panic
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn tensor_with_huge_claimed_nnz_errors() {
    // header claims 2^60 nnz with a tiny body: must fail on truncation, not
    // attempt a giant allocation blindly
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"FTNS");
    bytes.extend_from_slice(&1u32.to_le_bytes()); // version
    bytes.extend_from_slice(&2u32.to_le_bytes()); // order
    bytes.extend_from_slice(&4u64.to_le_bytes()); // dims
    bytes.extend_from_slice(&4u64.to_le_bytes());
    bytes.extend_from_slice(&(1u64 << 60).to_le_bytes()); // nnz
    let p = tmp("huge.ftns");
    std::fs::write(&p, &bytes).unwrap();
    assert!(io::read_binary(&p).is_err());
    std::fs::remove_file(p).ok();
}

#[test]
fn tensor_with_out_of_bounds_index_rejected() {
    // hand-craft a file whose index exceeds its dims; validate() must catch
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"FTNS");
    bytes.extend_from_slice(&1u32.to_le_bytes());
    bytes.extend_from_slice(&2u32.to_le_bytes());
    bytes.extend_from_slice(&3u64.to_le_bytes());
    bytes.extend_from_slice(&3u64.to_le_bytes());
    bytes.extend_from_slice(&1u64.to_le_bytes());
    bytes.extend_from_slice(&7u32.to_le_bytes()); // index 7 > dim 3
    bytes.extend_from_slice(&0u32.to_le_bytes());
    bytes.extend_from_slice(&1.0f32.to_le_bytes());
    let p = tmp("oob.ftns");
    std::fs::write(&p, &bytes).unwrap();
    let err = io::read_binary(&p).unwrap_err();
    assert!(err.to_string().contains("invalid tensor data"), "{err}");
    std::fs::remove_file(p).ok();
}

#[test]
fn text_tensor_hostile_lines() {
    for body in [
        "1 2 NaN\n",              // non-finite value parses but validate is on caller
        "1 2\n",                  // too few columns? (1 index + value is valid order-1)
        "a b 1.0\n",              // garbage indices
        "-5 2 1.0\n",             // negative index, zero-based
        "1 2 3 4 5 6 7 8 9\n1 2 3\n", // inconsistent order
    ] {
        let p = tmp("hostile.tns");
        std::fs::write(&p, body).unwrap();
        let _ = io::read_text(&p, None, false); // no panic
        std::fs::remove_file(p).ok();
    }
}

// ---------------------------------------------------------------- ingestion

/// Truncated or garbage `.tns` delta files must reject the whole ingest
/// atomically: the file is parsed and validated before any session state
/// is touched, so a failed `ingest_file` leaves the model, the prepared
/// cache, the dims and every `PrepStats` counter exactly as they were —
/// and the session keeps training as if the call never happened.
#[test]
fn corrupt_delta_files_reject_atomically() {
    use fastertucker::algo::Algo;
    use fastertucker::config::TrainConfig;
    use fastertucker::coordinator::Session;
    use fastertucker::tensor::coo::CooTensor;
    use std::sync::Arc;

    let mut t = CooTensor::new(vec![6, 5, 4]);
    let mut state = 0xD_E17Au64;
    for _ in 0..120 {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
        let a = ((state >> 33) % 6) as u32;
        let b = ((state >> 43) % 5) as u32;
        let c = ((state >> 53) % 4) as u32;
        t.push(&[a, b, c], ((state >> 20) % 9) as f32 - 4.0);
    }
    let cfg = TrainConfig {
        order: 3,
        dims: vec![6, 5, 4],
        j: 4,
        r: 4,
        lr_a: 0.01,
        lr_b: 1e-4,
        workers: 1,
        block_nnz: 128,
        fiber_threshold: 16,
        eval_sample_nnz: 0,
        ..TrainConfig::default()
    };
    let mut live =
        Session::new_shared(Algo::FasterTucker, cfg.clone(), Arc::new(t.clone()))
            .unwrap();
    // twin that never sees an ingest attempt — the "unchanged" oracle
    let mut twin =
        Session::new_shared(Algo::FasterTucker, cfg, Arc::new(t.clone())).unwrap();
    live.epoch();
    twin.epoch();

    let before_dims = live.cfg.dims.clone();
    let before_nnz = live.train_nnz();
    let before = live.prep_stats().clone();

    for (name, body) in [
        ("truncated mid-line", "0 1 0 1.5\n2 3\n"),
        ("garbage index", "0 1 0 1.5\n2 x 1 0.5\n"),
        ("garbage value", "0 1 0 1.5\n1 1 1 NOPE\n"),
        ("negative index", "0 0 0 1.0\n-3 1 0 1.0\n"),
        ("non-finite value", "0 0 0 NaN\n"),
        ("wrong order", "0 1 2.0\n1 0 1.0\n"),
    ] {
        let p = tmp(&format!("delta_{}.tns", name.replace(' ', "_")));
        std::fs::write(&p, body).unwrap();
        let err = live.ingest_file(&p, false);
        assert!(err.is_err(), "{name}: delta must be rejected");
        std::fs::remove_file(p).ok();

        // nothing moved: dims, retained tensor, staging counters
        assert_eq!(live.cfg.dims, before_dims, "{name}: dims changed");
        assert_eq!(live.train_nnz(), before_nnz, "{name}: train grew");
        let now = live.prep_stats();
        assert_eq!(now.builds, before.builds, "{name}: builds bumped");
        assert_eq!(
            now.resident_bytes, before.resident_bytes,
            "{name}: resident bytes changed"
        );
        assert_eq!(
            now.peak_resident_bytes, before.peak_resident_bytes,
            "{name}: peak changed"
        );
        assert_eq!(
            now.blocks_reused + now.blocks_rebuilt,
            before.blocks_reused + before.blocks_rebuilt,
            "{name}: block accounting changed"
        );
        assert_eq!(live.epochs_completed(), 1, "{name}: epoch counter moved");
    }

    // a missing file rejects the same way
    assert!(live
        .ingest_file(&tmp("never_written_delta.tns"), false)
        .is_err());

    // and training continues bitwise as if no ingest was ever attempted
    live.epoch();
    twin.epoch();
    let (fastertucker::coordinator::SessionModel::Fast(a),
         fastertucker::coordinator::SessionModel::Fast(b)) =
        (&live.model, &twin.model)
    else {
        panic!("expected fast models");
    };
    for n in 0..a.order() {
        assert_eq!(
            a.factors[n].max_abs_diff(&b.factors[n]),
            0.0,
            "mode {n}: rejected ingests perturbed training"
        );
        assert_eq!(a.c_tables[n].max_abs_diff(&b.c_tables[n]), 0.0);
    }
}

// ---------------------------------------------------------------- checkpoints

#[test]
fn truncated_checkpoint_errors() {
    let cfg = fastertucker::config::TrainConfig {
        order: 2,
        dims: vec![8, 8],
        j: 4,
        r: 4,
        ..Default::default()
    };
    let m = ModelState::init(&cfg, 1);
    let p = tmp("trunc.ckpt");
    m.save(&p).unwrap();
    let data = std::fs::read(&p).unwrap();
    for cut in [5usize, 16, data.len() / 2, data.len() - 1] {
        std::fs::write(&p, &data[..cut]).unwrap();
        assert!(ModelState::load(&p).is_err(), "cut at {cut} should fail");
    }
    std::fs::remove_file(p).ok();
}

#[test]
fn checkpoint_with_absurd_header_rejected() {
    let p = tmp("absurd.ckpt");
    let mut bytes = b"FTCK".to_vec();
    bytes.extend_from_slice(&9999u32.to_le_bytes()); // order 9999
    bytes.extend_from_slice(&4u32.to_le_bytes());
    bytes.extend_from_slice(&4u32.to_le_bytes());
    std::fs::write(&p, &bytes).unwrap();
    assert!(ModelState::load(&p).is_err());
    std::fs::remove_file(p).ok();
}

// ---------------------------------------------------------------- manifest

#[test]
fn manifest_schema_violations_error_cleanly() {
    for bad in [
        "",                                        // empty
        "{",                                       // truncated JSON
        "[]",                                      // wrong top-level type
        r#"{"version": 1}"#,                       // missing entries
        r#"{"version": 1, "entries": [42]}"#,      // non-object entry
        r#"{"version": 1, "entries": [{"name": "x", "op": "matmul",
            "file": "x.hlo.txt", "params": {"i": "big"}}]}"#, // bad param type
    ] {
        assert!(Manifest::parse(bad).is_err(), "accepted: {bad}");
    }
}

#[test]
fn runtime_load_with_missing_hlo_file_errors() {
    let dir = std::env::temp_dir().join(format!("ft_rt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "entries": [{"name": "ghost", "op": "matmul",
            "file": "ghost.hlo.txt", "params": {"i": 64, "j": 8, "r": 8}}]}"#,
    )
    .unwrap();
    assert!(PjrtRuntime::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn runtime_load_with_garbage_hlo_errors() {
    let dir = std::env::temp_dir().join(format!("ft_rtg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "entries": [{"name": "bad", "op": "matmul",
            "file": "bad.hlo.txt", "params": {"i": 64, "j": 8, "r": 8}}]}"#,
    )
    .unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "this is not HLO text at all").unwrap();
    assert!(PjrtRuntime::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------- config

#[test]
fn toml_hostile_inputs() {
    for bad in [
        "[never closed\n",
        "key with spaces = 1\n", // actually allowed? key is "key with spaces" — accept or reject, must not panic
        "= 5\n",
        "x = [1, \"mix\"]\n", // heterogeneous arrays parse (documented subset)
        "x = 99999999999999999999999999\n", // overflows i64 → falls back to float
    ] {
        let _ = Doc::parse(bad); // no panic
    }
    assert!(Doc::parse("= 5\n").is_err());
    assert!(Doc::parse("[never closed\n").is_err());
}

#[test]
fn session_rejects_mismatched_dims() {
    use fastertucker::algo::Algo;
    use fastertucker::config::TrainConfig;
    use fastertucker::coordinator::Session;
    use fastertucker::tensor::coo::CooTensor;
    let mut t = CooTensor::new(vec![4, 4]);
    t.push(&[1, 1], 1.0);
    let cfg = TrainConfig {
        order: 3, // wrong: tensor is order 2
        dims: vec![4, 4, 4],
        j: 2,
        r: 2,
        ..Default::default()
    };
    // Config itself is valid; the mismatch surfaces when structures are
    // built. Constructing with the tensor's real shape must be the caller's
    // contract — verify the validating path.
    let bad = TrainConfig { order: 2, dims: vec![4], ..cfg.clone() };
    assert!(Session::new(Algo::FasterTucker, bad, &t).is_err());
}
