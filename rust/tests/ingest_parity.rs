//! Online-ingestion parity: `Session::ingest` followed by training must be
//! **bitwise** indistinguishable from a cold `Session` built over the
//! concatenated (base ∪ delta) tensor after a full re-stage.
//!
//! The incremental path differs from the cold path in every mechanism —
//! sorted-merge restaging instead of a full re-sort, `grow_mode` instead of
//! a cold init at the larger dims, a clean-prefix block carry-over instead
//! of rebuilding every B-CSF block — so these tests pin the end result, not
//! the mechanism: same storage streams, same model bits, same training
//! trajectory. Delta shapes cover the awkward cases (empty delta, a single
//! non-zero, rows that grow a mode, duplicate coordinates that must fold in
//! base-then-delta order), at orders 3 and 4, under both schedulers.
//!
//! Multi-worker epochs are Hogwild — bitwise model parity is only defined
//! at 1 worker. At 2 and 8 workers the tests assert what *is* exact there:
//! the restaged prepared storage streams the identical element multiset,
//! block for block, as the cold build.

// this binary only uses `common::stream`
#[allow(dead_code)]
mod common;

use fastertucker::algo::Algo;
use fastertucker::config::{SchedMode, TrainConfig};
use fastertucker::coordinator::Session;
use fastertucker::data::synthetic::{order_sweep, recommender, RecommenderSpec};
use fastertucker::model::ModelState;
use fastertucker::tensor::coo::CooTensor;
use fastertucker::tensor::prepared::PreparedStorage;
use fastertucker::util::rng::Rng;
use std::sync::Arc;

fn tiny(seed: u64) -> CooTensor {
    recommender(&RecommenderSpec::tiny(), seed)
}

fn cfg_for(t: &CooTensor, workers: usize, sched: SchedMode) -> TrainConfig {
    TrainConfig {
        order: t.order(),
        dims: t.dims().to_vec(),
        j: 8,
        r: 4,
        lr_a: 0.01,
        lr_b: 1e-4,
        workers,
        fiber_threshold: 32,
        block_nnz: 512,
        sched,
        eval_sample_nnz: 0,
        ..TrainConfig::default()
    }
}

/// The delta re-dimensioned to `dims` and the base ++ delta concatenation —
/// exactly the tensor a cold load of the merged data would start from.
fn concat(base: &CooTensor, delta: &CooTensor, dims: &[usize]) -> CooTensor {
    let mut out =
        CooTensor::with_capacity(dims.to_vec(), base.nnz() + delta.nnz());
    for e in 0..base.nnz() {
        out.push(base.index(e), base.value(e));
    }
    for e in 0..delta.nnz() {
        out.push(delta.index(e), delta.value(e));
    }
    out
}

fn grown_dims(base: &CooTensor, delta: &CooTensor) -> Vec<usize> {
    base.dims()
        .iter()
        .zip(delta.dims())
        .map(|(&a, &b)| a.max(b))
        .collect()
}

fn assert_models_bitwise(a: &ModelState, b: &ModelState, what: &str) {
    assert_eq!(a.order(), b.order(), "{what}: order");
    for n in 0..a.order() {
        for (name, ma, mb) in [
            ("factor", &a.factors[n], &b.factors[n]),
            ("core", &a.cores[n], &b.cores[n]),
            ("c_table", &a.c_tables[n], &b.c_tables[n]),
        ] {
            assert_eq!(ma.rows(), mb.rows(), "{what}: {name} {n} rows");
            assert_eq!(ma.cols(), mb.cols(), "{what}: {name} {n} cols");
            for (i, (x, y)) in ma.data().iter().zip(mb.data()).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what}: {name} {n} flat index {i}: {x} vs {y}"
                );
            }
        }
    }
}

fn model_of(s: &Session) -> &ModelState {
    match &s.model {
        fastertucker::coordinator::SessionModel::Fast(m) => m,
        _ => panic!("expected a fast model"),
    }
}

/// The parity harness: ingest `delta` into a live session over `base`,
/// train both it and a cold session over the concatenation, and require
/// bitwise-equal models before and after every epoch (1 worker — the only
/// deterministic setting for whole-model comparison).
fn assert_ingest_train_parity(
    base: &CooTensor,
    delta: &CooTensor,
    sched: SchedMode,
    epochs: usize,
    what: &str,
) {
    let cfg = cfg_for(base, 1, sched);
    let mut live =
        Session::new_shared(Algo::FasterTucker, cfg.clone(), Arc::new(base.clone()))
            .unwrap();
    // ingest before the first epoch: bitwise whole-model comparison is
    // only meaningful when both sides start from the same state, and a
    // cold session has no way to inherit a partially trained model
    let report = live.ingest(delta.clone()).unwrap();
    assert_eq!(report.added_nnz, delta.nnz(), "{what}: added_nnz");

    let dims = grown_dims(base, delta);
    let merged = concat(base, delta, &dims);
    let mut cold_cfg = cfg.clone();
    cold_cfg.dims = dims.clone();
    let mut cold =
        Session::new_shared(Algo::FasterTucker, cold_cfg, Arc::new(merged))
            .unwrap();

    // the grown model must be bitwise what a cold init at the larger dims
    // draws, before any training
    assert_models_bitwise(model_of(&live), model_of(&cold), what);
    assert_eq!(live.cfg.dims, dims, "{what}: session dims after growth");
    assert_eq!(live.train_nnz(), Some(base.nnz() + delta.nnz()), "{what}: train nnz");

    for e in 0..epochs {
        live.epoch();
        cold.epoch();
        assert_models_bitwise(
            model_of(&live),
            model_of(&cold),
            &format!("{what}: after epoch {e}"),
        );
    }
}

/// Storage-level parity for a restage: the incrementally merged prepared
/// storage streams the identical (group, row, value-bits) multiset as a
/// cold prepare of the concatenation — the exact invariant multi-worker
/// training consumes.
fn assert_restage_stream_parity(
    base: &CooTensor,
    delta: &CooTensor,
    workers: usize,
    sched: SchedMode,
    what: &str,
) {
    let cfg = cfg_for(base, workers, sched);
    let prev = PreparedStorage::prepare(Algo::FasterTucker, &cfg, base).unwrap();
    let dims = grown_dims(base, delta);
    let mut delta_full =
        CooTensor::with_capacity(dims.clone(), delta.nnz());
    for e in 0..delta.nnz() {
        delta_full.push(delta.index(e), delta.value(e));
    }
    let merged = concat(base, delta, &dims);
    let mut grown_cfg = cfg.clone();
    grown_cfg.dims = dims;
    let staged = prev.restage(&grown_cfg, &merged, &delta_full).unwrap();
    let cold = PreparedStorage::prepare(Algo::FasterTucker, &grown_cfg, &merged)
        .unwrap();
    for n in 0..base.order() {
        assert_eq!(
            common::stream(&staged, n),
            common::stream(&cold, n),
            "{what}: mode {n} stream (workers {workers})"
        );
    }
    let p = staged.prep();
    assert_eq!(p.builds, 1, "{what}: restage counts as one build");
    assert_eq!(
        p.blocks_reused + p.blocks_rebuilt,
        (0..base.order()).map(|n| {
            use fastertucker::algo::engine::SparseStorage;
            staged.num_blocks(n)
        }).sum::<usize>(),
        "{what}: reuse accounting covers every block"
    );
}

/// A delta that repeats `n_dup` base coordinates (values fold), adds
/// `n_new` fresh in-range coordinates, and (optionally) `n_grow` rows past
/// the end of `grow_mode` — the general shape every specific test below is
/// a special case of.
fn mixed_delta(
    base: &CooTensor,
    seed: u64,
    n_dup: usize,
    n_new: usize,
    grow: Option<(usize, usize, usize)>, // (mode, extra_rows, nnz_there)
) -> CooTensor {
    let mut rng = Rng::new(seed);
    let mut dims = base.dims().to_vec();
    if let Some((m, extra, _)) = grow {
        dims[m] += extra;
    }
    let mut d = CooTensor::new(dims.clone());
    for _ in 0..n_dup {
        let e = rng.next_below(base.nnz());
        d.push(base.index(e), rng.uniform_f32(-1.0, 1.0));
    }
    for _ in 0..n_new {
        let coords: Vec<u32> = base
            .dims()
            .iter()
            .map(|&dim| rng.next_below(dim) as u32)
            .collect();
        d.push(&coords, rng.uniform_f32(-1.0, 1.0));
    }
    if let Some((m, extra, nnz_there)) = grow {
        for _ in 0..nnz_there {
            let mut coords: Vec<u32> = base
                .dims()
                .iter()
                .map(|&dim| rng.next_below(dim) as u32)
                .collect();
            // land in the grown tail of mode m
            coords[m] = (base.dims()[m] + rng.next_below(extra)) as u32;
            d.push(&coords, rng.uniform_f32(-1.0, 1.0));
        }
    }
    d
}

#[test]
fn empty_delta_is_a_noop() {
    let base = tiny(101);
    let cfg = cfg_for(&base, 1, SchedMode::Static);
    let mut live =
        Session::new_shared(Algo::FasterTucker, cfg.clone(), Arc::new(base.clone()))
            .unwrap();
    let report = live.ingest(CooTensor::new(base.dims().to_vec())).unwrap();
    assert_eq!(report.added_nnz, 0);
    assert!(report.grown.is_empty());
    assert_eq!(report.blocks_rebuilt, 0);
    assert_eq!(live.prep_stats().builds, 1, "no restage for an empty delta");
    // and training continues exactly as if ingest had never been called
    let mut untouched =
        Session::new_shared(Algo::FasterTucker, cfg, Arc::new(base.clone()))
            .unwrap();
    for _ in 0..2 {
        live.epoch();
        untouched.epoch();
    }
    assert_models_bitwise(model_of(&live), model_of(&untouched), "empty delta");
}

#[test]
fn single_nnz_delta_matches_cold_concat() {
    let base = tiny(103);
    let mut delta = CooTensor::new(base.dims().to_vec());
    delta.push(&[2, 3, 1], 1.25);
    assert_ingest_train_parity(
        &base,
        &delta,
        SchedMode::Static,
        3,
        "single nnz",
    );
}

#[test]
fn duplicate_coordinate_delta_folds_like_a_cold_load() {
    let base = tiny(105);
    // repeats of existing coordinates plus a repeated coordinate *within*
    // the delta: the merge must fold base duplicates first (base order),
    // then the delta's own, exactly like the cold build's stable sort
    let mut delta = mixed_delta(&base, 9, 6, 2, None);
    let c = base.index(0).to_vec();
    delta.push(&c, 0.5);
    delta.push(&c, -0.25);
    assert_ingest_train_parity(
        &base,
        &delta,
        SchedMode::Static,
        3,
        "duplicate coords",
    );
}

#[test]
fn mode_growing_delta_matches_cold_concat() {
    let base = tiny(107);
    // grow mode 0 by 7 rows, with updates to existing rows mixed in
    let delta = mixed_delta(&base, 11, 3, 3, Some((0, 7, 5)));
    assert_ingest_train_parity(&base, &delta, SchedMode::Static, 3, "grown mode");
}

#[test]
fn growing_the_leaf_mode_matches_cold_concat() {
    let base = tiny(109);
    // the last mode orders the CSF leaves — growing it exercises the merge
    // comparator's final tie-break level
    let delta = mixed_delta(&base, 13, 2, 2, Some((2, 9, 6)));
    assert_ingest_train_parity(&base, &delta, SchedMode::Static, 3, "grown leaf");
}

#[test]
fn stealing_scheduler_preserves_ingest_parity() {
    let base = tiny(111);
    let delta = mixed_delta(&base, 15, 4, 4, Some((1, 5, 4)));
    assert_ingest_train_parity(&base, &delta, SchedMode::Stealing, 3, "stealing");
}

#[test]
fn order_4_ingest_matches_cold_concat() {
    let base = order_sweep(4, 14, 900, 117);
    let delta = mixed_delta(&base, 17, 3, 3, Some((3, 6, 4)));
    assert_ingest_train_parity(&base, &delta, SchedMode::Static, 2, "order 4");
}

#[test]
fn restage_streams_match_cold_prepare_across_workers_and_shapes() {
    let base3 = tiny(121);
    let base4 = order_sweep(4, 12, 700, 123);
    let shapes: Vec<(&CooTensor, CooTensor, &str)> = vec![
        (&base3, CooTensor::new(base3.dims().to_vec()), "empty"),
        (&base3, mixed_delta(&base3, 21, 0, 1, None), "single"),
        (&base3, mixed_delta(&base3, 23, 5, 0, None), "dups"),
        (&base3, mixed_delta(&base3, 25, 2, 3, Some((0, 8, 6))), "grow mode 0"),
        (&base4, mixed_delta(&base4, 27, 3, 3, Some((2, 5, 4))), "order 4 grow"),
    ];
    for workers in [1usize, 2, 8] {
        for sched in [SchedMode::Static, SchedMode::Stealing] {
            for (base, delta, name) in &shapes {
                assert_restage_stream_parity(
                    base,
                    delta,
                    workers,
                    *sched,
                    &format!("{name} ({sched:?})"),
                );
            }
        }
    }
}

#[test]
fn multi_worker_training_after_ingest_stays_healthy() {
    // Hogwild races make >1-worker models non-comparable bitwise; what must
    // hold is that the ingested session trains on structures identical to
    // the cold session's (stream parity above) and converges equivalently
    let base = tiny(131);
    let delta = mixed_delta(&base, 31, 4, 6, Some((0, 6, 5)));
    let dims = grown_dims(&base, &delta);
    let merged = concat(&base, &delta, &dims);
    for workers in [2usize, 8] {
        let cfg = cfg_for(&base, workers, SchedMode::Static);
        let mut live = Session::new_shared(
            Algo::FasterTucker,
            cfg.clone(),
            Arc::new(base.clone()),
        )
        .unwrap();
        live.ingest(delta.clone()).unwrap();
        let mut cold_cfg = cfg.clone();
        cold_cfg.dims = dims.clone();
        let mut cold = Session::new_shared(
            Algo::FasterTucker,
            cold_cfg,
            Arc::new(merged.clone()),
        )
        .unwrap();
        let live_rec = live.run(8, None);
        let cold_rec = cold.run(8, None);
        let (a, b) = (live_rec.last_rmse(), cold_rec.last_rmse());
        assert!(
            (a - b).abs() / b < 0.1,
            "workers {workers}: ingested {a} vs cold {b}"
        );
        // the cached shard plans were rebuilt for the merged storage and
        // describe the same block structure on both sides
        assert_eq!(
            live.engine_plan_block_counts(),
            cold.engine_plan_block_counts(),
            "workers {workers}: plan block counts"
        );
    }
}

#[test]
fn warm_epochs_sweep_the_delta_then_blend_back() {
    let base = tiny(141);
    let mut cfg = cfg_for(&base, 1, SchedMode::Static);
    cfg.ingest_warm_epochs = 2;
    let mut live =
        Session::new_shared(Algo::FasterTucker, cfg, Arc::new(base.clone()))
            .unwrap();
    let delta = mixed_delta(&base, 41, 2, 4, None);
    live.ingest(delta.clone()).unwrap();
    // warm-up epochs train, advance the counter, and keep the model finite
    live.epoch();
    live.epoch();
    // blended-back epoch over the merged storage
    live.epoch();
    assert_eq!(live.epochs_completed(), 3);
    let m = model_of(&live);
    for n in 0..m.order() {
        assert!(m.factors[n].data().iter().all(|x| x.is_finite()));
    }
    // after the warm window closes, training is on the full merged sweep:
    // a 1-worker epoch from identical state must now match a session that
    // never warmed (same storage, same plan rebuild) — not asserted
    // bitwise here because the warm epochs themselves legitimately moved
    // the model; the full-sweep parity is pinned by the tests above.
}
