//! Registry + serving integration: eviction/rebuild bitwise parity and
//! torn-state-free concurrent top-k.
//!
//! Two headline guarantees (both extensions of the `session_resume.rs`
//! resume-parity harness):
//!
//! 1. **Eviction is invisible to the math.** Two sessions interleaved in a
//!    `SessionRegistry` under a budget that forces every step to evict the
//!    other session's prepared cache must produce *bitwise* the models an
//!    uninterrupted, never-evicted `Session` produces — while
//!    `PrepStats::builds` proves the rebuilds actually happened.
//! 2. **Serving is never torn.** Reader threads issuing batched top-k
//!    through a [`ServingHandle`] while `Session::step` runs concurrently
//!    only ever observe published epoch snapshots, and every observed
//!    answer is bit-identical to a from-checkpoint recompute of that
//!    epoch's model.
//! 3. **Delta publication is invisible.** Epoch snapshots are published as
//!    copy-on-write deltas (clean 64-row blocks shared with the previous
//!    snapshot); after every step — including randomized evict→rebuild
//!    interleavings — the chained delta snapshot must read bitwise like a
//!    from-scratch [`ServingSnapshot::capture`] of the stepped model.

use fastertucker::algo::Algo;
use fastertucker::config::{RefreshMode, SchedMode, TrainConfig};
use fastertucker::coordinator::{
    ServingSnapshot, Session, SessionModel, SessionRegistry, TopKQuery,
};
use fastertucker::data::synthetic::{recommender, RecommenderSpec};
use fastertucker::model::ModelState;
use fastertucker::tensor::coo::CooTensor;
use fastertucker::util::rng::Rng;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};

fn tmpfile(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ft_registry_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{}", std::process::id(), name))
}

fn cfg_for(t: &CooTensor, seed: u64) -> TrainConfig {
    TrainConfig {
        order: t.order(),
        dims: t.dims().to_vec(),
        j: 8,
        r: 4,
        lr_a: 0.01,
        lr_b: 1e-4,
        workers: 1, // single worker: no Hogwild races, exact determinism
        block_nnz: 512,
        fiber_threshold: 32,
        seed,
        ..TrainConfig::default()
    }
}

fn fast_model(s: &Session) -> &ModelState {
    match &s.model {
        SessionModel::Fast(m) => m,
        SessionModel::Full(_) => panic!("expected fast model"),
    }
}

fn assert_bitwise_equal(a: &ModelState, b: &ModelState, what: &str) {
    for n in 0..a.order() {
        assert_eq!(
            a.factors[n].max_abs_diff(&b.factors[n]),
            0.0,
            "{what}: factor mode {n} diverged"
        );
        assert_eq!(
            a.cores[n].max_abs_diff(&b.cores[n]),
            0.0,
            "{what}: core mode {n} diverged"
        );
        assert_eq!(
            a.c_tables[n].max_abs_diff(&b.c_tables[n]),
            0.0,
            "{what}: C table mode {n} diverged"
        );
    }
}

/// Two sessions under a 1-byte budget: every step of one evicts the other,
/// so each session rebuilds its prepared cache on every return to it. The
/// `builds` counter proves the evictions; the final models must still be
/// bitwise identical to uninterrupted never-evicted runs.
#[test]
fn eviction_and_rebuild_are_bitwise_invisible() {
    let ta = recommender(&RecommenderSpec::tiny(), 41);
    let tb = recommender(&RecommenderSpec::tiny(), 43);
    let epochs = 3usize;

    // uninterrupted references, no registry, no eviction
    let mut ref_a = Session::new(Algo::FasterTucker, cfg_for(&ta, 71), &ta).unwrap();
    let mut ref_b = Session::new(Algo::FasterTuckerCoo, cfg_for(&tb, 73), &tb).unwrap();
    ref_a.run(epochs, None);
    ref_b.run(epochs, None);

    // the same work through a registry whose budget admits one prepared
    // cache at a time (1 worker so the executor is bit-transparent)
    let mut reg = SessionRegistry::new(1, 1);
    reg.open("a", Algo::FasterTucker, cfg_for(&ta, 71), &ta).unwrap();
    reg.open("b", Algo::FasterTuckerCoo, cfg_for(&tb, 73), &tb).unwrap();
    for _ in 0..epochs {
        reg.step("a", None).unwrap();
        reg.step("b", None).unwrap();
    }

    // every return to an evicted session rebuilt: the initial build plus
    // one rebuild per epoch (a is evicted when b is admitted; b is evicted
    // by every step of a, and vice versa)
    let builds_a = reg.get("a").unwrap().prep_stats().builds;
    let builds_b = reg.get("b").unwrap().prep_stats().builds;
    assert_eq!(builds_a, 1 + epochs, "a: rebuilt on every return");
    assert_eq!(builds_b, 1 + epochs, "b: rebuilt on every return");
    assert_eq!(reg.evictions(), 1 + 2 * epochs);

    assert_bitwise_equal(
        fast_model(&ref_a),
        fast_model(reg.get("a").unwrap()),
        "evicted/rebuilt session a",
    );
    assert_bitwise_equal(
        fast_model(&ref_b),
        fast_model(reg.get("b").unwrap()),
        "evicted/rebuilt session b",
    );
}

/// A post-eviction `step` through the registry equals the same step on an
/// uninterrupted session — the single-step version of the parity claim,
/// directly against the resume harness's reference.
#[test]
fn post_eviction_step_matches_uninterrupted_step() {
    let t = recommender(&RecommenderSpec::tiny(), 47);
    let mut reference = Session::new(Algo::FasterTucker, cfg_for(&t, 71), &t).unwrap();
    reference.run(2, None);

    let mut reg = SessionRegistry::new(1, 0);
    reg.open("s", Algo::FasterTucker, cfg_for(&t, 71), &t).unwrap();
    reg.step("s", None).unwrap();
    // force an eviction by hand between steps
    reg.get_mut("s").unwrap().evict_prepared();
    assert!(!reg.get("s").unwrap().prepared_resident());
    reg.step("s", None).unwrap();
    assert_eq!(reg.get("s").unwrap().prep_stats().builds, 2);
    assert_bitwise_equal(
        fast_model(&reference),
        fast_model(reg.get("s").unwrap()),
        "post-eviction step",
    );
}

/// Concurrent serving: reader threads hammer batched top-k while the
/// session trains. Every observation must carry a published epoch label
/// and match, bit for bit, a recompute from that epoch's checkpoint file —
/// i.e. no reader ever saw a torn mid-pass state.
#[test]
fn concurrent_topk_matches_from_checkpoint_recompute() {
    let t = recommender(&RecommenderSpec::tiny(), 53);
    let mut cfg = cfg_for(&t, 77);
    cfg.workers = 2; // concurrency on the training side too
    let epochs = 4usize;
    let mut session = Session::new(Algo::FasterTucker, cfg, &t).unwrap();
    let handle = session.serving_handle().unwrap();

    let queries: Vec<TopKQuery> = (0..8)
        .map(|i| TopKQuery {
            mode: 1,
            fixed: vec![(i * 13) % t.dims()[0] as u32, (i * 3) % t.dims()[2] as u32],
            k: 5,
        })
        .collect();

    // per-epoch checkpoints: epoch 0 before training, then one per step
    let ckpt = |e: usize| tmpfile(&format!("serving_epoch_{e}.ckpt"));
    session.save_checkpoint(&ckpt(0)).unwrap();

    let done = AtomicBool::new(false);
    let mut observations = std::thread::scope(|scope| {
        let mut readers = Vec::new();
        for _ in 0..3 {
            let handle = handle.clone();
            let queries = &queries;
            let done = &done;
            readers.push(scope.spawn(move || {
                let mut obs = Vec::new();
                loop {
                    let batch = handle.top_k_batch(queries).expect("valid queries");
                    let epoch = batch[0].epoch;
                    // one snapshot per batch: every result shares the epoch
                    assert!(batch.iter().all(|r| r.epoch == epoch));
                    obs.push((epoch, batch));
                    if done.load(Ordering::Acquire) {
                        return obs;
                    }
                    std::thread::yield_now();
                }
            }));
        }
        for e in 1..=epochs {
            session.step(None);
            session.save_checkpoint(&ckpt(e)).unwrap();
        }
        done.store(true, Ordering::Release);
        readers
            .into_iter()
            .flat_map(|r| r.join().expect("reader thread"))
            .collect::<Vec<_>>()
    });
    assert!(!observations.is_empty());
    // a post-training read deterministically sees the final epoch; verify
    // it through the same recompute loop as the concurrent observations
    let final_batch = handle.top_k_batch(&queries).unwrap();
    assert_eq!(final_batch[0].epoch, epochs);
    observations.push((epochs, final_batch));

    // recompute every observed epoch from its checkpoint, through the same
    // GEMM the training refresh uses, and demand bit-identical answers
    for (epoch, batch) in &observations {
        assert!(*epoch <= epochs, "reader saw unpublished epoch {epoch}");
        let mut model = ModelState::load(&ckpt(*epoch)).unwrap();
        model.refresh_all_c();
        let snap = ServingSnapshot::capture(&model, *epoch);
        for (q, observed) in queries.iter().zip(batch.iter()) {
            let expect = snap.top_k(q).unwrap();
            assert_eq!(
                expect.items.len(),
                observed.items.len(),
                "epoch {epoch}: result length"
            );
            for (a, b) in expect.items.iter().zip(observed.items.iter()) {
                assert_eq!(a.0, b.0, "epoch {epoch}: ranked index diverged");
                assert_eq!(
                    a.1.to_bits(),
                    b.1.to_bits(),
                    "epoch {epoch}: score bits diverged — torn snapshot?"
                );
            }
        }
    }
    for e in 0..=epochs {
        std::fs::remove_file(ckpt(e)).ok();
    }
}

/// Every published row of a (delta-chained) snapshot, bit-compared against
/// a from-scratch capture of the same model state. This is the strongest
/// form of the block-sharing invariant: a stale shared block would show up
/// as a diverged row even if no current query happens to touch it.
fn assert_snapshot_matches_scratch(
    snap: &ServingSnapshot,
    m: &ModelState,
    what: &str,
) {
    let scratch = ServingSnapshot::capture(m, snap.epoch());
    assert_eq!(snap.order(), scratch.order(), "{what}: order");
    for n in 0..snap.order() {
        assert_eq!(snap.dim(n), scratch.dim(n), "{what}: dim mode {n}");
        for i in 0..snap.dim(n) {
            let (a, b) = (snap.c_row(n, i), scratch.c_row(n, i));
            assert_eq!(a.len(), b.len(), "{what}: stride mode {n}");
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what}: mode {n} row {i} — delta chain served a stale block"
                );
            }
        }
    }
}

/// Property: *any* interleaving of evict→rebuild with dirty-row
/// incremental refresh is bitwise identical to an uninterrupted session
/// running full-table refreshes. The two orthogonal mechanisms — cache
/// eviction (rebuilds staging structures) and incremental refresh (skips
/// clean C rows) — must not compound into drift, for randomized eviction
/// schedules. With a serving handle attached, the same schedule also
/// exercises the delta-publication chain: each step publishes a
/// copy-on-write snapshot keyed off the incremental refresh's dirty rows,
/// and every one must read like a from-scratch capture.
#[test]
fn random_evictions_with_incremental_refresh_match_full_refresh_reference() {
    let t = recommender(&RecommenderSpec::tiny(), 61);
    let mut rng = Rng::new(2024);
    for round in 0..3u32 {
        let steps = 4usize;

        // uninterrupted reference: full refresh, never evicted
        let mut full_cfg = cfg_for(&t, 71);
        full_cfg.refresh = RefreshMode::Full;
        let mut reference =
            Session::new(Algo::FasterTucker, full_cfg, &t).unwrap();

        // registry session: incremental refresh (the default), with a
        // randomized evict-before-step schedule
        let cfg = cfg_for(&t, 71);
        assert_eq!(cfg.refresh, RefreshMode::Incremental, "default refresh");
        let mut reg = SessionRegistry::new(1, 0);
        let name = format!("s{round}");
        reg.open(&name, Algo::FasterTucker, cfg, &t).unwrap();
        // attach serving: every step now publishes a delta snapshot
        let handle = reg.get_mut(&name).unwrap().serving_handle().unwrap();

        let mut evictions = 0usize;
        for step in 0..steps {
            reference.step(None);
            if rng.next_below(2) == 0 {
                reg.get_mut(&name).unwrap().evict_prepared();
                evictions += 1;
            }
            reg.step(&name, None).unwrap();
            // the handle now holds a chain of `step + 1` delta publications;
            // it must read bitwise like a from-scratch capture of the model
            assert_snapshot_matches_scratch(
                &handle.snapshot(),
                fast_model(reg.get(&name).unwrap()),
                &format!("round {round} step {step}"),
            );
        }
        // every eviction forced a real rebuild on the following step
        assert_eq!(
            reg.get(&name).unwrap().prep_stats().builds,
            1 + evictions,
            "round {round}: rebuild count"
        );
        assert_bitwise_equal(
            fast_model(&reference),
            fast_model(reg.get(&name).unwrap()),
            &format!("round {round} ({evictions} evictions)"),
        );
    }
}

/// Cached per-mode shard plans (and their steal-queue seeds) must not
/// survive an evict→rebuild of the prepared storage: the engine keys its
/// plan cache to the prepared-build generation, a rebuild bumps the
/// `builds` counter, and the next pass must re-derive plans against the
/// rebuilt block list — training through the rebuild stays bitwise
/// identical to an uninterrupted stealing-scheduled session.
#[test]
fn evict_rebuild_invalidates_cached_shard_plans() {
    let t = recommender(&RecommenderSpec::tiny(), 67);
    let mut cfg = cfg_for(&t, 79);
    cfg.sched = SchedMode::Stealing;

    let mut reference =
        Session::new(Algo::FasterTucker, cfg.clone(), &t).unwrap();
    reference.run(2, None);

    let mut reg = SessionRegistry::new(1, 0);
    let shared = std::sync::Arc::new(t.clone());
    let s = Session::new_shared(Algo::FasterTucker, cfg, shared).unwrap();
    reg.insert("s", s).unwrap();
    reg.step("s", None).unwrap();
    // the first step cached plans keyed to build generation 1
    let before = reg.get("s").unwrap();
    assert_eq!(before.engine_storage_epoch(), 1);
    assert!(before.engine_plan_block_counts().iter().any(|&n| n > 0));

    // evict between steps: the next step rebuilds the storage (build 2)
    reg.get_mut("s").unwrap().evict_prepared();
    reg.step("s", None).unwrap();
    let after = reg.get("s").unwrap();
    assert_eq!(after.prep_stats().builds, 2);
    // the plan cache was re-keyed to the rebuild — stale plans (and their
    // steal-queue seeds) were dropped, not reused against the new storage
    assert_eq!(after.engine_storage_epoch(), 2);
    assert!(after.engine_plan_block_counts().iter().any(|&n| n > 0));
    assert_bitwise_equal(
        fast_model(&reference),
        fast_model(after),
        "evict→rebuild under the stealing scheduler",
    );
}

/// Serving during ingestion: `ingest` mutates the model (grown factor
/// rows, re-staged storage) but publishes nothing — readers keep answering
/// from the pre-ingest snapshot, down to `Arc` identity, until the next
/// stepped epoch publishes. That publication then delta-copies the grown
/// mode and reads bitwise like a from-scratch capture.
#[test]
fn readers_hold_pre_ingest_snapshot_until_next_epoch_publishes() {
    let t = recommender(&RecommenderSpec::tiny(), 63);
    let d0 = t.dims()[0];
    let mut reg = SessionRegistry::new(1, 0);
    reg.open("s", Algo::FasterTucker, cfg_for(&t, 71), &t).unwrap();
    let handle = reg.serving_handle("s").unwrap();
    reg.step("s", None).unwrap();
    let before = handle.snapshot();
    assert_eq!(before.epoch(), 1);

    // a delta that grows mode 0 by 5 rows and updates an existing cell
    let mut dims = t.dims().to_vec();
    dims[0] += 5;
    let mut delta = CooTensor::new(dims);
    delta.push(&[(d0 + 2) as u32, 1, 0], 0.5);
    delta.push(&[(d0 + 4) as u32, 0, 1], -1.0);
    delta.push(&[0, 0, 0], 2.0);
    let report = reg.ingest("s", delta).unwrap();
    assert_eq!(report.added_nnz, 3);
    assert_eq!(report.grown, vec![(0, d0, d0 + 5)]);

    // mid-ingestion reads: the very same snapshot object, old shape
    let during = handle.snapshot();
    assert!(
        std::sync::Arc::ptr_eq(&before, &during),
        "ingest must not publish"
    );
    assert_eq!(during.dim(0), d0, "readers see the pre-growth shape");
    let q = TopKQuery { mode: 0, fixed: vec![1, 0], k: 4 };
    assert_eq!(handle.top_k(&q).unwrap().epoch, 1);

    // the next stepped epoch publishes the grown model
    reg.step("s", None).unwrap();
    let after = handle.snapshot();
    assert_eq!(after.epoch(), 2);
    assert_eq!(after.dim(0), d0 + 5, "published snapshot carries the growth");
    assert_snapshot_matches_scratch(
        &after,
        fast_model(reg.get("s").unwrap()),
        "first post-ingest publication",
    );
    // pruned top-k can rank the grown rows, bitwise the exhaustive oracle
    let q = TopKQuery { mode: 0, fixed: vec![1, 0], k: d0 + 5 };
    let pruned = after.top_k(&q).unwrap();
    let oracle = after.top_k_exhaustive(&q).unwrap();
    assert_eq!(pruned.items.len(), oracle.items.len());
    for (a, b) in pruned.items.iter().zip(oracle.items.iter()) {
        assert_eq!(a.0, b.0, "grown-row ranking diverged");
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "grown-row score diverged");
    }
}

/// Serving stays live across registry evictions: the prepared cache is
/// evictable, the model (and thus the snapshots) is not.
#[test]
fn serving_survives_eviction() {
    let t = recommender(&RecommenderSpec::tiny(), 59);
    let mut reg = SessionRegistry::new(1, 0);
    reg.open("s", Algo::FasterTucker, cfg_for(&t, 71), &t).unwrap();
    let handle = reg.serving_handle("s").unwrap();
    reg.step("s", None).unwrap();
    assert_eq!(handle.epoch(), 1);
    reg.get_mut("s").unwrap().evict_prepared();
    // queries keep answering from the last published snapshot
    let q = TopKQuery { mode: 0, fixed: vec![0, 0], k: 3 };
    assert_eq!(handle.top_k(&q).unwrap().epoch, 1);
    // and the next step rebuilds + publishes epoch 2
    reg.step("s", None).unwrap();
    assert_eq!(handle.epoch(), 2);
    assert_eq!(reg.get("s").unwrap().prep_stats().builds, 2);
}
