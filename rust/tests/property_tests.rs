//! Property-based tests over the core invariants, using the in-repo
//! mini-framework (`util::proptest`). Each property runs across dozens of
//! random seeds/sizes; failures print a replayable `FT_PROPTEST_SEED`.

use fastertucker::algo::grad::{
    chain_v_from_tables, chain_v_on_the_fly, chain_v_prefix_cached, fiber_w, Scratch,
};
use fastertucker::config::TrainConfig;
use fastertucker::coordinator::Session;
use fastertucker::algo::Algo;
use fastertucker::linalg::Matrix;
use fastertucker::tensor::bcsf::BcsfTensor;
use fastertucker::tensor::coo::CooTensor;
use fastertucker::tensor::csf::CsfTensor;
use fastertucker::util::proptest::{assert_allclose, run, Gen};
use fastertucker::util::rng::Rng;

mod common;

/// Random sparse tensor with occasional duplicate coordinates.
fn random_coo(g: &mut Gen) -> CooTensor {
    let dims = g.dims(5, 24);
    let order = dims.len();
    let nnz = g.usize_in(1, 200.min(g.size * 8).max(2));
    let mut t = CooTensor::new(dims.clone());
    let mut coords = vec![0u32; order];
    for _ in 0..nnz {
        for (k, c) in coords.iter_mut().enumerate() {
            *c = g.usize_in(0, dims[k]) as u32;
        }
        t.push(&coords, g.f32_in(-3.0, 3.0));
    }
    t
}

#[test]
fn prop_coo_csf_roundtrip_all_leaf_modes() {
    run("COO→CSF→COO preserves the (deduplicated) element set", 48, |g| {
        let coo = random_coo(g);
        for leaf in 0..coo.order() {
            let csf = CsfTensor::build(&coo, leaf);
            csf.validate().unwrap();
            // CSF merges duplicates by summing: compare against dedup oracle
            let mut want = std::collections::BTreeMap::new();
            for (c, v) in coo.iter() {
                *want.entry(c.to_vec()).or_insert(0.0f32) += v;
            }
            let got = csf.to_coo().canonical_elements();
            assert_eq!(got.len(), want.len());
            for (c, v) in got {
                let w = want[&c];
                assert!((v - w).abs() < 1e-4, "coords {c:?}: {v} vs {w}");
            }
        }
    });
}

#[test]
fn prop_bcsf_structural_invariants() {
    run("B-CSF tasks respect threshold and blocks tile tasks", 48, |g| {
        let coo = random_coo(g);
        let threshold = g.usize_in(1, 32);
        let block_nnz = g.usize_in(1, 64);
        for leaf in 0..coo.order() {
            let b = BcsfTensor::build(&coo, leaf, threshold, block_nnz);
            b.validate().unwrap();
            assert!(b.stats.max_block_nnz <= block_nnz + threshold);
        }
    });
}

/// The three B-CSF scheduling invariants the engine relies on, stated
/// directly against the COO input:
/// 1. every (deduplicated) COO non-zero appears in exactly one `Task`;
/// 2. no task exceeds `fiber_threshold` leaves;
/// 3. the block partition covers every task exactly once, in order.
#[test]
fn prop_bcsf_tasks_partition_the_nonzeros() {
    run("B-CSF tasks partition the non-zeros; blocks tile the tasks", 48, |g| {
        let coo = random_coo(g);
        let threshold = g.usize_in(1, 24);
        let block_nnz = g.usize_in(1, 96);
        // CSF merges duplicate coordinates by summation: dedup oracle
        let mut want = std::collections::BTreeMap::new();
        for (c, v) in coo.iter() {
            *want.entry(c.to_vec()).or_insert(0.0f32) += v;
        }
        for leaf in 0..coo.order() {
            let b = BcsfTensor::build(&coo, leaf, threshold, block_nnz);
            let order = b.order();
            let plen = order - 1;

            // (1) reconstruct every element from the task stream: the
            // multiset of (coords, value) must equal the dedup oracle,
            // which proves each non-zero lands in exactly one task.
            let mut got: Vec<(Vec<u32>, f32)> = Vec::with_capacity(b.nnz());
            for task in &b.tasks {
                // (2) threshold respected
                assert!(
                    task.len() <= threshold,
                    "leaf {leaf}: task len {} > threshold {threshold}",
                    task.len()
                );
                let path = b.fiber_path(task.fiber);
                let (leaf_idx, leaf_vals) = b.task_leaves(task);
                for (k, &i) in leaf_idx.iter().enumerate() {
                    let mut coords = vec![0u32; order];
                    for (d, &m) in b.csf.mode_order[..plen].iter().enumerate() {
                        coords[m] = path[d];
                    }
                    coords[b.csf.leaf_mode()] = i;
                    got.push((coords, leaf_vals[k]));
                }
            }
            assert_eq!(got.len(), want.len(), "leaf {leaf}: element count");
            got.sort_by(|a, b| a.0.cmp(&b.0));
            for ((gc, gv), (wc, wv)) in got.iter().zip(want.iter()) {
                assert_eq!(gc, wc, "leaf {leaf}: coordinate set");
                assert!((gv - wv).abs() < 1e-4, "leaf {leaf}: {gc:?}: {gv} vs {wv}");
            }

            // (3) blocks tile 0..tasks.len() exactly, in order
            let mut cursor = 0u32;
            for &(lo, hi) in &b.blocks {
                assert_eq!(lo, cursor, "leaf {leaf}: block gap/overlap");
                assert!(hi > lo, "leaf {leaf}: empty block");
                cursor = hi;
            }
            assert_eq!(cursor as usize, b.tasks.len(), "leaf {leaf}: tail uncovered");
        }
    });
}

/// Task packing never exceeds the greedy bound: a block closes as soon as
/// it reaches `block_nnz`, so it can overshoot by at most one task
/// (≤ threshold) — the quantity the paper's load-balance argument rests on.
#[test]
fn prop_bcsf_block_sizes_bounded() {
    run("B-CSF block sizes ≤ target + threshold", 48, |g| {
        let coo = random_coo(g);
        let threshold = g.usize_in(1, 24);
        let block_nnz = g.usize_in(1, 96);
        for leaf in 0..coo.order() {
            let b = BcsfTensor::build(&coo, leaf, threshold, block_nnz);
            for blk in 0..b.num_blocks() {
                let size: usize = b.block_tasks(blk).iter().map(|t| t.len()).sum();
                assert!(
                    size <= block_nnz + threshold,
                    "leaf {leaf} block {blk}: {size} > {block_nnz}+{threshold}"
                );
            }
            assert!(b.stats.max_block_nnz <= block_nnz + threshold);
        }
    });
}

#[test]
fn prop_chain_v_three_ways_agree() {
    run("chain products: tables == on-the-fly == prefix-cached", 64, |g| {
        let order = g.usize_in(2, 6);
        let j = g.usize_in(1, 12);
        let r = g.usize_in(1, 12);
        let dim = g.usize_in(1, 16);
        let mut rng = Rng::new(g.seed ^ 0xABCD);
        let factors: Vec<Matrix> =
            (0..order).map(|_| Matrix::uniform(dim, j, -1.0, 1.0, &mut rng)).collect();
        let cores: Vec<Matrix> =
            (0..order).map(|_| Matrix::uniform(j, r, -1.0, 1.0, &mut rng)).collect();
        let c_tables: Vec<Matrix> =
            factors.iter().zip(cores.iter()).map(|(a, b)| a.matmul(b)).collect();
        let n_excl = g.usize_in(0, order);
        let modes: Vec<usize> = (0..order).filter(|&m| m != n_excl).collect();
        let mut scratch = Scratch::new(order, j, r);
        let mut v1 = vec![0.0f32; r];
        let mut v2 = vec![0.0f32; r];
        for _ in 0..4 {
            let coords: Vec<u32> =
                modes.iter().map(|_| g.usize_in(0, dim) as u32).collect();
            chain_v_from_tables(&c_tables, &modes, &coords, &mut v1);
            chain_v_on_the_fly(&factors, &cores, &modes, &coords, &mut v2);
            chain_v_prefix_cached(&c_tables, &modes, &coords, &mut scratch);
            assert_allclose(&v1, &v2, 1e-3, 1e-4);
            // scratch.v is rank-padded; the real lanes must agree and the
            // pad lanes must be exactly zero
            assert_allclose(&v1, &scratch.v[..r], 1e-4, 1e-5);
            assert!(scratch.v[r..].iter().all(|&x| x == 0.0));
        }
    });
}

#[test]
fn prop_fiber_w_linear_in_v() {
    run("w = B·v is linear: w(αv1+v2) = αw(v1)+w(v2)", 32, |g| {
        let j = g.usize_in(1, 16);
        let r = g.usize_in(1, 16);
        let mut rng = Rng::new(g.seed);
        let b = Matrix::uniform(j, r, -1.0, 1.0, &mut rng);
        let v1: Vec<f32> = (0..r).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let v2: Vec<f32> = (0..r).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let alpha = g.f32_in(-2.0, 2.0);
        let combo: Vec<f32> =
            v1.iter().zip(v2.iter()).map(|(a, b)| alpha * a + b).collect();
        let mut w1 = vec![0.0f32; j];
        let mut w2 = vec![0.0f32; j];
        let mut wc = vec![0.0f32; j];
        fiber_w(&b, &v1, &mut w1);
        fiber_w(&b, &v2, &mut w2);
        fiber_w(&b, &combo, &mut wc);
        let expect: Vec<f32> =
            w1.iter().zip(w2.iter()).map(|(a, b)| alpha * a + b).collect();
        assert_allclose(&wc, &expect, 1e-4, 1e-5);
    });
}

/// The batched sink contract on random tensors: re-expanding every leaf
/// run one element at a time yields exactly the tensor's element multiset,
/// paired with the right group coordinates — what the old per-leaf stream
/// delivered, now as slices.
#[test]
fn prop_batched_leaf_runs_cover_element_multiset() {
    use common::{ground_truth, stream};
    use fastertucker::algo::engine::SparseStorage;
    use fastertucker::tensor::bcsf::BcsfShared;
    use fastertucker::tensor::coo::CooBlocks;

    run("batched leaf runs = per-leaf element multiset", 24, |g| {
        let coo = random_coo(g);
        let block_nnz = g.usize_in(1, 64);
        let threshold = g.usize_in(1, 16);
        let blocks = CooBlocks::new(&coo, block_nnz);
        for n in 0..coo.order() {
            assert_eq!(
                stream(&blocks, n),
                ground_truth(&coo, blocks.chain_modes(n), n),
                "coo mode {n}"
            );
        }
        let rotations: Vec<BcsfTensor> = (0..coo.order())
            .map(|n| BcsfTensor::build(&coo, n, threshold, block_nnz))
            .collect();
        let shared = BcsfShared::new(&rotations);
        for n in 0..coo.order() {
            let dedup = rotations[n].csf.to_coo();
            assert_eq!(
                stream(&shared, n),
                ground_truth(&dedup, shared.chain_modes(n), n),
                "bcsf mode {n}"
            );
        }
    });
}

#[test]
fn prop_matmul_associative_with_identity_blocks() {
    run("GEMM: (A·I)·B == A·B and A·(B·I) == A·B", 32, |g| {
        let m = g.usize_in(1, 12);
        let k = g.usize_in(1, 12);
        let n = g.usize_in(1, 12);
        let mut rng = Rng::new(g.seed);
        let a = Matrix::uniform(m, k, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(k, n, -1.0, 1.0, &mut rng);
        let mut eye = Matrix::zeros(k, k);
        for i in 0..k {
            eye.set(i, i, 1.0);
        }
        let direct = a.matmul(&b);
        let via1 = a.matmul(&eye).matmul(&b);
        assert!(direct.max_abs_diff(&via1) < 1e-4);
    });
}

#[test]
fn prop_training_never_produces_nan() {
    // SGD with an aggressive learning rate can legitimately diverge to NaN;
    // the property asserts stability under a conservative rate.
    run("3 epochs of every fast variant keep parameters finite", 12, |g| {
        let mut dims = g.dims(4, 20);
        if dims.len() < 3 {
            dims.push(4);
        }
        let order = dims.len();
        let nnz = g.usize_in(4, 120);
        let mut t = CooTensor::new(dims.clone());
        let mut coords = vec![0u32; order];
        let mut rng = Rng::new(g.seed);
        for _ in 0..nnz {
            for (k, c) in coords.iter_mut().enumerate() {
                *c = rng.next_below(dims[k]) as u32;
            }
            t.push(&coords, rng.uniform_f32(0.5, 5.0));
        }
        let cfg = TrainConfig {
            order,
            dims,
            j: 4,
            r: 4,
            lr_a: 0.005,
            lr_b: 1e-4,
            workers: 2,
            fiber_threshold: 8,
            block_nnz: 32,
            ..TrainConfig::default()
        };
        for algo in [Algo::FastTucker, Algo::FasterTuckerCoo, Algo::FasterTucker] {
            let mut session = Session::new(algo, cfg.clone(), &t).unwrap();
            let report = session.run(3, None);
            for rec in &report.convergence.records {
                assert!(
                    rec.rmse.is_finite(),
                    "{}: NaN rmse at epoch {}",
                    algo.name(),
                    rec.epoch
                );
            }
        }
    });
}

#[test]
fn prop_train_test_split_partitions() {
    run("train/test split is a partition for any fraction", 32, |g| {
        let coo = random_coo(g);
        let frac = g.f32_in(0.0, 0.9) as f64;
        let (train, test) =
            fastertucker::data::split::train_test(&coo, frac, g.seed);
        assert_eq!(train.nnz() + test.nnz(), coo.nnz());
        let mut all = train.canonical_elements();
        all.extend(test.canonical_elements());
        all.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
        let mut orig = coo.canonical_elements();
        orig.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
        assert_eq!(all, orig);
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_trees() {
    use fastertucker::util::json::Json;
    run("JSON value trees survive serialize→parse", 64, |g| {
        fn gen_value(g: &mut Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize_in(0, 4) } else { g.usize_in(0, 6) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f32_in(-1e6, 1e6) as f64 * 100.0).round() / 100.0),
                3 => {
                    let n = g.usize_in(0, 8);
                    Json::Str(
                        (0..n)
                            .map(|_| {
                                char::from_u32(g.usize_in(32, 1000) as u32)
                                    .unwrap_or('x')
                            })
                            .collect(),
                    )
                }
                4 => Json::Arr(
                    (0..g.usize_in(0, 4)).map(|_| gen_value(g, depth - 1)).collect(),
                ),
                _ => Json::Obj(
                    (0..g.usize_in(0, 4))
                        .map(|i| (format!("k{i}"), gen_value(g, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen_value(g, 3);
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, parsed);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, pretty);
    });
}

#[test]
fn prop_model_predict_consistent_after_refresh() {
    run("predict() == predict_direct() whenever C tables are fresh", 24, |g| {
        let order = g.usize_in(2, 5);
        let dims: Vec<usize> = (0..order).map(|_| g.usize_in(1, 16)).collect();
        let cfg = TrainConfig {
            order,
            dims: dims.clone(),
            j: g.usize_in(1, 8),
            r: g.usize_in(1, 8),
            ..TrainConfig::default()
        };
        let mut m = fastertucker::model::ModelState::init(&cfg, g.seed);
        // perturb + refresh
        let mode = g.usize_in(0, order);
        let row = g.usize_in(0, dims[mode]);
        m.factors[mode].row_mut(row)[0] += 0.5;
        m.refresh_c(mode);
        for _ in 0..4 {
            let coords: Vec<u32> =
                dims.iter().map(|&d| g.usize_in(0, d) as u32).collect();
            let a = m.predict(&coords);
            let b = m.predict_direct(&coords);
            assert!(
                (a - b).abs() < 1e-3 * (1.0 + a.abs().max(b.abs())),
                "{a} vs {b}"
            );
        }
    });
}
