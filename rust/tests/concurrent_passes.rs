//! Concurrent leased passes: overlap without divergence.
//!
//! The headline guarantee of the pass-backend/lease rework: two registry
//! tenants driving passes **concurrently** on disjoint worker-subset
//! leases of one shared [`Executor`] produce models **bitwise identical**
//! to the same sessions run serialized — and the overlap provably
//! happened (lease accounting + an in-pass rendezvous that can only
//! resolve if both passes are in flight at once).
//!
//! Also here: property tests for the lease allocator itself — leases are
//! disjoint, never exceed the worker budget, and release→reacquire is
//! starvation-free under a randomized multi-thread schedule (plus a
//! deterministic big-request-vs-churn starvation check: FIFO tickets mean
//! a full-budget request is served in arrival order, not starved).

use fastertucker::algo::Algo;
use fastertucker::config::TrainConfig;
use fastertucker::coordinator::{Session, SessionModel};
use fastertucker::data::synthetic::{recommender, RecommenderSpec};
use fastertucker::exec::{CpuShardBackend, PassBackend, PassRequest};
use fastertucker::model::ModelState;
use fastertucker::sched::pool::WorkerStats;
use fastertucker::sched::Executor;
use fastertucker::tensor::coo::CooTensor;
use fastertucker::util::proptest::run;
use fastertucker::util::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};

fn cfg_for(t: &CooTensor, seed: u64) -> TrainConfig {
    TrainConfig {
        order: t.order(),
        dims: t.dims().to_vec(),
        j: 8,
        r: 4,
        lr_a: 0.01,
        lr_b: 1e-4,
        workers: 1, // 1-worker leases: no Hogwild races, exact determinism
        block_nnz: 512,
        fiber_threshold: 32,
        seed,
        ..TrainConfig::default()
    }
}

fn fast_model(s: &Session) -> &ModelState {
    match &s.model {
        SessionModel::Fast(m) => m,
        SessionModel::Full(_) => panic!("expected fast model"),
    }
}

fn assert_bitwise_equal(a: &ModelState, b: &ModelState, what: &str) {
    for n in 0..a.order() {
        assert_eq!(
            a.factors[n].max_abs_diff(&b.factors[n]),
            0.0,
            "{what}: factor mode {n} diverged"
        );
        assert_eq!(
            a.cores[n].max_abs_diff(&b.cores[n]),
            0.0,
            "{what}: core mode {n} diverged"
        );
        assert_eq!(
            a.c_tables[n].max_abs_diff(&b.c_tables[n]),
            0.0,
            "{what}: C table mode {n} diverged"
        );
    }
}

/// A [`PassBackend`] decorator that rendezvouses with the other tenant at
/// the start of every pass, then delegates to [`CpuShardBackend`]. The
/// barrier sits *inside* the pass — after the lease is acquired — so it
/// can only release when both tenants hold leases simultaneously: the
/// test deadlocks (and times out) if the executor serialized them, and
/// the delegation keeps the math bit-identical to the plain CPU backend.
struct RendezvousBackend {
    inner: CpuShardBackend,
    barrier: Arc<Barrier>,
}

impl PassBackend for RendezvousBackend {
    fn name(&self) -> &'static str {
        "rendezvous(cpu)"
    }
    fn run_pass(&self, req: PassRequest<'_>) -> WorkerStats {
        self.barrier.wait();
        self.inner.run_pass(req)
    }
}

/// Two registry sessions, one 2-worker executor, 1-worker leases plumbed
/// through the registry's admission policy, every pass forced to overlap
/// with the other tenant's — and the resulting models must equal
/// serialized (no executor at all) runs bit for bit, while the executor's
/// lease accounting proves the overlap and attributes both leased slots
/// without double-counting.
#[test]
fn overlapped_leased_passes_match_serialized_runs() {
    let ta = recommender(&RecommenderSpec::tiny(), 81);
    let tb = recommender(&RecommenderSpec::tiny(), 83);
    let epochs = 3usize;

    // serialized references: plain sessions, no executor
    let mut ref_a = Session::new(Algo::FasterTucker, cfg_for(&ta, 71), &ta).unwrap();
    let mut ref_b = Session::new(Algo::FasterTuckerCoo, cfg_for(&tb, 73), &tb).unwrap();
    ref_a.run(epochs, None);
    ref_b.run(epochs, None);

    // concurrent tenants: opened through a registry whose admission
    // policy leases 1 of the 2-worker budget per pass, then extracted
    // with their executor attachment + lease intact so each can be driven
    // from its own thread
    let mut reg = fastertucker::coordinator::SessionRegistry::new(2, 0);
    reg.set_pass_lease(Some(1));
    reg.open("a", Algo::FasterTucker, cfg_for(&ta, 71), &ta).unwrap();
    reg.open("b", Algo::FasterTuckerCoo, cfg_for(&tb, 73), &tb).unwrap();
    let ex: Arc<Executor> = reg.executor().clone();
    // both algorithms run factor+core per epoch → equal pass counts, so
    // every pass of one tenant pairs with exactly one pass of the other
    let barrier = Arc::new(Barrier::new(2));
    let take = |reg: &mut fastertucker::coordinator::SessionRegistry, name: &str| {
        let mut s = reg.take_attached(name).unwrap();
        assert!(s.executor().is_some(), "take_attached keeps the shared pool");
        assert_eq!(s.lease_workers(), Some(1), "admission policy plumbed the lease");
        s.set_backend(Box::new(RendezvousBackend {
            inner: CpuShardBackend,
            barrier: barrier.clone(),
        }));
        s
    };
    let mut sa = take(&mut reg, "a");
    let mut sb = take(&mut reg, "b");
    std::thread::scope(|scope| {
        scope.spawn(|| {
            sa.run(epochs, None);
        });
        scope.spawn(|| {
            sb.run(epochs, None);
        });
    });

    // overlap actually occurred, via lease accounting
    assert_eq!(ex.peak_concurrent_leases(), 2, "passes never overlapped");
    assert_eq!(ex.concurrent_leases(), 0, "all leases released");
    let total_passes = 2 * 2 * epochs; // 2 tenants × (factor+core) × epochs
    assert_eq!(ex.passes_executed(), total_passes);
    assert_eq!(ex.leases_granted(), total_passes);
    // disjoint slot attribution: both budget slots saw work, and the
    // grand totals are exact (no double-counting across concurrent leases)
    let total = ex.total_stats();
    assert_eq!(total.blocks.len(), 2);
    assert!(total.blocks[0] > 0 && total.blocks[1] > 0, "one slot idle: {total:?}");

    // and the overlap was invisible to the math
    assert_bitwise_equal(fast_model(&ref_a), fast_model(&sa), "tenant a");
    assert_bitwise_equal(fast_model(&ref_b), fast_model(&sb), "tenant b");
}

/// Lease allocator properties under a randomized schedule: every live
/// lease's slots are disjoint from every other's, slots never leave the
/// budget, and every thread finishes its acquisition quota (the allocator
/// neither deadlocks nor starves anyone).
#[test]
fn lease_allocator_is_disjoint_bounded_and_starvation_free() {
    run("lease allocator", 12, |g| {
        let budget = g.usize_in(1, 9);
        let threads = g.usize_in(2, 5);
        let ops = 12usize;
        let ex = Executor::new(budget);
        let claimed: Vec<AtomicBool> =
            (0..budget).map(|_| AtomicBool::new(false)).collect();
        let seeds: Vec<u64> = (0..threads).map(|_| g.rng.next_u64()).collect();
        std::thread::scope(|scope| {
            for seed in seeds {
                let ex = &ex;
                let claimed = &claimed;
                scope.spawn(move || {
                    let mut rng = Rng::new(seed);
                    for _ in 0..ops {
                        // requests intentionally overshoot sometimes; the
                        // allocator clamps to [1, budget]
                        let want = 1 + rng.next_below(budget + 2);
                        let lease = ex.acquire(want);
                        assert_eq!(lease.workers(), want.clamp(1, budget));
                        for &s in lease.slots() {
                            assert!(s < budget, "slot {s} outside budget {budget}");
                            assert!(
                                !claimed[s].swap(true, Ordering::SeqCst),
                                "slot {s} leased to two holders"
                            );
                        }
                        std::thread::yield_now();
                        // clear before release: we still own the slots here
                        for &s in lease.slots() {
                            claimed[s].store(false, Ordering::SeqCst);
                        }
                        drop(lease);
                    }
                });
            }
        });
        // release→reacquire drained completely: nothing leaked, nothing
        // stuck (reaching this line at all is the starvation-freedom
        // evidence — every thread completed its quota)
        assert_eq!(ex.concurrent_leases(), 0);
        assert_eq!(ex.leases_granted(), threads * ops);
        assert!(ex.peak_concurrent_leases() >= 1);
    });
}

/// Deterministic starvation check: FIFO ticketing means repeated
/// full-budget acquisitions complete even while small-lease churners
/// hammer the executor — a greedy (non-FIFO) allocator would let the
/// 1-worker stream starve the full-budget tenant indefinitely.
#[test]
fn full_budget_reacquire_is_starvation_free_under_churn() {
    let ex = Executor::new(4);
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for _ in 0..3 {
            let ex = &ex;
            let stop = &stop;
            scope.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let lease = ex.acquire(1);
                    std::hint::black_box(lease.slots());
                }
            });
        }
        for round in 0..25 {
            let lease = ex.acquire(4);
            assert_eq!(lease.workers(), 4, "round {round}");
            let mut slots = lease.slots().to_vec();
            slots.sort_unstable();
            assert_eq!(slots, vec![0, 1, 2, 3], "full budget leased");
        }
        stop.store(true, Ordering::Release);
    });
}
