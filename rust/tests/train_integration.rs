//! Integration tests: full training runs across algorithms, formats and
//! worker counts, exercising the public API end to end (no PJRT — see
//! `runtime_integration.rs` for the artifact path).

use fastertucker::algo::Algo;
use fastertucker::config::TrainConfig;
use fastertucker::coordinator::{Session, SessionModel};
use fastertucker::data::split::{filter_cold, train_test};
use fastertucker::data::synthetic::{order_sweep, recommender, RecommenderSpec};
use fastertucker::metrics::rmse_mae;
use fastertucker::model::ModelState;
use fastertucker::tensor::prepared::PreparedStorage;
use fastertucker::tensor::{coo::CooTensor, io};

/// Bitwise whole-model comparison (factors, cores, C tables).
fn assert_models_bitwise(a: &Session, b: &Session, what: &str) {
    let (SessionModel::Fast(ma), SessionModel::Fast(mb)) = (&a.model, &b.model)
    else {
        panic!("{what}: expected fast models");
    };
    for n in 0..ma.order() {
        for (name, x, y) in [
            ("factor", &ma.factors[n], &mb.factors[n]),
            ("core", &ma.cores[n], &mb.cores[n]),
            ("c_table", &ma.c_tables[n], &mb.c_tables[n]),
        ] {
            assert_eq!(x.rows(), y.rows(), "{what}: {name} {n} rows");
            let same = x
                .data()
                .iter()
                .zip(y.data())
                .all(|(p, q)| p.to_bits() == q.to_bits());
            assert!(same, "{what}: {name} {n} diverged");
        }
    }
}

fn tiny(seed: u64) -> CooTensor {
    recommender(&RecommenderSpec::tiny(), seed)
}

fn cfg_for(t: &CooTensor, workers: usize) -> TrainConfig {
    TrainConfig {
        order: t.order(),
        dims: t.dims().to_vec(),
        j: 8,
        r: 8,
        lr_a: 0.01,
        lr_b: 1e-4,
        workers,
        fiber_threshold: 64,
        block_nnz: 1024,
        ..TrainConfig::default()
    }
}

#[test]
fn fastertucker_converges_to_low_rmse() {
    let t = tiny(1);
    let (train, test) = train_test(&t, 0.15, 2);
    let test = filter_cold(&test, &train);
    let mut session = Session::new(Algo::FasterTucker, cfg_for(&train, 4), &train).unwrap();
    let report = session.run(25, Some(&test));
    // planted rank-4 signal with noise 0.2 — a rank-8 model must reach
    // well below the initial error
    let first = report.convergence.records[0].rmse;
    let last = report.last_rmse();
    assert!(last < first * 0.75, "RMSE {first:.4} -> {last:.4}");
    assert!(last < 0.5, "final RMSE {last:.4} too high");
}

#[test]
fn all_fast_variants_reach_similar_accuracy() {
    // paper Fig. 3: the variants' convergence curves nearly coincide —
    // they compute the same updates
    let t = tiny(3);
    let (train, test) = train_test(&t, 0.15, 4);
    let test = filter_cold(&test, &train);
    let mut finals = Vec::new();
    for algo in [
        Algo::FastTucker,
        Algo::FasterTuckerCoo,
        Algo::FasterTuckerBcsf,
        Algo::FasterTucker,
    ] {
        let mut session = Session::new(algo, cfg_for(&train, 1), &train).unwrap();
        let report = session.run(10, Some(&test));
        finals.push(report.last_rmse());
    }
    let max = finals.iter().cloned().fold(f64::MIN, f64::max);
    let min = finals.iter().cloned().fold(f64::MAX, f64::min);
    assert!(
        (max - min) / min < 0.1,
        "variant accuracies diverged: {finals:?}"
    );
}

#[test]
fn parallel_matches_serial_accuracy() {
    // Hogwild races perturb individual updates but not convergence quality
    let t = tiny(5);
    let (train, test) = train_test(&t, 0.15, 6);
    let test = filter_cold(&test, &train);
    let mut rmse = Vec::new();
    for workers in [1usize, 8] {
        let mut session =
            Session::new(Algo::FasterTucker, cfg_for(&train, workers), &train).unwrap();
        let report = session.run(10, Some(&test));
        rmse.push(report.last_rmse());
    }
    assert!(
        (rmse[0] - rmse[1]).abs() / rmse[0] < 0.1,
        "serial {} vs parallel {}",
        rmse[0],
        rmse[1]
    );
}

#[test]
fn checkpoint_roundtrip_preserves_predictions() {
    let t = tiny(7);
    let mut session = Session::new(Algo::FasterTucker, cfg_for(&t, 2), &t).unwrap();
    session.run(3, None);
    let path = std::env::temp_dir().join(format!("ft_it_{}.ckpt", std::process::id()));
    if let SessionModel::Fast(m) = &session.model {
        m.save(&path).unwrap();
        let loaded = ModelState::load(&path).unwrap();
        let (r1, _) = rmse_mae(m, &t, 2);
        let (r2, _) = rmse_mae(&loaded, &t, 2);
        assert!((r1 - r2).abs() < 1e-9);
    } else {
        panic!("expected fast model");
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn tensor_io_roundtrip_through_training() {
    // write → read → train gives the same result as training the original
    let t = tiny(9);
    let path = std::env::temp_dir().join(format!("ft_io_{}.ftns", std::process::id()));
    io::write_binary(&t, &path).unwrap();
    let t2 = io::read_binary(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let mut tr1 = Session::new(Algo::FasterTucker, cfg_for(&t, 1), &t).unwrap();
    let mut tr2 = Session::new(Algo::FasterTucker, cfg_for(&t2, 1), &t2).unwrap();
    let r1 = tr1.run(3, None);
    let r2 = tr2.run(3, None);
    assert!((r1.last_rmse() - r2.last_rmse()).abs() < 1e-9);
}

#[test]
fn order_5_tensor_end_to_end() {
    let t = order_sweep(5, 15, 1500, 11);
    let cfg = TrainConfig {
        order: 5,
        dims: t.dims().to_vec(),
        j: 4,
        r: 4,
        lr_a: 0.01,
        lr_b: 1e-4,
        workers: 2,
        fiber_threshold: 16,
        block_nnz: 256,
        ..TrainConfig::default()
    };
    let mut session = Session::new(Algo::FasterTucker, cfg, &t).unwrap();
    let report = session.run(6, None);
    assert!(report.convergence.improved());
}

#[test]
fn degenerate_inputs_do_not_crash() {
    // single-element tensor
    let mut t = CooTensor::new(vec![3, 3, 3]);
    t.push(&[1, 2, 0], 4.0);
    let mut session = Session::new(Algo::FasterTucker, cfg_for(&t, 4), &t).unwrap();
    let report = session.run(2, None);
    assert_eq!(report.convergence.records.len(), 2);

    // tensor with a dimension of size 1
    let mut t = CooTensor::new(vec![5, 1, 5]);
    for i in 0..5u32 {
        t.push(&[i, 0, (i + 1) % 5], 2.0);
    }
    let mut session = Session::new(Algo::FasterTucker, cfg_for(&t, 2), &t).unwrap();
    session.run(2, None);
}

#[test]
fn extreme_learning_rate_diverges_but_stays_finite_with_clamp_off() {
    // document behaviour under a hostile config: values may blow up, but the
    // session itself must not panic
    let t = tiny(13);
    let mut cfg = cfg_for(&t, 2);
    cfg.lr_a = 5.0;
    let mut session = Session::new(Algo::FasterTucker, cfg, &t).unwrap();
    let report = session.run(2, None);
    assert_eq!(report.convergence.records.len(), 2);
}

/// The PR-9 acceptance case: a tensor whose full prepared set exceeds the
/// stage budget still stages and trains — mode-by-mode builds spill
/// completed rotations and page them back in during passes — and the
/// result is **bitwise** the unbounded run, with the measured peak
/// residency never above the budget.
#[test]
fn budget_capped_training_is_bitwise_unbounded() {
    let t = tiny(17);
    let cfg = cfg_for(&t, 1);
    // the minimum feasible budget (traversal + one rotation) is strictly
    // below the unbounded prepared size, so this run genuinely cannot
    // hold everything at once
    let probe = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
    let full = probe.prep().resident_bytes;
    let budget = probe.min_stage_budget_bytes();
    assert!(
        budget < full,
        "fixture too small: min budget {budget} >= full size {full}"
    );
    drop(probe);

    let mut capped_cfg = cfg.clone();
    capped_cfg.stage_budget_bytes = budget;
    let mut capped = Session::new(Algo::FasterTucker, capped_cfg, &t).unwrap();
    let mut unbounded = Session::new(Algo::FasterTucker, cfg, &t).unwrap();
    assert!(
        capped.prep_stats().peak_resident_bytes <= budget,
        "staging peak {} above budget {budget}",
        capped.prep_stats().peak_resident_bytes
    );
    assert!(capped.prep_stats().resident_bytes <= budget);
    for e in 0..3 {
        capped.epoch();
        unbounded.epoch();
        assert_models_bitwise(
            &capped,
            &unbounded,
            &format!("budgeted epoch {e}"),
        );
    }
}

/// Half-way and pathological-tiny budgets behave identically: anything at
/// or above the minimum trains bitwise-equal; anything below fails fast at
/// session construction with an actionable message.
#[test]
fn stage_budget_extremes_train_or_fail_fast() {
    let t = tiny(19);
    let cfg = cfg_for(&t, 2);
    let probe = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
    let full = probe.prep().resident_bytes;
    let min = probe.min_stage_budget_bytes();
    drop(probe);
    let mut reference = Session::new(Algo::FasterTucker, cfg.clone(), &t).unwrap();
    reference.epoch();
    // half-way between minimum and full: spills some rotations, not all
    let mut half_cfg = cfg.clone();
    half_cfg.stage_budget_bytes = ((min + full) / 2).max(min);
    let mut half = Session::new(Algo::FasterTucker, half_cfg, &t).unwrap();
    half.epoch();
    assert_models_bitwise(&half, &reference, "half budget");
    // pathological: below the minimum there is no feasible residency plan
    let mut tiny_cfg = cfg;
    tiny_cfg.stage_budget_bytes = min.saturating_sub(1).max(1);
    let err = Session::new(Algo::FasterTucker, tiny_cfg, &t)
        .err()
        .expect("sub-minimum budget must be rejected");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("budget"),
        "error should name the budget: {msg}"
    );
}

/// Ingesting into a budget-capped session falls back to a full (still
/// budget-capped) re-stage of the concatenation — spilled rotations have
/// no in-RAM prefix to merge into — and stays correct: the merged session
/// matches a cold session over the concatenation bitwise.
#[test]
fn ingest_into_budgeted_session_falls_back_to_cold_restage() {
    let t = tiny(23);
    let mut cfg = cfg_for(&t, 1);
    let probe = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
    // headroom over the base minimum: the merged tensor is a few nnz
    // bigger, and the budget must stay feasible for it too
    cfg.stage_budget_bytes = probe.min_stage_budget_bytes() + 4096;
    drop(probe);
    let mut live = Session::new_shared(
        Algo::FasterTucker,
        cfg.clone(),
        std::sync::Arc::new(t.clone()),
    )
    .unwrap();
    let mut delta = CooTensor::new(t.dims().to_vec());
    delta.push(&[1, 2, 0], 0.75);
    delta.push(&[0, 0, 1], -0.5);
    live.ingest(delta.clone()).unwrap();
    assert_eq!(live.prep_stats().builds, 2);
    let mut merged = CooTensor::with_capacity(t.dims().to_vec(), t.nnz() + 2);
    for e in 0..t.nnz() {
        merged.push(t.index(e), t.value(e));
    }
    for e in 0..delta.nnz() {
        merged.push(delta.index(e), delta.value(e));
    }
    let mut cold = Session::new(Algo::FasterTucker, cfg, &merged).unwrap();
    for e in 0..2 {
        live.epoch();
        cold.epoch();
        assert_models_bitwise(&live, &cold, &format!("budgeted ingest epoch {e}"));
    }
}

#[test]
fn cutucker_and_ptucker_integrate_with_session() {
    let t = tiny(15);
    let (train, test) = train_test(&t, 0.2, 8);
    let test = filter_cold(&test, &train);
    for algo in [Algo::CuTucker, Algo::PTucker] {
        let mut cfg = cfg_for(&train, 2);
        cfg.j = 4;
        cfg.r = 4;
        let mut session = Session::new(algo, cfg, &train).unwrap();
        let report = session.run(3, Some(&test));
        assert!(
            report.convergence.improved(),
            "{} did not improve",
            algo.name()
        );
    }
}
