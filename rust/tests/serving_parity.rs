//! Property suite for the serving read path: the pruned heap selection is
//! **bitwise** the exhaustive sort, and a chain of delta publications is
//! **bitwise** a from-scratch capture.
//!
//! The pruned path skips whole 64-row blocks on a Cauchy–Schwarz norm
//! bound and keeps only a size-k min-heap, so three things could silently
//! go wrong: the rounding slack could under-inflate the bound (a true
//! winner pruned), the heap order could diverge from the sort's tie-break
//! (equal scores, different index order), or a shared copy-on-write block
//! could go stale across epochs. Each property here is built to trip one
//! of those failure modes: signed factors drive negative scores (the bound
//! must still dominate |dot|), duplicated factor rows force *exact* score
//! ties across block boundaries, and the delta chain interleaves
//! incremental row touches with whole-mode invalidations.

use fastertucker::config::TrainConfig;
use fastertucker::coordinator::serving::BLOCK_ROWS;
use fastertucker::coordinator::{ServingSnapshot, TopKQuery};
use fastertucker::model::ModelState;
use fastertucker::util::ceil_div;
use fastertucker::util::rng::Rng;

/// A 3-mode model wide enough that mode 0 spans several 64-row blocks,
/// with factors resampled over `[-1, 1)` so chain products and scores take
/// both signs.
fn signed_model(seed: u64, r: usize) -> ModelState {
    let cfg = TrainConfig {
        order: 3,
        dims: vec![167, 80, 40],
        j: 6,
        r,
        ..TrainConfig::default()
    };
    let mut m = ModelState::init(&cfg, seed);
    let mut rng = Rng::new(seed ^ 0x5EED);
    for f in &mut m.factors {
        for x in f.data_mut() {
            *x = rng.uniform_f32(-1.0, 1.0);
        }
    }
    m.refresh_all_c();
    m
}

fn assert_results_bitwise(
    a: &fastertucker::coordinator::TopKResult,
    b: &fastertucker::coordinator::TopKResult,
    what: &str,
) {
    assert_eq!(a.epoch, b.epoch, "{what}: epoch");
    assert_eq!(a.items.len(), b.items.len(), "{what}: length");
    for (slot, (x, y)) in a.items.iter().zip(b.items.iter()).enumerate() {
        assert_eq!(x.0, y.0, "{what}: slot {slot} index");
        assert_eq!(
            x.1.to_bits(),
            y.1.to_bits(),
            "{what}: slot {slot} score bits"
        );
    }
}

/// Bit-compare every published row of two snapshots (the data the scorer
/// actually reads, pads included).
fn assert_snapshots_bitwise(a: &ServingSnapshot, b: &ServingSnapshot, what: &str) {
    assert_eq!(a.order(), b.order(), "{what}: order");
    for n in 0..a.order() {
        assert_eq!(a.dim(n), b.dim(n), "{what}: dim mode {n}");
        for i in 0..a.dim(n) {
            let (x, y) = (a.c_row(n, i), b.c_row(n, i));
            assert_eq!(x.len(), y.len(), "{what}: stride mode {n}");
            for (p, q) in x.iter().zip(y.iter()) {
                assert_eq!(p.to_bits(), q.to_bits(), "{what}: mode {n} row {i}");
            }
        }
    }
}

/// The headline property: for every mode, a spread of k values (including
/// the degenerate 0, the full dim, and past-the-dim), random fixed
/// coordinates, and several ranks (padded and unpadded), the pruned heap
/// path returns bit for bit what the full-sort oracle returns — while the
/// prune counters stay consistent with the block accounting.
#[test]
fn pruned_top_k_is_bitwise_the_exhaustive_sort() {
    for (seed, r) in [(11u64, 3usize), (13, 8), (17, 11)] {
        let m = signed_model(seed, r);
        let snap = ServingSnapshot::capture(&m, 7);
        let mut rng = Rng::new(seed.wrapping_mul(977));
        for mode in 0..3usize {
            let dim = snap.dim(mode);
            let dims = [167usize, 80, 40];
            for k in [0usize, 1, 5, dim, dim + 7] {
                // a handful of random fixed coordinates per (mode, k)
                for _ in 0..3 {
                    let mut fixed = Vec::new();
                    for (n, &d) in dims.iter().enumerate() {
                        if n != mode {
                            fixed.push(rng.next_below(d) as u32);
                        }
                    }
                    let q = TopKQuery { mode, fixed, k };
                    let (pruned, stats) = snap.top_k_with_stats(&q).unwrap();
                    let oracle = snap.top_k_exhaustive(&q).unwrap();
                    let what = format!("r={r} mode={mode} k={k}");
                    assert_results_bitwise(&pruned, &oracle, &what);
                    if k == 0 {
                        assert_eq!(
                            stats,
                            Default::default(),
                            "{what}: k=0 must do no work"
                        );
                    } else {
                        assert_eq!(
                            stats.blocks_scanned + stats.blocks_skipped,
                            ceil_div(dim, BLOCK_ROWS),
                            "{what}: block accounting"
                        );
                        assert!(
                            stats.rows_scored >= k.min(dim),
                            "{what}: the heap needs k scored rows"
                        );
                    }
                }
            }
        }
    }
}

/// Exact ties, across block boundaries: every factor row of mode 0 is a
/// copy of one of 8 distinct rows, so each score value appears 12 times
/// spread over three 64-row blocks. The heap path must rank tied indices
/// lowest-first exactly like the sort — this is also what makes the
/// strict-inequality prune bound safe.
#[test]
fn exact_ties_break_toward_lower_index() {
    let cfg = TrainConfig {
        order: 3,
        dims: vec![96, 8, 8],
        j: 4,
        r: 4,
        ..TrainConfig::default()
    };
    let mut m = ModelState::init(&cfg, 29);
    let mut rng = Rng::new(31);
    for f in &mut m.factors {
        for x in f.data_mut() {
            *x = rng.uniform_f32(-1.0, 1.0);
        }
    }
    // duplicate: row i of mode 0 = distinct row (i % 8)
    for i in 8..96 {
        let src = m.factors[0].row(i % 8).to_vec();
        m.factors[0].row_mut(i).copy_from_slice(&src);
    }
    m.refresh_all_c();
    let snap = ServingSnapshot::capture(&m, 1);
    for k in [1usize, 8, 12, 13, 30, 96] {
        let q = TopKQuery { mode: 0, fixed: vec![2, 5], k };
        let pruned = snap.top_k(&q).unwrap();
        let oracle = snap.top_k_exhaustive(&q).unwrap();
        assert_results_bitwise(&pruned, &oracle, &format!("ties k={k}"));
    }
    // sanity: the ties are real — the top 12 are one duplicated row's
    // copies, ascending index, identical bits
    let top = snap
        .top_k(&TopKQuery { mode: 0, fixed: vec![2, 5], k: 12 })
        .unwrap();
    let best_bits = top.items[0].1.to_bits();
    let base = top.items[0].0 % 8;
    for (slot, &(idx, score)) in top.items.iter().enumerate() {
        assert_eq!(score.to_bits(), best_bits, "slot {slot} not an exact tie");
        assert_eq!(idx, base + slot * 8, "ties must rank ascending by index");
    }
}

/// A chain of delta publications — incremental row touches, whole-mode
/// invalidations, and no-op epochs interleaved — reads bitwise like a
/// from-scratch capture at every link, with the copied/shared accounting
/// always summing to the full row count.
#[test]
fn delta_chain_matches_scratch_capture_at_every_epoch() {
    let mut m = signed_model(43, 5);
    let total_rows = 167 + 80 + 40;
    let mut prev = ServingSnapshot::capture(&m, 1);
    m.clear_publish_dirty();
    let mut rng = Rng::new(47);
    for epoch in 2..=7usize {
        match epoch % 3 {
            0 => {
                // whole-mode invalidation: a core nudge forces refresh_c
                let n = rng.next_below(3);
                m.cores[n].row_mut(0)[0] += 0.125;
                m.refresh_c(n);
            }
            1 => {
                // sparse touch: a few factor rows through the incremental
                // dirty-row path (the delta's intended workload)
                let n = rng.next_below(3);
                let rows = m.factors[n].rows();
                m.dirty[n].ensure(rows);
                for _ in 0..3 {
                    let i = rng.next_below(rows);
                    m.factors[n].row_mut(i)[0] += 0.25;
                    m.dirty[n].mark(i);
                }
                m.refresh_c_dirty(n, None);
            }
            _ => {
                // no-op epoch: nothing touched, everything shared
            }
        }
        let delta = ServingSnapshot::capture_delta(&m, epoch, &prev);
        m.clear_publish_dirty();
        let scratch = ServingSnapshot::capture(&m, epoch);
        assert_snapshots_bitwise(&delta, &scratch, &format!("epoch {epoch}"));
        let st = delta.stats();
        assert_eq!(
            st.rows_copied + st.rows_shared,
            total_rows,
            "epoch {epoch}: accounting"
        );
        if epoch % 3 == 2 {
            assert_eq!(st.rows_copied, 0, "no-op epoch must share everything");
            assert_eq!(st.bytes, 0, "no-op epoch must allocate nothing");
        }
        // pruned top-k answers through the delta match the scratch oracle
        let q = TopKQuery { mode: 0, fixed: vec![3, 9], k: 10 };
        assert_results_bitwise(
            &delta.top_k(&q).unwrap(),
            &scratch.top_k_exhaustive(&q).unwrap(),
            &format!("epoch {epoch} query"),
        );
        prev = delta;
    }
}

/// Mode growth (online ingestion) inside a delta chain: the grown
/// snapshot delta-copies only the new/extended tail of the grown mode,
/// reads bitwise like a from-scratch capture, and the pruned top-k ranks
/// the freshly grown rows exactly like the exhaustive oracle — including k
/// values that reach deep into the new tail.
#[test]
fn grown_mode_delta_chain_matches_scratch_and_prunes_exactly() {
    let mut m = signed_model(53, 6);
    let mut prev = ServingSnapshot::capture(&m, 1);
    m.clear_publish_dirty();

    // epoch 2: ingestion grew mode 0 from 167 to 257 rows — the old
    // partial tail block extends and new blocks appear; rows 0..128 (the
    // clean full blocks) must ride along shared
    m.grow_mode(0, 257, 53);
    let delta = ServingSnapshot::capture_delta(&m, 2, &prev);
    m.clear_publish_dirty();
    let scratch = ServingSnapshot::capture(&m, 2);
    assert_snapshots_bitwise(&delta, &scratch, "growth epoch");
    let st = delta.stats();
    assert_eq!(st.rows_copied + st.rows_shared, 257 + 80 + 40, "accounting");
    assert_eq!(
        st.rows_copied,
        257 - 128,
        "only the extended tail of the grown mode recopies"
    );
    for k in [1usize, 64, 170, 200, 257, 300] {
        let q = TopKQuery { mode: 0, fixed: vec![7, 13], k };
        assert_results_bitwise(
            &delta.top_k(&q).unwrap(),
            &scratch.top_k_exhaustive(&q).unwrap(),
            &format!("grown mode k={k}"),
        );
    }

    // epoch 3: nothing touched after the growth — everything shares,
    // at the new shape
    prev = delta;
    let quiet = ServingSnapshot::capture_delta(&m, 3, &prev);
    m.clear_publish_dirty();
    assert_snapshots_bitwise(
        &quiet,
        &ServingSnapshot::capture(&m, 3),
        "post-growth no-op",
    );
    assert_eq!(quiet.stats().rows_copied, 0, "no-op after growth shares all");

    // epoch 4: two modes grow at once, one by a single row
    prev = quiet;
    m.grow_mode(1, 110, 53);
    m.grow_mode(2, 41, 53);
    let delta2 = ServingSnapshot::capture_delta(&m, 4, &prev);
    m.clear_publish_dirty();
    let scratch2 = ServingSnapshot::capture(&m, 4);
    assert_snapshots_bitwise(&delta2, &scratch2, "double growth");
    let st2 = delta2.stats();
    assert_eq!(st2.rows_copied + st2.rows_shared, 257 + 110 + 41);
    for mode in 1..3usize {
        let dims = [257usize, 110, 41];
        let mut fixed = Vec::new();
        for (n, &d) in dims.iter().enumerate() {
            if n != mode {
                fixed.push((d - 1) as u32); // fix at freshly grown rows
            }
        }
        let q = TopKQuery { mode, fixed, k: dims[mode] };
        assert_results_bitwise(
            &delta2.top_k(&q).unwrap(),
            &scratch2.top_k_exhaustive(&q).unwrap(),
            &format!("double growth mode {mode}"),
        );
    }
}
