//! Shared helpers for the batched-sink multiset tests (`engine_parity.rs`
//! fixtures, `property_tests.rs` random tensors): collect every
//! `(group coords, update row, value bits)` triple a storage streams and
//! derive the ground-truth multiset independently from the raw COO
//! elements, so both suites pin the exact same sink contract.

use fastertucker::algo::engine::{BlockSink, SparseStorage};
use fastertucker::tensor::coo::CooTensor;

/// One streamed non-zero: `(chain-mode coords, update-mode row, value
/// bits)` — bits, not floats, so exactness is total-ordered and sortable.
pub type Triple = (Vec<u32>, u32, u32);

/// Sink that re-expands batched leaf runs one element at a time, pairing
/// each with the coordinates of the most recent group announcement, and
/// asserts the run-shape contract (no empty runs, no run before a group).
pub struct Collect {
    cur: Vec<u32>,
    pub triples: Vec<Triple>,
}

impl BlockSink for Collect {
    fn group(&mut self, coords: &[u32]) {
        self.cur.clear();
        self.cur.extend_from_slice(coords);
    }
    fn leaves(&mut self, rows: &[u32], vals: &[f32]) {
        assert_eq!(rows.len(), vals.len());
        assert!(!rows.is_empty(), "empty leaf run");
        assert!(!self.cur.is_empty(), "leaf run before any group");
        for (&i, &x) in rows.iter().zip(vals.iter()) {
            self.triples.push((self.cur.clone(), i, x.to_bits()));
        }
    }
}

/// Every triple the storage streams for mode `n`, sorted.
pub fn stream<St: SparseStorage>(s: &St, n: usize) -> Vec<Triple> {
    let mut c = Collect { cur: Vec::new(), triples: Vec::new() };
    for b in 0..s.num_blocks(n) {
        s.drive_block(n, b, &mut c);
    }
    c.triples.sort();
    c.triples
}

/// Ground truth from the raw COO elements: chain coords in `modes` order +
/// update row + value bits, sorted. (For CSF-backed storages pass the
/// deduplicated `csf.to_coo()` tensor.)
pub fn ground_truth(coo: &CooTensor, modes: &[usize], n: usize) -> Vec<Triple> {
    let mut v: Vec<Triple> = (0..coo.nnz())
        .map(|e| {
            let c = coo.index(e);
            (
                modes.iter().map(|&m| c[m]).collect(),
                c[n],
                coo.value(e).to_bits(),
            )
        })
        .collect();
    v.sort();
    v
}
