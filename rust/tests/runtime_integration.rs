//! PJRT runtime integration tests — require `make artifacts` (they
//! self-skip when `artifacts/manifest.json` is absent so `cargo test` stays
//! green on a fresh checkout).

use fastertucker::algo::Algo;
use fastertucker::config::{Compute, TrainConfig};
use fastertucker::coordinator::Session;
use fastertucker::data::split::train_test;
use fastertucker::data::synthetic::{recommender, RecommenderSpec};
use fastertucker::linalg::Matrix;
use fastertucker::runtime::PjrtRuntime;
use fastertucker::util::rng::Rng;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    // tests run from the crate root
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir.to_path_buf())
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn matmul_artifact_matches_rust_gemm() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir).unwrap();
    let mut rng = Rng::new(1);
    for (rows, j, r) in [(10usize, 32usize, 32usize), (1000, 32, 32), (1024, 32, 32)] {
        let a = Matrix::uniform(rows, j, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(j, r, -1.0, 1.0, &mut rng);
        let got = rt.matmul(&a, &b).unwrap();
        let want = a.matmul(&b);
        assert_eq!(got.rows(), rows);
        assert!(
            got.max_abs_diff(&want) < 1e-3,
            "({rows},{j},{r}): diff {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn predict_artifact_matches_rust_chain() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir).unwrap();
    let mut rng = Rng::new(2);
    // batch above the artifact size forces the chunked path
    for batch in [5usize, 8192, 9000] {
        let crows: Vec<Matrix> = (0..3)
            .map(|_| Matrix::uniform(batch, 32, -1.0, 1.0, &mut rng))
            .collect();
        let got = rt.predict_batch(&crows).unwrap();
        assert_eq!(got.len(), batch);
        for e in (0..batch).step_by((batch / 7).max(1)) {
            let mut want = 0.0f32;
            for rr in 0..32 {
                want += crows[0].get(e, rr) * crows[1].get(e, rr) * crows[2].get(e, rr);
            }
            assert!(
                (got[e] - want).abs() < 1e-3 * (1.0 + want.abs()),
                "batch {batch} elem {e}: {} vs {want}",
                got[e]
            );
        }
    }
}

#[test]
fn core_grad_artifact_matches_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir).unwrap();
    let mut rng = Rng::new(3);
    for batch in [100usize, 8192, 10000] {
        let ea = Matrix::uniform(batch, 32, -1.0, 1.0, &mut rng);
        let v = Matrix::uniform(batch, 32, -1.0, 1.0, &mut rng);
        let got = rt.core_grad(&ea, &v).unwrap();
        // reference: eaᵀ @ v
        let want = ea.transpose().matmul(&v);
        let denom = (batch as f32).sqrt();
        assert!(
            got.max_abs_diff(&want) / denom < 1e-3,
            "batch {batch}: diff {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn training_with_pjrt_matches_rust_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let t = recommender(&RecommenderSpec::tiny(), 21);
    let (train, test) = train_test(&t, 0.1, 1);
    let mk_cfg = |compute| TrainConfig {
        order: 3,
        dims: train.dims().to_vec(),
        j: 32,
        r: 32,
        lr_a: 0.01,
        lr_b: 1e-4,
        workers: 1,
        compute,
        ..TrainConfig::default()
    };
    let mut rust_sess = Session::new(Algo::FasterTucker, mk_cfg(Compute::Rust), &train).unwrap();
    let rust_report = rust_sess.run(3, Some(&test));

    let rt = PjrtRuntime::load(&dir).unwrap();
    let mut pjrt_sess = Session::new(Algo::FasterTucker, mk_cfg(Compute::Pjrt), &train)
        .unwrap()
        .with_runtime(rt);
    assert!(pjrt_sess.pjrt_active());
    let pjrt_report = pjrt_sess.run(3, Some(&test));

    // identical algorithm, different dense-kernel engine: convergence series
    // must agree to float tolerance
    for (a, b) in rust_report
        .convergence
        .records
        .iter()
        .zip(pjrt_report.convergence.records.iter())
    {
        assert!(
            (a.rmse - b.rmse).abs() < 5e-3,
            "epoch {}: rust {} vs pjrt {}",
            a.epoch,
            a.rmse,
            b.rmse
        );
    }
}

#[test]
fn runtime_rejects_missing_artifact_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir).unwrap();
    let mut rng = Rng::new(4);
    // J=7 is not in the artifact catalogue
    let a = Matrix::uniform(10, 7, -1.0, 1.0, &mut rng);
    let b = Matrix::uniform(7, 7, -1.0, 1.0, &mut rng);
    assert!(rt.matmul(&a, &b).is_err());
}
