//! Adaptive-scheduling fairness and parity properties.
//!
//! Three property families over the two-level scheduler:
//!
//! 1. **No tenant starved** — under randomized multi-tenant lease
//!    schedules against one shared [`Executor`], every blocking
//!    acquisition is eventually granted (FIFO tickets: no deadlock, no
//!    starvation) and no ticket is left stranded.
//! 2. **Fairness floor** — after QoS lease rebalancing, every tenant's
//!    lease is at least the (budget-clamped) fairness floor, and the
//!    leases tile the whole worker budget whenever it is large enough.
//! 3. **Static ≡ stealing parity** — on a commuting fixture (diagonal
//!    tensor: every nnz owns its factor rows in every mode, blocks hold a
//!    single nnz so per-block gradient partials are exact), whole training
//!    epochs under the stealing scheduler are *bitwise* identical to the
//!    serial static path at every worker count 1..=8, and static factor
//!    passes agree at every worker count too.

use fastertucker::algo::Algo;
use fastertucker::config::{SchedMode, TrainConfig};
use fastertucker::coordinator::{
    QosPolicy, Session, SessionModel, SessionRegistry,
};
use fastertucker::data::synthetic::{recommender, RecommenderSpec};
use fastertucker::model::ModelState;
use fastertucker::sched::Executor;
use fastertucker::tensor::coo::CooTensor;
use fastertucker::util::proptest::{run, Gen};
use std::sync::atomic::{AtomicUsize, Ordering};

fn cfg_for(t: &CooTensor) -> TrainConfig {
    TrainConfig {
        order: t.order(),
        dims: t.dims().to_vec(),
        j: 8,
        r: 4,
        lr_a: 0.01,
        lr_b: 1e-4,
        workers: 1,
        block_nnz: 512,
        fiber_threshold: 32,
        eval_sample_nnz: 0,
        ..TrainConfig::default()
    }
}

fn fast(s: &Session) -> &ModelState {
    match &s.model {
        SessionModel::Fast(m) => m,
        SessionModel::Full(_) => panic!("expected fast model"),
    }
}

fn assert_bitwise_same(a: &ModelState, b: &ModelState, what: &str) {
    for n in 0..a.order() {
        assert_eq!(
            a.factors[n].max_abs_diff(&b.factors[n]),
            0.0,
            "{what}: factor mode {n} diverged"
        );
        assert_eq!(
            a.cores[n].max_abs_diff(&b.cores[n]),
            0.0,
            "{what}: core mode {n} diverged"
        );
        assert_eq!(
            a.c_tables[n].max_abs_diff(&b.c_tables[n]),
            0.0,
            "{what}: C table mode {n} diverged"
        );
    }
}

/// Property 1: with randomized budgets, tenant counts, lease sizes, and
/// pass counts, every blocking leased pass completes — the FIFO admission
/// line cannot starve or deadlock any tenant — and the line drains fully.
#[test]
fn no_tenant_is_starved_under_randomized_lease_schedules() {
    run("every blocking acquisition is eventually granted", 8, |g| {
        let workers = g.usize_in(1, 5);
        let ex = Executor::new(workers);
        let tenants = g.usize_in(2, 5);
        let passes = g.usize_in(1, 4);
        let leases: Vec<usize> =
            (0..tenants).map(|_| g.usize_in(1, workers + 1)).collect();
        let executed = AtomicUsize::new(0);
        let (ex_ref, done_ref) = (&ex, &executed);
        std::thread::scope(|scope| {
            for &n in &leases {
                scope.spawn(move || {
                    for _ in 0..passes {
                        ex_ref.run_quiet_leased(n, |_w| {
                            done_ref.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(executed.load(Ordering::Relaxed), tenants * passes);
        assert_eq!(ex_ref.passes_executed(), tenants * passes);
        assert_eq!(ex_ref.pending_tickets(), 0, "no ticket left stranded");
    });
}

/// Property 2: after adaptive rebalancing, every tenant's lease is at
/// least the budget-clamped fairness floor; when the budget can cover the
/// floor for everyone, the leases tile the whole budget (work-conserving),
/// otherwise everyone degrades to the same minimal lease.
#[test]
fn adaptive_leases_stay_within_floor_and_budget() {
    let t = recommender(&RecommenderSpec::tiny(), 71);
    run("rebalanced leases respect the fairness floor", 6, |g| {
        let workers = g.usize_in(1, 6);
        let floor = g.usize_in(1, 4);
        let tenants = g.usize_in(2, 4);
        let mut reg = SessionRegistry::new(workers, 0);
        let names: Vec<String> = (0..tenants).map(|i| format!("t{i}")).collect();
        for name in &names {
            reg.open(name, Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        }
        reg.set_qos_policy(Some(QosPolicy {
            fairness_floor: floor,
            max_pending: usize::MAX,
        }));
        for _ in 0..g.usize_in(1, 4) {
            let who = g.usize_in(0, tenants);
            reg.step(&names[who], None).unwrap();
        }
        let budget = reg.executor().workers();
        let clamped = floor.min((budget / tenants).max(1));
        let leases: Vec<usize> = names
            .iter()
            .map(|n| {
                reg.get(n).unwrap().lease_workers().expect("policy sets a lease")
            })
            .collect();
        assert!(
            leases.iter().all(|&n| n >= clamped),
            "leases {leases:?} dip below the clamped floor {clamped}"
        );
        if clamped * tenants <= budget {
            assert_eq!(
                leases.iter().sum::<usize>(),
                budget,
                "leases {leases:?} must tile the {budget}-worker budget"
            );
        } else {
            assert!(
                leases.iter().all(|&n| n == clamped),
                "oversubscribed budget degrades to an equal split, got {leases:?}"
            );
        }
    });
}

/// Property 3: bitwise static ≡ stealing parity at 1..=8 workers. The
/// fixture makes both update disciplines commute exactly:
///
/// * diagonal tensor — every nnz `(i,i,i)` owns factor row `i` in every
///   mode, so Hogwild factor updates touch disjoint rows and the chain
///   reads only frozen other-mode state;
/// * `block_nnz = 1` — each block holds one nnz, so a per-block core
///   partial is the exact single contribution and the stealing core
///   pass's canonical ascending-block fold reproduces the serial
///   accumulation bit-for-bit.
///
/// Under that fixture, whole epochs (factor + core) under `--sched
/// stealing` must equal the serial static reference at every worker
/// count, and static factor passes must as well.
#[test]
fn stealing_matches_static_serial_bitwise_on_commuting_fixture() {
    run("static≡stealing parity at 1..=8 workers", 4, |g| {
        let d = g.usize_in(6, 24);
        let mut t = CooTensor::new(vec![d, d, d]);
        for i in 0..d {
            let i = i as u32;
            t.push(&[i, i, i], g.f32_in(0.5, 5.0));
        }
        let cfg = |workers: usize, sched: SchedMode| TrainConfig {
            order: 3,
            dims: vec![d, d, d],
            j: 4,
            r: 2,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers,
            block_nnz: 1, // single-nnz blocks: per-block partials are exact
            fiber_threshold: 32,
            eval_sample_nnz: 0,
            sched,
            seed: 99,
            ..TrainConfig::default()
        };

        // serial static reference: two full epochs
        let mut reference =
            Session::new(Algo::FasterTuckerCoo, cfg(1, SchedMode::Static), &t)
                .unwrap();
        reference.epoch();
        reference.epoch();

        // serial static reference for factor-only passes
        let mut factor_ref =
            Session::new(Algo::FasterTuckerCoo, cfg(1, SchedMode::Static), &t)
                .unwrap();
        factor_ref.factor_pass();
        factor_ref.factor_pass();

        for workers in 1..=8usize {
            let mut steal = Session::new(
                Algo::FasterTuckerCoo,
                cfg(workers, SchedMode::Stealing),
                &t,
            )
            .unwrap();
            steal.epoch();
            steal.epoch();
            assert_bitwise_same(
                fast(&reference),
                fast(&steal),
                &format!("stealing at {workers} workers vs serial static"),
            );

            let mut stat = Session::new(
                Algo::FasterTuckerCoo,
                cfg(workers, SchedMode::Static),
                &t,
            )
            .unwrap();
            stat.factor_pass();
            stat.factor_pass();
            assert_bitwise_same(
                fast(&factor_ref),
                fast(&stat),
                &format!("static factor passes at {workers} workers"),
            );
        }
    });
}

/// Memory-hierarchy parity on the commuting fixture: node-compact
/// placement over a forced synthetic 2-node topology plus the tiny-tile
/// prefetched leaf loop must leave every bit unchanged. Diagonal tensors
/// (orders 3 and 4) with single-nnz blocks make multi-worker updates
/// commute exactly, so whole stealing epochs and static factor passes at
/// 1/2/3/8 workers — workers pinned across both synthetic nodes, reading
/// their node's operand replica through tiles of 3 nnz — are compared
/// bitwise against the untiled, topology-blind serial static reference.
#[test]
fn numa_pinned_tiled_execution_matches_blind_serial_bitwise() {
    use fastertucker::config::NumaMode;

    run("numa+tiling parity at 1/2/3/8 workers", 3, |g| {
        for order in [3usize, 4] {
            let d = g.usize_in(6, 16);
            let mut t = CooTensor::new(vec![d; order]);
            for i in 0..d {
                let coords = vec![i as u32; order];
                t.push(&coords, g.f32_in(0.5, 5.0));
            }
            let cfg = |workers: usize,
                       sched: SchedMode,
                       numa: NumaMode,
                       tile_nnz: usize| TrainConfig {
                order,
                dims: vec![d; order],
                j: 4,
                r: 2,
                lr_a: 0.01,
                lr_b: 1e-4,
                workers,
                block_nnz: 1, // single-nnz blocks: per-block partials exact
                fiber_threshold: 32,
                eval_sample_nnz: 0,
                sched,
                numa,
                tile_nnz,
                seed: 99,
                ..TrainConfig::default()
            };

            // untiled topology-blind serial static references
            let blind =
                cfg(1, SchedMode::Static, NumaMode::Off, usize::MAX);
            let mut reference =
                Session::new(Algo::FasterTuckerCoo, blind.clone(), &t).unwrap();
            reference.epoch();
            reference.epoch();
            let mut factor_ref =
                Session::new(Algo::FasterTuckerCoo, blind, &t).unwrap();
            factor_ref.factor_pass();
            factor_ref.factor_pass();

            for workers in [1usize, 2, 3, 8] {
                let mut steal = Session::new(
                    Algo::FasterTuckerCoo,
                    cfg(workers, SchedMode::Stealing, NumaMode::Force(2), 3),
                    &t,
                )
                .unwrap();
                steal.epoch();
                steal.epoch();
                assert_bitwise_same(
                    fast(&reference),
                    fast(&steal),
                    &format!(
                        "order {order}: tiled stealing on 2 nodes at \
                         {workers} workers vs blind serial"
                    ),
                );

                let mut stat = Session::new(
                    Algo::FasterTuckerCoo,
                    cfg(workers, SchedMode::Static, NumaMode::Force(2), 3),
                    &t,
                )
                .unwrap();
                stat.factor_pass();
                stat.factor_pass();
                assert_bitwise_same(
                    fast(&factor_ref),
                    fast(&stat),
                    &format!(
                        "order {order}: tiled static factor passes on 2 \
                         nodes at {workers} workers vs blind serial"
                    ),
                );
            }
        }
    });
}

/// The stealing scheduler trains, not just schedules: a short multi-worker
/// stealing run on synthetic recommender data must reduce RMSE.
#[test]
fn stealing_training_converges_on_synthetic_data() {
    let t = recommender(&RecommenderSpec::tiny(), 73);
    let mut cfg = cfg_for(&t);
    cfg.workers = 2;
    cfg.sched = SchedMode::Stealing;
    let mut s = Session::new(Algo::FasterTucker, cfg, &t).unwrap();
    let report = s.run(3, None);
    assert!(report.convergence.improved(), "stealing run must reduce RMSE");
}
