//! Property harness for the dirty-row incremental `C^(n)` refresh.
//!
//! The claim under test is *bitwise exactness*: because each C row is a
//! pure function of its factor row, and the per-row kernel
//! (`Matrix::matmul_row_into`) replays `matmul_into`'s exact accumulation
//! order, an incremental refresh — serial or executor-parallel at any
//! worker count — can never drift from a full-table recompute. Not
//! "close": equal to the bit.
//!
//! `tests/engine_parity.rs` pins the same property through whole training
//! sessions; this harness attacks the refresh primitive directly with
//! randomized perturb→mark→refresh sequences and word-boundary shapes.

use fastertucker::config::TrainConfig;
use fastertucker::model::ModelState;
use fastertucker::sched::Executor;
use fastertucker::util::rng::Rng;

fn cfg(dims: Vec<usize>, j: usize, r: usize) -> TrainConfig {
    TrainConfig { order: dims.len(), dims, j, r, ..TrainConfig::default() }
}

/// Randomized rounds: perturb a random (possibly empty) subset of factor
/// rows of a random mode, mark exactly those rows dirty, refresh
/// incrementally — serial and through executors of several widths — and
/// demand every C table stays bitwise equal to a clone that full-refreshes
/// after the identical perturbations.
#[test]
fn randomized_incremental_refresh_sequences_are_bitwise_full_recomputes() {
    let c = cfg(vec![257, 130, 64], 9, 7);
    let mut inc = ModelState::init(&c, 11);
    let mut par2 = inc.clone();
    let mut par5 = inc.clone();
    let mut full = inc.clone();
    let ex2 = Executor::new(2);
    let ex5 = Executor::new(5);
    let mut rng = Rng::new(4242);
    for round in 0..12usize {
        let n = rng.next_below(3);
        let rows = inc.factors[n].rows();
        // same randomized edits applied to every model
        let touches = rng.next_below(rows / 4 + 1);
        let mut edits = Vec::new();
        for _ in 0..touches {
            let i = rng.next_below(rows);
            let k = rng.next_below(c.j);
            edits.push((i, k, rng.uniform_f32(-0.5, 0.5)));
        }
        for m in [&mut inc, &mut par2, &mut par5, &mut full] {
            for &(i, k, dv) in &edits {
                m.factors[n].row_mut(i)[k] += dv;
            }
        }
        for (m, pool) in
            [(&mut inc, None), (&mut par2, Some(&ex2)), (&mut par5, Some(&ex5))]
        {
            m.dirty[n].ensure(rows);
            for &(i, _, _) in &edits {
                m.dirty[n].mark(i);
            }
            // every fifth round exercises the mark_all fallback too
            if round % 5 == 4 {
                m.dirty[n].mark_all();
            }
            m.refresh_c_dirty(n, pool);
            assert!(!m.dirty[n].any(), "refresh must clear the dirty set");
        }
        full.refresh_c(n);
        for mode in 0..3 {
            for (what, m) in
                [("serial", &inc), ("2-worker", &par2), ("5-worker", &par5)]
            {
                assert_eq!(
                    m.c_tables[mode].max_abs_diff(&full.c_tables[mode]),
                    0.0,
                    "round {round}, mode {mode}: {what} incremental refresh \
                     drifted from the full recompute"
                );
            }
        }
    }
}

/// Word-boundary shapes: the parallel refresh splits the table on 64-row
/// (one-bitset-word) boundaries, so row counts at and around multiples of
/// 64 — including a table smaller than one word — must all land exactly.
#[test]
fn word_boundary_shapes_refresh_exactly() {
    for rows in [1usize, 63, 64, 65, 129] {
        let c = cfg(vec![rows, 7, 5], 4, 3);
        let mut m = ModelState::init(&c, 3);
        let mut full = m.clone();
        let touched = if rows == 1 { vec![0] } else { vec![0, rows - 1] };
        for &i in &touched {
            m.factors[0].row_mut(i)[0] += 0.25;
            full.factors[0].row_mut(i)[0] += 0.25;
        }
        m.dirty[0].ensure(rows);
        for &i in &touched {
            m.dirty[0].mark(i);
        }
        let pool = Executor::new(8);
        m.refresh_c_dirty(0, Some(&pool));
        full.refresh_c(0);
        assert_eq!(
            m.c_tables[0].max_abs_diff(&full.c_tables[0]),
            0.0,
            "rows {rows}: word-boundary refresh drifted"
        );
    }
}
