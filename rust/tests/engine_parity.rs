//! Engine parity suite: every `(storage × chain × target)` instantiation of
//! the generic epoch engine must reproduce the pre-refactor hand-written
//! hot loops **bit-for-bit** on one worker.
//!
//! The reference implementations below are frozen copies of the seed's four
//! epoch loops (COO FastTucker, COO FasterTucker, B-CSF no-share ablation,
//! full B-CSF FasterTucker — factor and core each), expressed through the
//! same public kernel primitives (`grad::*`, `RacyMatrix`) so both sides
//! execute the identical sequence of f32 operations. Any reordering or
//! dropped term in the engine shows up as a non-zero max-abs-diff.
//!
//! Coverage: tensor order ∈ {3, 4}, two epochs of interleaved
//! factor + core updates (so refreshed `C` tables feed back), exact
//! equality (`max_abs_diff == 0.0`) on factors, cores, and `C` tables.

use fastertucker::algo::fastertucker::{
    core_epoch_bcsf, core_epoch_bcsf_noshare, core_epoch_coo, factor_epoch_bcsf,
    factor_epoch_bcsf_noshare, factor_epoch_coo, refresh_rust,
};
use fastertucker::algo::fastucker;
use fastertucker::algo::grad::{
    accumulate_core_grad, apply_core_grad, chain_v_from_tables, chain_v_on_the_fly,
    chain_v_prefix_cached, fiber_w, Scratch,
};
use fastertucker::config::TrainConfig;
use fastertucker::data::synthetic::{order_sweep, recommender, RecommenderSpec};
use fastertucker::linalg::{dot, Matrix};
use fastertucker::model::ModelState;
use fastertucker::sched::racy::RacyMatrix;
use fastertucker::tensor::bcsf::BcsfTensor;
use fastertucker::tensor::coo::CooTensor;
use fastertucker::util::ceil_div;

mod common;

// ------------------------------------------------------------------ fixtures

fn setup(order: usize) -> (ModelState, CooTensor, TrainConfig) {
    let t = match order {
        // power-law 3-order tensor: long fibers exercise sub-fiber splitting
        3 => recommender(&RecommenderSpec::tiny(), 33),
        // dense-ish 4-order tensor: ~3 nnz per fiber exercises sharing
        4 => order_sweep(4, 8, 1500, 44),
        _ => unreachable!("parity suite covers orders 3 and 4"),
    };
    let cfg = TrainConfig {
        order,
        dims: t.dims().to_vec(),
        // j=6, r=5: not multiples of 4, so the unrolled dot/update remainders
        // are on the parity path too
        j: 6,
        r: 5,
        lr_a: 0.01,
        lr_b: 1e-4,
        workers: 1,
        block_nnz: 256,
        fiber_threshold: 16,
        ..TrainConfig::default()
    };
    let model = ModelState::init(&cfg, 7);
    (model, t, cfg)
}

fn build_bcsf(t: &CooTensor, cfg: &TrainConfig) -> Vec<BcsfTensor> {
    (0..t.order())
        .map(|n| BcsfTensor::build(t, n, cfg.fiber_threshold, cfg.block_nnz))
        .collect()
}

fn assert_identical(engine: &ModelState, reference: &ModelState, what: &str) {
    for n in 0..engine.order() {
        assert_eq!(
            engine.factors[n].max_abs_diff(&reference.factors[n]),
            0.0,
            "{what}: factor mode {n} diverged"
        );
        assert_eq!(
            engine.cores[n].max_abs_diff(&reference.cores[n]),
            0.0,
            "{what}: core mode {n} diverged"
        );
        assert_eq!(
            engine.c_tables[n].max_abs_diff(&reference.c_tables[n]),
            0.0,
            "{what}: C table mode {n} diverged"
        );
    }
}

// ------------------------------------------- frozen pre-refactor references

/// Seed `fastucker::factor_epoch` / `fastertucker::factor_epoch_coo`:
/// blocked COO traversal, per-element chain + `w`, Hogwild row SGD.
fn ref_factor_coo(
    model: &mut ModelState,
    data: &CooTensor,
    cfg: &TrainConfig,
    use_tables: bool,
) {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let nnz = data.nnz();
    let block = cfg.block_nnz.max(1);
    let num_blocks = ceil_div(nnz, block);
    let scale = 1.0 - cfg.lr_a * cfg.lambda_a;

    for n in 0..order {
        let modes: Vec<usize> = (0..order).filter(|&m| m != n).collect();
        let mut target =
            std::mem::replace(&mut model.factors[n], Matrix::zeros(0, 0));
        {
            let racy = RacyMatrix::new(&mut target);
            let mut s = Scratch::new(order, j, r);
            for b in 0..num_blocks {
                let lo = b * block;
                let hi = (lo + block).min(nnz);
                for e in lo..hi {
                    let coords = data.index(e);
                    let x = data.value(e);
                    s.sub.clear();
                    s.sub.extend(modes.iter().map(|&m| coords[m]));
                    if use_tables {
                        chain_v_from_tables(&model.c_tables, &modes, &s.sub, &mut s.v);
                    } else {
                        chain_v_on_the_fly(
                            &model.factors,
                            &model.cores,
                            &modes,
                            &s.sub,
                            &mut s.v,
                        );
                    }
                    fiber_w(&model.cores[n], &s.v, &mut s.w);
                    let i = coords[n] as usize;
                    let e_val = x - racy.row_dot(i, &s.w);
                    racy.row_sgd_update(i, scale, cfg.lr_a * e_val, &s.w);
                }
            }
        }
        model.factors[n] = target;
        if use_tables {
            model.refresh_c(n);
        }
    }
}

/// Seed `fastucker::core_epoch` / `fastertucker::core_epoch_coo`.
fn ref_core_coo(
    model: &mut ModelState,
    data: &CooTensor,
    cfg: &TrainConfig,
    use_tables: bool,
) {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let nnz = data.nnz();
    let block = cfg.block_nnz.max(1);
    let num_blocks = ceil_div(nnz, block);

    for n in 0..order {
        let modes: Vec<usize> = (0..order).filter(|&m| m != n).collect();
        let mut s = Scratch::new(order, j, r);
        for b in 0..num_blocks {
            let lo = b * block;
            let hi = (lo + block).min(nnz);
            for e in lo..hi {
                let coords = data.index(e);
                let x = data.value(e);
                s.sub.clear();
                s.sub.extend(modes.iter().map(|&m| coords[m]));
                if use_tables {
                    chain_v_from_tables(&model.c_tables, &modes, &s.sub, &mut s.v);
                } else {
                    chain_v_on_the_fly(
                        &model.factors,
                        &model.cores,
                        &modes,
                        &s.sub,
                        &mut s.v,
                    );
                }
                fiber_w(&model.cores[n], &s.v, &mut s.w);
                let a = model.factors[n].row(coords[n] as usize);
                let xhat = dot(a, &s.w);
                accumulate_core_grad(&mut s.grad, x - xhat, &s.v, a);
            }
        }
        apply_core_grad(&mut model.cores[n], &s.grad, nnz, cfg.lr_b, cfg.lambda_b);
        if use_tables {
            model.refresh_c(n);
        }
    }
}

/// Seed `fastertucker::factor_epoch_bcsf`: fiber-shared `v`/`w`, prefix
/// cache reset per block.
fn ref_factor_bcsf_shared(model: &mut ModelState, bcsf: &[BcsfTensor], cfg: &TrainConfig) {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let scale = 1.0 - cfg.lr_a * cfg.lambda_a;

    for n in 0..order {
        let t = &bcsf[n];
        let internal = &t.csf.mode_order[..order - 1];
        let mut target =
            std::mem::replace(&mut model.factors[n], Matrix::zeros(0, 0));
        {
            let racy = RacyMatrix::new(&mut target);
            let mut s = Scratch::new(order, j, r);
            for blk in 0..t.num_blocks() {
                s.reset_prefix();
                let mut prev_fiber = u32::MAX;
                let mut first = true;
                for task in t.block_tasks(blk) {
                    if first || task.fiber != prev_fiber {
                        chain_v_prefix_cached(
                            &model.c_tables,
                            internal,
                            t.fiber_path(task.fiber),
                            &mut s,
                        );
                        fiber_w(&model.cores[n], &s.v, &mut s.w);
                        prev_fiber = task.fiber;
                        first = false;
                    }
                    let (leaf_idx, leaf_vals) = t.task_leaves(task);
                    for (k, &i) in leaf_idx.iter().enumerate() {
                        let i = i as usize;
                        let e_val = leaf_vals[k] - racy.row_dot(i, &s.w);
                        racy.row_sgd_update(i, scale, cfg.lr_a * e_val, &s.w);
                    }
                }
            }
        }
        model.factors[n] = target;
        model.refresh_c(n);
    }
}

/// Seed `fastertucker::factor_epoch_bcsf_noshare`: B-CSF traversal order,
/// per-element recomputation.
fn ref_factor_bcsf_noshare(model: &mut ModelState, bcsf: &[BcsfTensor], cfg: &TrainConfig) {
    let order = model.order();
    let (j, r) = (model.j(), model.r());
    let scale = 1.0 - cfg.lr_a * cfg.lambda_a;

    for n in 0..order {
        let t = &bcsf[n];
        let internal = &t.csf.mode_order[..order - 1];
        let mut target =
            std::mem::replace(&mut model.factors[n], Matrix::zeros(0, 0));
        {
            let racy = RacyMatrix::new(&mut target);
            let mut s = Scratch::new(order, j, r);
            for blk in 0..t.num_blocks() {
                for task in t.block_tasks(blk) {
                    let path = t.fiber_path(task.fiber);
                    let (leaf_idx, leaf_vals) = t.task_leaves(task);
                    for (k, &i) in leaf_idx.iter().enumerate() {
                        chain_v_from_tables(&model.c_tables, internal, path, &mut s.v);
                        fiber_w(&model.cores[n], &s.v, &mut s.w);
                        let i = i as usize;
                        let e_val = leaf_vals[k] - racy.row_dot(i, &s.w);
                        racy.row_sgd_update(i, scale, cfg.lr_a * e_val, &s.w);
                    }
                }
            }
        }
        model.factors[n] = target;
        model.refresh_c(n);
    }
}

/// Seed `fastertucker::core_epoch_bcsf` (shared) /
/// `core_epoch_bcsf_noshare` (per-element).
fn ref_core_bcsf(
    model: &mut ModelState,
    bcsf: &[BcsfTensor],
    cfg: &TrainConfig,
    share: bool,
) {
    let order = model.order();
    let (j, r) = (model.j(), model.r());

    for n in 0..order {
        let t = &bcsf[n];
        let internal = &t.csf.mode_order[..order - 1];
        let nnz = t.nnz();
        let mut s = Scratch::new(order, j, r);
        for blk in 0..t.num_blocks() {
            s.reset_prefix();
            let mut prev_fiber = u32::MAX;
            let mut first = true;
            for task in t.block_tasks(blk) {
                if share {
                    if first || task.fiber != prev_fiber {
                        chain_v_prefix_cached(
                            &model.c_tables,
                            internal,
                            t.fiber_path(task.fiber),
                            &mut s,
                        );
                        fiber_w(&model.cores[n], &s.v, &mut s.w);
                        prev_fiber = task.fiber;
                        first = false;
                    }
                }
                let path = t.fiber_path(task.fiber);
                let (leaf_idx, leaf_vals) = t.task_leaves(task);
                for (k, &i) in leaf_idx.iter().enumerate() {
                    if !share {
                        chain_v_from_tables(&model.c_tables, internal, path, &mut s.v);
                        fiber_w(&model.cores[n], &s.v, &mut s.w);
                    }
                    let a = model.factors[n].row(i as usize);
                    let xhat = dot(a, &s.w);
                    accumulate_core_grad(&mut s.grad, leaf_vals[k] - xhat, &s.v, a);
                }
            }
        }
        apply_core_grad(&mut model.cores[n], &s.grad, nnz, cfg.lr_b, cfg.lambda_b);
        model.refresh_c(n);
    }
}

// ------------------------------------------------------------------- parity

const EPOCHS: usize = 2;

#[test]
fn parity_fastucker_coo_factor_and_core() {
    for order in [3usize, 4] {
        let (m0, t, cfg) = setup(order);
        let mut m_engine = m0.clone();
        let mut m_ref = m0;
        for _ in 0..EPOCHS {
            fastucker::factor_epoch(&mut m_engine, &t, &cfg);
            fastucker::core_epoch(&mut m_engine, &t, &cfg);
            ref_factor_coo(&mut m_ref, &t, &cfg, false);
            ref_core_coo(&mut m_ref, &t, &cfg, false);
        }
        assert_identical(&m_engine, &m_ref, &format!("fastucker order {order}"));
    }
}

#[test]
fn parity_fastertucker_coo_factor_and_core() {
    for order in [3usize, 4] {
        let (m0, t, cfg) = setup(order);
        let mut m_engine = m0.clone();
        let mut m_ref = m0;
        for _ in 0..EPOCHS {
            factor_epoch_coo(&mut m_engine, &t, &cfg, &refresh_rust);
            core_epoch_coo(&mut m_engine, &t, &cfg, &refresh_rust);
            ref_factor_coo(&mut m_ref, &t, &cfg, true);
            ref_core_coo(&mut m_ref, &t, &cfg, true);
        }
        assert_identical(&m_engine, &m_ref, &format!("fastertucker-coo order {order}"));
    }
}

#[test]
fn parity_bcsf_noshare_factor_and_core() {
    for order in [3usize, 4] {
        let (m0, t, cfg) = setup(order);
        let bcsf = build_bcsf(&t, &cfg);
        let mut m_engine = m0.clone();
        let mut m_ref = m0;
        for _ in 0..EPOCHS {
            factor_epoch_bcsf_noshare(&mut m_engine, &bcsf, &cfg, &refresh_rust);
            core_epoch_bcsf_noshare(&mut m_engine, &bcsf, &cfg, &refresh_rust);
            ref_factor_bcsf_noshare(&mut m_ref, &bcsf, &cfg);
            ref_core_bcsf(&mut m_ref, &bcsf, &cfg, false);
        }
        assert_identical(&m_engine, &m_ref, &format!("bcsf-noshare order {order}"));
    }
}

#[test]
fn parity_bcsf_shared_factor_and_core() {
    for order in [3usize, 4] {
        let (m0, t, cfg) = setup(order);
        let bcsf = build_bcsf(&t, &cfg);
        let mut m_engine = m0.clone();
        let mut m_ref = m0;
        for _ in 0..EPOCHS {
            factor_epoch_bcsf(&mut m_engine, &bcsf, &cfg, &refresh_rust);
            core_epoch_bcsf(&mut m_engine, &bcsf, &cfg, &refresh_rust);
            ref_factor_bcsf_shared(&mut m_ref, &bcsf, &cfg);
            ref_core_bcsf(&mut m_ref, &bcsf, &cfg, true);
        }
        assert_identical(&m_engine, &m_ref, &format!("bcsf-shared order {order}"));
    }
}

/// The session's cached `PreparedStorage` dispatch must agree with the
/// named wrapper instantiations in `algo::fastertucker`/`algo::fastucker`
/// — the algo → (storage, chain) mapping exists in both places, and this
/// pins them together: one epoch driven through a `Session` (over the
/// owned, once-built storage) equals the same epoch driven through the
/// per-pass wrappers, exactly, for every engine-backed algorithm.
#[test]
fn session_dispatch_matches_direct_instantiations() {
    use fastertucker::algo::Algo;
    use fastertucker::coordinator::{Session, SessionModel};
    use fastertucker::util::rng::Rng;

    let (_, t, cfg) = setup(3);
    for algo in [
        Algo::FastTucker,
        Algo::FasterTuckerCoo,
        Algo::FasterTuckerBcsf,
        Algo::FasterTucker,
    ] {
        let mut session = Session::new(algo, cfg.clone(), &t).unwrap();
        session.factor_pass();
        session.core_pass();

        // Replicate the coordinator's data prep: the model seeded with
        // cfg.seed, the COO shuffled with the coordinator's documented
        // seed, B-CSF rotations built from the unshuffled input.
        let mut shuffled = t.clone();
        shuffled.shuffle(&mut Rng::new(cfg.seed ^ 0x5088));
        let mut m = ModelState::init(&cfg, cfg.seed);
        match algo {
            Algo::FastTucker => {
                fastucker::factor_epoch(&mut m, &shuffled, &cfg);
                fastucker::core_epoch(&mut m, &shuffled, &cfg);
            }
            Algo::FasterTuckerCoo => {
                factor_epoch_coo(&mut m, &shuffled, &cfg, &refresh_rust);
                core_epoch_coo(&mut m, &shuffled, &cfg, &refresh_rust);
            }
            Algo::FasterTuckerBcsf => {
                let bcsf = build_bcsf(&t, &cfg);
                factor_epoch_bcsf_noshare(&mut m, &bcsf, &cfg, &refresh_rust);
                core_epoch_bcsf_noshare(&mut m, &bcsf, &cfg, &refresh_rust);
            }
            Algo::FasterTucker => {
                let bcsf = build_bcsf(&t, &cfg);
                factor_epoch_bcsf(&mut m, &bcsf, &cfg, &refresh_rust);
                core_epoch_bcsf(&mut m, &bcsf, &cfg, &refresh_rust);
            }
            _ => unreachable!(),
        }
        let tm = match &session.model {
            SessionModel::Fast(tm) => tm,
            SessionModel::Full(_) => unreachable!(),
        };
        // FastTucker leaves C tables stale in both paths until the epoch
        // wrapper syncs them, so compare the trained parameters only.
        for n in 0..3 {
            assert_eq!(
                tm.factors[n].max_abs_diff(&m.factors[n]),
                0.0,
                "{algo:?}: session vs wrapper factor {n}"
            );
            assert_eq!(
                tm.cores[n].max_abs_diff(&m.cores[n]),
                0.0,
                "{algo:?}: session vs wrapper core {n}"
            );
        }
    }
}

/// The batched leaf streams must cover exactly the element multiset the
/// old per-leaf stream delivered: one `(chain coords, update row, value)`
/// triple per stored non-zero, with the group announced before its runs.
/// The ground truth is derived independently from the raw COO elements
/// (deduplicated through CSF for the B-CSF layouts), so a batching bug
/// that dropped a run, duplicated a slice boundary, or mispaired groups
/// and leaves cannot cancel out.
#[test]
fn batched_stream_covers_exact_element_multiset() {
    use common::{ground_truth, stream};
    use fastertucker::algo::engine::SparseStorage;
    use fastertucker::tensor::bcsf::{BcsfPerElement, BcsfShared};
    use fastertucker::tensor::coo::CooBlocks;

    for order in [3usize, 4] {
        let (_, t, cfg) = setup(order);
        let coo_blocks = CooBlocks::new(&t, cfg.block_nnz);
        for n in 0..order {
            assert_eq!(
                stream(&coo_blocks, n),
                ground_truth(&t, coo_blocks.chain_modes(n), n),
                "coo order {order} mode {n}"
            );
        }
        let bcsf = build_bcsf(&t, &cfg);
        let shared = BcsfShared::new(&bcsf);
        let per_elem = BcsfPerElement::new(&bcsf);
        for n in 0..order {
            // CSF merges duplicate coordinates by summation; compare against
            // the deduplicated element set it stores.
            let dedup = bcsf[n].csf.to_coo();
            let want = ground_truth(&dedup, shared.chain_modes(n), n);
            assert_eq!(stream(&shared, n), want, "bcsf-shared order {order} mode {n}");
            assert_eq!(
                stream(&per_elem, n),
                want,
                "bcsf-per-element order {order} mode {n}"
            );
        }
    }
}

/// The memory-hierarchy knobs must be numerically invisible: a session on
/// a forced synthetic 2-node topology with a deliberately tiny leaf tile
/// (7 nnz — every fiber run of the fixtures crosses several tile
/// boundaries, and prefetch issues on each) must reproduce the
/// topology-blind untiled session bit-for-bit, for every engine-backed
/// algorithm, orders 3 and 4, two interleaved factor+core epochs. Tiling
/// only chunks the existing leaf iteration order and the node replicas
/// are byte copies of the primary, so any divergence means the tiled loop
/// reordered a reduction or a replica went stale.
#[test]
fn tiled_replicated_session_is_bitwise_topology_blind() {
    use fastertucker::algo::Algo;
    use fastertucker::config::NumaMode;
    use fastertucker::coordinator::{Session, SessionModel};

    let fast = |s: &Session| -> ModelState {
        match &s.model {
            SessionModel::Fast(m) => m.clone(),
            SessionModel::Full(_) => unreachable!("engine algos use fast models"),
        }
    };
    for order in [3usize, 4] {
        let (_, t, base) = setup(order);
        for algo in [
            Algo::FastTucker,
            Algo::FasterTuckerCoo,
            Algo::FasterTuckerBcsf,
            Algo::FasterTucker,
        ] {
            let mut blind_cfg = base.clone();
            blind_cfg.numa = NumaMode::Off;
            blind_cfg.tile_nnz = usize::MAX;
            let mut aware_cfg = base.clone();
            aware_cfg.numa = NumaMode::Force(2);
            aware_cfg.tile_nnz = 7;

            let mut blind = Session::new(algo, blind_cfg, &t).unwrap();
            let mut aware = Session::new(algo, aware_cfg, &t).unwrap();
            for _ in 0..EPOCHS {
                blind.factor_pass();
                blind.core_pass();
                aware.factor_pass();
                aware.core_pass();
            }
            assert_identical(
                &fast(&aware),
                &fast(&blind),
                &format!("{algo:?} order {order} tiled+2-nodes vs blind"),
            );
        }
    }
}

/// Cross-check: the parity fixtures really exercise multi-block and
/// multi-task inputs (otherwise the prefix-reset and block-boundary logic
/// would be vacuously covered).
#[test]
fn parity_fixtures_are_nontrivial() {
    for order in [3usize, 4] {
        let (_, t, cfg) = setup(order);
        assert!(ceil_div(t.nnz(), cfg.block_nnz) > 1, "order {order}: one COO block");
        let bcsf = build_bcsf(&t, &cfg);
        for (n, b) in bcsf.iter().enumerate() {
            assert!(b.num_blocks() > 1, "order {order} mode {n}: one B-CSF block");
            assert!(
                b.tasks.len() > b.num_blocks(),
                "order {order} mode {n}: trivial task packing"
            );
        }
    }
}
