//! Hot-path allocation budget, pinned with a counting global allocator.
//!
//! The batched engine's claim is that after the first (warm-up) epoch the
//! epoch path performs **no data-proportional allocation**: scratch buffers
//! are pooled in the session's `EngineState`, chain-mode lists are cached
//! at prepare time, the COO walker uses a stack coordinate buffer, and the
//! rank-padded kernel operands and cached per-mode shard plans are
//! resynced/reused in place. What remains per pass is a small constant
//! number of bookkeeping allocations (the cloned run config's dims, the
//! per-pass `WorkerStats` vectors) — a handful per mode, independent of
//! nnz.
//!
//! Pre-rework, the per-block `sub` coordinate buffer alone cost one
//! allocation per COO block (~700 for this fixture), so the bound below
//! fails loudly if per-block or per-leaf allocation ever creeps back in.
//!
//! One test in this binary, so no unrelated test thread pollutes the
//! counter. The main scenarios run one worker inline (strictly
//! single-threaded measured region); a final two-worker scenario on a
//! forced 2-node topology pins the replication + tiling machinery to the
//! same zero-steady-state-allocation claim, with a bound that admits only
//! the constant thread-spawn bookkeeping.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use fastertucker::algo::Algo;
use fastertucker::config::{NumaMode, RefreshMode, TrainConfig};
use fastertucker::coordinator::Session;
use fastertucker::data::synthetic::order_sweep;

#[test]
fn epoch_path_allocations_are_constant_not_per_nnz() {
    // Big enough that any per-block (let alone per-leaf) allocation blows
    // the bound: ~120k nnz / 512-nnz blocks ≈ 235 blocks per mode pass.
    // Covering both refresh modes pins the dirty-set bookkeeping too: the
    // per-worker bitsets are grow-only (ensured during warm-up), marking is
    // a word OR, the pass-end merge unions in place, and the serial
    // incremental refresh recomputes rows into the existing table — none
    // of which may allocate per row, per block, or per leaf.
    let nnz = 120_000usize;
    let t = order_sweep(3, 200, nnz, 9);
    for algo in [Algo::FasterTuckerCoo, Algo::FasterTucker] {
        for refresh in [RefreshMode::Full, RefreshMode::Incremental] {
            let cfg = TrainConfig {
                order: 3,
                dims: t.dims().to_vec(),
                j: 8,
                r: 8,
                lr_a: 1e-3,
                lr_b: 2e-5,
                workers: 1, // inline execution: no thread-spawn allocations
                block_nnz: 512,
                fiber_threshold: 64,
                eval_sample_nnz: 0,
                refresh,
                ..TrainConfig::default()
            };
            let mut session = Session::new(algo, cfg, &t).expect("session");
            // Warm-up epoch: fills the scratch pool, sizes the padded
            // operands, and grows the dirty bitsets — the one-time costs
            // the budget excludes.
            session.factor_pass();
            session.core_pass();

            let before = ALLOCS.load(Ordering::Relaxed);
            session.factor_pass();
            session.core_pass();
            let spent = ALLOCS.load(Ordering::Relaxed) - before;

            // Measured budget is ~35 events per epoch (config clone + stats
            // vectors + plan weights, × 3 modes × 2 passes). 160 leaves
            // slack for allocator-internal noise while staying an order of
            // magnitude below anything nnz-proportional.
            assert!(
                spent < 160,
                "{} ({} refresh): epoch allocated {spent} times — hot path \
                 regressed (per-block, per-leaf, or per-dirty-row \
                 allocation crept back in)",
                algo.name(),
                refresh.name()
            );
        }
    }

    // Memory-hierarchy scenario: a forced synthetic 2-node topology at two
    // workers keeps a node-1 operand replica coherent (incremental 64-row
    // block resync after every dirty publish) and routes every leaf through
    // the cache-tiled prefetched loop. All of that must be allocation-free
    // in steady state: the replica mirrors and per-node scratch pools are
    // sized once by `set_worker_homes` at session build and resynced in
    // place. The measured epoch still pays the constant thread-spawn +
    // bookkeeping cost (3 modes × 2 workers × 2 passes ≈ 12 spawns, a few
    // allocations each), so the bound is looser than the inline one above
    // — but replication itself contributes zero, and any per-block
    // (~1400 events here) or per-dirty-row regression still blows it.
    {
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 8,
            r: 8,
            lr_a: 1e-3,
            lr_b: 2e-5,
            workers: 2,
            block_nnz: 512,
            fiber_threshold: 64,
            eval_sample_nnz: 0,
            refresh: RefreshMode::Incremental,
            numa: NumaMode::Force(2),
            tile_nnz: 97,
            ..TrainConfig::default()
        };
        let mut session =
            Session::new(Algo::FasterTucker, cfg, &t).expect("session");
        session.factor_pass();
        session.core_pass();

        let before = ALLOCS.load(Ordering::Relaxed);
        session.factor_pass();
        session.core_pass();
        let spent = ALLOCS.load(Ordering::Relaxed) - before;

        assert!(
            spent < 600,
            "numa 2-nodes / tiled epoch allocated {spent} times — node \
             replication or the tiled leaf loop started allocating per pass"
        );
    }
}
