//! Session-layer integration: checkpoint → warm-start parity, staged-once
//! storage reuse, and dataset-driven sessions.
//!
//! The headline guarantee: on one worker with a fixed seed, training k
//! epochs, checkpointing, and warm-starting a fresh `Session` for m more
//! epochs is **bitwise-identical** to an uninterrupted k+m-epoch run. That
//! holds because (a) the `FTCK` checkpoint round-trips every f32 exactly,
//! (b) `PreparedStorage` re-derives the identical shuffled traversal and
//! B-CSF rotations from `(train, seed)`, (c) warm start re-derives the `C`
//! tables through the same GEMM the training refresh uses, and (d) the LR
//! decay schedule is a function of the *global* epoch counter.

use fastertucker::algo::Algo;
use fastertucker::config::TrainConfig;
use fastertucker::coordinator::{Session, SessionModel};
use fastertucker::data::dataset::Dataset;
use fastertucker::data::synthetic::{recommender, RecommenderSpec};
use fastertucker::model::ModelState;
use fastertucker::tensor::coo::CooTensor;
use fastertucker::tensor::io;
use std::path::PathBuf;

fn tmpfile(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ft_session_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{}", std::process::id(), name))
}

fn cfg_for(t: &CooTensor) -> TrainConfig {
    TrainConfig {
        order: t.order(),
        dims: t.dims().to_vec(),
        j: 8,
        r: 4,
        lr_a: 0.01,
        lr_b: 1e-4,
        workers: 1, // single worker: no Hogwild races, exact determinism
        block_nnz: 512,
        fiber_threshold: 32,
        seed: 71,
        ..TrainConfig::default()
    }
}

fn fast_model(s: &Session) -> &ModelState {
    match &s.model {
        SessionModel::Fast(m) => m,
        SessionModel::Full(_) => panic!("expected fast model"),
    }
}

fn assert_bitwise_equal(a: &ModelState, b: &ModelState, what: &str) {
    for n in 0..a.order() {
        assert_eq!(
            a.factors[n].max_abs_diff(&b.factors[n]),
            0.0,
            "{what}: factor mode {n} diverged"
        );
        assert_eq!(
            a.cores[n].max_abs_diff(&b.cores[n]),
            0.0,
            "{what}: core mode {n} diverged"
        );
        assert_eq!(
            a.c_tables[n].max_abs_diff(&b.c_tables[n]),
            0.0,
            "{what}: C table mode {n} diverged"
        );
    }
}

/// Train k epochs → checkpoint → warm-start a new session → m more epochs
/// must equal an uninterrupted k+m run bit for bit, for every engine-backed
/// algorithm (and with a decaying LR schedule, which must continue from the
/// global epoch counter).
#[test]
fn resume_is_bitwise_identical_to_uninterrupted_run() {
    let t = recommender(&RecommenderSpec::tiny(), 21);
    for (algo, lr_decay) in [
        (Algo::FasterTucker, 1.0f32),
        (Algo::FastTucker, 1.0),
        (Algo::FasterTuckerCoo, 0.5),
        (Algo::FasterTuckerBcsf, 1.0),
    ] {
        let mut cfg = cfg_for(&t);
        cfg.lr_decay = lr_decay;
        let (k, m) = (3usize, 2usize);

        // uninterrupted k+m epochs
        let mut full = Session::new(algo, cfg.clone(), &t).unwrap();
        full.run(k + m, None);

        // k epochs → checkpoint → fresh warm-started session → m epochs
        let mut head = Session::new(algo, cfg.clone(), &t).unwrap();
        head.run(k, None);
        let ckpt = tmpfile(&format!("resume_{}.ckpt", algo.name()));
        head.save_checkpoint(&ckpt).unwrap();
        let restored = ModelState::load(&ckpt).unwrap();
        let mut tail = Session::warm_start(algo, cfg.clone(), &t, restored, k).unwrap();
        assert_eq!(tail.epochs_completed(), k);
        let report = tail.run(m, None);
        std::fs::remove_file(&ckpt).ok();

        assert_eq!(report.start_epoch, k);
        assert_eq!(report.epochs_completed, k + m);
        // global epoch numbering continues across the warm start
        let epochs: Vec<usize> =
            report.convergence.records.iter().map(|r| r.epoch).collect();
        assert_eq!(epochs, vec![k, k + 1]);
        assert_bitwise_equal(
            fast_model(&full),
            fast_model(&tail),
            &format!("{} (lr_decay {lr_decay})", algo.name()),
        );
    }
}

/// The checkpoint itself round-trips the trained state exactly (chunked
/// byte IO, unchanged FTCK format).
#[test]
fn checkpoint_roundtrip_is_exact_after_training() {
    let t = recommender(&RecommenderSpec::tiny(), 23);
    let mut session = Session::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
    session.run(2, None);
    let ckpt = tmpfile("roundtrip.ckpt");
    session.save_checkpoint(&ckpt).unwrap();
    let loaded = ModelState::load(&ckpt).unwrap();
    std::fs::remove_file(&ckpt).ok();
    let m = fast_model(&session);
    for n in 0..m.order() {
        assert_eq!(m.factors[n].max_abs_diff(&loaded.factors[n]), 0.0);
        assert_eq!(m.cores[n].max_abs_diff(&loaded.cores[n]), 0.0);
    }
}

/// A `.tns` text file round-trips and drives a full `Session` end to end —
/// the file-backed ingestion path of the Dataset layer.
#[test]
fn tns_file_dataset_drives_a_session() {
    let t = recommender(&RecommenderSpec::tiny(), 25);
    let path = tmpfile("drive.tns");
    io::write_text(&t, &path, true).unwrap();
    let dataset = Dataset::from_path(&path, true);
    let loaded = dataset.load().unwrap();
    assert_eq!(loaded.nnz(), t.nnz());
    let (train, test) = dataset.load_split(0.2, 7).unwrap();
    let test = test.expect("split requested");
    std::fs::remove_file(&path).ok();

    let mut session = Session::new(Algo::FasterTucker, cfg_for(&train), &train).unwrap();
    assert_eq!(session.prep_stats().builds, 1);
    let report = session.run(5, Some(&test));
    assert_eq!(session.prep_stats().builds, 1);
    assert!(
        report.convergence.improved(),
        "file-backed session did not improve: {:?}",
        report.convergence.records.iter().map(|r| r.rmse).collect::<Vec<_>>()
    );
}

/// Self-evaluation without a test set uses the capped deterministic sample,
/// and two sessions with the same seed report identical first-epoch RMSE.
#[test]
fn capped_self_eval_is_deterministic_across_sessions() {
    let t = recommender(&RecommenderSpec::tiny(), 27);
    let mut cfg = cfg_for(&t);
    cfg.eval_sample_nnz = 800;
    let mut a = Session::new(Algo::FasterTucker, cfg.clone(), &t).unwrap();
    let mut b = Session::new(Algo::FasterTucker, cfg, &t).unwrap();
    assert_eq!(a.eval_sample().unwrap().nnz(), 800);
    let ra = a.step(None);
    let rb = b.step(None);
    assert_eq!(ra.rmse, rb.rmse);
    assert_eq!(ra.mae, rb.mae);
}
