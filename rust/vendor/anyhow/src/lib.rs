//! Offline stand-in for the `anyhow` crate — the API subset this repository
//! uses, vendored because the build container has no crates.io access.
//!
//! Provided: [`Error`], [`Result`], the [`anyhow!`] and [`bail!`] macros, and
//! the [`Context`] extension trait for `Result` and `Option`. Semantics match
//! upstream `anyhow` where it matters to callers:
//!
//! * `Display` prints the outermost context; `{:#}` prints the whole chain
//!   (`outer: inner: root`), like upstream's alternate formatting.
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`] (capturing its `source()` chain).
//! * `.context(..)` / `.with_context(..)` wrap errors (and `None`) with an
//!   outer message.

use std::fmt;

/// A string-backed error carrying a context chain, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    fn wrap(mut self, ctx: String) -> Error {
        self.chain.insert(0, ctx);
        self
    }

    /// Wrap with an outer context message (parity with upstream's
    /// `Error::context`).
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        self.wrap(ctx.to_string())
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` below coherent (same trick as upstream).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Sealed conversion used by [`super::Context`]: standard errors and
    /// [`super::Error`] itself both flow into `Error`.
    pub trait ToError {
        fn to_error(self) -> super::Error;
    }
    impl<E> ToError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn to_error(self) -> super::Error {
            super::Error::from(self)
        }
    }
    impl ToError for super::Error {
        fn to_error(self) -> super::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::ToError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.to_error().wrap(context.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.to_error().wrap(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_err() -> std::num::ParseIntError {
        "not a number".parse::<u32>().unwrap_err()
    }

    #[test]
    fn display_prints_outermost_alternate_prints_chain() {
        let root = parse_err().to_string();
        let e: Error = Result::<(), _>::Err(parse_err())
            .context("reading header")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading header");
        assert_eq!(format!("{e:#}"), format!("reading header: {root}"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(parse_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), parse_err().to_string());
    }

    #[test]
    fn option_context_and_with_context() {
        let e = None::<u32>.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let e = None::<u32>.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_format() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let b = anyhow!("x = {}", 3);
        assert_eq!(b.to_string(), "x = 3");
        let v = 9;
        let c = anyhow!("inline {v}");
        assert_eq!(c.to_string(), "inline 9");
        fn bails() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 1");
    }

    #[test]
    fn context_stacks_on_anyhow_errors() {
        let e = anyhow!("root").context("mid").context("top");
        assert_eq!(e.to_string(), "top");
        assert_eq!(format!("{e:#}"), "top: mid: root");
        assert_eq!(e.root_cause(), "root");
    }
}
