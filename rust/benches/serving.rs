//! Serving-path microbenchmark: batched top-k throughput through a
//! [`ServingHandle`] snapshot, single-reader and concurrent, plus the
//! publish cost the training loop pays per epoch.
//!
//! ```sh
//! cargo bench --bench serving -- [--quick]
//! ```
//!
//! Reported per configuration: queries per second for one reader, queries
//! per second aggregated over 4 concurrent readers (the handle is lock-free
//! past one short `Arc` clone, so this should scale), and microseconds per
//! epoch-snapshot publish (the only cost training pays for serving).

use fastertucker::bench::{time_fn, Table};
use fastertucker::config::TrainConfig;
use fastertucker::coordinator::{ServingHandle, TopKQuery};
use fastertucker::model::ModelState;
use fastertucker::util::rng::Rng;

fn queries(dims: &[usize], mode: usize, k: usize, n: usize, seed: u64) -> Vec<TopKQuery> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let fixed = dims
                .iter()
                .enumerate()
                .filter(|&(m, _)| m != mode)
                .map(|(_, &d)| rng.next_below(d) as u32)
                .collect();
            TopKQuery { mode, fixed, k }
        })
        .collect()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (dim, batch, iters) = if quick { (2_000, 64, 20) } else { (50_000, 256, 50) };
    let cfg = TrainConfig {
        order: 3,
        dims: vec![dim, dim / 10, 64],
        j: 32,
        r: 32,
        ..TrainConfig::default()
    };
    let model = ModelState::init(&cfg, 7);
    let handle = ServingHandle::from_model(&model);
    let qs = queries(&cfg.dims, 1, 10, batch, 11);

    let mut table = Table::new(
        "serving path — batched top-k over the C tables",
        &["metric", "value"],
    );

    // single reader, batched
    let stats = time_fn(2, iters, || {
        let res = handle.top_k_batch(&qs).expect("valid queries");
        assert_eq!(res.len(), qs.len());
    });
    let qps = batch as f64 / stats.mean;
    table.row(vec!["1 reader, queries/s".into(), format!("{qps:.0}")]);

    // 4 concurrent readers hammering the same snapshot
    let readers = 4;
    let stats = time_fn(1, iters.max(5) / 5, || {
        std::thread::scope(|scope| {
            for _ in 0..readers {
                let handle = handle.clone();
                let qs = &qs;
                scope.spawn(move || {
                    handle.top_k_batch(qs).expect("valid queries");
                });
            }
        });
    });
    let qps4 = (readers * batch) as f64 / stats.mean;
    table.row(vec![
        format!("{readers} readers, aggregate queries/s"),
        format!("{qps4:.0}"),
    ]);

    // publish cost: what the training loop pays at each epoch boundary
    let stats = time_fn(2, iters, || {
        let h = ServingHandle::from_model(&model);
        std::hint::black_box(h.epoch());
    });
    table.row(vec![
        "snapshot capture+publish, µs".into(),
        format!("{:.1}", stats.mean * 1e6),
    ]);

    println!("{}", table.render());
    println!("dims {:?}, J={} R={}, batch {batch}", cfg.dims, cfg.j, cfg.r);
}
