//! Serving-path microbenchmark: the three hot-path claims of the serving
//! layer, each measured against an in-run baseline so the emitted JSON
//! always carries a same-machine comparison.
//!
//! ```sh
//! cargo bench --bench serving -- [--quick]
//! ```
//!
//! 1. **Scoring**: ns/query through the frozen pre-SIMD scalar path (chain
//!    over the raw `C` tables, scalar dot, full sort) vs the SIMD
//!    exhaustive path vs the SIMD + norm-pruned heap path.
//! 2. **Publication**: bytes and seconds of a from-scratch snapshot
//!    capture vs a delta capture on a ~1% *clustered* dirty workload (a
//!    contiguous hot-row window — the recommender shape where a few
//!    popular entities retrain every epoch; a uniformly random 1% would
//!    touch nearly every 64-row block and deltas could not help anyone).
//! 3. **Fan-out**: the same batch through a leased 4-worker executor
//!    subset.
//!
//! Output: human table on stdout + machine-readable `BENCH_serving.json`
//! (schema `bench_serving_v1`; path overridable via `FT_BENCH_OUT`) in the
//! working directory. Optional regression gates: `FT_MIN_SERVE_SPEEDUP`
//! bounds scalar-vs-pruned ns/query, `FT_MAX_PUBLISH_BYTES_PCT` bounds
//! delta bytes as a percentage of the full capture.

use fastertucker::bench::{time_fn, Table};
use fastertucker::config::TrainConfig;
use fastertucker::coordinator::{ServingHandle, ServingSnapshot, TopKQuery};
use fastertucker::model::ModelState;
use fastertucker::sched::Executor;
use fastertucker::util::json::Json;
use fastertucker::util::rng::Rng;
use std::sync::Arc;

/// Frozen copy of the pre-SIMD serving scorer: chain product over the raw
/// (unpadded) `C` tables, 4-way-unrolled scalar dot per candidate, full
/// `O(I log I)` sort. Kept here as the in-run baseline the speedup numbers
/// are measured against — do not "fix" it.
mod legacy {
    use fastertucker::coordinator::TopKQuery;
    use fastertucker::linalg::dot;
    use fastertucker::model::ModelState;

    pub fn top_k(m: &ModelState, q: &TopKQuery) -> Vec<(usize, f32)> {
        let order = m.order();
        let r = m.c_tables[q.mode].cols();
        let mut v = vec![1.0f32; r];
        let mut kk = 0;
        for mode in 0..order {
            if mode == q.mode {
                continue;
            }
            let row = m.c_tables[mode].row(q.fixed[kk] as usize);
            kk += 1;
            for (vr, cr) in v.iter_mut().zip(row) {
                *vr *= *cr;
            }
        }
        let table = &m.c_tables[q.mode];
        let mut ranked: Vec<(usize, f32)> = (0..table.rows())
            .map(|i| (i, dot(table.row(i), &v)))
            .collect();
        ranked.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        ranked.truncate(q.k.min(ranked.len()));
        ranked
    }
}

fn queries(dims: &[usize], mode: usize, k: usize, n: usize, seed: u64) -> Vec<TopKQuery> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let fixed = dims
                .iter()
                .enumerate()
                .filter(|&(m, _)| m != mode)
                .map(|(_, &d)| rng.next_below(d) as u32)
                .collect();
            TopKQuery { mode, fixed, k }
        })
        .collect()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (dim, batch, iters, k) =
        if quick { (2_000, 64, 20, 20) } else { (50_000, 256, 50, 50) };
    let cfg = TrainConfig {
        order: 3,
        dims: vec![dim, dim / 10, 64],
        j: 32,
        r: 32,
        ..TrainConfig::default()
    };
    let mut model = ModelState::init(&cfg, 7);
    // signed factors: scores take both signs, so the norm bound is
    // exercised on its |dot| side, not a best case of all-positive data
    let mut rng = Rng::new(17);
    for f in &mut model.factors {
        for x in f.data_mut() {
            *x = rng.uniform_f32(-0.5, 0.5);
        }
    }
    model.refresh_all_c();
    let handle = ServingHandle::from_model(&model);
    let snap = handle.snapshot();
    let qs = queries(&cfg.dims, 0, k, batch, 11);

    let mut table = Table::new(
        "serving hot path — scoring, publication, fan-out",
        &["metric", "value"],
    );

    // -- scoring: scalar full sort vs SIMD full sort vs SIMD pruned heap --
    let scalar = time_fn(2, iters, || {
        for q in &qs {
            std::hint::black_box(legacy::top_k(&model, q));
        }
    });
    let simd_full = time_fn(2, iters, || {
        for q in &qs {
            std::hint::black_box(snap.top_k_exhaustive(q).expect("valid query"));
        }
    });
    let pruned = time_fn(2, iters, || {
        let res = handle.top_k_batch(&qs).expect("valid queries");
        assert_eq!(res.len(), qs.len());
    });
    let per_query = |s: &fastertucker::bench::Stats| s.min / batch as f64 * 1e9;
    let (scalar_ns, simd_ns, pruned_ns) =
        (per_query(&scalar), per_query(&simd_full), per_query(&pruned));
    let simd_speedup = scalar_ns / simd_ns;
    let serve_speedup = scalar_ns / pruned_ns;
    table.row(vec!["scalar full sort, ns/query".into(), format!("{scalar_ns:.0}")]);
    table.row(vec!["SIMD full sort, ns/query".into(), format!("{simd_ns:.0}")]);
    table.row(vec!["SIMD pruned heap, ns/query".into(), format!("{pruned_ns:.0}")]);
    table.row(vec!["serve speedup (scalar/pruned)".into(), format!("{serve_speedup:.2}x")]);

    // the pruned path must agree with the exhaustive oracle bit for bit —
    // a benchmark that measures a wrong answer measures nothing
    let (check, prune_stats) = snap.top_k_with_stats(&qs[0]).expect("valid query");
    let oracle = snap.top_k_exhaustive(&qs[0]).expect("valid query");
    assert_eq!(check.items.len(), oracle.items.len());
    for (a, b) in check.items.iter().zip(oracle.items.iter()) {
        assert_eq!(a.0, b.0, "pruned/exhaustive index mismatch");
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "pruned/exhaustive bits mismatch");
    }
    table.row(vec![
        "blocks skipped / scanned (1 query)".into(),
        format!("{} / {}", prune_stats.blocks_skipped, prune_stats.blocks_scanned),
    ]);

    // -- fan-out: the same batch over a leased 4-worker executor subset --
    let mut fanned = handle.clone();
    fanned.set_executor(Arc::new(Executor::new(4)), 0);
    let fan = time_fn(2, iters, || {
        let res = fanned.top_k_batch(&qs).expect("valid queries");
        assert_eq!(res.len(), qs.len());
    });
    let fan_ns = per_query(&fan);
    table.row(vec!["pruned + 4-worker fan-out, ns/query".into(), format!("{fan_ns:.0}")]);

    // -- publication: full capture vs delta on a ~1% clustered hot window --
    let hot = (dim / 100).max(1);
    let prev = ServingSnapshot::capture(&model, 1);
    model.clear_publish_dirty();
    model.dirty[0].ensure(model.factors[0].rows());
    for i in 0..hot {
        model.factors[0].row_mut(i)[0] += 1e-3;
        model.dirty[0].mark(i);
    }
    model.refresh_c_dirty(0, None);
    // publish_dirty now carries exactly the hot window; it is deliberately
    // NOT cleared between timed iterations, so every delta capture below
    // re-does the same (hot-blocks-only) work
    let full_pub = time_fn(2, iters, || {
        std::hint::black_box(ServingSnapshot::capture(&model, 2));
    });
    let delta_pub = time_fn(2, iters, || {
        std::hint::black_box(ServingSnapshot::capture_delta(&model, 2, &prev));
    });
    let full_cap = ServingSnapshot::capture(&model, 2);
    let delta_cap = ServingSnapshot::capture_delta(&model, 2, &prev);
    let (full_bytes, delta_bytes) =
        (full_cap.stats().bytes, delta_cap.stats().bytes);
    let delta_pct = delta_bytes as f64 / full_bytes as f64 * 100.0;
    let publish_speedup = full_pub.min / delta_pub.min;
    table.row(vec![
        "full publish, µs / bytes".into(),
        format!("{:.1} / {}", full_pub.min * 1e6, full_bytes),
    ]);
    table.row(vec![
        "delta publish, µs / bytes".into(),
        format!("{:.1} / {}", delta_pub.min * 1e6, delta_bytes),
    ]);
    table.row(vec![
        "delta bytes, % of full".into(),
        format!("{delta_pct:.2}%"),
    ]);

    println!("{}", table.render());
    println!(
        "dims {:?}, J={} R={}, batch {batch}, k={k}, hot rows {hot}",
        cfg.dims, cfg.j, cfg.r
    );

    let doc = Json::obj(vec![
        ("schema", Json::str("bench_serving_v1")),
        ("quick", Json::Bool(quick)),
        (
            "config",
            Json::obj(vec![
                ("dims", Json::arr_usize(&cfg.dims)),
                ("j", Json::num(cfg.j as f64)),
                ("r", Json::num(cfg.r as f64)),
                ("batch", Json::num(batch as f64)),
                ("k", Json::num(k as f64)),
            ]),
        ),
        (
            "query",
            Json::obj(vec![
                (
                    "description",
                    Json::str(
                        "ns/query over a batched top-k workload: frozen \
                         scalar chain+dot+full-sort baseline vs the SIMD \
                         exhaustive path vs the SIMD norm-pruned heap path \
                         (all three answer identically)",
                    ),
                ),
                ("scalar_full_ns_per_query", Json::num(scalar_ns)),
                ("simd_full_ns_per_query", Json::num(simd_ns)),
                ("pruned_ns_per_query", Json::num(pruned_ns)),
                ("fanout_ns_per_query", Json::num(fan_ns)),
                ("simd_speedup", Json::num(simd_speedup)),
                ("serve_speedup", Json::num(serve_speedup)),
                ("blocks_skipped", Json::num(prune_stats.blocks_skipped as f64)),
                ("blocks_scanned", Json::num(prune_stats.blocks_scanned as f64)),
                ("rows_pruned", Json::num(prune_stats.rows_pruned as f64)),
                ("rows_scored", Json::num(prune_stats.rows_scored as f64)),
            ]),
        ),
        (
            "publish",
            Json::obj(vec![
                (
                    "description",
                    Json::str(
                        "epoch-snapshot publication cost, from-scratch \
                         capture vs copy-on-write delta, on a clustered \
                         ~1%-dirty hot-row window",
                    ),
                ),
                ("hot_rows", Json::num(hot as f64)),
                ("full_seconds", Json::num(full_pub.min)),
                ("delta_seconds", Json::num(delta_pub.min)),
                ("full_bytes", Json::num(full_bytes as f64)),
                ("delta_bytes", Json::num(delta_bytes as f64)),
                ("delta_bytes_pct", Json::num(delta_pct)),
                ("publish_speedup", Json::num(publish_speedup)),
            ]),
        ),
    ]);
    let out = std::env::var("FT_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_serving.json".to_string());
    match std::fs::write(&out, doc.to_string_pretty()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("warning: could not write {out}: {e}"),
    }

    // Serve-speedup gate: FT_MIN_SERVE_SPEEDUP=2 enforces the ≥2x
    // acceptance bound on scalar-vs-pruned ns/query at full scale (CI's
    // quick mode sets a noise-tolerant bound).
    if let Ok(bound) = std::env::var("FT_MIN_SERVE_SPEEDUP") {
        let bound: f64 = bound.parse().expect("FT_MIN_SERVE_SPEEDUP must be a float");
        assert!(
            serve_speedup >= bound,
            "serve speedup {serve_speedup:.2}x fell below the \
             FT_MIN_SERVE_SPEEDUP bound {bound:.2}x — the SIMD/pruned \
             read path stopped paying for itself"
        );
    }

    // Publication gate: FT_MAX_PUBLISH_BYTES_PCT=10 enforces the delta
    // bytes staying under 10% of a full capture on the ~1%-dirty workload
    // (CI smoke relaxes the bound: quick mode's smaller tables make each
    // 64-row block a bigger fraction of the total).
    if let Ok(bound) = std::env::var("FT_MAX_PUBLISH_BYTES_PCT") {
        let bound: f64 =
            bound.parse().expect("FT_MAX_PUBLISH_BYTES_PCT must be a float");
        assert!(
            delta_pct <= bound,
            "delta publication moved {delta_pct:.2}% of the full capture's \
             bytes, above the FT_MAX_PUBLISH_BYTES_PCT bound {bound:.2}% — \
             block sharing regressed"
        );
    }
}
