//! `cargo bench --bench ablation` — design-choice ablations: B-CSF fiber
//! threshold and scheduler block granularity (DESIGN.md §8).

use fastertucker::bench::experiments::{self, BenchScale};

fn main() {
    if std::env::args().any(|a| a == "--list") {
        println!("ablation: bench");
        return;
    }
    let scale = BenchScale::from_env();
    eprintln!("running ablations at scale {scale:?}");
    println!("{}", experiments::ablation_threshold(&scale).render());
    println!("{}", experiments::ablation_block_size(&scale).render());
}
