//! `cargo bench --bench table5_speedup` — regenerates the paper's Table V,
//! with each dataset's per-iteration cost split into three columns:
//! one-time **staging**, per-pass **C-refresh**, and per-pass **sweep**
//! (the refresh timer runs inside the pass, so the columns tile the
//! measured iteration). Scale via FT_NNZ / FT_EPOCHS / FT_J / FT_R /
//! FT_WORKERS.

use fastertucker::bench::experiments::{self, BenchScale};

fn main() {
    // cargo test passes --bench harness args; a bench binary with
    // harness=false must tolerate and ignore them.
    if std::env::args().any(|a| a == "--list") {
        println!("table5_speedup: bench");
        return;
    }
    let scale = BenchScale::from_env();
    eprintln!("running Table V at scale {scale:?}");
    let table = experiments::table5(&scale);
    println!("{}", table.render());
    println!("(results persisted under results/)");
}
