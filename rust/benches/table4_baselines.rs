//! `cargo bench --bench table4_baselines` — regenerates the paper's Table IV.
//! Scale via FT_NNZ / FT_EPOCHS / FT_J / FT_R / FT_WORKERS.

use fastertucker::bench::experiments::{self, BenchScale};

fn main() {
    // cargo test passes --bench harness args; a bench binary with
    // harness=false must tolerate and ignore them.
    if std::env::args().any(|a| a == "--list") {
        println!("table4_baselines: bench");
        return;
    }
    let scale = BenchScale::from_env();
    eprintln!("running Table IV at scale {scale:?}");
    let table = experiments::table4(&scale);
    println!("{}", table.render());
    println!("(results persisted under results/)");
}
