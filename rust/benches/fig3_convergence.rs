//! `cargo bench --bench fig3_convergence` — regenerates the paper's Fig. 2/3.
//! Scale via FT_NNZ / FT_EPOCHS / FT_J / FT_R / FT_WORKERS.

use fastertucker::bench::experiments::{self, BenchScale};

fn main() {
    // cargo test passes --bench harness args; a bench binary with
    // harness=false must tolerate and ignore them.
    if std::env::args().any(|a| a == "--list") {
        println!("fig3_convergence: bench");
        return;
    }
    let scale = BenchScale::from_env();
    eprintln!("running Fig. 2/3 at scale {scale:?}");
    let table = experiments::fig3(&scale);
    println!("{}", table.render());
    println!("(results persisted under results/)");
}
