//! `cargo bench --bench fig4bc_sparsity` — regenerates the paper's Fig. 4(b,c).
//! Scale via FT_NNZ / FT_EPOCHS / FT_J / FT_R / FT_WORKERS.

use fastertucker::bench::experiments::{self, BenchScale};

fn main() {
    // cargo test passes --bench harness args; a bench binary with
    // harness=false must tolerate and ignore them.
    if std::env::args().any(|a| a == "--list") {
        println!("fig4bc_sparsity: bench");
        return;
    }
    let scale = BenchScale::from_env();
    eprintln!("running Fig. 4(b,c) at scale {scale:?}");
    let table = experiments::fig4bc(&scale);
    println!("{}", table.render());
    println!("(results persisted under results/)");
}
