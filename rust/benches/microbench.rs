//! Microbenchmarks for the hot path, two layers:
//!
//! 1. **Primitives** — chain products (table vs on-the-fly), fiber `w`
//!    matvec, row SGD update, C-table GEMM, B-CSF construction.
//! 2. **Epoch sweeps** — ns per non-zero visit for every engine algorithm,
//!    factor and core pass separately, staging reported on the side (the
//!    paper's Table V split), plus a **frozen pre-PR baseline**: the
//!    per-leaf `dyn`-dispatch walker with the old scalar kernels, measured
//!    in the *same run* so `BENCH_epoch.json` always carries a
//!    baseline-vs-current speedup for the perf trajectory.
//!
//! Output: human table on stdout + machine-readable `BENCH_epoch.json`
//! (schema `bench_epoch_v6`; path overridable via `FT_BENCH_OUT`) in the
//! working directory — including the `backend` dimension (Session via
//! `Box<dyn PassBackend>` vs the frozen pre-backend direct engine
//! invocation, gated by `FT_MAX_BACKEND_OVERHEAD_PCT`), the `staging`
//! dimension (executor-parallel `prepare` vs an in-run serial baseline,
//! gated by `FT_MIN_STAGING_SPEEDUP`), the `refresh` dimension
//! (dirty-row incremental C-refresh vs the full GEMM on a sparse-touch
//! workload, gated by `FT_MIN_REFRESH_SPEEDUP`), the `sched` dimension
//! (static shared-counter LPT claiming vs block-granular work stealing
//! on a skewed fiber distribution, gated by `FT_MIN_STEAL_SPEEDUP`),
//! the `qos` dimension (serving p99 under a training flood, blocking
//! lease acquisition vs the shipping non-blocking admitted path, gated
//! by `FT_MIN_QOS_SPEEDUP`), the `ingest` dimension (absorbing a
//! tail-concentrated ~1% COO delta: cold full re-stage of the
//! concatenated tensor vs the incremental dirty-block `restage`, gated
//! by `FT_MIN_INGEST_SPEEDUP`), and the `numa` dimension (topology-blind
//! untiled multi-worker epochs vs NUMA-pinned node-replicated execution
//! with cache-tiled prefetched kernels, gated by `FT_MIN_NUMA_SPEEDUP` —
//! enforced only on machines with ≥2 NUMA nodes; single-node machines
//! report the measurement honestly without gating). `--quick` shrinks
//! the workload for CI smoke runs.

use fastertucker::algo::engine::{self, EngineState};
use fastertucker::algo::grad::{
    chain_v_from_tables, chain_v_on_the_fly, fiber_w, Scratch,
};
use fastertucker::algo::Algo;
use fastertucker::bench::{time_fn, Table};
use fastertucker::config::{NumaMode, SchedMode, TrainConfig};
use fastertucker::coordinator::{Session, SessionRegistry, TopKQuery};
use fastertucker::data::synthetic::{recommender, RecommenderSpec};
use fastertucker::linalg::Matrix;
use fastertucker::model::ModelState;
use fastertucker::sched::racy::RacyMatrix;
use fastertucker::tensor::bcsf::BcsfTensor;
use fastertucker::tensor::coo::CooTensor;
use fastertucker::tensor::prepared::PreparedStorage;
use fastertucker::util::json::Json;
use fastertucker::util::rng::Rng;

/// Frozen pre-PR hot path: one virtual call per group *and per leaf*
/// through a `&mut dyn` sink, driving the old scalar kernels (pre-lane
/// `fiber_w`, 4-way `row_dot`, element-wise update through `load`/`store`).
/// Kept verbatim so every run measures the baseline it improves on.
mod legacy {
    use fastertucker::config::TrainConfig;
    use fastertucker::linalg::Matrix;
    use fastertucker::model::ModelState;
    use fastertucker::sched::racy::RacyMatrix;
    use fastertucker::tensor::bcsf::BcsfTensor;

    pub trait LeafSink {
        fn group(&mut self, path: &[u32]);
        fn leaf(&mut self, row: usize, x: f32);
    }

    struct Scratch {
        v: Vec<f32>,
        w: Vec<f32>,
        prev_path: Vec<u32>,
        pprod: Vec<f32>,
    }

    impl Scratch {
        fn new(order: usize, j: usize, r: usize) -> Scratch {
            Scratch {
                v: vec![0.0; r],
                w: vec![0.0; j],
                prev_path: Vec::new(),
                pprod: vec![0.0; (order.max(2) - 1) * r],
            }
        }
    }

    /// Old prefix-cached chain (scalar, unpadded stride).
    fn chain_v_prefix_cached(
        c_tables: &[Matrix],
        modes: &[usize],
        path: &[u32],
        s: &mut Scratch,
    ) {
        let r = s.v.len();
        let plen = modes.len();
        let shared = if s.prev_path.len() == plen {
            s.prev_path
                .iter()
                .zip(path.iter())
                .take_while(|(a, b)| a == b)
                .count()
        } else {
            0
        };
        for k in shared..plen {
            let crow = c_tables[modes[k]].row(path[k] as usize);
            let (lo, hi) = (k * r, (k + 1) * r);
            if k == 0 {
                s.pprod[lo..hi].copy_from_slice(&crow[..r]);
            } else {
                let (prev, cur) = s.pprod.split_at_mut(lo);
                let prev = &prev[lo - r..];
                for i in 0..r {
                    cur[i] = prev[i] * crow[i];
                }
            }
        }
        s.v.copy_from_slice(&s.pprod[(plen - 1) * r..plen * r]);
        s.prev_path.clear();
        s.prev_path.extend_from_slice(path);
    }

    /// Old scalar `w = B·v`.
    fn fiber_w(b: &Matrix, v: &[f32], w: &mut [f32]) {
        let r = v.len();
        for (wj, brow) in w.iter_mut().zip(b.data().chunks_exact(r)) {
            let mut acc = 0.0f32;
            for (&bv, &vv) in brow.iter().zip(v.iter()) {
                acc += bv * vv;
            }
            *wj = acc;
        }
    }

    /// Old 4-way unrolled Hogwild row dot.
    fn row_dot(racy: &RacyMatrix, i: usize, w: &[f32]) -> f32 {
        let cols = w.len();
        let chunks = cols / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for k in 0..chunks {
            let j = k * 4;
            s0 += racy.load(i, j) * w[j];
            s1 += racy.load(i, j + 1) * w[j + 1];
            s2 += racy.load(i, j + 2) * w[j + 2];
            s3 += racy.load(i, j + 3) * w[j + 3];
        }
        let mut s = (s0 + s1) + (s2 + s3);
        for j in chunks * 4..cols {
            s += racy.load(i, j) * w[j];
        }
        s
    }

    fn row_sgd_update(racy: &RacyMatrix, i: usize, scale: f32, step: f32, w: &[f32]) {
        for (j, &wj) in w.iter().enumerate() {
            let old = racy.load(i, j);
            racy.store(i, j, scale * old + step * wj);
        }
    }

    struct FactorSink<'a> {
        c_tables: &'a [Matrix],
        modes: &'a [usize],
        core_n: &'a Matrix,
        racy: &'a RacyMatrix<'a>,
        scale: f32,
        lr: f32,
        s: Scratch,
    }

    impl LeafSink for FactorSink<'_> {
        fn group(&mut self, path: &[u32]) {
            chain_v_prefix_cached(self.c_tables, self.modes, path, &mut self.s);
            fiber_w(self.core_n, &self.s.v, &mut self.s.w);
        }
        fn leaf(&mut self, row: usize, x: f32) {
            let e = x - row_dot(self.racy, row, &self.s.w);
            row_sgd_update(self.racy, row, self.scale, self.lr * e, &self.s.w);
        }
    }

    /// Old per-leaf block walk: dynamic dispatch for every single non-zero.
    fn drive_block(t: &BcsfTensor, b: usize, sink: &mut dyn LeafSink) {
        let mut prev_fiber = u32::MAX;
        let mut first = true;
        for task in t.block_tasks(b) {
            if first || task.fiber != prev_fiber {
                sink.group(t.fiber_path(task.fiber));
                prev_fiber = task.fiber;
                first = false;
            }
            let (leaf_idx, leaf_vals) = t.task_leaves(task);
            for (k, &i) in leaf_idx.iter().enumerate() {
                sink.leaf(i as usize, leaf_vals[k]);
            }
        }
    }

    /// Pre-PR FasterTucker factor epoch: single worker, traversal-order
    /// blocks, per-leaf dispatch, scalar kernels.
    pub fn factor_epoch_bcsf(
        model: &mut ModelState,
        bcsf: &[BcsfTensor],
        cfg: &TrainConfig,
    ) {
        let order = model.order();
        let (j, r) = (model.j(), model.r());
        let scale = 1.0 - cfg.lr_a * cfg.lambda_a;
        for n in 0..order {
            let t = &bcsf[n];
            let internal = &t.csf.mode_order[..order - 1];
            let mut target =
                std::mem::replace(&mut model.factors[n], Matrix::zeros(0, 0));
            {
                let racy = RacyMatrix::new(&mut target);
                let mut sink = FactorSink {
                    c_tables: &model.c_tables,
                    modes: internal,
                    core_n: &model.cores[n],
                    racy: &racy,
                    scale,
                    lr: cfg.lr_a,
                    s: Scratch::new(order, j, r),
                };
                for b in 0..t.num_blocks() {
                    sink.s.prev_path.clear();
                    let dyn_sink: &mut dyn LeafSink = &mut sink;
                    drive_block(t, b, dyn_sink);
                }
            }
            model.factors[n] = target;
            model.refresh_c(n);
        }
    }
}

struct EpochRow {
    algo: &'static str,
    factor_ns_per_visit: f64,
    core_ns_per_visit: f64,
    staging_seconds: f64,
}

/// Mean seconds per factor/core pass on a fresh session (1 worker so the
/// sweep numbers are kernel cost, not scheduling noise), after one warm-up.
fn measure_algo(algo: Algo, cfg: &TrainConfig, data: &CooTensor, epochs: usize) -> EpochRow {
    let mut session = Session::new(algo, cfg.clone(), data).expect("session");
    let staging_seconds = session.prep_seconds();
    session.factor_pass();
    session.core_pass();
    let mut fs = Vec::new();
    let mut cs = Vec::new();
    for _ in 0..epochs {
        fs.push(session.factor_pass());
        cs.push(session.core_pass());
    }
    let visits = (cfg.order * data.nnz()) as f64;
    EpochRow {
        algo: algo.name(),
        factor_ns_per_visit: fs.iter().sum::<f64>() / fs.len() as f64 * 1e9 / visits,
        core_ns_per_visit: cs.iter().sum::<f64>() / cs.len() as f64 * 1e9 / visits,
        staging_seconds,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.iter().any(|a| a == "--list") {
        println!("microbench: bench");
        return;
    }
    let quick = args.iter().any(|a| a == "--quick");

    // ------------------------------------------------------ primitives
    let mut rng = Rng::new(1);
    let (order, j, r, dim) = (3usize, 32usize, 32usize, 4096usize);
    let factors: Vec<Matrix> =
        (0..order).map(|_| Matrix::uniform(dim, j, -0.2, 0.2, &mut rng)).collect();
    let cores: Vec<Matrix> =
        (0..order).map(|_| Matrix::uniform(j, r, -0.2, 0.2, &mut rng)).collect();
    let c_tables: Vec<Matrix> =
        factors.iter().zip(cores.iter()).map(|(a, b)| a.matmul(b)).collect();

    let mut table = Table::new(
        "microbench — hot-path primitives (ns/op)",
        &["primitive", "ns/op", "ops/s"],
    );
    let reps = if quick { 4_000usize } else { 20_000 };
    let modes = [0usize, 1];
    let coords_list: Vec<[u32; 2]> = (0..reps)
        .map(|_| [rng.next_below(dim) as u32, rng.next_below(dim) as u32])
        .collect();

    let mut scratch = Scratch::new(order, j, r);
    let mut rows: Vec<(String, f64)> = Vec::new();

    let s = time_fn(1, 5, || {
        for c in &coords_list {
            chain_v_from_tables(&c_tables, &modes, c, &mut scratch.v);
            std::hint::black_box(&scratch.v);
        }
    });
    rows.push(("chain_v (C tables, N=3)".into(), s.mean / reps as f64));

    let s = time_fn(1, 5, || {
        for c in &coords_list {
            chain_v_on_the_fly(&factors, &cores, &modes, c, &mut scratch.v);
            std::hint::black_box(&scratch.v);
        }
    });
    rows.push(("chain_v (on-the-fly, N=3)".into(), s.mean / reps as f64));

    let padded_core = cores[0].rank_padded();
    let v: Vec<f32> = (0..scratch.v.len()).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
    let s = time_fn(1, 5, || {
        for _ in 0..reps {
            fiber_w(&padded_core, &v, &mut scratch.w);
            std::hint::black_box(&scratch.w);
        }
    });
    rows.push(("fiber_w (B·v, 32x32, padded)".into(), s.mean / reps as f64));

    let mut target = factors[0].clone();
    {
        let racy = RacyMatrix::new(&mut target);
        let w: Vec<f32> = (0..j).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let s = time_fn(1, 5, || {
            for c in &coords_list {
                let i = c[0] as usize;
                let e = 1.0 - racy.row_dot(i, &w);
                racy.row_sgd_update(i, 0.999, 0.001 * e, &w);
            }
        });
        rows.push(("row dot+sgd_update (J=32)".into(), s.mean / reps as f64));
    }

    let s = time_fn(1, 3, || {
        let c = factors[0].matmul(&cores[0]);
        std::hint::black_box(&c);
    });
    rows.push((format!("C refresh GEMM ({dim}x{j}@{j}x{r})"), s.mean));

    let data = recommender(&RecommenderSpec::tiny(), 3);
    let s = time_fn(1, 3, || {
        let b = BcsfTensor::build_default(&data, 0);
        std::hint::black_box(&b);
    });
    rows.push(("B-CSF build (tiny, 4k nnz)".into(), s.mean));

    for (name, secs) in rows {
        table.row(vec![
            name,
            format!("{:.1}", secs * 1e9),
            format!("{:.3e}", 1.0 / secs),
        ]);
    }
    println!("{}", table.render());

    // ---------------------------------------------------- epoch sweeps
    let (nnz, ej, er, epochs) =
        if quick { (30_000usize, 8usize, 8usize, 2usize) } else { (300_000, 32, 32, 3) };
    let data = recommender(&RecommenderSpec::netflix_like(nnz), 90);
    let cfg = TrainConfig {
        order: data.order(),
        dims: data.dims().to_vec(),
        j: ej,
        r: er,
        lr_a: 1e-3,
        lr_b: 2e-5,
        workers: 1,
        eval_sample_nnz: 0,
        ..TrainConfig::default()
    };

    let algos = [
        Algo::FastTucker,
        Algo::FasterTuckerCoo,
        Algo::FasterTuckerBcsf,
        Algo::FasterTucker,
    ];
    let measured: Vec<EpochRow> =
        algos.iter().map(|&a| measure_algo(a, &cfg, &data, epochs)).collect();

    // Pre-PR baseline: per-leaf dyn dispatch + scalar kernels, same data,
    // same B-CSF structures, same number of epochs, measured right here.
    let bcsf: Vec<BcsfTensor> = (0..cfg.order)
        .map(|n| BcsfTensor::build(&data, n, cfg.fiber_threshold, cfg.block_nnz))
        .collect();
    let visits = (cfg.order * data.nnz()) as f64;
    let mut model = ModelState::init(&cfg, cfg.seed);
    legacy::factor_epoch_bcsf(&mut model, &bcsf, &cfg); // warm-up
    let mut ls = Vec::new();
    for _ in 0..epochs {
        let t = std::time::Instant::now();
        legacy::factor_epoch_bcsf(&mut model, &bcsf, &cfg);
        ls.push(t.elapsed().as_secs_f64());
    }
    let legacy_factor_ns = ls.iter().sum::<f64>() / ls.len() as f64 * 1e9 / visits;

    let current_factor_ns = measured
        .iter()
        .find(|m| m.algo == Algo::FasterTucker.name())
        .expect("fastertucker measured")
        .factor_ns_per_visit;
    let speedup = legacy_factor_ns / current_factor_ns;

    // Backend dimension: the Session path now routes every pass through a
    // `Box<dyn PassBackend>` (CpuShardBackend by default). Measure the
    // frozen pre-backend path — a direct generic-engine invocation over
    // the same once-built storage, exactly what `Session::engine_pass` did
    // before the backend layer — in the same run, so the dispatch
    // overhead of the backend seam is machine-checked per commit.
    let prebackend_factor_ns = {
        let storage = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &data)
            .expect("prepare");
        let mut state = EngineState::new();
        let mut model = ModelState::init(&cfg, cfg.seed);
        let chain = storage.chain();
        let factor = |m: &mut ModelState, st: &mut EngineState| {
            engine::factor_epoch_with(m, &storage, chain, &cfg, &engine::refresh_rust, st);
        };
        let core = |m: &mut ModelState, st: &mut EngineState| {
            engine::core_epoch_with(m, &storage, chain, &cfg, &engine::refresh_rust, st);
        };
        // same warm-up discipline as measure_algo: one untimed epoch
        factor(&mut model, &mut state);
        core(&mut model, &mut state);
        let mut fs = Vec::new();
        for _ in 0..epochs {
            let t = std::time::Instant::now();
            factor(&mut model, &mut state);
            fs.push(t.elapsed().as_secs_f64());
            core(&mut model, &mut state);
        }
        fs.iter().sum::<f64>() / fs.len() as f64 * 1e9 / visits
    };
    let backend_overhead_pct = (current_factor_ns / prebackend_factor_ns - 1.0) * 100.0;

    // Staging dimension: `PreparedStorage::prepare` routes the per-mode
    // B-CSF builds (and the fiber-run split inside each build) through the
    // executor. The serial baseline is measured *in this run*, on the same
    // tensor, so the reported speedup is self-contained.
    let stage_lanes = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8);
    let stage_reps = if quick { 2 } else { 3 };
    let mut scfg = cfg.clone();
    scfg.stage_workers = 1;
    let staging_serial = time_fn(1, stage_reps, || {
        let s = PreparedStorage::prepare(Algo::FasterTucker, &scfg, &data)
            .expect("serial staging");
        std::hint::black_box(&s);
    });
    scfg.stage_workers = stage_lanes;
    let staging_parallel = time_fn(1, stage_reps, || {
        let s = PreparedStorage::prepare(Algo::FasterTucker, &scfg, &data)
            .expect("parallel staging");
        std::hint::black_box(&s);
    });
    let staging_speedup = staging_serial.min / staging_parallel.min;

    // Refresh dimension: a sparse-touch workload — roughly 1% of mode-0
    // factor rows touched per round — full-table GEMM vs the dirty-row
    // incremental refresh (marking cost included: that is the real
    // per-pass bookkeeping).
    let mut rmodel = ModelState::init(&cfg, 7);
    let rows0 = cfg.dims[0];
    let touched: Vec<usize> = (0..rows0).step_by(101).collect();
    let refresh_reps = if quick { 20 } else { 50 };
    let refresh_full = time_fn(2, refresh_reps, || {
        rmodel.refresh_c(0);
        std::hint::black_box(&rmodel.c_tables[0]);
    });
    let refresh_incremental = time_fn(2, refresh_reps, || {
        rmodel.dirty[0].ensure(rows0);
        for &i in &touched {
            rmodel.dirty[0].mark(i);
        }
        rmodel.refresh_c_dirty(0, None);
        std::hint::black_box(&rmodel.c_tables[0]);
    });
    let refresh_speedup = refresh_full.min / refresh_incremental.min;

    // Sched dimension: static shared-counter LPT claiming vs
    // block-granular work stealing, multi-worker, on a deliberately
    // skewed tensor (quadratically biased coordinates concentrate
    // non-zeros into heavy head fibers, so per-block costs vary and idle
    // workers have something worth stealing). Both schedules run the
    // same Session path; the stealing run's `QosStats::steals` counter
    // witnesses that blocks actually migrated.
    let sched_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 4);
    let skew_nnz = if quick { 20_000usize } else { 150_000 };
    let skew_dim = 600usize;
    let skewed = {
        let mut t = CooTensor::new(vec![skew_dim, skew_dim, skew_dim]);
        let mut r = Rng::new(17);
        for _ in 0..skew_nnz {
            let c: Vec<u32> = (0..3)
                .map(|_| {
                    let u = r.next_below(skew_dim);
                    (u * u / skew_dim) as u32
                })
                .collect();
            t.push(&c, r.uniform_f32(0.5, 5.0));
        }
        t
    };
    let mut sched_cfg = cfg.clone();
    sched_cfg.dims = skewed.dims().to_vec();
    sched_cfg.workers = sched_workers;
    sched_cfg.block_nnz = 512; // many small blocks = stealable units
    let skew_visits = (sched_cfg.order * skewed.nnz()) as f64;
    let measure_sched = |mode: SchedMode| -> (f64, usize) {
        let mut c = sched_cfg.clone();
        c.sched = mode;
        let mut s = Session::new(Algo::FasterTucker, c, &skewed).expect("session");
        s.factor_pass();
        s.core_pass();
        let mut best = f64::INFINITY;
        for _ in 0..epochs {
            let t = std::time::Instant::now();
            s.factor_pass();
            s.core_pass();
            best = best.min(t.elapsed().as_secs_f64());
        }
        (best * 1e9 / skew_visits, s.qos_stats().steals)
    };
    let (sched_static_ns, _) = measure_sched(SchedMode::Static);
    let (sched_steal_ns, steal_count) = measure_sched(SchedMode::Stealing);
    let steal_speedup = sched_static_ns / sched_steal_ns;

    // Numa dimension: topology-blind untiled multi-worker epochs vs the
    // memory-hierarchy-aware path — NUMA-pinned workers reading node-local
    // operand replicas, with the cache-tiled prefetched leaf loop. Both
    // runs are the same Session path over the same tensor; the node count
    // is reported honestly, and the gate below only binds on machines
    // where placement can matter (≥2 nodes).
    let numa_nodes = fastertucker::sched::Topology::detect(NumaMode::Auto).nodes();
    let numa_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8);
    let measure_numa = |numa: NumaMode, tile_nnz: usize| -> f64 {
        let mut c = cfg.clone();
        c.workers = numa_workers;
        c.numa = numa;
        c.tile_nnz = tile_nnz;
        let mut s = Session::new(Algo::FasterTucker, c, &data).expect("session");
        s.factor_pass();
        s.core_pass();
        let mut best = f64::INFINITY;
        for _ in 0..epochs {
            let t = std::time::Instant::now();
            s.factor_pass();
            s.core_pass();
            best = best.min(t.elapsed().as_secs_f64());
        }
        best * 1e9 / visits
    };
    let numa_blind_ns = measure_numa(NumaMode::Off, usize::MAX);
    let numa_aware_ns = measure_numa(NumaMode::Auto, 0);
    let numa_speedup = numa_blind_ns / numa_aware_ns;

    // QoS dimension: serving p99 latency while a training tenant floods
    // the shared executor with full-budget passes. The pre-admission
    // behavior — every reader *blocks* for a worker lease — is measured
    // against the shipping admitted path (`try_acquire` + inline
    // fallback), same snapshot, same queries, same flood.
    let qos_workers = 2usize;
    let mut qreg = SessionRegistry::new(qos_workers, 0);
    let mut qcfg = cfg.clone();
    qcfg.workers = qos_workers;
    qreg.open("flood", Algo::FasterTucker, qcfg, &data).expect("open");
    qreg.step("flood", None).expect("step"); // warm + publish a snapshot
    let qos_executor = qreg.executor().clone();
    let mut flood = qreg.take_attached("flood").expect("tenant");
    let handle = flood.serving_handle().expect("handle");
    let mut fan = handle.clone();
    fan.set_executor(qos_executor.clone(), 1);
    let (d0, d2) = (data.dims()[0] as u32, data.dims()[2] as u32);
    let queries: Vec<TopKQuery> = (0..16u32)
        .map(|q| TopKQuery {
            mode: 1,
            fixed: vec![q * 7 % d0, q * 13 % d2],
            k: 10,
        })
        .collect();
    let qos_batches = if quick { 30usize } else { 120 };
    let p99 = |mut xs: Vec<f64>| -> f64 {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((xs.len() as f64 * 0.99).ceil() as usize).clamp(1, xs.len());
        xs[idx - 1]
    };
    let mut qos_phase = |blocking: bool| -> f64 {
        use std::sync::atomic::{AtomicBool, Ordering};
        let stop = AtomicBool::new(false);
        let mut lats = Vec::with_capacity(qos_batches);
        std::thread::scope(|sc| {
            sc.spawn(|| {
                while !stop.load(Ordering::Relaxed) {
                    flood.factor_pass();
                    flood.core_pass();
                }
            });
            for _ in 0..qos_batches {
                let t = std::time::Instant::now();
                if blocking {
                    qos_executor.run_quiet_leased(1, |_w| {
                        let r = handle.top_k_batch(&queries).expect("topk");
                        std::hint::black_box(&r);
                    });
                } else {
                    let r = fan.top_k_batch(&queries).expect("topk");
                    std::hint::black_box(&r);
                }
                lats.push(t.elapsed().as_secs_f64());
            }
            stop.store(true, Ordering::Relaxed);
        });
        p99(lats)
    };
    let qos_blocking_p99 = qos_phase(true);
    let qos_admitted_p99 = qos_phase(false);
    let qos_speedup = qos_blocking_p99 / qos_admitted_p99;

    // Ingest dimension: absorbing a ~1% appended COO delta — cold full
    // re-stage of the concatenated tensor vs the incremental
    // `PreparedStorage::restage`, which re-sorts from the pristine input
    // but carries every B-CSF block ahead of the first delta-touched
    // element over bitwise-unchanged. The delta is tail-concentrated
    // (high indices in every mode — the shape online appends actually
    // have), so most of every rotation's sort order stays clean.
    let ingest_base =
        PreparedStorage::prepare(Algo::FasterTucker, &cfg, &data).expect("base");
    let delta_nnz = (data.nnz() / 100).max(16);
    let delta = {
        let mut d = CooTensor::new(cfg.dims.clone());
        let mut r = Rng::new(23);
        for _ in 0..delta_nnz {
            let c: Vec<u32> = cfg
                .dims
                .iter()
                .map(|&dim| (dim - 1 - r.next_below((dim / 50).max(1))) as u32)
                .collect();
            d.push(&c, r.uniform_f32(0.5, 5.0));
        }
        d
    };
    let merged = {
        let mut m =
            CooTensor::with_capacity(cfg.dims.clone(), data.nnz() + delta.nnz());
        for e in 0..data.nnz() {
            m.push(data.index(e), data.value(e));
        }
        for e in 0..delta.nnz() {
            m.push(delta.index(e), delta.value(e));
        }
        m
    };
    let ingest_reps = if quick { 2 } else { 3 };
    let ingest_full = time_fn(1, ingest_reps, || {
        let s = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &merged)
            .expect("full re-stage");
        std::hint::black_box(&s);
    });
    let ingest_incremental = time_fn(1, ingest_reps, || {
        let s = ingest_base.restage(&cfg, &merged, &delta).expect("restage");
        std::hint::black_box(&s);
    });
    let ingest_speedup = ingest_full.min / ingest_incremental.min;
    let (ingest_reused, ingest_rebuilt) = {
        let s = ingest_base.restage(&cfg, &merged, &delta).expect("restage");
        (s.prep().blocks_reused, s.prep().blocks_rebuilt)
    };

    let mut etable = Table::new(
        "epoch sweeps — ns per non-zero visit (1 worker; staging separate)",
        &["algorithm", "factor ns/nnz", "core ns/nnz", "staging s"],
    );
    for m in &measured {
        etable.row(vec![
            m.algo.to_string(),
            format!("{:.1}", m.factor_ns_per_visit),
            format!("{:.1}", m.core_ns_per_visit),
            format!("{:.4}", m.staging_seconds),
        ]);
    }
    etable.row(vec![
        "pre-PR baseline (per-leaf dyn, scalar kernels)".to_string(),
        format!("{:.1}", legacy_factor_ns),
        "-".to_string(),
        "-".to_string(),
    ]);
    etable.row(vec![
        "pre-backend path (direct engine, no dyn PassBackend)".to_string(),
        format!("{:.1}", prebackend_factor_ns),
        "-".to_string(),
        "-".to_string(),
    ]);
    println!("{}", etable.render());
    println!(
        "cuFasterTucker factor sweep speedup vs pre-PR baseline: {speedup:.2}x"
    );
    println!(
        "CpuShardBackend dispatch overhead vs pre-backend path: {backend_overhead_pct:+.2}%"
    );
    println!(
        "staging speedup (stage_workers {stage_lanes} vs 1, same run): {staging_speedup:.2}x"
    );
    println!(
        "refresh speedup (dirty-row incremental vs full, ~1% rows touched): \
         {refresh_speedup:.2}x"
    );
    println!(
        "sched: static {sched_static_ns:.1} vs stealing {sched_steal_ns:.1} \
         ns/nnz ({sched_workers} workers, skewed blocks, {steal_count} steals): \
         {steal_speedup:.2}x"
    );
    println!(
        "qos: serving batch p99 under training flood — blocking \
         {:.0}us vs admitted {:.0}us: {qos_speedup:.2}x",
        qos_blocking_p99 * 1e6,
        qos_admitted_p99 * 1e6
    );
    println!(
        "ingest: full re-stage {:.4}s vs incremental restage {:.4}s \
         ({} nnz delta; {ingest_reused} blocks reused, {ingest_rebuilt} \
         rebuilt): {ingest_speedup:.2}x",
        ingest_full.min,
        ingest_incremental.min,
        delta.nnz()
    );
    println!(
        "numa: blind untiled {numa_blind_ns:.1} vs pinned+replicated+tiled \
         {numa_aware_ns:.1} ns/nnz ({numa_nodes} node(s), {numa_workers} \
         workers): {numa_speedup:.2}x"
    );

    let algo_rows: Vec<Json> = measured
        .iter()
        .map(|m| {
            Json::obj(vec![
                ("algo", Json::str(m.algo)),
                ("factor_ns_per_nnz", Json::num(m.factor_ns_per_visit)),
                ("core_ns_per_nnz", Json::num(m.core_ns_per_visit)),
                ("staging_seconds", Json::num(m.staging_seconds)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::str("bench_epoch_v6")),
        ("quick", Json::Bool(quick)),
        ("nnz", Json::num(data.nnz() as f64)),
        ("order", Json::num(cfg.order as f64)),
        ("j", Json::num(cfg.j as f64)),
        ("r", Json::num(cfg.r as f64)),
        ("workers", Json::num(1.0)),
        ("epochs", Json::num(epochs as f64)),
        ("algos", Json::Arr(algo_rows)),
        (
            "baseline",
            Json::obj(vec![
                (
                    "description",
                    Json::str(
                        "pre-PR FasterTucker factor pass: \
                         per-leaf dyn dispatch + scalar kernels",
                    ),
                ),
                ("factor_ns_per_nnz", Json::num(legacy_factor_ns)),
            ]),
        ),
        ("fastertucker_factor_speedup_vs_baseline", Json::num(speedup)),
        (
            "backend",
            Json::obj(vec![
                ("name", Json::str("cpu")),
                (
                    "description",
                    Json::str(
                        "Session pass via Box<dyn PassBackend> (CpuShardBackend) \
                         vs the frozen pre-backend direct engine invocation, \
                         same storage, same run",
                    ),
                ),
                ("factor_ns_per_nnz", Json::num(current_factor_ns)),
                ("prebackend_factor_ns_per_nnz", Json::num(prebackend_factor_ns)),
                ("overhead_pct", Json::num(backend_overhead_pct)),
            ]),
        ),
        (
            "staging",
            Json::obj(vec![
                (
                    "description",
                    Json::str(
                        "executor-parallel PreparedStorage::prepare \
                         (per-mode B-CSF builds + intra-build fiber-run \
                         splits) vs the in-run serial baseline",
                    ),
                ),
                ("staging_workers", Json::num(stage_lanes as f64)),
                ("serial_seconds", Json::num(staging_serial.min)),
                ("parallel_seconds", Json::num(staging_parallel.min)),
                ("speedup", Json::num(staging_speedup)),
            ]),
        ),
        (
            "refresh",
            Json::obj(vec![
                (
                    "description",
                    Json::str(
                        "dirty-row incremental C-refresh vs full-table GEMM \
                         on a sparse-touch workload (~1% of rows marked)",
                    ),
                ),
                ("rows", Json::num(rows0 as f64)),
                ("touched_rows", Json::num(touched.len() as f64)),
                ("full_seconds", Json::num(refresh_full.min)),
                ("incremental_seconds", Json::num(refresh_incremental.min)),
                ("speedup", Json::num(refresh_speedup)),
            ]),
        ),
        (
            "sched",
            Json::obj(vec![
                (
                    "description",
                    Json::str(
                        "static shared-counter LPT claiming vs block-granular \
                         work stealing (--sched stealing), whole factor+core \
                         epochs on a skewed fiber distribution, same run",
                    ),
                ),
                ("workers", Json::num(sched_workers as f64)),
                ("block_nnz", Json::num(512.0)),
                ("skew_nnz", Json::num(skewed.nnz() as f64)),
                ("static_ns_per_nnz", Json::num(sched_static_ns)),
                ("stealing_ns_per_nnz", Json::num(sched_steal_ns)),
                ("steals", Json::num(steal_count as f64)),
                ("speedup", Json::num(steal_speedup)),
            ]),
        ),
        (
            "qos",
            Json::obj(vec![
                (
                    "description",
                    Json::str(
                        "serving batch p99 under a training flood on a shared \
                         executor: blocking lease acquisition (pre-admission \
                         behavior) vs the shipping non-blocking admitted path \
                         (try_acquire + inline fallback)",
                    ),
                ),
                ("workers", Json::num(qos_workers as f64)),
                ("batches", Json::num(qos_batches as f64)),
                ("queries_per_batch", Json::num(queries.len() as f64)),
                ("blocking_p99_seconds", Json::num(qos_blocking_p99)),
                ("admitted_p99_seconds", Json::num(qos_admitted_p99)),
                ("p99_speedup", Json::num(qos_speedup)),
            ]),
        ),
        (
            "ingest",
            Json::obj(vec![
                (
                    "description",
                    Json::str(
                        "absorbing a tail-concentrated ~1% COO delta: cold \
                         full re-stage of the concatenated tensor vs the \
                         incremental restage that carries every clean-prefix \
                         B-CSF block over bitwise-unchanged",
                    ),
                ),
                ("delta_nnz", Json::num(delta.nnz() as f64)),
                ("blocks_reused", Json::num(ingest_reused as f64)),
                ("blocks_rebuilt", Json::num(ingest_rebuilt as f64)),
                ("full_restage_seconds", Json::num(ingest_full.min)),
                ("incremental_seconds", Json::num(ingest_incremental.min)),
                ("speedup", Json::num(ingest_speedup)),
            ]),
        ),
        (
            "numa",
            Json::obj(vec![
                (
                    "description",
                    Json::str(
                        "topology-blind untiled multi-worker epochs (--numa \
                         off, tiling disabled) vs NUMA-pinned workers reading \
                         node-local replicas through the cache-tiled \
                         prefetched leaf loop (--numa auto, auto tile), same \
                         tensor, same run",
                    ),
                ),
                ("nodes", Json::num(numa_nodes as f64)),
                ("workers", Json::num(numa_workers as f64)),
                ("blind_ns_per_nnz", Json::num(numa_blind_ns)),
                ("aware_ns_per_nnz", Json::num(numa_aware_ns)),
                ("speedup", Json::num(numa_speedup)),
            ]),
        ),
    ]);
    let out = std::env::var("FT_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_epoch.json".to_string());
    match std::fs::write(&out, doc.to_string_pretty()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("warning: could not write {out}: {e}"),
    }

    // Optional regression gate: FT_MIN_SPEEDUP=1.3 makes the run fail when
    // the measured baseline-vs-current factor-sweep speedup drops below the
    // bound (CI's bench-smoke sets a noise-tolerant bound for quick mode;
    // the PR acceptance bound is 1.3 at full scale).
    if let Ok(bound) = std::env::var("FT_MIN_SPEEDUP") {
        let bound: f64 = bound.parse().expect("FT_MIN_SPEEDUP must be a float");
        assert!(
            speedup >= bound,
            "factor-sweep speedup {speedup:.2}x fell below the FT_MIN_SPEEDUP \
             bound {bound:.2}x — hot-path regression"
        );
    }

    // Backend-overhead gate: FT_MAX_BACKEND_OVERHEAD_PCT=1 enforces the
    // ≤1% acceptance bound on the CpuShardBackend dispatch cost at full
    // scale (CI's quick mode sets a noise-tolerant bound; sub-millisecond
    // pass times on shared runners jitter far more than 1%).
    if let Ok(bound) = std::env::var("FT_MAX_BACKEND_OVERHEAD_PCT") {
        let bound: f64 =
            bound.parse().expect("FT_MAX_BACKEND_OVERHEAD_PCT must be a float");
        assert!(
            backend_overhead_pct <= bound,
            "CpuShardBackend overhead {backend_overhead_pct:.2}% exceeds the \
             FT_MAX_BACKEND_OVERHEAD_PCT bound {bound:.2}% — the PassBackend \
             seam leaked cost into the hot path"
        );
    }

    // Staging gate: FT_MIN_STAGING_SPEEDUP bounds the executor-parallel
    // prepare against the in-run serial baseline (PR acceptance: ≥1.5 at
    // 4+ workers at full scale; CI smoke sets a noise-tolerant bound).
    if let Ok(bound) = std::env::var("FT_MIN_STAGING_SPEEDUP") {
        let bound: f64 =
            bound.parse().expect("FT_MIN_STAGING_SPEEDUP must be a float");
        assert!(
            staging_speedup >= bound,
            "staging speedup {staging_speedup:.2}x (stage_workers {stage_lanes}) \
             fell below the FT_MIN_STAGING_SPEEDUP bound {bound:.2}x — the \
             parallel staging pipeline regressed"
        );
    }

    // Refresh gate: FT_MIN_REFRESH_SPEEDUP bounds the dirty-row incremental
    // refresh against the full-table GEMM on the sparse-touch workload.
    if let Ok(bound) = std::env::var("FT_MIN_REFRESH_SPEEDUP") {
        let bound: f64 =
            bound.parse().expect("FT_MIN_REFRESH_SPEEDUP must be a float");
        assert!(
            refresh_speedup >= bound,
            "incremental-refresh speedup {refresh_speedup:.2}x fell below the \
             FT_MIN_REFRESH_SPEEDUP bound {bound:.2}x — dirty-row refresh \
             stopped paying for itself"
        );
    }

    // Sched gate: FT_MIN_STEAL_SPEEDUP bounds stealing vs static on the
    // skewed workload. Static claiming is already dynamic (shared-counter
    // LPT), so the full-scale acceptance bound is a modest 1.05; quick
    // mode's sub-millisecond passes jitter more than the schedulers
    // differ, so CI smoke only catches stealing becoming grossly slower.
    if let Ok(bound) = std::env::var("FT_MIN_STEAL_SPEEDUP") {
        let bound: f64 =
            bound.parse().expect("FT_MIN_STEAL_SPEEDUP must be a float");
        assert!(
            steal_speedup >= bound,
            "stealing speedup {steal_speedup:.2}x fell below the \
             FT_MIN_STEAL_SPEEDUP bound {bound:.2}x — block-granular \
             stealing regressed vs static LPT claiming"
        );
    }

    // QoS gate: FT_MIN_QOS_SPEEDUP bounds the p99 improvement of the
    // admitted (non-blocking) serving path over blocking lease
    // acquisition under the training flood (full-scale acceptance: ≥2;
    // the admitted path never parks in the queue, so its p99 is pure
    // scoring cost while the blocking path eats pass-length waits).
    if let Ok(bound) = std::env::var("FT_MIN_QOS_SPEEDUP") {
        let bound: f64 =
            bound.parse().expect("FT_MIN_QOS_SPEEDUP must be a float");
        assert!(
            qos_speedup >= bound,
            "admitted-serving p99 speedup {qos_speedup:.2}x fell below the \
             FT_MIN_QOS_SPEEDUP bound {bound:.2}x — admission control \
             stopped protecting readers from training floods"
        );
    }

    // Ingest gate: FT_MIN_INGEST_SPEEDUP bounds the incremental restage
    // against the cold full re-stage on the appended-delta workload
    // (full-scale acceptance: ≥2 — nearly every block sits ahead of the
    // first delta-touched element; CI smoke sets 0.9, catching only
    // incremental ingestion becoming slower than starting over).
    if let Ok(bound) = std::env::var("FT_MIN_INGEST_SPEEDUP") {
        let bound: f64 =
            bound.parse().expect("FT_MIN_INGEST_SPEEDUP must be a float");
        assert!(
            ingest_speedup >= bound,
            "incremental-ingest speedup {ingest_speedup:.2}x fell below the \
             FT_MIN_INGEST_SPEEDUP bound {bound:.2}x — dirty-block restage \
             stopped beating a cold re-stage"
        );
    }

    // Numa gate: FT_MIN_NUMA_SPEEDUP bounds the memory-hierarchy-aware path
    // (pinned workers + node replicas + cache tiling) against the
    // topology-blind untiled run. Placement only pays for itself when the
    // machine actually has remote memory, so the bound is enforced only at
    // ≥2 detected NUMA nodes (full-scale acceptance there: ≥1.15; CI smoke
    // sets 1, catching only outright regressions). Single-node machines
    // report the measurement honestly and skip the gate.
    if let Ok(bound) = std::env::var("FT_MIN_NUMA_SPEEDUP") {
        let bound: f64 =
            bound.parse().expect("FT_MIN_NUMA_SPEEDUP must be a float");
        if numa_nodes >= 2 {
            assert!(
                numa_speedup >= bound,
                "numa-aware speedup {numa_speedup:.2}x fell below the \
                 FT_MIN_NUMA_SPEEDUP bound {bound:.2}x at {numa_nodes} \
                 nodes — pinning + replicas + tiling stopped paying for \
                 themselves"
            );
        } else {
            println!(
                "numa gate skipped: {numa_nodes} node(s) detected (bound \
                 {bound:.2}x applies at >=2 nodes; measured \
                 {numa_speedup:.2}x)"
            );
        }
    }
}
