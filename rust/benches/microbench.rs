//! Microbenchmarks for the hot-path primitives: chain products (table vs
//! on-the-fly), fiber `w` matvec, row SGD update, C-table GEMM, and B-CSF
//! construction. Feeds the §Perf iteration log in EXPERIMENTS.md.

use fastertucker::algo::grad::{
    chain_v_from_tables, chain_v_on_the_fly, fiber_w, Scratch,
};
use fastertucker::bench::{time_fn, Table};
use fastertucker::data::synthetic::{recommender, RecommenderSpec};
use fastertucker::linalg::Matrix;
use fastertucker::sched::racy::RacyMatrix;
use fastertucker::tensor::bcsf::BcsfTensor;
use fastertucker::util::rng::Rng;

fn main() {
    if std::env::args().any(|a| a == "--list") {
        println!("microbench: bench");
        return;
    }
    let mut rng = Rng::new(1);
    let (order, j, r, dim) = (3usize, 32usize, 32usize, 4096usize);
    let factors: Vec<Matrix> =
        (0..order).map(|_| Matrix::uniform(dim, j, -0.2, 0.2, &mut rng)).collect();
    let cores: Vec<Matrix> =
        (0..order).map(|_| Matrix::uniform(j, r, -0.2, 0.2, &mut rng)).collect();
    let c_tables: Vec<Matrix> =
        factors.iter().zip(cores.iter()).map(|(a, b)| a.matmul(b)).collect();

    let mut table = Table::new(
        "microbench — hot-path primitives (ns/op)",
        &["primitive", "ns/op", "ops/s"],
    );
    let reps = 20_000usize;
    let modes = [0usize, 1];
    let coords_list: Vec<[u32; 2]> = (0..reps)
        .map(|_| [rng.next_below(dim) as u32, rng.next_below(dim) as u32])
        .collect();

    let mut scratch = Scratch::new(order, j, r);
    let mut rows: Vec<(String, f64)> = Vec::new();

    let s = time_fn(1, 5, || {
        for c in &coords_list {
            chain_v_from_tables(&c_tables, &modes, c, &mut scratch.v);
            std::hint::black_box(&scratch.v);
        }
    });
    rows.push(("chain_v (C tables, N=3)".into(), s.mean / reps as f64));

    let s = time_fn(1, 5, || {
        for c in &coords_list {
            chain_v_on_the_fly(&factors, &cores, &modes, c, &mut scratch.v);
            std::hint::black_box(&scratch.v);
        }
    });
    rows.push(("chain_v (on-the-fly, N=3)".into(), s.mean / reps as f64));

    let v: Vec<f32> = (0..r).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
    let s = time_fn(1, 5, || {
        for _ in 0..reps {
            fiber_w(&cores[0], &v, &mut scratch.w);
            std::hint::black_box(&scratch.w);
        }
    });
    rows.push(("fiber_w (B·v, 32x32)".into(), s.mean / reps as f64));

    let mut target = factors[0].clone();
    {
        let racy = RacyMatrix::new(&mut target);
        let w: Vec<f32> = (0..j).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let s = time_fn(1, 5, || {
            for c in &coords_list {
                let i = c[0] as usize;
                let e = 1.0 - racy.row_dot(i, &w);
                racy.row_sgd_update(i, 0.999, 0.001 * e, &w);
            }
        });
        rows.push(("row dot+sgd_update (J=32)".into(), s.mean / reps as f64));
    }

    let s = time_fn(1, 3, || {
        let c = factors[0].matmul(&cores[0]);
        std::hint::black_box(&c);
    });
    rows.push((format!("C refresh GEMM ({dim}x{j}@{j}x{r})"), s.mean));

    let data = recommender(&RecommenderSpec::tiny(), 3);
    let s = time_fn(1, 3, || {
        let b = BcsfTensor::build_default(&data, 0);
        std::hint::black_box(&b);
    });
    rows.push(("B-CSF build (tiny, 4k nnz)".into(), s.mean));

    for (name, secs) in rows {
        table.row(vec![
            name,
            format!("{:.1}", secs * 1e9),
            format!("{:.3e}", 1.0 / secs),
        ]);
    }
    println!("{}", table.render());
}
