//! The dense full core tensor `G ∈ R^{J×J×…×J}` (N times) used by the
//! classic Tucker baselines, with the contraction kernels both need.
//!
//! Storage: one row-major copy *per mode*, `perm[n]` laid out with mode `n`
//! first (`G_n[j_n, rest]`), so the mode-n partial contraction
//! `h[j_n] = Σ_rest G[j_n, rest]·Π_{m≠n} a^{(m)}[j_m]` reduces to a chain of
//! contiguous dot products (progressive contraction, cost ≈ J^{N-1}·(1+1/J+…)
//! per element instead of N·J^N for the naive sum).

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Full core tensor with per-mode permuted copies.
#[derive(Clone, Debug)]
pub struct CoreTensor {
    /// Order N.
    order: usize,
    /// Rank J (uniform).
    j: usize,
    /// `perm[n]`: G with mode n slowest; length `J^N` each.
    perm: Vec<Vec<f32>>,
}

impl CoreTensor {
    /// `J^N` — panics on overflow (the "out of memory" verdict of Table IV
    /// is produced by [`super::costmodel`] *before* anyone constructs this).
    pub fn len(order: usize, j: usize) -> usize {
        j.checked_pow(order as u32).expect("core tensor size overflow")
    }

    /// Random uniform init in `[0, s)`.
    pub fn init(order: usize, j: usize, s: f32, rng: &mut Rng) -> CoreTensor {
        let n = Self::len(order, j);
        let base: Vec<f32> = (0..n).map(|_| rng.uniform_f32(0.0, s)).collect();
        let mut ct = CoreTensor { order, j, perm: vec![base; order] };
        ct.rebuild_perms_from(0);
        ct
    }

    /// Order N.
    #[inline]
    pub fn order(&self) -> usize {
        self.order
    }
    /// Rank J (uniform across modes).
    #[inline]
    pub fn j(&self) -> usize {
        self.j
    }

    /// The canonical (mode-0-major) storage.
    pub fn canonical(&self) -> &[f32] {
        &self.perm[0]
    }

    /// Rebuild all permuted copies from copy `src` (after an update).
    pub fn rebuild_perms_from(&mut self, src: usize) {
        let (order, j) = (self.order, self.j);
        let n = self.perm[src].len();
        let base = self.perm[src].clone();
        // decode src layout: mode order is [src, 0,1,..,src-1,src+1,..]
        // We define perm[n] layout as mode order [n, 0..N without n].
        // map flat index in perm[src] -> multi-index -> flat in perm[dst].
        let mode_order = |m: usize| -> Vec<usize> {
            let mut v = vec![m];
            v.extend((0..order).filter(|&x| x != m));
            v
        };
        let src_order = mode_order(src);
        let mut idx = vec![0usize; order]; // multi-index by true mode id
        for dst in 0..order {
            if dst == src {
                continue;
            }
            let dst_order = mode_order(dst);
            let out = &mut self.perm[dst];
            // iterate flat over src layout, maintaining the multi-index
            idx.iter_mut().for_each(|x| *x = 0);
            for (flat, &v) in base.iter().enumerate() {
                // compute dst flat index
                let mut f = 0usize;
                for &m in &dst_order {
                    f = f * j + idx[m];
                }
                out[f] = v;
                let _ = flat;
                // increment multi-index in src order (last fastest)
                for k in (0..order).rev() {
                    let m = src_order[k];
                    idx[m] += 1;
                    if idx[m] < j {
                        break;
                    }
                    idx[m] = 0;
                }
            }
            debug_assert_eq!(out.len(), n);
        }
    }

    /// Progressive contraction: `h[j_n] = Σ_{rest} G_n[j_n, rest] · Π a`,
    /// where `rows[k]` is the factor row of the k-th *other* mode in
    /// ascending mode order. `scratch` must hold `J^{N-1}` floats; `h` holds
    /// `J` floats.
    pub fn contract_except(
        &self,
        n: usize,
        rows: &[&[f32]],
        scratch: &mut Vec<f32>,
        h: &mut [f32],
    ) {
        let (order, j) = (self.order, self.j);
        debug_assert_eq!(rows.len(), order - 1);
        debug_assert_eq!(h.len(), j);
        let g = &self.perm[n];
        // layout of perm[n]: [n, others ascending]; contract others from the
        // last (stride-1) inward.
        // pass 1: contract the last other-mode directly from g.
        let mut cur_len = g.len();
        scratch.clear();
        scratch.resize(cur_len / j, 0.0);
        {
            let a = rows[order - 2];
            for (o, chunk) in scratch.iter_mut().zip(g.chunks_exact(j)) {
                let mut s = 0.0f32;
                for (x, &ai) in chunk.iter().zip(a.iter()) {
                    s += x * ai;
                }
                *o = s;
            }
            cur_len /= j;
        }
        // passes 2..: contract remaining other-modes in place
        for k in (0..order - 2).rev() {
            let a = rows[k];
            let new_len = cur_len / j;
            for out_i in 0..new_len {
                let base = out_i * j;
                let mut s = 0.0f32;
                for (jj, &ai) in a.iter().enumerate() {
                    s += scratch[base + jj] * ai;
                }
                scratch[out_i] = s;
            }
            cur_len = new_len;
        }
        debug_assert_eq!(cur_len, j);
        h.copy_from_slice(&scratch[..j]);
    }

    /// Accumulate the core gradient for one non-zero into `grad`
    /// (canonical layout): `grad += e · a^(0) ⊗ a^(1) ⊗ … ⊗ a^(N-1)`.
    pub fn accumulate_grad(
        order: usize,
        j: usize,
        grad: &mut [f32],
        e: f32,
        rows: &[&[f32]],
        scratch: &mut Vec<f32>,
    ) {
        debug_assert_eq!(rows.len(), order);
        debug_assert_eq!(grad.len(), j.pow(order as u32));
        // expand outer product progressively: start [e], multiply per mode
        scratch.clear();
        scratch.push(e);
        for a in rows {
            let prev_len = scratch.len();
            scratch.resize(prev_len * j, 0.0);
            // expand in place from the back
            for i in (0..prev_len).rev() {
                let p = scratch[i];
                let base = i * j;
                for (jj, &aj) in a.iter().enumerate() {
                    scratch[base + jj] = p * aj;
                }
            }
        }
        for (g, &s) in grad.iter_mut().zip(scratch.iter()) {
            *g += s;
        }
    }

    /// Apply an accumulated gradient: `G ← G + γ(grad/|Ω| − λG)` and refresh
    /// the permuted copies.
    pub fn apply_grad(&mut self, grad: &[f32], nnz: usize, lr: f32, lambda: f32) {
        let inv = 1.0 / nnz.max(1) as f32;
        for (g, &d) in self.perm[0].iter_mut().zip(grad.iter()) {
            *g += lr * (d * inv - lambda * *g);
        }
        self.rebuild_perms_from(0);
    }

    /// Predict `x̂ = Σ G[j…] Π a` given all N factor rows.
    pub fn predict(&self, rows: &[&[f32]], scratch: &mut Vec<f32>, h: &mut [f32]) -> f32 {
        self.contract_except(0, &rows[1..], scratch, h);
        let mut s = 0.0f32;
        for (&hi, &ai) in h.iter().zip(rows[0].iter()) {
            s += hi * ai;
        }
        s
    }

    /// Frobenius norm² (regularization term).
    pub fn norm_sq(&self) -> f64 {
        self.perm[0].iter().map(|&x| (x as f64) * (x as f64)).sum()
    }
}

/// Gather the factor rows for modes ≠ n in ascending order.
pub fn other_rows<'a>(
    factors: &'a [Matrix],
    coords: &[u32],
    n: usize,
    out: &mut Vec<&'a [f32]>,
) {
    out.clear();
    for (m, &c) in coords.iter().enumerate() {
        if m != n {
            out.push(factors[m].row(c as usize));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_contract(ct: &CoreTensor, n: usize, rows: &[&[f32]]) -> Vec<f32> {
        let (order, j) = (ct.order(), ct.j());
        let g = ct.canonical();
        let mut h = vec![0.0f32; j];
        let total = g.len();
        let mut idx = vec![0usize; order];
        for flat in 0..total {
            // canonical layout: mode 0 slowest, mode N-1 fastest
            let mut rem = flat;
            for m in (0..order).rev() {
                idx[m] = rem % j;
                rem /= j;
            }
            let mut p = 1.0f32;
            let mut k = 0;
            for m in 0..order {
                if m != n {
                    p *= rows[k][idx[m]];
                    k += 1;
                }
            }
            h[idx[n]] += g[flat] * p;
        }
        h
    }

    #[test]
    fn progressive_contraction_matches_naive() {
        let mut rng = Rng::new(1);
        for order in [2usize, 3, 4] {
            let j = 4;
            let ct = CoreTensor::init(order, j, 1.0, &mut rng);
            let row_data: Vec<Vec<f32>> = (0..order)
                .map(|_| (0..j).map(|_| rng.uniform_f32(-1.0, 1.0)).collect())
                .collect();
            for n in 0..order {
                let rows: Vec<&[f32]> = (0..order)
                    .filter(|&m| m != n)
                    .map(|m| row_data[m].as_slice())
                    .collect();
                let mut scratch = Vec::new();
                let mut h = vec![0.0f32; j];
                ct.contract_except(n, &rows, &mut scratch, &mut h);
                let expect = naive_contract(&ct, n, &rows);
                for (a, b) in h.iter().zip(expect.iter()) {
                    assert!(
                        (a - b).abs() < 1e-3,
                        "order {order} mode {n}: {h:?} vs {expect:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn perm_copies_consistent() {
        let mut rng = Rng::new(2);
        let ct = CoreTensor::init(3, 3, 1.0, &mut rng);
        // element (1,2,0) must be identical in every permuted copy
        let j = 3;
        let (a, b, c) = (1usize, 2usize, 0usize);
        let v0 = ct.perm[0][(a * j + b) * j + c]; // layout [0,1,2]
        let v1 = ct.perm[1][(b * j + a) * j + c]; // layout [1,0,2]
        let v2 = ct.perm[2][(c * j + a) * j + b]; // layout [2,0,1]
        assert_eq!(v0, v1);
        assert_eq!(v0, v2);
    }

    #[test]
    fn predict_matches_full_sum() {
        let mut rng = Rng::new(3);
        let ct = CoreTensor::init(3, 4, 1.0, &mut rng);
        let rows_data: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..4).map(|_| rng.uniform_f32(0.0, 1.0)).collect())
            .collect();
        let rows: Vec<&[f32]> = rows_data.iter().map(|v| v.as_slice()).collect();
        let mut scratch = Vec::new();
        let mut h = vec![0.0f32; 4];
        let p = ct.predict(&rows, &mut scratch, &mut h);
        // naive
        let mut expect = 0.0f32;
        let g = ct.canonical();
        for j0 in 0..4 {
            for j1 in 0..4 {
                for j2 in 0..4 {
                    expect += g[(j0 * 4 + j1) * 4 + j2]
                        * rows[0][j0]
                        * rows[1][j1]
                        * rows[2][j2];
                }
            }
        }
        assert!((p - expect).abs() < 1e-3, "{p} vs {expect}");
    }

    #[test]
    fn grad_is_outer_product() {
        let (order, j) = (3, 2);
        let rows_data: Vec<Vec<f32>> =
            vec![vec![1.0, 2.0], vec![3.0, 5.0], vec![7.0, 11.0]];
        let rows: Vec<&[f32]> = rows_data.iter().map(|v| v.as_slice()).collect();
        let mut grad = vec![0.0f32; 8];
        let mut scratch = Vec::new();
        CoreTensor::accumulate_grad(order, j, &mut grad, 2.0, &rows, &mut scratch);
        // grad[(j0*2+j1)*2+j2] = 2 * a0[j0]*a1[j1]*a2[j2]
        assert_eq!(grad[0], 2.0 * 1.0 * 3.0 * 7.0);
        assert_eq!(grad[7], 2.0 * 2.0 * 5.0 * 11.0);
        assert_eq!(grad[5], 2.0 * 2.0 * 3.0 * 11.0);
    }

    #[test]
    fn apply_grad_updates_and_rebuilds() {
        let mut rng = Rng::new(4);
        let mut ct = CoreTensor::init(2, 2, 1.0, &mut rng);
        let before = ct.perm[0].clone();
        let grad = vec![1.0f32; 4];
        ct.apply_grad(&grad, 1, 0.1, 0.0);
        for (a, b) in ct.perm[0].iter().zip(before.iter()) {
            assert!((a - (b + 0.1)).abs() < 1e-6);
        }
        // perm[1] must reflect the update too (transpose for order 2)
        assert_eq!(ct.perm[1][1], ct.perm[0][2]);
    }
}
