//! Analytical cost model for Table IV verdicts.
//!
//! The paper's Table IV reports, for several full-Tucker systems, either a
//! single-iteration time or a failure mode (`out of memory` / `out of
//! time`). We fully implement P-Tucker and cuTucker; for **Vest, ParTi and
//! GTA** (closed or CUDA-only code bases) we reproduce the *verdicts* from
//! first-principles cost formulas, calibrated against our measured cuTucker
//! throughput. Every estimated row is labelled `estimated` in the bench
//! output — never presented as a measurement.
//!
//! Formulas (per iteration, J = rank per mode, N = order, |Ω| = nnz):
//!
//! * memory for TTM-style intermediates (ParTi, GTA): the mode-n TTM chain
//!   materializes `|Ω|·J^{N-1}` floats in the worst case.
//! * Vest: coordinate-wise updates over the full core with pruning —
//!   `c_vest·|Ω|·J^N` flops with a large constant (their paper reports
//!   minutes-per-iteration at this scale).
//! * GTA/ParTi compute: `|Ω|·J^{N-1}·N` flops per TTMc sweep.

use crate::util::json::Json;

/// Hardware envelope used for the verdicts (defaults model the paper's
/// testbed: 12 GB GPU memory / 64 GB host memory).
#[derive(Clone, Debug)]
pub struct Envelope {
    /// Device memory available to TTM-style intermediates.
    pub gpu_mem_bytes: f64,
    /// Host memory ceiling for the CPU-resident systems.
    pub host_mem_bytes: f64,
    /// Sustained flops of the calibration machine (measured, not assumed).
    pub flops: f64,
    /// Above this many seconds per iteration the paper reports out-of-time.
    pub timeout_seconds: f64,
}

impl Default for Envelope {
    fn default() -> Self {
        Envelope {
            gpu_mem_bytes: 12e9,
            host_mem_bytes: 64e9,
            flops: 5e9, // overwritten by calibration in the bench harness
            timeout_seconds: 3600.0,
        }
    }
}

/// Workload description.
#[derive(Clone, Debug)]
pub struct Workload {
    /// Tensor order N.
    pub order: usize,
    /// Mode sizes.
    pub dims: Vec<usize>,
    /// Stored non-zeros |Ω|.
    pub nnz: usize,
    /// Rank J per mode.
    pub j: usize,
}

/// Verdict for one (algorithm, workload) cell of Table IV.
#[derive(Clone, Debug, PartialEq)]
pub enum Verdict {
    /// Estimated seconds per iteration.
    Seconds(f64),
    /// The intermediates exceed the hardware envelope's memory.
    OutOfMemory,
    /// The estimated iteration time exceeds the timeout.
    OutOfTime,
}

impl Verdict {
    /// Human-readable Table IV cell, always labelled `estimated`.
    pub fn render(&self) -> String {
        match self {
            Verdict::Seconds(s) => format!("{s:.3} (estimated)"),
            Verdict::OutOfMemory => "out of memory (estimated)".into(),
            Verdict::OutOfTime => "out of time (estimated)".into(),
        }
    }

    /// JSON form for the persisted result files.
    pub fn to_json(&self) -> Json {
        match self {
            Verdict::Seconds(s) => Json::obj(vec![
                ("kind", Json::str("seconds")),
                ("value", Json::num(*s)),
                ("estimated", Json::Bool(true)),
            ]),
            Verdict::OutOfMemory => Json::obj(vec![
                ("kind", Json::str("oom")),
                ("estimated", Json::Bool(true)),
            ]),
            Verdict::OutOfTime => Json::obj(vec![
                ("kind", Json::str("oot")),
                ("estimated", Json::Bool(true)),
            ]),
        }
    }
}

fn jpow(j: usize, p: usize) -> f64 {
    (j as f64).powi(p as i32)
}

/// ParTi (GPU TTMc): the semi-sparse TTM output stores ~`|Ω|·J` values,
/// fiber-compressed by ~2× (calibrated so the paper's observed verdicts
/// reproduce: runs Netflix at J=32, OOMs Yahoo at J=32, runs Yahoo at J=8).
pub fn parti_verdict(w: &Workload, env: &Envelope) -> Verdict {
    let inter = w.nnz as f64 * w.j as f64 * 2.0; // 4 B × 0.5 fiber compression
    let factors: f64 =
        w.dims.iter().map(|&d| d as f64 * w.j as f64 * 4.0).sum::<f64>();
    if inter + factors > env.gpu_mem_bytes {
        return Verdict::OutOfMemory;
    }
    let flops = w.nnz as f64 * jpow(w.j, w.order - 1) * w.order as f64 * 2.0;
    Verdict::Seconds(flops / env.flops)
}

/// GTA (heterogeneous TTMc + SVD): materializes the dense unfolded factor
/// `I_max × J^{N-1}` plus a `|Ω|·J` TTM buffer (calibrated: OOM on both
/// datasets at J=32, runs Netflix at J=16 and Yahoo at J=8 — Table IV).
pub fn gta_verdict(w: &Workload, env: &Envelope) -> Verdict {
    let imax = w.dims.iter().copied().max().unwrap_or(1) as f64;
    let inter = imax * jpow(w.j, w.order - 1) * 4.0 + w.nnz as f64 * w.j as f64 * 4.0;
    if inter > env.gpu_mem_bytes {
        return Verdict::OutOfMemory;
    }
    let ttm = w.nnz as f64 * jpow(w.j, w.order - 1) * w.order as f64 * 2.0;
    let svd: f64 = w
        .dims
        .iter()
        .map(|&d| d as f64 * jpow(w.j, w.order - 1) * w.j as f64)
        .sum();
    let secs = (ttm + svd) / env.flops;
    if secs > env.timeout_seconds {
        Verdict::OutOfTime
    } else {
        Verdict::Seconds(secs)
    }
}

/// Vest (very sparse core ALS on CPU): per-parameter coordinate descent over
/// the full core + factors; the constant is calibrated from the Vest paper's
/// own reported runtimes (~minutes per iteration at 1e8 nnz, J=16).
pub fn vest_verdict(w: &Workload, env: &Envelope) -> Verdict {
    let flops = 60.0 * w.nnz as f64 * jpow(w.j, w.order) ;
    let mem = w.nnz as f64 * 16.0 + jpow(w.j, w.order) * 8.0;
    if mem > env.host_mem_bytes {
        return Verdict::OutOfMemory;
    }
    let secs = flops / env.flops;
    if secs > env.timeout_seconds {
        Verdict::OutOfTime
    } else {
        Verdict::Seconds(secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn netflix(j: usize) -> Workload {
        Workload {
            order: 3,
            dims: vec![480_189, 17_770, 2_182],
            nnz: 99_072_112,
            j,
        }
    }

    fn yahoo(j: usize) -> Workload {
        Workload {
            order: 3,
            dims: vec![1_000_990, 624_961, 3_075],
            nnz: 250_272_286,
            j,
        }
    }

    #[test]
    fn parti_ooms_on_yahoo_at_j32() {
        // paper: ParTi(Factor) = out of memory on Yahoo!Music at J=32
        let env = Envelope::default();
        assert_eq!(parti_verdict(&yahoo(32), &env), Verdict::OutOfMemory);
    }

    #[test]
    fn parti_runs_netflix_at_j32_and_yahoo_at_j8() {
        // paper Table IV: ParTi(Factor) = 67.5 s on Netflix at J=32; ran
        // Yahoo at J=8 (54.9 s) after reducing the rank
        let env = Envelope::default();
        assert!(matches!(parti_verdict(&netflix(32), &env), Verdict::Seconds(_)));
        assert!(matches!(parti_verdict(&yahoo(8), &env), Verdict::Seconds(_)));
    }

    #[test]
    fn gta_runs_at_reduced_ranks() {
        // paper §V-B: GTA ran Netflix at J=16 (243.8 s) and Yahoo at J=8
        let env = Envelope::default();
        assert!(matches!(gta_verdict(&netflix(16), &env), Verdict::Seconds(_)));
        assert!(matches!(gta_verdict(&yahoo(8), &env), Verdict::Seconds(_)));
    }

    #[test]
    fn gta_ooms_at_j32_both() {
        // paper: GTA(Factor) = out of memory on both datasets at J=32
        let env = Envelope::default();
        assert_eq!(gta_verdict(&netflix(32), &env), Verdict::OutOfMemory);
        assert_eq!(gta_verdict(&yahoo(32), &env), Verdict::OutOfMemory);
    }

    #[test]
    fn vest_times_out_at_j32() {
        // paper: Vest = out of time on both datasets at J=32
        let env = Envelope::default();
        assert_eq!(vest_verdict(&netflix(32), &env), Verdict::OutOfTime);
        assert_eq!(vest_verdict(&yahoo(32), &env), Verdict::OutOfTime);
    }

    #[test]
    fn verdict_rendering_is_labelled() {
        assert!(Verdict::Seconds(1.5).render().contains("estimated"));
        assert!(Verdict::OutOfMemory.render().contains("estimated"));
        let j = Verdict::OutOfTime.to_json();
        assert_eq!(j.get("estimated").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn small_workloads_get_finite_estimates() {
        let env = Envelope::default();
        let w = Workload { order: 3, dims: vec![1000, 1000, 1000], nnz: 1_000_000, j: 8 };
        assert!(matches!(parti_verdict(&w, &env), Verdict::Seconds(_)));
        assert!(matches!(gta_verdict(&w, &env), Verdict::Seconds(_)));
    }
}
