//! P-Tucker baseline — scalable row-wise ALS Tucker factorization
//! (Oh, Park, Lee, Kang; ICDE'18; Table IV rows "P-Tucker(Factor)").
//!
//! For each mode `n` and each row `i`, gather the non-zeros of slice
//! `X(i_n = i)`, build the `J×J` normal equations
//! `(Σ_e h_e h_eᵀ + λI) a = Σ_e x_e h_e` with
//! `h_e = G ×_{m≠n} a^{(m)}_{i_m}`, and solve by Cholesky. The per-element
//! contraction costs `≈J^N` (full core tensor) — same exponential term as
//! cuTucker, plus the `J³` solve per row.

use crate::config::TrainConfig;
use crate::linalg::{solve_spd, Matrix};
use crate::sched::pool::parallel_dynamic;
use crate::tensor::coo::CooTensor;

use super::core_tensor::other_rows;
use super::cutucker::CuTuckerModel;

/// Element ids grouped by mode-n row — the slice index P-Tucker iterates.
pub struct SliceIndex {
    /// `rows[i]` = element ids whose mode-n coordinate is `i`.
    pub per_mode: Vec<Vec<Vec<u32>>>,
}

impl SliceIndex {
    /// Group element ids by their coordinate in every mode.
    pub fn build(data: &CooTensor) -> SliceIndex {
        let order = data.order();
        let mut per_mode: Vec<Vec<Vec<u32>>> = data
            .dims()
            .iter()
            .map(|&d| vec![Vec::new(); d])
            .collect();
        for e in 0..data.nnz() {
            let coords = data.index(e);
            for n in 0..order {
                per_mode[n][coords[n] as usize].push(e as u32);
            }
        }
        SliceIndex { per_mode }
    }

    /// Approximate heap footprint: one element id per non-zero per mode,
    /// plus the per-row vector headers — what a registry eviction of a
    /// P-Tucker session's prepared cache frees alongside the COO copy.
    pub fn heap_bytes(&self) -> usize {
        self.per_mode
            .iter()
            .map(|rows| {
                rows.iter().map(|ids| ids.capacity() * 4).sum::<usize>()
                    + rows.capacity() * std::mem::size_of::<Vec<u32>>()
            })
            .sum()
    }
}

/// One ALS factor sweep (all modes, every row solved once). Rows whose slice
/// is empty keep their previous value; rows whose system is singular are
/// skipped (counted in the return value for diagnostics).
pub fn als_factor_sweep(
    model: &mut CuTuckerModel,
    data: &CooTensor,
    index: &SliceIndex,
    cfg: &TrainConfig,
) -> usize {
    let order = model.factors.len();
    let j = model.core.j();
    let workers = cfg.effective_workers();
    let skipped = std::sync::atomic::AtomicUsize::new(0);

    for n in 0..order {
        let dim = model.factors[n].rows();
        // solve all rows against the CURRENT other factors (Gauss–Seidel
        // across modes, Jacobi within a mode — P-Tucker's scheme), writing
        // into a fresh matrix to keep within-mode updates independent.
        let mut new_rows = Matrix::zeros(dim, j);
        {
            let new_racy = crate::sched::racy::RacyMatrix::new(&mut new_rows);
            let factors = &model.factors;
            let core = &model.core;
            let slices = &index.per_mode[n];
            let skipped = &skipped;
            parallel_dynamic(workers, dim, |_w, i| {
                let elems = &slices[i];
                let mut row_out = vec![0.0f32; j];
                if elems.is_empty() {
                    // keep previous value
                    for (jj, r) in row_out.iter_mut().enumerate() {
                        *r = factors[n].get(i, jj);
                    }
                    new_racy.write_row(i, &row_out);
                    return;
                }
                let mut hth = Matrix::zeros(j, j);
                let mut rhs = vec![0.0f32; j];
                let mut h = vec![0.0f32; j];
                let mut rows_buf: Vec<&[f32]> = Vec::with_capacity(order - 1);
                let mut scratch: Vec<f32> = Vec::new();
                for &e in elems {
                    let coords = data.index(e as usize);
                    let x = data.value(e as usize);
                    other_rows(factors, coords, n, &mut rows_buf);
                    core.contract_except(n, &rows_buf, &mut scratch, &mut h);
                    for a in 0..j {
                        let ha = h[a];
                        rhs[a] += x * ha;
                        let row = hth.row_mut(a);
                        for b in 0..j {
                            row[b] += ha * h[b];
                        }
                    }
                }
                for d in 0..j {
                    hth.set(d, d, hth.get(d, d) + cfg.lambda_a.max(1e-6));
                }
                match solve_spd(&hth, &rhs) {
                    Ok(sol) => new_racy.write_row(i, &sol),
                    Err(_) => {
                        skipped.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        for (jj, r) in row_out.iter_mut().enumerate() {
                            *r = factors[n].get(i, jj);
                        }
                        new_racy.write_row(i, &row_out);
                    }
                }
            });
        }
        model.factors[n] = new_rows;
    }
    skipped.load(std::sync::atomic::Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};

    fn setup() -> (CuTuckerModel, CooTensor, SliceIndex, TrainConfig) {
        let t = recommender(&RecommenderSpec::tiny(), 41);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 4,
            r: 4,
            lambda_a: 0.1,
            workers: 2,
            ..TrainConfig::default()
        };
        let model = CuTuckerModel::init(&cfg, 9);
        let index = SliceIndex::build(&t);
        (model, t, index, cfg)
    }

    #[test]
    fn slice_index_covers_every_element_per_mode() {
        let (_, t, index, _) = setup();
        for n in 0..3 {
            let total: usize = index.per_mode[n].iter().map(|v| v.len()).sum();
            assert_eq!(total, t.nnz());
        }
    }

    #[test]
    fn als_sweep_reduces_error_substantially() {
        let (mut m, t, index, cfg) = setup();
        let (before, _) = m.rmse_mae(&t);
        als_factor_sweep(&mut m, &t, &index, &cfg);
        let (after1, _) = m.rmse_mae(&t);
        als_factor_sweep(&mut m, &t, &index, &cfg);
        let (after2, _) = m.rmse_mae(&t);
        // ALS takes large steps: first sweep should beat SGD's single epochs
        assert!(after1 < before * 0.9, "RMSE {before} -> {after1}");
        assert!(after2 <= after1 * 1.01, "second sweep regressed: {after1} -> {after2}");
    }

    #[test]
    fn empty_slices_keep_rows() {
        let (mut m, _, _, cfg) = setup();
        // craft a tensor that never touches row 5 of mode 0
        let mut t = CooTensor::new(vec![10, 4, 4]);
        t.push(&[0, 0, 0], 1.0);
        t.push(&[1, 1, 1], 2.0);
        let index = SliceIndex::build(&t);
        let mut cfg = cfg;
        cfg.dims = vec![10, 4, 4];
        let mut m2 = CuTuckerModel::init(&cfg, 1);
        let before = m2.factors[0].row(5).to_vec();
        als_factor_sweep(&mut m2, &t, &index, &cfg);
        assert_eq!(m2.factors[0].row(5), &before[..]);
        let _ = &mut m;
    }

    #[test]
    fn als_result_is_finite() {
        let (mut m, t, index, cfg) = setup();
        for _ in 0..3 {
            als_factor_sweep(&mut m, &t, &index, &cfg);
        }
        for n in 0..3 {
            assert!(m.factors[n].data().iter().all(|x| x.is_finite()));
        }
    }
}
