//! Baseline algorithms the paper compares against (Table IV).
//!
//! * [`core_tensor`] — the dense full core tensor `G ∈ R^{J^N}` shared by
//!   both full-Tucker baselines, with the progressive-contraction kernels.
//! * [`cutucker`] — cuTucker: element-wise SGD over factor matrices and the
//!   full core tensor (paper [28]). The `J^N` contraction per non-zero is
//!   the exponential cost FastTucker removes.
//! * [`ptucker`] — P-Tucker: row-wise ALS; each factor row solves `J×J`
//!   normal equations over its slice (Oh et al., ICDE'18).
//! * [`costmodel`] — analytical verdicts (out-of-memory / out-of-time /
//!   estimated seconds) for the baselines we do not fully implement
//!   (Vest, ParTi, GTA) — clearly labelled as estimates in Table IV output.

pub mod core_tensor;
pub mod cutucker;
pub mod ptucker;
pub mod costmodel;
