//! cuTucker baseline — element-wise SGD over factor matrices plus the FULL
//! core tensor `G ∈ R^{J^N}` (paper [28]; Table IV rows "cuTucker").
//!
//! Per non-zero, per mode, the contraction `h = G ×_{m≠n} a^{(m)}` costs
//! ≈`J^{N-1}·J = J^N` multiplications — the exponential term that motivates
//! FastTucker. We keep the implementation honest (progressive contraction,
//! no wasted work) so the Table IV gap measures the algorithm, not sloppiness.

use crate::config::TrainConfig;
use crate::linalg::Matrix;
use crate::sched::pool::parallel_reduce;
use crate::sched::racy::RacyMatrix;
use crate::tensor::coo::CooTensor;
use crate::util::ceil_div;
use crate::util::rng::Rng;

use super::core_tensor::{other_rows, CoreTensor};

/// cuTucker model: factor matrices (shared shape with the FastTucker family)
/// plus the full core tensor.
pub struct CuTuckerModel {
    /// `A^(n) ∈ R^{I_n×J}` per mode.
    pub factors: Vec<Matrix>,
    /// The full core tensor `G` with per-mode permuted copies.
    pub core: CoreTensor,
}

impl CuTuckerModel {
    /// Random initialization scaled so the initial prediction lands near
    /// the middle of the rating range.
    pub fn init(cfg: &TrainConfig, seed: u64) -> CuTuckerModel {
        let mut rng = Rng::new(seed ^ 0xC07E);
        // scale so initial x̂ ≈ mid-range: x̂ = Σ_{J^N} g·Πa, g,a ~ U(0,s):
        // E[x̂] ≈ J^N·(s/2)^{N+1}; solve for s at target 2.5.
        let n = cfg.order as f64;
        let jn = (cfg.j as f64).powf(n);
        let s = 2.0 * (2.5 / jn).powf(1.0 / (n + 1.0)) as f32;
        let factors = cfg
            .dims
            .iter()
            .map(|&d| Matrix::uniform(d, cfg.j, 0.0, s, &mut rng))
            .collect();
        let core = CoreTensor::init(cfg.order, cfg.j, s, &mut rng);
        CuTuckerModel { factors, core }
    }

    /// Predict one element via progressive contraction of the full core.
    pub fn predict(&self, coords: &[u32]) -> f32 {
        let order = self.factors.len();
        let mut rows: Vec<&[f32]> = Vec::with_capacity(order);
        for (m, &c) in coords.iter().enumerate() {
            rows.push(self.factors[m].row(c as usize));
        }
        let mut scratch = Vec::new();
        let mut h = vec![0.0f32; self.core.j()];
        self.core.predict(&rows, &mut scratch, &mut h)
    }

    /// Test RMSE/MAE (serial; baseline evaluation is not timed).
    pub fn rmse_mae(&self, data: &CooTensor) -> (f64, f64) {
        if data.nnz() == 0 {
            return (0.0, 0.0);
        }
        let (mut se, mut ae) = (0.0f64, 0.0f64);
        for (c, x) in data.iter() {
            let err = (x - self.predict(c)) as f64;
            se += err * err;
            ae += err.abs();
        }
        let n = data.nnz() as f64;
        ((se / n).sqrt(), ae / n)
    }
}

/// Per-worker scratch for the cuTucker loops.
struct CtScratch<'a> {
    rows: Vec<&'a [f32]>,
    contraction: Vec<f32>,
    h: Vec<f32>,
    grad: Vec<f32>,
}

/// One factor-update epoch (all modes).
pub fn factor_epoch(model: &mut CuTuckerModel, data: &CooTensor, cfg: &TrainConfig) {
    let order = model.factors.len();
    let j = model.core.j();
    let nnz = data.nnz();
    let workers = cfg.effective_workers();
    let block = cfg.block_nnz.max(1);
    let num_blocks = ceil_div(nnz, block);
    let scale = 1.0 - cfg.lr_a * cfg.lambda_a;

    for n in 0..order {
        let mut target = std::mem::replace(&mut model.factors[n], Matrix::zeros(0, 0));
        {
            let racy = RacyMatrix::new(&mut target);
            let factors = &model.factors;
            let core = &model.core;
            parallel_reduce(
                workers,
                num_blocks,
                || CtScratch {
                    rows: Vec::with_capacity(order),
                    contraction: Vec::new(),
                    h: vec![0.0; j],
                    grad: Vec::new(),
                },
                |s, _w, b| {
                    let lo = b * block;
                    let hi = (lo + block).min(nnz);
                    for e in lo..hi {
                        let coords = data.index(e);
                        let x = data.value(e);
                        other_rows(factors, coords, n, &mut s.rows);
                        core.contract_except(n, &s.rows, &mut s.contraction, &mut s.h);
                        let i = coords[n] as usize;
                        let e_val = x - racy.row_dot(i, &s.h);
                        racy.row_sgd_update(i, scale, cfg.lr_a * e_val, &s.h);
                    }
                },
                |_a, _b| {},
            );
        }
        model.factors[n] = target;
    }
}

/// One core-tensor update epoch: full-batch gradient over all non-zeros.
pub fn core_epoch(model: &mut CuTuckerModel, data: &CooTensor, cfg: &TrainConfig) {
    let order = model.factors.len();
    let j = model.core.j();
    let glen = CoreTensor::len(order, j);
    let nnz = data.nnz();
    let workers = cfg.effective_workers();
    let block = cfg.block_nnz.max(1);
    let num_blocks = ceil_div(nnz, block);

    let factors = &model.factors;
    let core = &model.core;
    let grad = parallel_reduce(
        workers,
        num_blocks,
        || CtScratch {
            rows: Vec::with_capacity(order),
            contraction: Vec::new(),
            h: vec![0.0; j],
            grad: vec![0.0; glen],
        },
        |s, _w, b| {
            let lo = b * block;
            let hi = (lo + block).min(nnz);
            for e in lo..hi {
                let coords = data.index(e);
                let x = data.value(e);
                s.rows.clear();
                for (m, &c) in coords.iter().enumerate() {
                    s.rows.push(factors[m].row(c as usize));
                }
                let xhat = core.predict(&s.rows, &mut s.contraction, &mut s.h);
                CoreTensor::accumulate_grad(
                    order,
                    j,
                    &mut s.grad,
                    x - xhat,
                    &s.rows,
                    &mut s.contraction,
                );
            }
        },
        |acc, other| {
            for (g, o) in acc.grad.iter_mut().zip(other.grad.iter()) {
                *g += o;
            }
        },
    )
    .grad;
    model.core.apply_grad(&grad, nnz, cfg.lr_b, cfg.lambda_b);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};

    fn setup() -> (CuTuckerModel, CooTensor, TrainConfig) {
        let t = recommender(&RecommenderSpec::tiny(), 31);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 4,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-3,
            workers: 2,
            block_nnz: 512,
            ..TrainConfig::default()
        };
        let model = CuTuckerModel::init(&cfg, 7);
        (model, t, cfg)
    }

    #[test]
    fn init_prediction_scale() {
        let (m, t, _) = setup();
        let p = m.predict(t.index(0));
        assert!(p.is_finite() && p > 0.0 && p < 100.0, "p={p}");
    }

    #[test]
    fn factor_epoch_reduces_error() {
        let (mut m, t, cfg) = setup();
        let (before, _) = m.rmse_mae(&t);
        for _ in 0..3 {
            factor_epoch(&mut m, &t, &cfg);
        }
        let (after, _) = m.rmse_mae(&t);
        assert!(after < before, "RMSE {before} -> {after}");
    }

    #[test]
    fn core_epoch_reduces_error() {
        let (mut m, t, cfg) = setup();
        let (before, _) = m.rmse_mae(&t);
        for _ in 0..5 {
            core_epoch(&mut m, &t, &cfg);
        }
        let (after, _) = m.rmse_mae(&t);
        assert!(after < before, "RMSE {before} -> {after}");
    }

    #[test]
    fn joint_training_converges_well() {
        let (mut m, t, cfg) = setup();
        let (before, _) = m.rmse_mae(&t);
        for _ in 0..6 {
            factor_epoch(&mut m, &t, &cfg);
            core_epoch(&mut m, &t, &cfg);
        }
        let (after, _) = m.rmse_mae(&t);
        assert!(after < before * 0.8, "RMSE {before} -> {after}");
    }
}
