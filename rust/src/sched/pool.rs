//! Dynamic self-scheduling worker pool over block ids.
//!
//! No rayon offline; `std::thread::scope` + an atomic work counter is all the
//! paper's execution model needs: workers repeatedly claim the next block
//! until the queue drains. Per-worker counters feed the load-balance numbers
//! reported in EXPERIMENTS.md — both blocks claimed and, when the caller
//! supplies per-block weights (`ShardPlan`'s measured nnz), non-zeros
//! claimed.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Per-worker accounting from one parallel region.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// Blocks processed per worker.
    pub blocks: Vec<usize>,
    /// Busy seconds per worker.
    pub busy: Vec<f64>,
    /// Non-zeros claimed per worker (all zero when the region ran without
    /// per-block weights).
    pub nnz: Vec<usize>,
}

impl WorkerStats {
    /// Zeroed stats for `workers` workers.
    pub fn with_workers(workers: usize) -> WorkerStats {
        let w = workers.max(1);
        WorkerStats {
            blocks: vec![0; w],
            busy: vec![0.0; w],
            nnz: vec![0; w],
        }
    }

    /// Max/mean block imbalance ratio (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        Self::max_over_mean(&self.blocks)
    }

    /// Max/mean claimed-nnz imbalance ratio (1.0 = perfect) — the tighter
    /// balance figure LPT packing targets: blocks are equal only up to the
    /// `target + threshold` bound, non-zeros are what workers actually pay.
    pub fn nnz_imbalance(&self) -> f64 {
        Self::max_over_mean(&self.nnz)
    }

    fn max_over_mean(xs: &[usize]) -> f64 {
        if xs.is_empty() {
            return 1.0;
        }
        let max = *xs.iter().max().unwrap() as f64;
        let mean = xs.iter().sum::<usize>() as f64 / xs.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Total blocks processed across workers.
    pub fn total_blocks(&self) -> usize {
        self.blocks.iter().sum()
    }

    /// Total non-zeros claimed across workers.
    pub fn total_nnz(&self) -> usize {
        self.nnz.iter().sum()
    }

    /// Accumulate a lease-local region's stats into this (budget-wide) one,
    /// mapping the region's worker index `w` to the global worker slot
    /// `slots[w]` — how [`crate::sched::Executor`] attributes
    /// concurrently-leased passes to *disjoint* worker slots instead of
    /// piling every lease's worker 0 onto the same global slot.
    ///
    /// Contract: a leased pass runs with at most `slots.len()` workers. If
    /// a caller ever reports more, the excess is folded onto the lease's
    /// last slot so totals stay exact (and a debug assertion fires).
    pub fn absorb_at(&mut self, other: &WorkerStats, slots: &[usize]) {
        if slots.is_empty() {
            debug_assert!(other.total_blocks() == 0 && other.total_nnz() == 0);
            return;
        }
        debug_assert!(
            other.blocks.len() <= slots.len(),
            "pass reported {} workers on a {}-worker lease",
            other.blocks.len(),
            slots.len()
        );
        let last = *slots.last().expect("non-empty checked");
        let want = slots.iter().copied().max().unwrap_or(0) + 1;
        if self.blocks.len() < want {
            self.blocks.resize(want, 0);
        }
        if self.busy.len() < want {
            self.busy.resize(want, 0.0);
        }
        if self.nnz.len() < want {
            self.nnz.resize(want, 0);
        }
        let slot_of = |w: usize| slots.get(w).copied().unwrap_or(last);
        for (w, &b) in other.blocks.iter().enumerate() {
            self.blocks[slot_of(w)] += b;
        }
        for (w, &b) in other.busy.iter().enumerate() {
            self.busy[slot_of(w)] += b;
        }
        for (w, &b) in other.nnz.iter().enumerate() {
            self.nnz[slot_of(w)] += b;
        }
    }

    /// Accumulate another parallel region's stats element-wise (used to sum
    /// the per-mode passes of one epoch into one report).
    pub fn absorb(&mut self, other: &WorkerStats) {
        if self.blocks.len() < other.blocks.len() {
            self.blocks.resize(other.blocks.len(), 0);
        }
        if self.busy.len() < other.busy.len() {
            self.busy.resize(other.busy.len(), 0.0);
        }
        if self.nnz.len() < other.nnz.len() {
            self.nnz.resize(other.nnz.len(), 0);
        }
        for (a, b) in self.blocks.iter_mut().zip(other.blocks.iter()) {
            *a += b;
        }
        for (a, b) in self.busy.iter_mut().zip(other.busy.iter()) {
            *a += b;
        }
        for (a, b) in self.nnz.iter_mut().zip(other.nnz.iter()) {
            *a += b;
        }
    }
}

/// Run `f(worker_id, block_id)` for every `block_id in 0..num_blocks`,
/// dynamically load-balanced over `workers` threads. Returns per-worker
/// stats. `workers == 1` runs inline (no thread spawn) so single-worker
/// baselines measure pure algorithm time.
pub fn parallel_dynamic<F>(workers: usize, num_blocks: usize, f: F) -> WorkerStats
where
    F: Fn(usize, usize) + Sync,
{
    parallel_reduce_stats(workers, num_blocks, || (), |_acc, w, b| f(w, b), |_acc, _o| {}).1
}

/// Parallel map-reduce: each worker folds its claimed blocks into a local
/// accumulator (`init()` per worker, `step(acc, worker, block)`), then the
/// locals are merged with `merge`. Used for gradient accumulation in the
/// core-matrix update (paper Algorithm 5 accumulates into global memory; a
/// per-worker local + tree merge is the shared-memory-hierarchy analogue).
pub fn parallel_reduce<Acc, I, S, M>(
    workers: usize,
    num_blocks: usize,
    init: I,
    step: S,
    merge: M,
) -> Acc
where
    Acc: Send,
    I: Fn() -> Acc + Sync,
    S: Fn(&mut Acc, usize, usize) + Sync,
    M: Fn(&mut Acc, Acc),
{
    parallel_reduce_stats(workers, num_blocks, init, step, merge).0
}

/// [`parallel_reduce`] that also reports per-worker [`WorkerStats`] — the
/// load-balance evidence the B-CSF benches assert against (the paper's
/// §IV-B claim is precisely that blocked scheduling keeps this flat).
pub fn parallel_reduce_stats<Acc, I, S, M>(
    workers: usize,
    num_blocks: usize,
    init: I,
    step: S,
    merge: M,
) -> (Acc, WorkerStats)
where
    Acc: Send,
    I: Fn() -> Acc + Sync,
    S: Fn(&mut Acc, usize, usize) + Sync,
    M: Fn(&mut Acc, Acc),
{
    parallel_reduce_stats_weighted(workers, num_blocks, init, step, merge, |_| 0)
}

/// [`parallel_reduce_stats`] with a per-block weight (`ShardPlan` passes
/// the block's measured non-zeros): each worker's claimed weight is
/// recorded in [`WorkerStats::nnz`].
pub fn parallel_reduce_stats_weighted<Acc, I, S, M, W>(
    workers: usize,
    num_blocks: usize,
    init: I,
    step: S,
    merge: M,
    weight: W,
) -> (Acc, WorkerStats)
where
    Acc: Send,
    I: Fn() -> Acc + Sync,
    S: Fn(&mut Acc, usize, usize) + Sync,
    M: Fn(&mut Acc, Acc),
    W: Fn(usize) -> usize + Sync,
{
    let workers = workers.max(1);
    let mut stats = WorkerStats::with_workers(workers);
    if workers == 1 {
        let t = std::time::Instant::now();
        let mut acc = init();
        let mut claimed = 0usize;
        for b in 0..num_blocks {
            step(&mut acc, 0, b);
            claimed += weight(b);
        }
        stats.blocks[0] = num_blocks;
        stats.busy[0] = t.elapsed().as_secs_f64();
        stats.nnz[0] = claimed;
        return (acc, stats);
    }
    let next = AtomicUsize::new(0);
    let locals: Vec<(Acc, usize, usize, f64)> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let next = &next;
            let init = &init;
            let step = &step;
            let weight = &weight;
            handles.push(scope.spawn(move || {
                let t = std::time::Instant::now();
                let mut acc = init();
                let mut mine = 0usize;
                let mut claimed = 0usize;
                loop {
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= num_blocks {
                        break;
                    }
                    step(&mut acc, w, b);
                    mine += 1;
                    claimed += weight(b);
                }
                (acc, mine, claimed, t.elapsed().as_secs_f64())
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut it = locals.into_iter();
    let (mut acc, blocks0, nnz0, busy0) = it.next().unwrap();
    stats.blocks[0] = blocks0;
    stats.busy[0] = busy0;
    stats.nnz[0] = nnz0;
    for (w, (local, blk, claimed, busy)) in it.enumerate() {
        merge(&mut acc, local);
        stats.blocks[w + 1] = blk;
        stats.busy[w + 1] = busy;
        stats.nnz[w + 1] = claimed;
    }
    (acc, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn all_blocks_processed_exactly_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let stats = parallel_dynamic(4, n, |_w, b| {
            hits[b].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(stats.blocks.iter().sum::<usize>(), n);
    }

    #[test]
    fn single_worker_inline() {
        let sum = AtomicU64::new(0);
        let stats = parallel_dynamic(1, 10, |w, b| {
            assert_eq!(w, 0);
            sum.fetch_add(b as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
        assert_eq!(stats.blocks, vec![10]);
    }

    #[test]
    fn zero_blocks_is_fine() {
        let stats = parallel_dynamic(4, 0, |_w, _b| panic!("no blocks"));
        assert_eq!(stats.blocks.iter().sum::<usize>(), 0);
    }

    #[test]
    fn more_workers_than_blocks() {
        let stats = parallel_dynamic(16, 3, |_w, _b| {});
        assert_eq!(stats.blocks.iter().sum::<usize>(), 3);
    }

    #[test]
    fn reduce_sums_correctly() {
        let total = parallel_reduce(
            4,
            100,
            || 0u64,
            |acc, _w, b| *acc += b as u64,
            |acc, other| *acc += other,
        );
        assert_eq!(total, (0..100u64).sum());
    }

    #[test]
    fn reduce_single_worker() {
        let total = parallel_reduce(
            1,
            10,
            || 0u64,
            |acc, _w, b| *acc += b as u64 + 1,
            |acc, other| *acc += other,
        );
        assert_eq!(total, 55);
    }

    #[test]
    fn reduce_vector_accumulators() {
        // per-worker gradient-style accumulation
        let grad = parallel_reduce(
            3,
            30,
            || vec![0.0f64; 4],
            |acc, _w, b| acc[b % 4] += 1.0,
            |acc, other| {
                for (a, o) in acc.iter_mut().zip(other) {
                    *a += o;
                }
            },
        );
        assert_eq!(grad.iter().sum::<f64>(), 30.0);
    }

    #[test]
    fn reduce_stats_counts_every_block_once() {
        let (total, stats) = parallel_reduce_stats(
            4,
            64,
            || 0u64,
            |acc, _w, b| *acc += b as u64,
            |acc, other| *acc += other,
        );
        assert_eq!(total, (0..64u64).sum());
        assert_eq!(stats.total_blocks(), 64);
        assert_eq!(stats.blocks.len(), 4);
        assert!(stats.imbalance() >= 1.0 - 1e-9);
        // unweighted region: no claimed nnz recorded
        assert_eq!(stats.total_nnz(), 0);
    }

    #[test]
    fn reduce_stats_single_worker_inline() {
        let (total, stats) = parallel_reduce_stats(
            1,
            10,
            || 0u64,
            |acc, w, _b| {
                assert_eq!(w, 0);
                *acc += 1;
            },
            |acc, other| *acc += other,
        );
        assert_eq!(total, 10);
        assert_eq!(stats.blocks, vec![10]);
        assert!((stats.imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn weighted_reduce_accounts_every_blocks_weight_once() {
        for workers in [1usize, 4] {
            let (_, stats) = parallel_reduce_stats_weighted(
                workers,
                100,
                || 0u64,
                |acc, _w, b| *acc += b as u64,
                |acc, other| *acc += other,
                |b| b + 1,
            );
            assert_eq!(stats.total_nnz(), (1..=100).sum::<usize>(), "{workers} workers");
            assert_eq!(stats.total_blocks(), 100);
        }
    }

    #[test]
    fn absorb_at_maps_lease_slots_without_double_counting() {
        let mut total = WorkerStats::with_workers(4);
        let lease_a = WorkerStats { blocks: vec![3], busy: vec![0.5], nnz: vec![30] };
        let lease_b = WorkerStats { blocks: vec![7], busy: vec![1.0], nnz: vec![70] };
        // two concurrently-leased 1-worker passes land on *different* slots
        total.absorb_at(&lease_a, &[2]);
        total.absorb_at(&lease_b, &[0]);
        assert_eq!(total.blocks, vec![7, 0, 3, 0]);
        assert_eq!(total.nnz, vec![70, 0, 30, 0]);
        assert_eq!(total.total_blocks(), 10);
        assert_eq!(total.total_nnz(), 100);
        // a wider lease maps element-wise onto its slot list
        let wide = WorkerStats { blocks: vec![1, 2], busy: vec![0.1, 0.2], nnz: vec![5, 6] };
        total.absorb_at(&wide, &[1, 3]);
        assert_eq!(total.blocks, vec![7, 1, 3, 2]);
        assert_eq!(total.nnz, vec![70, 5, 30, 6]);
    }

    #[test]
    fn stats_absorb_sums_elementwise() {
        let mut a = WorkerStats {
            blocks: vec![1, 2],
            busy: vec![0.5, 0.5],
            nnz: vec![10, 20],
        };
        let b = WorkerStats {
            blocks: vec![3, 4, 5],
            busy: vec![1.0, 1.0, 1.0],
            nnz: vec![1, 2, 3],
        };
        a.absorb(&b);
        assert_eq!(a.blocks, vec![4, 6, 5]);
        assert_eq!(a.nnz, vec![11, 22, 3]);
        assert_eq!(a.total_blocks(), 15);
        assert!((a.busy.iter().sum::<f64>() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_of_even_split_is_low() {
        let stats = WorkerStats {
            blocks: vec![10, 10, 10, 10],
            busy: vec![],
            nnz: vec![512, 500, 505, 507],
        };
        assert!((stats.imbalance() - 1.0).abs() < 1e-9);
        assert!(stats.nnz_imbalance() < 1.02);
        let skewed = WorkerStats {
            blocks: vec![40, 0, 0, 0],
            busy: vec![],
            nnz: vec![4000, 0, 0, 0],
        };
        assert!(skewed.imbalance() > 3.9);
        assert!(skewed.nnz_imbalance() > 3.9);
    }
}
