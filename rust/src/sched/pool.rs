//! Dynamic self-scheduling worker pool over block ids.
//!
//! No rayon offline; `std::thread::scope` + an atomic work counter is all the
//! paper's execution model needs: workers repeatedly claim the next block
//! until the queue drains. Per-worker counters feed the load-balance numbers
//! reported in EXPERIMENTS.md.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Per-worker accounting from one parallel region.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// Blocks processed per worker.
    pub blocks: Vec<usize>,
    /// Busy seconds per worker.
    pub busy: Vec<f64>,
}

impl WorkerStats {
    /// Max/mean block imbalance ratio (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        if self.blocks.is_empty() {
            return 1.0;
        }
        let max = *self.blocks.iter().max().unwrap() as f64;
        let mean =
            self.blocks.iter().sum::<usize>() as f64 / self.blocks.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Run `f(worker_id, block_id)` for every `block_id in 0..num_blocks`,
/// dynamically load-balanced over `workers` threads. Returns per-worker
/// stats. `workers == 1` runs inline (no thread spawn) so single-worker
/// baselines measure pure algorithm time.
pub fn parallel_dynamic<F>(workers: usize, num_blocks: usize, f: F) -> WorkerStats
where
    F: Fn(usize, usize) + Sync,
{
    let workers = workers.max(1);
    let mut stats = WorkerStats {
        blocks: vec![0; workers],
        busy: vec![0.0; workers],
    };
    if workers == 1 {
        let t = std::time::Instant::now();
        for b in 0..num_blocks {
            f(0, b);
        }
        stats.blocks[0] = num_blocks;
        stats.busy[0] = t.elapsed().as_secs_f64();
        return stats;
    }
    let next = AtomicUsize::new(0);
    let counts: Vec<AtomicUsize> = (0..workers).map(|_| AtomicUsize::new(0)).collect();
    let busy: Vec<std::sync::Mutex<f64>> =
        (0..workers).map(|_| std::sync::Mutex::new(0.0)).collect();
    std::thread::scope(|scope| {
        for w in 0..workers {
            let f = &f;
            let next = &next;
            let counts = &counts;
            let busy = &busy;
            scope.spawn(move || {
                let t = std::time::Instant::now();
                let mut mine = 0usize;
                loop {
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= num_blocks {
                        break;
                    }
                    f(w, b);
                    mine += 1;
                }
                counts[w].store(mine, Ordering::Relaxed);
                *busy[w].lock().unwrap() = t.elapsed().as_secs_f64();
            });
        }
    });
    for w in 0..workers {
        stats.blocks[w] = counts[w].load(Ordering::Relaxed);
        stats.busy[w] = *busy[w].lock().unwrap();
    }
    stats
}

/// Parallel map-reduce: each worker folds its claimed blocks into a local
/// accumulator (`init()` per worker, `step(acc, worker, block)`), then the
/// locals are merged with `merge`. Used for gradient accumulation in the
/// core-matrix update (paper Algorithm 5 accumulates into global memory; a
/// per-worker local + tree merge is the shared-memory-hierarchy analogue).
pub fn parallel_reduce<Acc, I, S, M>(
    workers: usize,
    num_blocks: usize,
    init: I,
    step: S,
    merge: M,
) -> Acc
where
    Acc: Send,
    I: Fn() -> Acc + Sync,
    S: Fn(&mut Acc, usize, usize) + Sync,
    M: Fn(&mut Acc, Acc),
{
    let workers = workers.max(1);
    if workers == 1 {
        let mut acc = init();
        for b in 0..num_blocks {
            step(&mut acc, 0, b);
        }
        return acc;
    }
    let next = AtomicUsize::new(0);
    let locals: Vec<Acc> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let next = &next;
            let init = &init;
            let step = &step;
            handles.push(scope.spawn(move || {
                let mut acc = init();
                loop {
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= num_blocks {
                        break;
                    }
                    step(&mut acc, w, b);
                }
                acc
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut it = locals.into_iter();
    let mut acc = it.next().unwrap();
    for local in it {
        merge(&mut acc, local);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn all_blocks_processed_exactly_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let stats = parallel_dynamic(4, n, |_w, b| {
            hits[b].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(stats.blocks.iter().sum::<usize>(), n);
    }

    #[test]
    fn single_worker_inline() {
        let sum = AtomicU64::new(0);
        let stats = parallel_dynamic(1, 10, |w, b| {
            assert_eq!(w, 0);
            sum.fetch_add(b as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
        assert_eq!(stats.blocks, vec![10]);
    }

    #[test]
    fn zero_blocks_is_fine() {
        let stats = parallel_dynamic(4, 0, |_w, _b| panic!("no blocks"));
        assert_eq!(stats.blocks.iter().sum::<usize>(), 0);
    }

    #[test]
    fn more_workers_than_blocks() {
        let stats = parallel_dynamic(16, 3, |_w, _b| {});
        assert_eq!(stats.blocks.iter().sum::<usize>(), 3);
    }

    #[test]
    fn reduce_sums_correctly() {
        let total = parallel_reduce(
            4,
            100,
            || 0u64,
            |acc, _w, b| *acc += b as u64,
            |acc, other| *acc += other,
        );
        assert_eq!(total, (0..100u64).sum());
    }

    #[test]
    fn reduce_single_worker() {
        let total = parallel_reduce(
            1,
            10,
            || 0u64,
            |acc, _w, b| *acc += b as u64 + 1,
            |acc, other| *acc += other,
        );
        assert_eq!(total, 55);
    }

    #[test]
    fn reduce_vector_accumulators() {
        // per-worker gradient-style accumulation
        let grad = parallel_reduce(
            3,
            30,
            || vec![0.0f64; 4],
            |acc, _w, b| acc[b % 4] += 1.0,
            |acc, other| {
                for (a, o) in acc.iter_mut().zip(other) {
                    *a += o;
                }
            },
        );
        assert_eq!(grad.iter().sum::<f64>(), 30.0);
    }

    #[test]
    fn imbalance_of_even_split_is_low() {
        let stats = WorkerStats { blocks: vec![10, 10, 10, 10], busy: vec![] };
        assert!((stats.imbalance() - 1.0).abs() < 1e-9);
        let skewed = WorkerStats { blocks: vec![40, 0, 0, 0], busy: vec![] };
        assert!(skewed.imbalance() > 3.9);
    }
}
