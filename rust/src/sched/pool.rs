//! Dynamic self-scheduling worker pool over block ids.
//!
//! No rayon offline; `std::thread::scope` + an atomic work counter is all the
//! paper's execution model needs: workers repeatedly claim the next block
//! until the queue drains. Per-worker counters feed the load-balance numbers
//! reported in EXPERIMENTS.md — both blocks claimed and, when the caller
//! supplies per-block weights (`ShardPlan`'s measured nnz), non-zeros
//! claimed.

use super::topo::{self, WorkerHome};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Per-worker accounting from one parallel region.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// Blocks processed per worker.
    pub blocks: Vec<usize>,
    /// Busy seconds per worker.
    pub busy: Vec<f64>,
    /// Non-zeros claimed per worker (all zero when the region ran without
    /// per-block weights).
    pub nnz: Vec<usize>,
    /// Blocks a worker executed that were seeded to a *different* worker's
    /// queue (all zero for non-stealing regions).
    pub steals: Vec<usize>,
}

impl WorkerStats {
    /// Zeroed stats for `workers` workers.
    pub fn with_workers(workers: usize) -> WorkerStats {
        let w = workers.max(1);
        WorkerStats {
            blocks: vec![0; w],
            busy: vec![0.0; w],
            nnz: vec![0; w],
            steals: vec![0; w],
        }
    }

    /// Max/mean block imbalance ratio (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        Self::max_over_mean(&self.blocks)
    }

    /// Max/mean claimed-nnz imbalance ratio (1.0 = perfect) — the tighter
    /// balance figure LPT packing targets: blocks are equal only up to the
    /// `target + threshold` bound, non-zeros are what workers actually pay.
    pub fn nnz_imbalance(&self) -> f64 {
        Self::max_over_mean(&self.nnz)
    }

    /// Max/mean busy-seconds imbalance ratio (1.0 = perfect) — skew in
    /// *time* units, the figure claimed-nnz balance only approximates
    /// (heterogeneous blocks make equal nnz shares take unequal time).
    pub fn latency_imbalance(&self) -> f64 {
        if self.busy.is_empty() {
            return 1.0;
        }
        let max = self.busy.iter().cloned().fold(0.0f64, f64::max);
        let mean = self.busy.iter().sum::<f64>() / self.busy.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    fn max_over_mean(xs: &[usize]) -> f64 {
        if xs.is_empty() {
            return 1.0;
        }
        let max = *xs.iter().max().unwrap() as f64;
        let mean = xs.iter().sum::<usize>() as f64 / xs.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Total blocks processed across workers.
    pub fn total_blocks(&self) -> usize {
        self.blocks.iter().sum()
    }

    /// Total non-zeros claimed across workers.
    pub fn total_nnz(&self) -> usize {
        self.nnz.iter().sum()
    }

    /// Total stolen-block executions across workers (0 for non-stealing
    /// regions).
    pub fn total_steals(&self) -> usize {
        self.steals.iter().sum()
    }

    /// Accumulate a lease-local region's stats into this (budget-wide) one,
    /// mapping the region's worker index `w` to the global worker slot
    /// `slots[w]` — how [`crate::sched::Executor`] attributes
    /// concurrently-leased passes to *disjoint* worker slots instead of
    /// piling every lease's worker 0 onto the same global slot.
    ///
    /// Contract: a leased pass runs with at most `slots.len()` workers. If
    /// a caller ever reports more, the excess is folded onto the lease's
    /// last slot so totals stay exact (and a debug assertion fires).
    pub fn absorb_at(&mut self, other: &WorkerStats, slots: &[usize]) {
        if slots.is_empty() {
            debug_assert!(other.total_blocks() == 0 && other.total_nnz() == 0);
            return;
        }
        debug_assert!(
            other.blocks.len() <= slots.len(),
            "pass reported {} workers on a {}-worker lease",
            other.blocks.len(),
            slots.len()
        );
        let last = *slots.last().expect("non-empty checked");
        let want = slots.iter().copied().max().unwrap_or(0) + 1;
        if self.blocks.len() < want {
            self.blocks.resize(want, 0);
        }
        if self.busy.len() < want {
            self.busy.resize(want, 0.0);
        }
        if self.nnz.len() < want {
            self.nnz.resize(want, 0);
        }
        if self.steals.len() < want {
            self.steals.resize(want, 0);
        }
        let slot_of = |w: usize| slots.get(w).copied().unwrap_or(last);
        for (w, &b) in other.blocks.iter().enumerate() {
            self.blocks[slot_of(w)] += b;
        }
        for (w, &b) in other.busy.iter().enumerate() {
            self.busy[slot_of(w)] += b;
        }
        for (w, &b) in other.nnz.iter().enumerate() {
            self.nnz[slot_of(w)] += b;
        }
        for (w, &b) in other.steals.iter().enumerate() {
            self.steals[slot_of(w)] += b;
        }
    }

    /// Aggregate the per-worker counters by NUMA node: worker `w` charges
    /// `homes[w].node` (node 0 when `homes` is short or empty — unhomed
    /// regions are single-node by definition). Returns per-node
    /// `(blocks, nnz)`, indexed by node id, sized to the largest node
    /// seen. This is a view, not a field: `WorkerStats` stays exactly the
    /// per-worker record every absorb/imbalance path already handles.
    pub fn per_node(&self, homes: &[WorkerHome]) -> (Vec<usize>, Vec<usize>) {
        let node_of =
            |w: usize| homes.get(w).map(|h| h.node).unwrap_or(0);
        let nodes = (0..self.blocks.len().max(self.nnz.len()))
            .map(node_of)
            .max()
            .unwrap_or(0)
            + 1;
        let mut blocks = vec![0usize; nodes];
        let mut nnz = vec![0usize; nodes];
        for (w, &b) in self.blocks.iter().enumerate() {
            blocks[node_of(w)] += b;
        }
        for (w, &x) in self.nnz.iter().enumerate() {
            nnz[node_of(w)] += x;
        }
        (blocks, nnz)
    }

    /// Accumulate another parallel region's stats element-wise (used to sum
    /// the per-mode passes of one epoch into one report).
    pub fn absorb(&mut self, other: &WorkerStats) {
        if self.blocks.len() < other.blocks.len() {
            self.blocks.resize(other.blocks.len(), 0);
        }
        if self.busy.len() < other.busy.len() {
            self.busy.resize(other.busy.len(), 0.0);
        }
        if self.nnz.len() < other.nnz.len() {
            self.nnz.resize(other.nnz.len(), 0);
        }
        if self.steals.len() < other.steals.len() {
            self.steals.resize(other.steals.len(), 0);
        }
        for (a, b) in self.blocks.iter_mut().zip(other.blocks.iter()) {
            *a += b;
        }
        for (a, b) in self.busy.iter_mut().zip(other.busy.iter()) {
            *a += b;
        }
        for (a, b) in self.nnz.iter_mut().zip(other.nnz.iter()) {
            *a += b;
        }
        for (a, b) in self.steals.iter_mut().zip(other.steals.iter()) {
            *a += b;
        }
    }
}

/// Run `f(worker_id, block_id)` for every `block_id in 0..num_blocks`,
/// dynamically load-balanced over `workers` threads. Returns per-worker
/// stats. `workers == 1` runs inline (no thread spawn) so single-worker
/// baselines measure pure algorithm time.
pub fn parallel_dynamic<F>(workers: usize, num_blocks: usize, f: F) -> WorkerStats
where
    F: Fn(usize, usize) + Sync,
{
    parallel_reduce_stats(workers, num_blocks, || (), |_acc, w, b| f(w, b), |_acc, _o| {}).1
}

/// Parallel map-reduce: each worker folds its claimed blocks into a local
/// accumulator (`init()` per worker, `step(acc, worker, block)`), then the
/// locals are merged with `merge`. Used for gradient accumulation in the
/// core-matrix update (paper Algorithm 5 accumulates into global memory; a
/// per-worker local + tree merge is the shared-memory-hierarchy analogue).
pub fn parallel_reduce<Acc, I, S, M>(
    workers: usize,
    num_blocks: usize,
    init: I,
    step: S,
    merge: M,
) -> Acc
where
    Acc: Send,
    I: Fn() -> Acc + Sync,
    S: Fn(&mut Acc, usize, usize) + Sync,
    M: Fn(&mut Acc, Acc),
{
    parallel_reduce_stats(workers, num_blocks, init, step, merge).0
}

/// [`parallel_reduce`] that also reports per-worker [`WorkerStats`] — the
/// load-balance evidence the B-CSF benches assert against (the paper's
/// §IV-B claim is precisely that blocked scheduling keeps this flat).
pub fn parallel_reduce_stats<Acc, I, S, M>(
    workers: usize,
    num_blocks: usize,
    init: I,
    step: S,
    merge: M,
) -> (Acc, WorkerStats)
where
    Acc: Send,
    I: Fn() -> Acc + Sync,
    S: Fn(&mut Acc, usize, usize) + Sync,
    M: Fn(&mut Acc, Acc),
{
    parallel_reduce_stats_weighted(workers, num_blocks, init, step, merge, |_| 0)
}

/// [`parallel_reduce_stats`] with a per-block weight (`ShardPlan` passes
/// the block's measured non-zeros): each worker's claimed weight is
/// recorded in [`WorkerStats::nnz`].
pub fn parallel_reduce_stats_weighted<Acc, I, S, M, W>(
    workers: usize,
    num_blocks: usize,
    init: I,
    step: S,
    merge: M,
    weight: W,
) -> (Acc, WorkerStats)
where
    Acc: Send,
    I: Fn() -> Acc + Sync,
    S: Fn(&mut Acc, usize, usize) + Sync,
    M: Fn(&mut Acc, Acc),
    W: Fn(usize) -> usize + Sync,
{
    parallel_reduce_stats_weighted_homed(
        workers, num_blocks, &[], init, step, merge, weight,
    )
}

/// [`parallel_reduce_stats_weighted`] with per-worker memory-hierarchy
/// homes: each spawned worker binds to `homes[w]`
/// ([`topo::bind_worker`] — records its NUMA node for replica selection
/// and pins when the home names a real CPU) **before** running `init`,
/// so per-worker state allocated in `init` is first-touched on the
/// worker's home node. An empty (or short) `homes` leaves workers
/// unbound — exactly the unhomed behaviour. The single-worker inline
/// path never binds: the caller thread's placement is not the pool's to
/// change, and inline passes are the bit-reproducibility anchor.
/// Binding never affects results, only placement.
#[allow(clippy::too_many_arguments)]
pub fn parallel_reduce_stats_weighted_homed<Acc, I, S, M, W>(
    workers: usize,
    num_blocks: usize,
    homes: &[WorkerHome],
    init: I,
    step: S,
    merge: M,
    weight: W,
) -> (Acc, WorkerStats)
where
    Acc: Send,
    I: Fn() -> Acc + Sync,
    S: Fn(&mut Acc, usize, usize) + Sync,
    M: Fn(&mut Acc, Acc),
    W: Fn(usize) -> usize + Sync,
{
    let workers = workers.max(1);
    let mut stats = WorkerStats::with_workers(workers);
    if workers == 1 {
        let t = std::time::Instant::now();
        let mut acc = init();
        let mut claimed = 0usize;
        for b in 0..num_blocks {
            step(&mut acc, 0, b);
            claimed += weight(b);
        }
        stats.blocks[0] = num_blocks;
        stats.busy[0] = t.elapsed().as_secs_f64();
        stats.nnz[0] = claimed;
        return (acc, stats);
    }
    let next = AtomicUsize::new(0);
    let locals: Vec<(Acc, usize, usize, f64)> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let next = &next;
            let init = &init;
            let step = &step;
            let weight = &weight;
            let home = homes.get(w);
            handles.push(scope.spawn(move || {
                topo::bind_worker(home);
                let t = std::time::Instant::now();
                let mut acc = init();
                let mut mine = 0usize;
                let mut claimed = 0usize;
                loop {
                    let b = next.fetch_add(1, Ordering::Relaxed);
                    if b >= num_blocks {
                        break;
                    }
                    step(&mut acc, w, b);
                    mine += 1;
                    claimed += weight(b);
                }
                (acc, mine, claimed, t.elapsed().as_secs_f64())
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut it = locals.into_iter();
    let (mut acc, blocks0, nnz0, busy0) = it.next().unwrap();
    stats.blocks[0] = blocks0;
    stats.busy[0] = busy0;
    stats.nnz[0] = nnz0;
    for (w, (local, blk, claimed, busy)) in it.enumerate() {
        merge(&mut acc, local);
        stats.blocks[w + 1] = blk;
        stats.busy[w + 1] = busy;
        stats.nnz[w + 1] = claimed;
    }
    (acc, stats)
}

/// One worker's deque in a stealing region: the seeded blocks plus the
/// remaining seeded weight (what thieves rank victims by).
struct StealQueue {
    deque: Mutex<VecDeque<u32>>,
    /// Sum of the weights of the blocks still in `deque` (relaxed reads
    /// are only a victim-selection heuristic; the deque mutex is the
    /// ground truth).
    remaining: AtomicU64,
}

/// Block-granular work stealing over per-worker deques.
///
/// `queues[w]` seeds worker `w`'s deque (front = heaviest, as
/// [`crate::sched::shard::ShardPlan::steal_queues`] packs them). A worker
/// pops its own queue from the **front**; when empty it steals one block
/// from the **back** (small-filler end) of the queue with the largest
/// remaining seeded weight. Every block runs exactly once; `steps` land in
/// per-worker accumulators merged in worker order — callers needing
/// schedule-independent merge bits (core gradients) must route per-block
/// results through canonical-order slots themselves (the engine does).
///
/// One worker runs inline, draining queue 0 front-to-back — with an
/// identity-seeded queue that is exactly the serial static path, which is
/// what the stealing parity tests anchor on.
pub fn parallel_reduce_stealing<Acc, I, S, M, W>(
    queues: &[Vec<u32>],
    init: I,
    step: S,
    merge: M,
    weight: W,
) -> (Acc, WorkerStats)
where
    Acc: Send,
    I: Fn() -> Acc + Sync,
    S: Fn(&mut Acc, usize, usize) + Sync,
    M: Fn(&mut Acc, Acc),
    W: Fn(usize) -> usize + Sync,
{
    let (acc, stats, _cross) =
        parallel_reduce_stealing_homed(queues, &[], init, step, merge, weight);
    (acc, stats)
}

/// [`parallel_reduce_stealing`] with per-worker memory-hierarchy homes:
/// spawned workers bind to `homes[w]` before `init` (first-touch +
/// optional pin, exactly as
/// [`parallel_reduce_stats_weighted_homed`]), and each steal whose thief
/// and victim live on *different* nodes is charged to the third return
/// value — the cross-node migration count, the price stealing pays for
/// rebalancing across the hierarchy (the stolen block's staged arrays
/// live on the victim's node). Empty `homes` = unbound workers, zero
/// cross-node steals.
pub fn parallel_reduce_stealing_homed<Acc, I, S, M, W>(
    queues: &[Vec<u32>],
    homes: &[WorkerHome],
    init: I,
    step: S,
    merge: M,
    weight: W,
) -> (Acc, WorkerStats, usize)
where
    Acc: Send,
    I: Fn() -> Acc + Sync,
    S: Fn(&mut Acc, usize, usize) + Sync,
    M: Fn(&mut Acc, Acc),
    W: Fn(usize) -> usize + Sync,
{
    let node_of = |w: usize| homes.get(w).map(|h| h.node).unwrap_or(0);
    let workers = queues.len().max(1);
    let mut stats = WorkerStats::with_workers(workers);
    if workers == 1 {
        let t = std::time::Instant::now();
        let mut acc = init();
        let mut claimed = 0usize;
        let own = queues.first().map(|q| q.as_slice()).unwrap_or(&[]);
        for &b in own {
            step(&mut acc, 0, b as usize);
            claimed += weight(b as usize);
        }
        stats.blocks[0] = own.len();
        stats.busy[0] = t.elapsed().as_secs_f64();
        stats.nnz[0] = claimed;
        return (acc, stats, 0);
    }
    let shared: Vec<StealQueue> = queues
        .iter()
        .map(|q| StealQueue {
            remaining: AtomicU64::new(
                q.iter().map(|&b| weight(b as usize) as u64).sum(),
            ),
            deque: Mutex::new(q.iter().copied().collect()),
        })
        .collect();
    let blocks_left =
        AtomicUsize::new(queues.iter().map(|q| q.len()).sum::<usize>());
    let locals: Vec<(Acc, usize, usize, usize, usize, f64)> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = &shared;
            let blocks_left = &blocks_left;
            let init = &init;
            let step = &step;
            let weight = &weight;
            let node_of = &node_of;
            let home = homes.get(w);
            handles.push(scope.spawn(move || {
                topo::bind_worker(home);
                let t = std::time::Instant::now();
                let mut acc = init();
                let (mut mine, mut claimed, mut stolen) = (0usize, 0usize, 0usize);
                let mut cross = 0usize;
                let pop = |victim: usize, back: bool| -> Option<u32> {
                    let mut dq = shared[victim].deque.lock().unwrap();
                    let got = if back { dq.pop_back() } else { dq.pop_front() };
                    if let Some(b) = got {
                        shared[victim]
                            .remaining
                            .fetch_sub(weight(b as usize) as u64, Ordering::Relaxed);
                        blocks_left.fetch_sub(1, Ordering::Relaxed);
                    }
                    got
                };
                while blocks_left.load(Ordering::Acquire) > 0 {
                    // own queue first: front = heaviest of the seed
                    if let Some(b) = pop(w, false) {
                        step(&mut acc, w, b as usize);
                        mine += 1;
                        claimed += weight(b as usize);
                        continue;
                    }
                    // steal from the heaviest remaining queue (ties to the
                    // lowest id), taking the light back end so the victim
                    // keeps its big in-progress prefix
                    let victim = shared
                        .iter()
                        .enumerate()
                        .filter(|(v, q)| {
                            *v != w && q.remaining.load(Ordering::Relaxed) > 0
                        })
                        .max_by_key(|(v, q)| {
                            (q.remaining.load(Ordering::Relaxed), usize::MAX - *v)
                        })
                        .map(|(v, _)| v);
                    match victim.map(|v| (v, pop(v, true))) {
                        Some((v, Some(b))) => {
                            step(&mut acc, w, b as usize);
                            mine += 1;
                            stolen += 1;
                            if node_of(w) != node_of(v) {
                                // the stolen block's staged arrays live on
                                // the victim's node: a cross-node migration
                                cross += 1;
                            }
                            claimed += weight(b as usize);
                        }
                        // raced with another thief (or the tail is only
                        // in-flight blocks): re-check and let the region end
                        _ => std::hint::spin_loop(),
                    }
                }
                (acc, mine, claimed, stolen, cross, t.elapsed().as_secs_f64())
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut it = locals.into_iter();
    let (mut acc, blocks0, nnz0, steals0, cross0, busy0) = it.next().unwrap();
    let mut cross_total = cross0;
    stats.blocks[0] = blocks0;
    stats.busy[0] = busy0;
    stats.nnz[0] = nnz0;
    stats.steals[0] = steals0;
    for (w, (local, blk, claimed, stolen, cross, busy)) in it.enumerate() {
        merge(&mut acc, local);
        stats.blocks[w + 1] = blk;
        stats.busy[w + 1] = busy;
        stats.nnz[w + 1] = claimed;
        stats.steals[w + 1] = stolen;
        cross_total += cross;
    }
    (acc, stats, cross_total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn all_blocks_processed_exactly_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let stats = parallel_dynamic(4, n, |_w, b| {
            hits[b].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(stats.blocks.iter().sum::<usize>(), n);
    }

    #[test]
    fn single_worker_inline() {
        let sum = AtomicU64::new(0);
        let stats = parallel_dynamic(1, 10, |w, b| {
            assert_eq!(w, 0);
            sum.fetch_add(b as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
        assert_eq!(stats.blocks, vec![10]);
    }

    #[test]
    fn zero_blocks_is_fine() {
        let stats = parallel_dynamic(4, 0, |_w, _b| panic!("no blocks"));
        assert_eq!(stats.blocks.iter().sum::<usize>(), 0);
    }

    #[test]
    fn more_workers_than_blocks() {
        let stats = parallel_dynamic(16, 3, |_w, _b| {});
        assert_eq!(stats.blocks.iter().sum::<usize>(), 3);
    }

    #[test]
    fn reduce_sums_correctly() {
        let total = parallel_reduce(
            4,
            100,
            || 0u64,
            |acc, _w, b| *acc += b as u64,
            |acc, other| *acc += other,
        );
        assert_eq!(total, (0..100u64).sum());
    }

    #[test]
    fn reduce_single_worker() {
        let total = parallel_reduce(
            1,
            10,
            || 0u64,
            |acc, _w, b| *acc += b as u64 + 1,
            |acc, other| *acc += other,
        );
        assert_eq!(total, 55);
    }

    #[test]
    fn reduce_vector_accumulators() {
        // per-worker gradient-style accumulation
        let grad = parallel_reduce(
            3,
            30,
            || vec![0.0f64; 4],
            |acc, _w, b| acc[b % 4] += 1.0,
            |acc, other| {
                for (a, o) in acc.iter_mut().zip(other) {
                    *a += o;
                }
            },
        );
        assert_eq!(grad.iter().sum::<f64>(), 30.0);
    }

    #[test]
    fn reduce_stats_counts_every_block_once() {
        let (total, stats) = parallel_reduce_stats(
            4,
            64,
            || 0u64,
            |acc, _w, b| *acc += b as u64,
            |acc, other| *acc += other,
        );
        assert_eq!(total, (0..64u64).sum());
        assert_eq!(stats.total_blocks(), 64);
        assert_eq!(stats.blocks.len(), 4);
        assert!(stats.imbalance() >= 1.0 - 1e-9);
        // unweighted region: no claimed nnz recorded
        assert_eq!(stats.total_nnz(), 0);
    }

    #[test]
    fn reduce_stats_single_worker_inline() {
        let (total, stats) = parallel_reduce_stats(
            1,
            10,
            || 0u64,
            |acc, w, _b| {
                assert_eq!(w, 0);
                *acc += 1;
            },
            |acc, other| *acc += other,
        );
        assert_eq!(total, 10);
        assert_eq!(stats.blocks, vec![10]);
        assert!((stats.imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn weighted_reduce_accounts_every_blocks_weight_once() {
        for workers in [1usize, 4] {
            let (_, stats) = parallel_reduce_stats_weighted(
                workers,
                100,
                || 0u64,
                |acc, _w, b| *acc += b as u64,
                |acc, other| *acc += other,
                |b| b + 1,
            );
            assert_eq!(stats.total_nnz(), (1..=100).sum::<usize>(), "{workers} workers");
            assert_eq!(stats.total_blocks(), 100);
        }
    }

    #[test]
    fn absorb_at_maps_lease_slots_without_double_counting() {
        let mut total = WorkerStats::with_workers(4);
        let lease_a = WorkerStats {
            blocks: vec![3],
            busy: vec![0.5],
            nnz: vec![30],
            ..Default::default()
        };
        let lease_b = WorkerStats {
            blocks: vec![7],
            busy: vec![1.0],
            nnz: vec![70],
            ..Default::default()
        };
        // two concurrently-leased 1-worker passes land on *different* slots
        total.absorb_at(&lease_a, &[2]);
        total.absorb_at(&lease_b, &[0]);
        assert_eq!(total.blocks, vec![7, 0, 3, 0]);
        assert_eq!(total.nnz, vec![70, 0, 30, 0]);
        assert_eq!(total.total_blocks(), 10);
        assert_eq!(total.total_nnz(), 100);
        // a wider lease maps element-wise onto its slot list
        let wide = WorkerStats {
            blocks: vec![1, 2],
            busy: vec![0.1, 0.2],
            nnz: vec![5, 6],
            ..Default::default()
        };
        total.absorb_at(&wide, &[1, 3]);
        assert_eq!(total.blocks, vec![7, 1, 3, 2]);
        assert_eq!(total.nnz, vec![70, 5, 30, 6]);
    }

    #[test]
    fn stats_absorb_sums_elementwise() {
        let mut a = WorkerStats {
            blocks: vec![1, 2],
            busy: vec![0.5, 0.5],
            nnz: vec![10, 20],
            ..Default::default()
        };
        let b = WorkerStats {
            blocks: vec![3, 4, 5],
            busy: vec![1.0, 1.0, 1.0],
            nnz: vec![1, 2, 3],
            steals: vec![1, 0, 2],
        };
        a.absorb(&b);
        assert_eq!(a.blocks, vec![4, 6, 5]);
        assert_eq!(a.nnz, vec![11, 22, 3]);
        assert_eq!(a.steals, vec![1, 0, 2]);
        assert_eq!(a.total_steals(), 3);
        assert_eq!(a.total_blocks(), 15);
        assert!((a.busy.iter().sum::<f64>() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn latency_imbalance_mirrors_busy_skew() {
        let even = WorkerStats {
            busy: vec![1.0, 1.0, 1.0, 1.0],
            ..Default::default()
        };
        assert!((even.latency_imbalance() - 1.0).abs() < 1e-12);
        let skewed = WorkerStats {
            busy: vec![4.0, 0.0, 0.0, 0.0],
            ..Default::default()
        };
        assert!((skewed.latency_imbalance() - 4.0).abs() < 1e-12);
        // degenerate cases stay at the perfect-balance sentinel
        assert!((WorkerStats::default().latency_imbalance() - 1.0).abs() < 1e-12);
        let idle = WorkerStats {
            busy: vec![0.0, 0.0],
            ..Default::default()
        };
        assert!((idle.latency_imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stealing_processes_every_seeded_block_once() {
        for queues in [
            // balanced seed
            vec![vec![0u32, 1, 2], vec![3, 4, 5], vec![6, 7], vec![8, 9]],
            // everything seeded on one queue: the others must steal
            vec![(0u32..32).collect::<Vec<u32>>(), vec![], vec![], vec![]],
            // empty region
            vec![vec![], vec![]],
        ] {
            let n: usize = queues.iter().map(|q| q.len()).sum();
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let (total, stats) = parallel_reduce_stealing(
                &queues,
                || 0u64,
                |acc, _w, b| {
                    hits[b].fetch_add(1, Ordering::Relaxed);
                    *acc += b as u64;
                },
                |acc, other| *acc += other,
                |b| b + 1,
            );
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
            assert_eq!(total, (0..n as u64).sum());
            assert_eq!(stats.total_blocks(), n);
            assert_eq!(stats.total_nnz(), (1..=n).sum::<usize>());
        }
    }

    #[test]
    fn stealing_single_worker_runs_queue_in_seed_order() {
        let queues = vec![vec![5u32, 3, 1, 4]];
        let seen = Mutex::new(Vec::new());
        let (count, stats) = parallel_reduce_stealing(
            &queues,
            || 0usize,
            |acc, w, b| {
                assert_eq!(w, 0);
                seen.lock().unwrap().push(b as u32);
                *acc += 1;
            },
            |acc, other| *acc += other,
            |_| 1,
        );
        assert_eq!(count, 4);
        assert_eq!(*seen.lock().unwrap(), vec![5, 3, 1, 4]);
        assert_eq!(stats.blocks, vec![4]);
        assert_eq!(stats.total_steals(), 0);
    }

    #[test]
    fn stealing_from_a_single_loaded_queue_records_steals() {
        // all work on queue 0; a slow step forces workers 1..3 to steal
        let queues = vec![(0u32..64).collect::<Vec<u32>>(), vec![], vec![], vec![]];
        let (_, stats) = parallel_reduce_stealing(
            &queues,
            || (),
            |_acc, _w, _b| {
                std::thread::sleep(std::time::Duration::from_micros(200));
            },
            |_acc, _o| {},
            |_| 1,
        );
        assert_eq!(stats.total_blocks(), 64);
        assert!(
            stats.total_steals() > 0,
            "idle workers should have stolen from the loaded queue: {:?}",
            stats.steals
        );
        // steals are attributed to the thief, not the victim
        assert_eq!(stats.steals[0], 0);
    }

    #[test]
    fn per_node_aggregates_worker_counters_by_home() {
        let stats = WorkerStats {
            blocks: vec![3, 4, 5, 6],
            busy: vec![],
            nnz: vec![30, 40, 50, 60],
            ..Default::default()
        };
        // unhomed regions are single-node by definition
        let (blocks, nnz) = stats.per_node(&[]);
        assert_eq!(blocks, vec![18]);
        assert_eq!(nnz, vec![180]);
        // a 2-node split charges each worker's home node
        let homes: Vec<WorkerHome> = [0, 0, 1, 1]
            .iter()
            .map(|&node| WorkerHome { node, cpu: None })
            .collect();
        let (blocks, nnz) = stats.per_node(&homes);
        assert_eq!(blocks, vec![7, 11]);
        assert_eq!(nnz, vec![70, 110]);
    }

    #[test]
    fn homed_reduce_binds_workers_to_their_nodes() {
        let homes: Vec<WorkerHome> = [0, 1, 1]
            .iter()
            .map(|&node| WorkerHome { node, cpu: None })
            .collect();
        // every step must observe the node its worker was bound to
        let (nodes_seen, stats) = parallel_reduce_stats_weighted_homed(
            3,
            30,
            &homes,
            Vec::new,
            |acc: &mut Vec<(usize, usize)>, w, _b| {
                acc.push((w, crate::sched::topo::current_node()));
            },
            |acc, other| acc.extend(other),
            |_| 1,
        );
        assert_eq!(stats.total_blocks(), 30);
        for (w, node) in nodes_seen {
            assert_eq!(node, homes[w].node, "worker {w} saw the wrong node");
        }
    }

    #[test]
    fn homed_stealing_counts_cross_node_migrations() {
        // all work seeded on worker 0 (node 0); workers on node 1 must
        // cross the node boundary to steal
        let queues = vec![(0u32..64).collect::<Vec<u32>>(), vec![], vec![], vec![]];
        let homes: Vec<WorkerHome> = [0, 0, 1, 1]
            .iter()
            .map(|&node| WorkerHome { node, cpu: None })
            .collect();
        let (_, stats, cross) = parallel_reduce_stealing_homed(
            &queues,
            &homes,
            || (),
            |_acc, _w, _b| {
                std::thread::sleep(std::time::Duration::from_micros(200));
            },
            |_acc, _o| {},
            |_| 1,
        );
        assert_eq!(stats.total_blocks(), 64);
        let node1_steals: usize = stats.steals[2] + stats.steals[3];
        assert_eq!(
            cross, node1_steals,
            "every node-1 steal from node-0 queues is a migration"
        );
        // unhomed stealing never charges migrations
        let (_, _, cross) = parallel_reduce_stealing_homed(
            &queues,
            &[],
            || (),
            |_acc, _w, _b| {},
            |_acc, _o| {},
            |_| 1,
        );
        assert_eq!(cross, 0);
    }

    #[test]
    fn imbalance_of_even_split_is_low() {
        let stats = WorkerStats {
            blocks: vec![10, 10, 10, 10],
            busy: vec![],
            nnz: vec![512, 500, 505, 507],
            ..Default::default()
        };
        assert!((stats.imbalance() - 1.0).abs() < 1e-9);
        assert!(stats.nnz_imbalance() < 1.02);
        let skewed = WorkerStats {
            blocks: vec![40, 0, 0, 0],
            busy: vec![],
            nnz: vec![4000, 0, 0, 0],
            ..Default::default()
        };
        assert!(skewed.imbalance() > 3.9);
        assert!(skewed.nnz_imbalance() > 3.9);
    }
}
