//! Hogwild-style shared matrix access.
//!
//! The paper's CUDA kernels update factor rows from many thread-groups with
//! no synchronization (stale/interleaved reads are tolerated by SGD — the
//! classic Hogwild! result). A plain `&mut` aliased across threads is UB in
//! Rust, so [`RacyMatrix`] reinterprets the matrix storage as relaxed
//! `AtomicU32` cells: on x86-64 a relaxed 32-bit load/store compiles to an
//! ordinary `mov`, so this costs nothing over the CUDA semantics while
//! staying data-race-free by the language's rules.

use crate::linalg::simd::{reduce_lanes, LANES};
use crate::linalg::Matrix;
use std::sync::atomic::{AtomicU32, Ordering};

/// A shared, lock-free view over a [`Matrix`] allowing concurrent row reads
/// and writes with relaxed atomicity (element-wise; rows are *not* updated
/// atomically as a unit — exactly the GPU behaviour).
pub struct RacyMatrix<'a> {
    cells: &'a [AtomicU32],
    rows: usize,
    cols: usize,
}

unsafe impl<'a> Sync for RacyMatrix<'a> {}
unsafe impl<'a> Send for RacyMatrix<'a> {}

impl<'a> RacyMatrix<'a> {
    /// Take exclusive ownership of `m`'s storage for the view's lifetime.
    pub fn new(m: &'a mut Matrix) -> Self {
        let rows = m.rows();
        let cols = m.cols();
        let data = m.data_mut();
        // SAFETY: AtomicU32 has the same size/alignment as u32/f32 and
        // `repr(transparent)`-compatible layout; we hold the unique &mut so
        // no other safe alias exists; all access goes through atomics.
        let cells = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const AtomicU32, data.len())
        };
        RacyMatrix { cells, rows, cols }
    }

    /// Row count of the viewed matrix.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Column count of the viewed matrix.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    fn cell(&self, i: usize, j: usize) -> &AtomicU32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.cells[i * self.cols + j]
    }

    /// Read one element.
    #[inline]
    pub fn load(&self, i: usize, j: usize) -> f32 {
        f32::from_bits(self.cell(i, j).load(Ordering::Relaxed))
    }

    /// Write one element.
    #[inline]
    pub fn store(&self, i: usize, j: usize, v: f32) {
        self.cell(i, j).store(v.to_bits(), Ordering::Relaxed);
    }

    /// Copy row `i` into `buf` (paper: load `a_{i_n}` into registers).
    #[inline]
    pub fn read_row(&self, i: usize, buf: &mut [f32]) {
        debug_assert_eq!(buf.len(), self.cols);
        let base = i * self.cols;
        for (j, b) in buf.iter_mut().enumerate() {
            *b = f32::from_bits(self.cells[base + j].load(Ordering::Relaxed));
        }
    }

    /// Write `buf` into row `i`.
    #[inline]
    pub fn write_row(&self, i: usize, buf: &[f32]) {
        debug_assert_eq!(buf.len(), self.cols);
        let base = i * self.cols;
        for (j, &v) in buf.iter().enumerate() {
            self.cells[base + j].store(v.to_bits(), Ordering::Relaxed);
        }
    }

    /// Dot product of row `i` with `w` without copying the row out.
    /// 8-lane blocked like the `algo::kernels` layer: relaxed atomic loads
    /// compile to plain `mov`s but inhibit auto-vectorization, so the FP
    /// dependency chain is broken by hand into [`LANES`] independent
    /// accumulators, reduced through the one fixed tree
    /// ([`crate::linalg::simd::reduce_lanes`]) every reducing kernel shares.
    #[inline]
    pub fn row_dot(&self, i: usize, w: &[f32]) -> f32 {
        debug_assert_eq!(w.len(), self.cols);
        let base = i * self.cols;
        let cells = &self.cells[base..base + self.cols];
        let mut acc = [0.0f32; LANES];
        let chunks = self.cols / LANES;
        for k in 0..chunks {
            let j = k * LANES;
            for l in 0..LANES {
                acc[l] +=
                    f32::from_bits(cells[j + l].load(Ordering::Relaxed)) * w[j + l];
            }
        }
        for j in chunks * LANES..self.cols {
            acc[j - chunks * LANES] +=
                f32::from_bits(cells[j].load(Ordering::Relaxed)) * w[j];
        }
        reduce_lanes(acc)
    }

    /// The fused SGD row update `a ← (1 − γλ)·a + (γe)·w` (paper eq. 9/10),
    /// performed element-wise in place (8-lane blocked like
    /// [`Self::row_dot`]; element-wise, so lane shape never changes bits).
    #[inline]
    pub fn row_sgd_update(&self, i: usize, scale: f32, step: f32, w: &[f32]) {
        debug_assert_eq!(w.len(), self.cols);
        let base = i * self.cols;
        let cells = &self.cells[base..base + self.cols];
        let chunks = self.cols / LANES;
        for k in 0..chunks {
            let j = k * LANES;
            // independent load→fma→store chains; relaxed = plain mov on x86
            let mut old = [0.0f32; LANES];
            for l in 0..LANES {
                old[l] = f32::from_bits(cells[j + l].load(Ordering::Relaxed));
            }
            for l in 0..LANES {
                cells[j + l].store(
                    (scale * old[l] + step * w[j + l]).to_bits(),
                    Ordering::Relaxed,
                );
            }
        }
        for j in chunks * LANES..self.cols {
            let old = f32::from_bits(cells[j].load(Ordering::Relaxed));
            cells[j].store((scale * old + step * w[j]).to_bits(), Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::pool::parallel_dynamic;

    #[test]
    fn load_store_roundtrip() {
        let mut m = Matrix::zeros(3, 4);
        {
            let v = RacyMatrix::new(&mut m);
            v.store(1, 2, 7.5);
            assert_eq!(v.load(1, 2), 7.5);
        }
        assert_eq!(m.get(1, 2), 7.5);
    }

    #[test]
    fn row_ops_match_serial() {
        let mut m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let v = RacyMatrix::new(&mut m);
        let mut buf = [0f32; 3];
        v.read_row(1, &mut buf);
        assert_eq!(buf, [4., 5., 6.]);
        assert_eq!(v.row_dot(0, &[1., 1., 1.]), 6.0);
        v.write_row(0, &[9., 9., 9.]);
        assert_eq!(v.row_dot(0, &[1., 0., 0.]), 9.0);
    }

    #[test]
    fn sgd_update_formula() {
        let mut m = Matrix::from_vec(1, 2, vec![2.0, 4.0]);
        let v = RacyMatrix::new(&mut m);
        // a ← 0.5*a + 2.0*w
        v.row_sgd_update(0, 0.5, 2.0, &[1.0, 10.0]);
        assert_eq!(v.load(0, 0), 0.5 * 2.0 + 2.0 * 1.0);
        assert_eq!(v.load(0, 1), 0.5 * 4.0 + 2.0 * 10.0);
    }

    #[test]
    fn concurrent_disjoint_rows_are_exact() {
        let rows = 64;
        let mut m = Matrix::zeros(rows, 8);
        let v = RacyMatrix::new(&mut m);
        parallel_dynamic(8, rows, |_w, i| {
            let buf = [i as f32; 8];
            v.write_row(i, &buf);
        });
        drop(v);
        for i in 0..rows {
            assert!(m.row(i).iter().all(|&x| x == i as f32));
        }
    }

    #[test]
    fn concurrent_same_row_lands_one_of_the_writes() {
        // racy by design: the final value must be one of the written values,
        // never a torn/garbage bit pattern
        let mut m = Matrix::zeros(1, 4);
        let v = RacyMatrix::new(&mut m);
        parallel_dynamic(8, 100, |_w, b| {
            let val = (b % 7) as f32;
            v.write_row(0, &[val; 4]);
        });
        drop(v);
        for &x in m.row(0) {
            assert!((0.0..7.0).contains(&x) && x == x.trunc());
        }
    }
}
