//! Worker-parallel execution substrate — the CPU realization of the paper's
//! GPU mapping (§IV-B/C):
//!
//! * A **worker** (paper: warp-sized thread-group) is an OS thread that
//!   claims B-CSF *blocks* (paper: sub-tensors) from a shared atomic queue —
//!   dynamic self-scheduling, exactly how thread-blocks drain a grid.
//! * Factor rows are updated **Hogwild-style**: concurrent workers may touch
//!   the same row without locks, as the CUDA kernels do. [`racy`] provides
//!   a data-race-free (atomic, relaxed) view over a matrix so this is sound
//!   in Rust while compiling to plain loads/stores on x86.
//! * [`pool`] reports per-worker load so benches can show B-CSF's balance.
//! * [`executor`] is the multi-session seam: one process-wide [`Executor`]
//!   owns the worker budget and hands it out as disjoint worker-subset
//!   leases ([`WorkerLease`]), so many resident sessions share a single
//!   pool — concurrently when their lease sizes fit the budget — instead
//!   of stacking per-session thread counts.

pub mod executor;
pub mod pool;
pub mod racy;
pub mod shard;
pub mod topo;

pub use executor::{Backpressure, Executor, WorkerLease};
pub use pool::{
    parallel_dynamic, parallel_reduce, parallel_reduce_stats,
    parallel_reduce_stats_weighted, parallel_reduce_stats_weighted_homed,
    parallel_reduce_stealing_homed, WorkerStats,
};
pub use racy::RacyMatrix;
pub use shard::ShardPlan;
pub use topo::{current_node, Topology, WorkerHome};
