//! NUMA/core topology discovery and worker homes — the placement layer
//! under the executor.
//!
//! cuFasterTucker's speedups come from mapping the invariant-reusing TTM
//! chain onto the GPU memory hierarchy; the CPU analogue is knowing which
//! cores share which memory. [`Topology`] discovers the node→CPU map from
//! `/sys/devices/system/node` (deterministic single-node fallback when the
//! tree is absent, unreadable, or disabled via `--numa off`), and
//! [`Topology::assign_homes`] turns it into per-worker-slot
//! [`WorkerHome`]s: node-grouped, deterministic, lowest-node-first. The
//! executor pins real (non-synthetic, multi-node) homes with a raw
//! `sched_setaffinity` at spawn; everything else — replica selection,
//! node-compact leases, per-node stats — keys off the home's `node` alone,
//! so synthetic topologies (`--numa N-nodes`) exercise every multi-node
//! path on single-socket hardware without pinning to fictitious CPUs.
//!
//! Placement is never allowed to change the math: homes select which
//! bitwise-identical replica a worker reads and which CPU it runs on,
//! nothing else.

use crate::config::NumaMode;
use std::cell::Cell;
use std::path::Path;

/// One worker slot's memory-hierarchy assignment: the NUMA node whose
/// replica it reads (and whose memory its scratch should live in), plus
/// the concrete CPU to pin to — `None` for single-node and synthetic
/// topologies, where pinning would either be a no-op or actively wrong.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerHome {
    /// NUMA node index (0-based, dense).
    pub node: usize,
    /// CPU to pin this slot's thread to, when the node is real.
    pub cpu: Option<u32>,
}

impl WorkerHome {
    /// The single-node, unpinned home every slot gets without NUMA.
    pub fn local() -> WorkerHome {
        WorkerHome { node: 0, cpu: None }
    }
}

/// A discovered (or forced) NUMA topology: which CPUs belong to which
/// node. Nodes are dense and sorted; empty nodes are dropped at parse
/// time, so `nodes()` ≥ 1 always.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Online CPU ids per node, ascending within each node; outer index
    /// is the dense node id (which may differ from the kernel's node
    /// number when nodes are sparse — only the grouping matters here).
    node_cpus: Vec<Vec<u32>>,
    /// True when the nodes are fictitious (`--numa N-nodes`): homes carry
    /// node ids for replica/lease purposes but never a pinnable CPU.
    synthetic: bool,
}

impl Topology {
    /// The trivial topology: one node holding every available CPU, never
    /// pinned. This is both the `--numa off` behaviour and the fallback
    /// when `/sys` discovery finds nothing.
    pub fn single_node() -> Topology {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Topology {
            node_cpus: vec![(0..n as u32).collect()],
            synthetic: false,
        }
    }

    /// A synthetic `nodes`-node topology splitting the available CPUs
    /// round-robin. Deterministic; never pinned.
    pub fn synthetic(nodes: usize) -> Topology {
        let nodes = nodes.max(1);
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut node_cpus = vec![Vec::new(); nodes];
        for cpu in 0..n.max(nodes) as u32 {
            node_cpus[cpu as usize % nodes].push(cpu);
        }
        Topology { node_cpus, synthetic: true }
    }

    /// Discover the topology per the configured mode: `Off` → single
    /// node, `Force(n)` → synthetic, `Auto` → parse `/sys` (single-node
    /// fallback on any failure).
    pub fn detect(mode: NumaMode) -> Topology {
        match mode {
            NumaMode::Off => Topology::single_node(),
            NumaMode::Force(n) => Topology::synthetic(n),
            NumaMode::Auto => Topology::from_sys_paths(
                Path::new("/sys/devices/system/node"),
                Some(Path::new("/sys/devices/system/cpu/online")),
            )
            .unwrap_or_else(Topology::single_node),
        }
    }

    /// Parse a topology from a `/sys/devices/system/node`-shaped tree:
    /// each `node<N>/cpulist` contributes one node, filtered against the
    /// online CPU list when one is given (offline CPUs never become
    /// homes). Returns `None` when no node contributes any CPU — callers
    /// fall back to [`Topology::single_node`]. Exposed (rather than
    /// private) so the golden-file tests can drive fake trees.
    pub fn from_sys_paths(
        node_root: &Path,
        online_path: Option<&Path>,
    ) -> Option<Topology> {
        let online: Option<Vec<u32>> = online_path.and_then(|p| {
            let s = std::fs::read_to_string(p).ok()?;
            parse_cpulist(s.trim())
        });
        let entries = std::fs::read_dir(node_root).ok()?;
        // Collect (kernel node number, cpus) then sort by node number so
        // directory-iteration order can never reorder the dense ids.
        let mut nodes: Vec<(usize, Vec<u32>)> = Vec::new();
        for e in entries.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            let Some(num) = name.strip_prefix("node") else { continue };
            let Ok(num) = num.parse::<usize>() else { continue };
            let Ok(s) = std::fs::read_to_string(e.path().join("cpulist")) else {
                continue;
            };
            let Some(mut cpus) = parse_cpulist(s.trim()) else { continue };
            if let Some(on) = &online {
                cpus.retain(|c| on.contains(c));
            }
            if !cpus.is_empty() {
                nodes.push((num, cpus));
            }
        }
        if nodes.is_empty() {
            return None;
        }
        nodes.sort_by_key(|(num, _)| *num);
        Some(Topology {
            node_cpus: nodes.into_iter().map(|(_, c)| c).collect(),
            synthetic: false,
        })
    }

    /// Number of nodes (≥ 1).
    pub fn nodes(&self) -> usize {
        self.node_cpus.len()
    }

    /// Whether this topology came from `--numa N-nodes` (homes carry node
    /// ids but no pinnable CPUs).
    pub fn is_synthetic(&self) -> bool {
        self.synthetic
    }

    /// CPU count on node `n` (0 when out of range).
    pub fn node_len(&self, n: usize) -> usize {
        self.node_cpus.get(n).map_or(0, Vec::len)
    }

    /// Assign `workers` slots their homes: slots fill node 0's CPUs
    /// first, then node 1's, and so on (node-grouped so node-compact
    /// lease allocation can hand out contiguous same-node slot runs),
    /// wrapping round-robin once every CPU is taken. Single-node
    /// topologies produce all-[`WorkerHome::local`] homes — the exact
    /// pre-NUMA behaviour. CPUs are only recorded on real multi-node
    /// topologies; synthetic and single-node homes are never pinned.
    pub fn assign_homes(&self, workers: usize) -> Vec<WorkerHome> {
        if self.nodes() <= 1 {
            return vec![WorkerHome::local(); workers];
        }
        if self.synthetic {
            // fictitious nodes shape the *workers*, not the CPUs: split
            // the slot range into `nodes` contiguous balanced groups so
            // `--numa N-nodes` exercises the multi-node paths at any
            // worker count on any machine (never pinned)
            let nodes = self.nodes();
            return (0..workers)
                .map(|w| WorkerHome { node: w * nodes / workers.max(1), cpu: None })
                .collect();
        }
        let flat: Vec<WorkerHome> = self
            .node_cpus
            .iter()
            .enumerate()
            .flat_map(|(node, cpus)| {
                cpus.iter().map(move |&cpu| WorkerHome { node, cpu: Some(cpu) })
            })
            .collect();
        (0..workers).map(|w| flat[w % flat.len()]).collect()
    }
}

/// Parse a kernel cpulist (`"0-3,8-11"`, `"0"`, `""`) into ascending CPU
/// ids. Returns `None` on malformed input (treated as "no CPUs here").
pub fn parse_cpulist(s: &str) -> Option<Vec<u32>> {
    let mut cpus = Vec::new();
    let s = s.trim();
    if s.is_empty() {
        return Some(cpus);
    }
    for part in s.split(',') {
        let part = part.trim();
        if let Some((lo, hi)) = part.split_once('-') {
            let lo: u32 = lo.trim().parse().ok()?;
            let hi: u32 = hi.trim().parse().ok()?;
            if hi < lo {
                return None;
            }
            cpus.extend(lo..=hi);
        } else {
            cpus.push(part.parse().ok()?);
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    Some(cpus)
}

thread_local! {
    /// The NUMA node the current thread was bound to at spawn (0 when
    /// unbound — the caller thread, inline passes, and every thread on a
    /// single-node machine). Workers read this to pick their replica.
    static CURRENT_NODE: Cell<usize> = const { Cell::new(0) };
}

/// The NUMA node the current thread is homed on (0 when unbound).
pub fn current_node() -> usize {
    CURRENT_NODE.with(Cell::get)
}

/// Bind the current thread to a worker home: records the node for
/// replica selection and — when the home names a real CPU — pins via
/// `sched_setaffinity`. Call from inside the spawned worker thread,
/// before any first-touch allocation. `None` (and homes without a CPU)
/// only set the node. Pinning is best-effort: a failed syscall leaves
/// the thread floating but the node binding (and therefore the math)
/// intact.
pub fn bind_worker(home: Option<&WorkerHome>) {
    let home = home.copied().unwrap_or_else(WorkerHome::local);
    CURRENT_NODE.with(|n| n.set(home.node));
    if let Some(cpu) = home.cpu {
        let _ = pin_to_cpu(cpu);
    }
}

/// Pin the calling thread to one CPU with a raw `sched_setaffinity`
/// syscall (no libc dependency). Returns whether the kernel accepted the
/// mask. Non-Linux-syscall targets compile to a no-op returning `false`.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn pin_to_cpu(cpu: u32) -> bool {
    // A fixed 1024-bit mask (the kernel's historical cpu_set_t size);
    // CPUs beyond it are out of scope for this best-effort pin.
    let mut mask = [0usize; 1024 / (usize::BITS as usize)];
    let idx = cpu as usize / usize::BITS as usize;
    if idx >= mask.len() {
        return false;
    }
    mask[idx] = 1usize << (cpu as usize % usize::BITS as usize);
    let size = std::mem::size_of_val(&mask);
    let ptr = mask.as_ptr();
    let ret: isize;
    #[cfg(target_arch = "x86_64")]
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => ret, // __NR_sched_setaffinity
            in("rdi") 0usize,                 // pid 0 = calling thread
            in("rsi") size,
            in("rdx") ptr,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    #[cfg(target_arch = "aarch64")]
    unsafe {
        std::arch::asm!(
            "svc 0",
            in("x8") 122isize, // __NR_sched_setaffinity
            inlateout("x0") 0usize => ret,
            in("x1") size,
            in("x2") ptr,
            options(nostack),
        );
    }
    ret == 0
}

/// No-op fallback for targets without the raw-syscall pin.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn pin_to_cpu(_cpu: u32) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;
    use std::path::PathBuf;

    /// Build a fake `/sys/devices/system/node`-shaped tree under a unique
    /// temp dir; returns (node_root, online_path_or_none).
    fn fake_sys(
        tag: &str,
        nodes: &[(usize, &str)],
        online: Option<&str>,
    ) -> (PathBuf, Option<PathBuf>) {
        let root = std::env::temp_dir()
            .join(format!("ft_topo_{tag}_{}", std::process::id()));
        let node_root = root.join("node");
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&node_root).unwrap();
        for (num, cpulist) in nodes {
            let d = node_root.join(format!("node{num}"));
            fs::create_dir_all(&d).unwrap();
            fs::write(d.join("cpulist"), format!("{cpulist}\n")).unwrap();
        }
        let online_path = online.map(|s| {
            let p = root.join("online");
            fs::write(&p, format!("{s}\n")).unwrap();
            p
        });
        (node_root, online_path)
    }

    #[test]
    fn parse_cpulist_handles_ranges_singles_and_garbage() {
        assert_eq!(parse_cpulist("0-3").unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0-3,8-11").unwrap(), vec![0, 1, 2, 3, 8, 9, 10, 11]);
        assert_eq!(parse_cpulist("5").unwrap(), vec![5]);
        assert_eq!(parse_cpulist("3,1,1").unwrap(), vec![1, 3]);
        assert_eq!(parse_cpulist("").unwrap(), Vec::<u32>::new());
        assert!(parse_cpulist("3-1").is_none());
        assert!(parse_cpulist("a-b").is_none());
    }

    #[test]
    fn golden_single_node_tree() {
        let (root, online) = fake_sys("one", &[(0, "0-3")], None);
        let t = Topology::from_sys_paths(&root, online.as_deref()).unwrap();
        assert_eq!(t.nodes(), 1);
        assert_eq!(t.node_len(0), 4);
        assert!(!t.is_synthetic());
        // single node → every home is the unpinned local home
        assert_eq!(t.assign_homes(3), vec![WorkerHome::local(); 3]);
    }

    #[test]
    fn golden_two_node_tree_assigns_node_grouped_pinned_homes() {
        let (root, online) = fake_sys("two", &[(0, "0-1"), (1, "2-3")], None);
        let t = Topology::from_sys_paths(&root, online.as_deref()).unwrap();
        assert_eq!(t.nodes(), 2);
        let homes = t.assign_homes(5);
        assert_eq!(
            homes,
            vec![
                WorkerHome { node: 0, cpu: Some(0) },
                WorkerHome { node: 0, cpu: Some(1) },
                WorkerHome { node: 1, cpu: Some(2) },
                WorkerHome { node: 1, cpu: Some(3) },
                // oversubscription wraps round-robin, lowest node first
                WorkerHome { node: 0, cpu: Some(0) },
            ]
        );
    }

    #[test]
    fn golden_sparse_cpulists_and_sparse_node_numbers() {
        // node numbers 0 and 2 (1 is absent) with holey CPU ranges — the
        // dense ids must follow ascending kernel node numbers.
        let (root, online) = fake_sys("sparse", &[(2, "12-13"), (0, "0-1,8-9")], None);
        let t = Topology::from_sys_paths(&root, online.as_deref()).unwrap();
        assert_eq!(t.nodes(), 2);
        assert_eq!(t.node_len(0), 4); // kernel node0: 0,1,8,9
        assert_eq!(t.node_len(1), 2); // kernel node2: 12,13
        let homes = t.assign_homes(6);
        assert_eq!(homes[0], WorkerHome { node: 0, cpu: Some(0) });
        assert_eq!(homes[3], WorkerHome { node: 0, cpu: Some(9) });
        assert_eq!(homes[4], WorkerHome { node: 1, cpu: Some(12) });
        assert_eq!(homes[5], WorkerHome { node: 1, cpu: Some(13) });
    }

    #[test]
    fn golden_offline_cpus_are_filtered_and_empty_nodes_dropped() {
        // node1's only CPUs are offline → node1 vanishes entirely.
        let (root, online) =
            fake_sys("off", &[(0, "0-3"), (1, "4-7")], Some("0-3"));
        let t = Topology::from_sys_paths(&root, online.as_deref()).unwrap();
        assert_eq!(t.nodes(), 1);
        assert_eq!(t.node_len(0), 4);
        // partial offlining trims but keeps the node
        let (root, online) =
            fake_sys("part", &[(0, "0-3"), (1, "4-7")], Some("0-5"));
        let t = Topology::from_sys_paths(&root, online.as_deref()).unwrap();
        assert_eq!(t.nodes(), 2);
        assert_eq!(t.node_len(1), 2); // CPUs 4,5 survive
    }

    #[test]
    fn missing_tree_yields_none_and_detect_falls_back() {
        let root = std::env::temp_dir().join("ft_topo_definitely_absent");
        assert!(Topology::from_sys_paths(&root, None).is_none());
        // --numa off is always the single-node topology
        let t = Topology::detect(NumaMode::Off);
        assert_eq!(t.nodes(), 1);
        assert!(!t.is_synthetic());
        assert_eq!(t.assign_homes(4), vec![WorkerHome::local(); 4]);
        // auto never panics regardless of the host
        let t = Topology::detect(NumaMode::Auto);
        assert!(t.nodes() >= 1);
    }

    #[test]
    fn synthetic_topology_is_deterministic_and_never_pinned() {
        let t = Topology::detect(NumaMode::Force(2));
        assert_eq!(t.nodes(), 2);
        assert!(t.is_synthetic());
        let homes = t.assign_homes(4);
        assert!(homes.iter().all(|h| h.cpu.is_none()), "synthetic homes never pin");
        assert_eq!(homes[0].node, 0, "lowest node first");
        assert!(homes.iter().any(|h| h.node == 1), "both nodes used");
        assert_eq!(homes, t.assign_homes(4), "deterministic");
    }

    #[test]
    fn bind_worker_sets_current_node() {
        assert_eq!(current_node(), 0);
        std::thread::scope(|s| {
            s.spawn(|| {
                bind_worker(Some(&WorkerHome { node: 3, cpu: None }));
                assert_eq!(current_node(), 3);
                bind_worker(None);
                assert_eq!(current_node(), 0);
            });
        });
        assert_eq!(current_node(), 0, "binding is thread-local");
    }
}
