//! Shared pass executor — the worker-pool seam multi-tensor serving uses.
//!
//! Before the registry, every [`crate::coordinator::Session`] decided its
//! own thread parallelism (`TrainConfig::workers`) and each engine pass
//! spawned that many scoped workers. With several sessions in one process
//! that composes badly: N sessions × W workers oversubscribes the machine
//! the moment two sessions train at once, and no single place can observe
//! or bound the process-wide execution.
//!
//! An [`Executor`] is that single place. It owns the *one* worker budget
//! (the paper's GPU analogue: one device, many resident decompositions),
//! serializes training passes through an admission gate so at most one
//! pass runs at a time, and accumulates each engine pass's measured
//! [`WorkerStats`]. `SessionRegistry` creates one `Executor` and attaches
//! it to every session it admits, so all registered sessions — engine
//! algorithms and full-core baselines alike — execute their passes on the
//! same pool budget instead of each bringing its own threads. The pass itself still runs through the
//! scoped-thread substrate in [`super::pool`] — the executor decides *how
//! many* workers a pass gets and *when* it may start, which is exactly the
//! placement seam the ROADMAP's NUMA item needs next.
//!
//! Determinism note: the executor only overrides the worker count and
//! serializes passes; with `workers == 1` a pass executed through an
//! executor is bit-identical to the same pass executed directly (the
//! bit-reproducibility contract of `tests/engine_parity.rs` and
//! `tests/registry_serving.rs` rests on this).

use super::pool::WorkerStats;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A process-wide execution slot for engine passes: one worker budget,
/// one pass at a time, aggregate per-worker accounting.
pub struct Executor {
    /// Resolved worker count every admitted pass runs with.
    workers: usize,
    /// Admission gate: at most one pass executes at a time, so N resident
    /// sessions never stack N thread pools on one machine.
    gate: Mutex<()>,
    /// Passes executed through this executor (all sessions combined).
    passes: AtomicUsize,
    /// Accumulated per-worker stats of every executed pass.
    stats: Mutex<WorkerStats>,
}

impl Executor {
    /// Executor with a fixed worker budget; `0` resolves to all available
    /// cores once, at construction, so the budget is stable for the
    /// executor's lifetime.
    pub fn new(workers: usize) -> Executor {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        Executor {
            workers,
            gate: Mutex::new(()),
            passes: AtomicUsize::new(0),
            stats: Mutex::new(WorkerStats::with_workers(workers)),
        }
    }

    /// The worker budget every pass executed here runs with.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// How many passes have executed through this executor (across all
    /// attached sessions) — the evidence that sessions share one pool.
    pub fn passes_executed(&self) -> usize {
        self.passes.load(Ordering::Relaxed)
    }

    /// Accumulated per-worker stats over every executed pass.
    pub fn total_stats(&self) -> WorkerStats {
        self.stats.lock().unwrap().clone()
    }

    /// Execute one pass under the admission gate. `f` receives the
    /// executor's worker budget and must run the pass with exactly that
    /// many workers, returning the pass's measured stats.
    pub fn run_pass<F: FnOnce(usize) -> WorkerStats>(&self, f: F) -> WorkerStats {
        let _slot = self.gate.lock().unwrap();
        let pass_stats = f(self.workers);
        self.passes.fetch_add(1, Ordering::Relaxed);
        self.stats.lock().unwrap().absorb(&pass_stats);
        pass_stats
    }

    /// Execute a pass that reports no per-worker stats (the full-core
    /// baselines): same admission gate, same worker budget handed to `f`,
    /// counted in [`Executor::passes_executed`].
    pub fn run_quiet<F: FnOnce(usize)>(&self, f: F) {
        let _slot = self.gate.lock().unwrap();
        f(self.workers);
        self.passes.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::shard::ShardPlan;

    #[test]
    fn zero_workers_resolves_to_at_least_one() {
        assert!(Executor::new(0).workers() >= 1);
        assert_eq!(Executor::new(3).workers(), 3);
    }

    #[test]
    fn run_pass_counts_and_accumulates() {
        let ex = Executor::new(2);
        assert_eq!(ex.passes_executed(), 0);
        for _ in 0..3 {
            let stats = ex.run_pass(|workers| {
                let plan = ShardPlan::new(workers, 10);
                plan.execute_with_stats(|| (), |_a, _w, _b| {}, |_a, _o| {}).1
            });
            assert_eq!(stats.total_blocks(), 10);
        }
        assert_eq!(ex.passes_executed(), 3);
        assert_eq!(ex.total_stats().total_blocks(), 30);
    }

    #[test]
    fn gate_serializes_passes() {
        // two threads hammer the executor; the gate means per-pass stats
        // absorb without interleaving, so the total is exact
        let ex = Executor::new(1);
        std::thread::scope(|scope| {
            for _ in 0..2 {
                scope.spawn(|| {
                    for _ in 0..50 {
                        ex.run_pass(|w| {
                            let plan = ShardPlan::new(w, 4);
                            plan.execute_with_stats(|| (), |_a, _w, _b| {}, |_a, _o| {})
                                .1
                        });
                    }
                });
            }
        });
        assert_eq!(ex.passes_executed(), 100);
        assert_eq!(ex.total_stats().total_blocks(), 400);
    }
}
