//! Shared pass executor — the worker-pool seam multi-tenant serving uses.
//!
//! Before the registry, every [`crate::coordinator::Session`] decided its
//! own thread parallelism (`TrainConfig::workers`) and each engine pass
//! spawned that many scoped workers. With several sessions in one process
//! that composes badly: N sessions × W workers oversubscribes the machine
//! the moment two sessions train at once, and no single place can observe
//! or bound the process-wide execution.
//!
//! An [`Executor`] is that single place. It owns the *one* worker budget
//! (the paper's GPU analogue: one device, many resident decompositions)
//! and accumulates each pass's measured [`WorkerStats`]. Since the
//! pass-backend rework the budget is handed out as **worker-subset
//! leases** ([`WorkerLease`]): a pass requests `n` workers and runs on a
//! leased *disjoint* subset of the budget's worker slots, so two registry
//! tenants can execute passes **concurrently** instead of serializing
//! behind one global gate. [`Executor::run_pass`]/[`Executor::run_quiet`]
//! keep the old exclusive semantics — they are full-budget leases — while
//! [`Executor::run_leased`] is the overlapping path sessions use when a
//! lease size is configured ([`crate::coordinator::Session::set_lease_workers`],
//! plumbed by the registry's admission policy).
//!
//! Lease allocation is FIFO-fair: requests are served strictly in ticket
//! order, so a full-budget request cannot be starved by a stream of small
//! ones (head-of-line blocking is the price, and the right trade for an
//! admission gate). `tests/concurrent_passes.rs` property-tests
//! disjointness, budget, and starvation-freedom under randomized
//! schedules. Each lease's pass stats are absorbed into the executor's
//! totals at the lease's *slot indices* ([`WorkerStats::absorb_at`]), so
//! concurrently-leased passes never pile onto the same global worker slot.
//!
//! Determinism note: a lease changes *which* worker slots host a pass,
//! never the shard order within it — the pass runs with `lease.workers()`
//! threads exactly as a private pool of that size would. With a 1-worker
//! lease a pass executed through an executor is bit-identical to the same
//! pass executed directly (the bit-reproducibility contract of
//! `tests/engine_parity.rs`, `tests/registry_serving.rs`, and
//! `tests/concurrent_passes.rs` rests on this).

use super::pool::WorkerStats;
use super::topo::{Topology, WorkerHome};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Admission refused: the pending-ticket line is at its configured bound
/// (or, for [`Executor::try_acquire`], the request would have to wait at
/// all). The caller sheds load instead of queueing — retry later, run
/// inline, or surface the rejection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Backpressure {
    /// Tickets already waiting when the request arrived.
    pub pending: usize,
    /// The pending-line bound that refused it.
    pub limit: usize,
}

impl std::fmt::Display for Backpressure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "executor admission refused: {} tickets pending (limit {})",
            self.pending, self.limit
        )
    }
}

impl std::error::Error for Backpressure {}

/// Lease bookkeeping behind one mutex: the free-slot map plus the FIFO
/// ticket line and the concurrency counters.
struct LeaseState {
    /// `free[slot]` — whether the budget's worker slot is unleased.
    free: Vec<bool>,
    /// Count of `true` entries in `free` (kept in sync for cheap waits).
    available: usize,
    /// Next ticket to hand out.
    next_ticket: u64,
    /// Ticket currently allowed to acquire (strict FIFO service).
    now_serving: u64,
    /// Leases currently held.
    in_flight: usize,
    /// High-water mark of `in_flight` — the overlap evidence
    /// `tests/concurrent_passes.rs` asserts on.
    peak_in_flight: usize,
    /// Leases granted over the executor's lifetime.
    granted: usize,
}

/// A leased, disjoint subset of an [`Executor`]'s worker slots, released
/// back to the budget on drop. Obtained with [`Executor::acquire`]; the
/// `run_*` helpers manage one internally.
pub struct WorkerLease<'a> {
    executor: &'a Executor,
    slots: Vec<usize>,
}

impl WorkerLease<'_> {
    /// How many workers this lease grants (the worker count the pass must
    /// run with).
    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// The leased global worker-slot indices — disjoint from every other
    /// live lease of the same executor; pass-local worker `w` is
    /// attributed to global slot `slots()[w]` in the executor's totals.
    pub fn slots(&self) -> &[usize] {
        &self.slots
    }

    /// The worker homes behind this lease's slots, in slot order:
    /// pass-local worker `w` should bind to `homes()[w]`
    /// ([`crate::sched::topo::bind_worker`]) at spawn.
    pub fn homes(&self) -> Vec<WorkerHome> {
        self.executor.homes_for(&self.slots)
    }
}

impl Drop for WorkerLease<'_> {
    fn drop(&mut self) {
        self.executor.release(&self.slots);
    }
}

/// A process-wide execution budget for engine passes: one worker pool,
/// leased out in disjoint subsets, with aggregate per-slot accounting.
pub struct Executor {
    /// Total worker budget leases are carved from.
    workers: usize,
    /// Each budget slot's memory-hierarchy home, assigned at construction
    /// from the topology ([`Topology::assign_homes`]): node-grouped, so
    /// node-compact lease allocation hands out contiguous same-node slot
    /// runs. All-[`WorkerHome::local`] without NUMA.
    homes: Vec<WorkerHome>,
    /// Lease allocator state (slot map + FIFO line + counters).
    lease: Mutex<LeaseState>,
    /// Wakes ticket holders on release/advance.
    lease_cv: Condvar,
    /// Passes executed through this executor (all sessions combined).
    passes: AtomicUsize,
    /// Accumulated per-slot stats of every executed pass.
    stats: Mutex<WorkerStats>,
    /// Bound on the pending-ticket line for [`Executor::acquire_admitted`]
    /// (`usize::MAX` = unbounded, the [`Executor::acquire`] behavior).
    max_pending: AtomicUsize,
    /// Admission refusals (bounded-line rejections + failed
    /// [`Executor::try_acquire`] attempts) — the backpressure evidence the
    /// QoS metrics export.
    rejections: AtomicUsize,
    /// Total seconds requests spent waiting in the ticket line before
    /// their lease was granted (only accumulated by requests that actually
    /// waited).
    queue_wait: Mutex<f64>,
}

impl Executor {
    /// Executor with a fixed worker budget; `0` resolves to all available
    /// cores once, at construction, so the budget is stable for the
    /// executor's lifetime.
    pub fn new(workers: usize) -> Executor {
        // the default executor is topology-blind: one node, no pinning —
        // the exact pre-NUMA behaviour
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        Executor {
            workers,
            homes: vec![WorkerHome::local(); workers],
            lease: Mutex::new(LeaseState {
                free: vec![true; workers],
                available: workers,
                next_ticket: 0,
                now_serving: 0,
                in_flight: 0,
                peak_in_flight: 0,
                granted: 0,
            }),
            lease_cv: Condvar::new(),
            passes: AtomicUsize::new(0),
            stats: Mutex::new(WorkerStats::with_workers(workers)),
            max_pending: AtomicUsize::new(usize::MAX),
            rejections: AtomicUsize::new(0),
            queue_wait: Mutex::new(0.0),
        }
    }

    /// Executor whose worker slots are homed on a NUMA topology: slot
    /// homes come from [`Topology::assign_homes`] (node-grouped,
    /// deterministic), lease allocation becomes node-compact, and leased
    /// passes can pin their workers to the homes' CPUs. With a
    /// single-node topology this is exactly [`Executor::new`].
    pub fn with_topology(workers: usize, topo: &Topology) -> Executor {
        let mut ex = Executor::new(workers);
        ex.homes = topo.assign_homes(ex.workers);
        ex
    }

    /// Bound the pending-ticket line: [`Executor::acquire_admitted`]
    /// refuses (instead of queueing) once `max` tickets are already
    /// waiting. `usize::MAX` (the default) disables the bound.
    pub fn set_max_pending(&self, max: usize) {
        self.max_pending.store(max, Ordering::Relaxed);
    }

    /// The configured pending-line bound.
    pub fn max_pending(&self) -> usize {
        self.max_pending.load(Ordering::Relaxed)
    }

    /// Tickets currently waiting for a lease (handed out, not yet served).
    pub fn pending_tickets(&self) -> usize {
        let st = self.lease.lock().unwrap();
        (st.next_ticket - st.now_serving) as usize
    }

    /// Admission refusals so far (bounded-line rejections and failed
    /// [`Executor::try_acquire`] attempts).
    pub fn admission_rejections(&self) -> usize {
        self.rejections.load(Ordering::Relaxed)
    }

    /// Total seconds requests spent queued in the ticket line before their
    /// lease was granted.
    pub fn queue_wait_seconds(&self) -> f64 {
        *self.queue_wait.lock().unwrap()
    }

    /// The total worker budget leases are carved from (a full-budget lease
    /// — [`Executor::run_pass`] — is exclusive, the pre-lease behavior).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The home of one budget slot ([`WorkerHome::local`] out of range,
    /// which cannot happen for leased slots).
    pub fn home_of(&self, slot: usize) -> WorkerHome {
        self.homes.get(slot).copied().unwrap_or_else(WorkerHome::local)
    }

    /// The homes behind a slot list, in order (what a leased pass hands
    /// to the worker pool so each spawned worker binds to its slot's
    /// home).
    pub fn homes_for(&self, slots: &[usize]) -> Vec<WorkerHome> {
        slots.iter().map(|&s| self.home_of(s)).collect()
    }

    /// Number of distinct NUMA nodes the budget's slots are homed on
    /// (≥ 1).
    pub fn nodes(&self) -> usize {
        self.homes.iter().map(|h| h.node).max().unwrap_or(0) + 1
    }

    /// The largest number of budget slots homed on any single node — the
    /// biggest lease that can possibly avoid straddling nodes. QoS lease
    /// resizing caps each tenant here so adaptive leases stay
    /// node-compact.
    pub fn max_node_slots(&self) -> usize {
        let nodes = self.nodes();
        (0..nodes)
            .map(|n| self.homes.iter().filter(|h| h.node == n).count())
            .max()
            .unwrap_or(self.workers)
    }

    /// How many passes have executed through this executor (across all
    /// attached sessions) — the evidence that sessions share one pool.
    pub fn passes_executed(&self) -> usize {
        self.passes.load(Ordering::Relaxed)
    }

    /// Leases granted over the executor's lifetime (every `run_*` call
    /// takes exactly one).
    pub fn leases_granted(&self) -> usize {
        self.lease.lock().unwrap().granted
    }

    /// Leases currently held.
    pub fn concurrent_leases(&self) -> usize {
        self.lease.lock().unwrap().in_flight
    }

    /// High-water mark of concurrently held leases — `>= 2` proves that
    /// two tenants' passes actually overlapped on this executor.
    pub fn peak_concurrent_leases(&self) -> usize {
        self.lease.lock().unwrap().peak_in_flight
    }

    /// Accumulated per-slot stats over every executed pass. Each leased
    /// pass's per-worker stats are recorded at the lease's disjoint slot
    /// indices, so concurrent passes never double-count or conflate slots.
    pub fn total_stats(&self) -> WorkerStats {
        self.stats.lock().unwrap().clone()
    }

    /// Block until `n` workers (clamped to `[1, budget]`) are free, then
    /// lease a disjoint slot subset. Strict FIFO: requests are served in
    /// arrival order, so a large request is never starved by smaller ones
    /// slipping past it. Never refused — the pending line is treated as
    /// unbounded. The lease is released on drop.
    pub fn acquire(&self, n: usize) -> WorkerLease<'_> {
        self.acquire_bounded(n, usize::MAX)
            .expect("unbounded admission cannot be refused")
    }

    /// [`Executor::acquire`] behind the admission gate: if the request
    /// cannot be granted immediately and the pending-ticket line already
    /// holds [`Executor::max_pending`] waiters, refuse with
    /// [`Backpressure`] instead of queueing. This is what bounds how much
    /// latency a flood of training tenants can pile up in front of later
    /// arrivals.
    pub fn acquire_admitted(&self, n: usize) -> Result<WorkerLease<'_>, Backpressure> {
        self.acquire_bounded(n, self.max_pending())
    }

    /// Non-blocking acquire: a lease only if it is grantable *right now*
    /// (no waiters ahead, enough free slots); never enters the ticket
    /// line. Equivalent to a zero-bound admission gate.
    pub fn try_acquire(&self, n: usize) -> Option<WorkerLease<'_>> {
        self.acquire_bounded(n, 0).ok()
    }

    fn acquire_bounded(
        &self,
        n: usize,
        max_pending: usize,
    ) -> Result<WorkerLease<'_>, Backpressure> {
        let n = n.clamp(1, self.workers);
        let mut st = self.lease.lock().unwrap();
        let immediate = st.now_serving == st.next_ticket && st.available >= n;
        if !immediate {
            let pending = (st.next_ticket - st.now_serving) as usize;
            if pending >= max_pending {
                drop(st);
                self.rejections.fetch_add(1, Ordering::Relaxed);
                return Err(Backpressure { pending, limit: max_pending });
            }
        }
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        let mut wait_from: Option<std::time::Instant> = None;
        while st.now_serving != ticket || st.available < n {
            if wait_from.is_none() {
                wait_from = Some(std::time::Instant::now());
            }
            st = self.lease_cv.wait(st).unwrap();
        }
        st.now_serving += 1;
        st.available -= n;
        let slots = self.pick_slots(&mut st.free, n);
        debug_assert_eq!(slots.len(), n, "available count out of sync");
        st.in_flight += 1;
        st.peak_in_flight = st.peak_in_flight.max(st.in_flight);
        st.granted += 1;
        drop(st);
        if let Some(t0) = wait_from {
            *self.queue_wait.lock().unwrap() += t0.elapsed().as_secs_f64();
        }
        // the next ticket in line may be admissible concurrently
        self.lease_cv.notify_all();
        Ok(WorkerLease { executor: self, slots })
    }

    /// Node-compact slot selection: lease `n` free slots, preferring to
    /// fill one node before spilling. Among nodes with `>= n` free slots,
    /// the one with the *fewest* free slots wins (best fit — big nodes
    /// stay whole for big leases), ties to the lowest node id; within the
    /// node, the lowest free slots in ascending order. When no single
    /// node fits, spill across nodes most-free-first (so the straddle
    /// touches as few nodes as possible), ties again to the lowest node
    /// id, slots ascending within each. On a single-node topology this
    /// degenerates to the pre-NUMA ascending free-slot scan exactly.
    /// Deterministic for a given free map.
    fn pick_slots(&self, free: &mut [bool], n: usize) -> Vec<usize> {
        let nodes = self.nodes();
        // free slots per node, ascending slot order (homes are
        // node-grouped, but don't rely on it)
        let mut per_node: Vec<Vec<usize>> = vec![Vec::new(); nodes];
        for (slot, f) in free.iter().enumerate() {
            if *f {
                per_node[self.home_of(slot).node].push(slot);
            }
        }
        let mut slots = Vec::with_capacity(n);
        let fit = (0..nodes)
            .filter(|&nd| per_node[nd].len() >= n)
            .min_by_key(|&nd| (per_node[nd].len(), nd));
        match fit {
            Some(nd) => slots.extend_from_slice(&per_node[nd][..n]),
            None => {
                let mut order: Vec<usize> = (0..nodes).collect();
                order.sort_by_key(|&nd| (usize::MAX - per_node[nd].len(), nd));
                for nd in order {
                    for &slot in &per_node[nd] {
                        if slots.len() == n {
                            break;
                        }
                        slots.push(slot);
                    }
                }
            }
        }
        for &slot in &slots {
            free[slot] = false;
        }
        slots
    }

    /// Return a lease's slots to the budget and wake the ticket line.
    fn release(&self, slots: &[usize]) {
        let mut st = self.lease.lock().unwrap();
        for &s in slots {
            debug_assert!(!st.free[s], "slot {s} released twice");
            st.free[s] = true;
        }
        st.available += slots.len();
        st.in_flight -= 1;
        drop(st);
        self.lease_cv.notify_all();
    }

    /// Execute one pass on a leased `n`-worker subset. `f` receives the
    /// lease's worker count and must run the pass with exactly that many
    /// workers, returning the pass's measured stats — which are also the
    /// **per-lease** stats handed back to the caller (sessions keep them;
    /// `bench/experiments.rs` asserts `nnz_imbalance()` on them per
    /// lease). Two sessions calling this with `n` summing within the
    /// budget run their passes concurrently.
    pub fn run_leased<F: FnOnce(usize) -> WorkerStats>(&self, n: usize, f: F) -> WorkerStats {
        self.run_leased_on(n, |lease| f(lease.workers()))
    }

    /// [`Executor::run_leased`] exposing the whole lease to the pass, so
    /// placement-aware passes can read [`WorkerLease::homes`] (which node
    /// each pass-local worker should bind to and read replicas from) as
    /// well as the worker count. Identical lease/accounting semantics.
    pub fn run_leased_on<F: FnOnce(&WorkerLease<'_>) -> WorkerStats>(
        &self,
        n: usize,
        f: F,
    ) -> WorkerStats {
        let lease = self.acquire(n);
        let pass_stats = f(&lease);
        self.passes.fetch_add(1, Ordering::Relaxed);
        self.stats.lock().unwrap().absorb_at(&pass_stats, lease.slots());
        pass_stats
    }

    /// Execute one pass under an exclusive full-budget lease (the
    /// pre-lease admission-gate semantics): at most one such pass runs at
    /// a time, and it may use every worker in the budget.
    pub fn run_pass<F: FnOnce(usize) -> WorkerStats>(&self, f: F) -> WorkerStats {
        self.run_leased(self.workers, f)
    }

    /// [`Executor::run_leased`] for passes that report no per-worker stats
    /// (the full-core baselines): same lease, counted in
    /// [`Executor::passes_executed`].
    pub fn run_quiet_leased<F: FnOnce(usize)>(&self, n: usize, f: F) {
        let lease = self.acquire(n);
        f(lease.workers());
        self.passes.fetch_add(1, Ordering::Relaxed);
    }

    /// Execute a stats-less pass under an exclusive full-budget lease.
    pub fn run_quiet<F: FnOnce(usize)>(&self, f: F) {
        self.run_quiet_leased(self.workers, f)
    }

    /// Scoped data-parallel for over the items of a mutable slice, run on
    /// a leased `n`-worker subset: the index range `0..items.len()` is
    /// split into `min(n, len)` **contiguous, disjoint** chunks, each
    /// leased worker owns one chunk exclusively, and `f(i, &mut items[i])`
    /// runs once per index. Because every index is visited exactly once
    /// and `f` observes only its own item, the result is identical for
    /// every worker count — which is what lets the staging pipeline, the
    /// dirty-row refresh, and the serving layer's batched top-k fan-out
    /// ([`crate::coordinator::ServingHandle::set_executor`]) parallelize
    /// without perturbing bit-reproducibility.
    ///
    /// Runs inline (no threads spawned) when the lease resolves to one
    /// worker or the slice has at most one item. Counts as a lease but
    /// **not** as a pass: [`Executor::passes_executed`] observes only
    /// training passes.
    pub fn run_indexed<T, F>(&self, n: usize, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let lease = self.acquire(n);
        Self::indexed_with_workers(lease.workers(), items, f);
    }

    /// [`Executor::run_indexed`] for latency-sensitive readers: if a lease
    /// for `n` workers is grantable right now it fans out exactly like
    /// `run_indexed`; otherwise it runs the loop **inline on the calling
    /// thread** instead of queueing behind the FIFO ticket line. The
    /// result is identical either way (every index visited exactly once,
    /// worker-count-independent by `run_indexed`'s contract) — only the
    /// latency profile changes: a serving reader degrades to serial scan
    /// speed under load instead of waiting for a flood of queued training
    /// passes to drain. Returns whether a lease was granted (false = ran
    /// inline under backpressure).
    pub fn run_indexed_nonblocking<T, F>(&self, n: usize, items: &mut [T], f: F) -> bool
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        match self.try_acquire(n) {
            Some(lease) => {
                Self::indexed_with_workers(lease.workers(), items, f);
                true
            }
            None => {
                Self::indexed_with_workers(1, items, f);
                false
            }
        }
    }

    fn indexed_with_workers<T, F>(workers: usize, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let workers = workers.min(items.len()).max(1);
        if workers <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let chunk = crate::util::ceil_div(items.len(), workers);
        std::thread::scope(|scope| {
            for (w, own) in items.chunks_mut(chunk).enumerate() {
                let f = &f;
                scope.spawn(move || {
                    let base = w * chunk;
                    for (k, item) in own.iter_mut().enumerate() {
                        f(base + k, item);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::shard::ShardPlan;

    #[test]
    fn zero_workers_resolves_to_at_least_one() {
        assert!(Executor::new(0).workers() >= 1);
        assert_eq!(Executor::new(3).workers(), 3);
    }

    #[test]
    fn run_pass_counts_and_accumulates() {
        let ex = Executor::new(2);
        assert_eq!(ex.passes_executed(), 0);
        for _ in 0..3 {
            let stats = ex.run_pass(|workers| {
                let plan = ShardPlan::new(workers, 10);
                plan.execute_with_stats(|| (), |_a, _w, _b| {}, |_a, _o| {}).1
            });
            assert_eq!(stats.total_blocks(), 10);
        }
        assert_eq!(ex.passes_executed(), 3);
        assert_eq!(ex.total_stats().total_blocks(), 30);
        assert_eq!(ex.leases_granted(), 3);
        assert_eq!(ex.concurrent_leases(), 0);
    }

    #[test]
    fn gate_serializes_passes() {
        // two threads hammer the full-budget path; exclusive leases mean
        // per-pass stats absorb without interleaving, so the total is exact
        let ex = Executor::new(1);
        std::thread::scope(|scope| {
            for _ in 0..2 {
                scope.spawn(|| {
                    for _ in 0..50 {
                        ex.run_pass(|w| {
                            let plan = ShardPlan::new(w, 4);
                            plan.execute_with_stats(|| (), |_a, _w, _b| {}, |_a, _o| {})
                                .1
                        });
                    }
                });
            }
        });
        assert_eq!(ex.passes_executed(), 100);
        assert_eq!(ex.total_stats().total_blocks(), 400);
    }

    #[test]
    fn leases_are_disjoint_and_clamped() {
        let ex = Executor::new(3);
        let a = ex.acquire(1);
        let b = ex.acquire(2);
        assert_eq!(a.workers(), 1);
        assert_eq!(b.workers(), 2);
        let mut all: Vec<usize> = a.slots().iter().chain(b.slots()).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 3, "leased slots overlap");
        assert!(all.iter().all(|&s| s < 3));
        assert_eq!(ex.concurrent_leases(), 2);
        assert_eq!(ex.peak_concurrent_leases(), 2);
        drop(a);
        drop(b);
        assert_eq!(ex.concurrent_leases(), 0);
        // requests are clamped to [1, budget]
        assert_eq!(ex.acquire(0).workers(), 1);
        assert_eq!(ex.acquire(64).workers(), 3);
    }

    #[test]
    fn leased_stats_land_on_the_leased_slots() {
        // Pin slot 0 with a live lease; a concurrent leased pass must then
        // run on slot 1 and have its stats attributed there — the
        // double-count fix: before slot mapping, every lease's worker 0
        // piled onto global slot 0.
        let ex = Executor::new(2);
        let blocker = ex.acquire(1);
        assert_eq!(blocker.slots(), &[0]);
        let stats = ex.run_leased(1, |w| {
            assert_eq!(w, 1);
            let plan = ShardPlan::lpt(w, vec![3, 7]);
            plan.execute_with_stats(|| (), |_a, _w, _b| {}, |_a, _o| {}).1
        });
        assert_eq!(stats.total_blocks(), 2);
        assert_eq!(stats.total_nnz(), 10);
        drop(blocker);
        let total = ex.total_stats();
        assert_eq!(total.blocks, vec![0, 2]);
        assert_eq!(total.nnz, vec![0, 10]);
        // a later lease reuses the freed slot 0
        ex.run_leased(1, |w| {
            let plan = ShardPlan::lpt(w, vec![5]);
            plan.execute_with_stats(|| (), |_a, _w, _b| {}, |_a, _o| {}).1
        });
        let total = ex.total_stats();
        assert_eq!(total.blocks, vec![1, 2]);
        assert_eq!(total.total_nnz(), 15);
    }

    #[test]
    fn run_indexed_visits_every_index_once_any_worker_count() {
        // 1-worker (inline) and 3-worker runs must produce identical
        // results: every index visited exactly once, disjoint ownership.
        for workers in [1usize, 3] {
            let ex = Executor::new(workers);
            let mut items: Vec<(usize, u32)> = (0..10).map(|i| (0usize, i as u32)).collect();
            ex.run_indexed(workers, &mut items, |i, item| {
                item.0 += 1;
                item.1 = item.1.wrapping_mul(3).wrapping_add(i as u32);
            });
            for (i, &(visits, v)) in items.iter().enumerate() {
                assert_eq!(visits, 1, "index {i} visited {visits} times");
                assert_eq!(v, (i as u32).wrapping_mul(3).wrapping_add(i as u32));
            }
            // a lease was taken and released; no pass was counted
            assert_eq!(ex.leases_granted(), 1);
            assert_eq!(ex.concurrent_leases(), 0);
            assert_eq!(ex.passes_executed(), 0);
        }
        // empty and single-item slices run inline without panicking
        let ex = Executor::new(4);
        let mut empty: Vec<u8> = Vec::new();
        ex.run_indexed(4, &mut empty, |_, _| {});
        let mut one = [7u8];
        ex.run_indexed(4, &mut one, |_, x| *x += 1);
        assert_eq!(one[0], 8);
    }

    #[test]
    fn try_acquire_never_queues() {
        let ex = Executor::new(2);
        let held = ex.try_acquire(2).expect("idle executor grants immediately");
        assert_eq!(held.workers(), 2);
        // all slots leased: a try must refuse, not wait
        assert!(ex.try_acquire(1).is_none());
        assert_eq!(ex.admission_rejections(), 1);
        drop(held);
        // freed: grantable again
        let again = ex.try_acquire(1).expect("freed slot grantable");
        assert_eq!(again.workers(), 1);
        // a partial fit also refuses (2 wanted, 1 free)
        assert!(ex.try_acquire(2).is_none());
        assert_eq!(ex.admission_rejections(), 2);
    }

    #[test]
    fn bounded_admission_refuses_once_line_is_full() {
        let ex = Executor::new(1);
        ex.set_max_pending(1);
        assert_eq!(ex.max_pending(), 1);
        let held = ex.acquire(1);
        // one waiter is admitted into the line, the second is refused
        std::thread::scope(|scope| {
            let ex = &ex;
            let waiter = scope.spawn(move || ex.acquire_admitted(1).map(|l| l.workers()));
            // let the waiter reach the ticket line
            while ex.pending_tickets() == 0 {
                std::thread::yield_now();
            }
            let refused = ex.acquire_admitted(1);
            match refused {
                Err(bp) => {
                    assert_eq!(bp.limit, 1);
                    assert!(bp.pending >= 1);
                    assert!(bp.to_string().contains("admission refused"));
                }
                Ok(_) => panic!("full line must refuse"),
            }
            drop(held);
            assert_eq!(waiter.join().unwrap(), Ok(1));
        });
        assert_eq!(ex.admission_rejections(), 1);
        // the admitted waiter actually waited, and its wait was recorded
        assert!(ex.queue_wait_seconds() > 0.0);
        // an immediately-grantable request passes even a zero bound
        ex.set_max_pending(0);
        assert!(ex.acquire_admitted(1).is_ok());
    }

    #[test]
    fn run_indexed_nonblocking_falls_back_inline_under_load() {
        let ex = Executor::new(2);
        let mut items: Vec<usize> = vec![0; 8];
        // idle: leases and fans out
        assert!(ex.run_indexed_nonblocking(2, &mut items, |_i, x| *x += 1));
        // saturated: runs inline, same result, no queueing
        let held = ex.acquire(2);
        assert!(!ex.run_indexed_nonblocking(2, &mut items, |_i, x| *x += 1));
        drop(held);
        assert!(items.iter().all(|&x| x == 2));
        // exactly one lease was granted by the two nonblocking calls
        assert_eq!(ex.leases_granted(), 2);
    }

    #[test]
    fn node_compact_leases_prefer_one_node_and_tie_break_low() {
        use crate::config::NumaMode;
        use crate::sched::topo::Topology;
        // 4 slots over a synthetic 2-node topology: homes are
        // node-grouped [0,0,1,1]
        let topo = Topology::detect(NumaMode::Force(2));
        let ex = Executor::with_topology(4, &topo);
        assert_eq!(ex.nodes(), 2);
        assert_eq!(ex.max_node_slots(), 2);
        assert_eq!(ex.home_of(0).node, 0);
        assert_eq!(ex.home_of(3).node, 1);
        // a 2-slot lease fills exactly one node (both fit → lowest wins)
        let a = ex.acquire(2);
        assert_eq!(a.slots(), &[0, 1]);
        assert!(a.homes().iter().all(|h| h.node == 0));
        // the next 2-slot lease fills the other node, not a straddle
        let b = ex.acquire(2);
        assert_eq!(b.slots(), &[2, 3]);
        assert!(b.homes().iter().all(|h| h.node == 1));
        drop(a);
        drop(b);
        // best fit: with node 0 half-leased, a 1-slot lease takes the
        // *smaller* free pool (node 0's remaining slot), keeping node 1
        // whole for a later 2-slot lease
        let hold = ex.acquire(1);
        assert_eq!(hold.slots(), &[0]);
        let one = ex.acquire(1);
        assert_eq!(one.slots(), &[1], "best-fit picks the depleted node");
        let two = ex.acquire(2);
        assert_eq!(two.slots(), &[2, 3], "node 1 stayed whole");
        drop(one);
        drop(two);
        // spill: 3 slots cannot fit one node — most-free node first
        // (node 1, 2 free) then lowest (node 0's remaining slot 1)
        let spill = ex.acquire(3);
        assert_eq!(spill.slots(), &[2, 3, 1]);
        drop(spill);
        drop(hold);
        // the default executor (no topology) is single-node: ascending
        // scan, pre-NUMA identical
        let plain = Executor::new(3);
        assert_eq!(plain.nodes(), 1);
        assert_eq!(plain.max_node_slots(), 3);
        assert_eq!(plain.acquire(2).slots(), &[0, 1]);
    }

    #[test]
    fn run_leased_on_exposes_homes_and_accounts_identically() {
        use crate::config::NumaMode;
        use crate::sched::topo::Topology;
        let ex = Executor::with_topology(2, &Topology::detect(NumaMode::Force(2)));
        let stats = ex.run_leased_on(1, |lease| {
            assert_eq!(lease.workers(), 1);
            assert_eq!(lease.homes().len(), 1);
            assert_eq!(lease.homes()[0].node, 0);
            let plan = ShardPlan::lpt(lease.workers(), vec![4]);
            plan.execute_with_stats(|| (), |_a, _w, _b| {}, |_a, _o| {}).1
        });
        assert_eq!(stats.total_blocks(), 1);
        assert_eq!(ex.passes_executed(), 1);
        assert_eq!(ex.total_stats().blocks, vec![1, 0]);
    }

    #[test]
    fn concurrent_leased_passes_overlap() {
        // Both passes must be in flight at once: each waits inside its
        // pass until the other has arrived, which can only resolve if the
        // executor admits the two 1-worker leases concurrently.
        let ex = Executor::new(2);
        let barrier = std::sync::Barrier::new(2);
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let ex = &ex;
                let barrier = &barrier;
                scope.spawn(move || {
                    ex.run_leased(1, |w| {
                        barrier.wait();
                        WorkerStats::with_workers(w)
                    });
                });
            }
        });
        assert_eq!(ex.peak_concurrent_leases(), 2);
        assert_eq!(ex.passes_executed(), 2);
    }
}
