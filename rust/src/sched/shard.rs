//! Shard planning for the epoch engine.
//!
//! A [`ShardPlan`] describes how one epoch pass's schedulable blocks are
//! spread over workers: dynamic self-scheduling over `num_blocks` block ids,
//! exactly the paper's thread-groups draining a grid of sub-tensors. The
//! engine executes every pass through a plan so the two update disciplines
//! share one substrate:
//!
//! * **factor passes** — Hogwild writes through [`super::racy::RacyMatrix`]
//!   (no per-worker state to merge);
//! * **core passes** — per-worker gradient accumulators merged after the
//!   pass (the shared-memory-hierarchy analogue of Algorithm 5's global
//!   accumulation).
//!
//! Every execution reports per-worker [`WorkerStats`] so load balance is a
//! measured, assertable quantity rather than an assumption.

use super::pool::{parallel_reduce_stats, WorkerStats};

/// A partition of `num_blocks` schedulable blocks over `workers` workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    pub workers: usize,
    pub num_blocks: usize,
}

impl ShardPlan {
    pub fn new(workers: usize, num_blocks: usize) -> ShardPlan {
        ShardPlan { workers: workers.max(1), num_blocks }
    }

    /// Run `step(acc, worker, block)` over all blocks with per-worker
    /// accumulators, merging them at the end. Discards stats.
    pub fn execute<Acc, I, S, M>(&self, init: I, step: S, merge: M) -> Acc
    where
        Acc: Send,
        I: Fn() -> Acc + Sync,
        S: Fn(&mut Acc, usize, usize) + Sync,
        M: Fn(&mut Acc, Acc),
    {
        self.execute_with_stats(init, step, merge).0
    }

    /// [`Self::execute`], also returning the measured per-worker stats.
    pub fn execute_with_stats<Acc, I, S, M>(
        &self,
        init: I,
        step: S,
        merge: M,
    ) -> (Acc, WorkerStats)
    where
        Acc: Send,
        I: Fn() -> Acc + Sync,
        S: Fn(&mut Acc, usize, usize) + Sync,
        M: Fn(&mut Acc, Acc),
    {
        parallel_reduce_stats(self.workers, self.num_blocks, init, step, merge)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_normalizes_workers() {
        let p = ShardPlan::new(0, 10);
        assert_eq!(p.workers, 1);
        assert_eq!(p.num_blocks, 10);
    }

    #[test]
    fn execute_covers_all_blocks() {
        let p = ShardPlan::new(3, 100);
        let (sum, stats) = p.execute_with_stats(
            || 0usize,
            |acc, _w, b| *acc += b,
            |acc, other| *acc += other,
        );
        assert_eq!(sum, (0..100).sum::<usize>());
        assert_eq!(stats.total_blocks(), 100);
    }

    #[test]
    fn execute_discarding_stats_matches() {
        let p = ShardPlan::new(2, 17);
        let sum = p.execute(|| 0usize, |acc, _w, _b| *acc += 1, |acc, o| *acc += o);
        assert_eq!(sum, 17);
    }
}
