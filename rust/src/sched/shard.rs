//! Shard planning for the epoch engine.
//!
//! A [`ShardPlan`] describes how one epoch pass's schedulable blocks are
//! spread over workers: dynamic self-scheduling over block ids, exactly the
//! paper's thread-groups draining a grid of sub-tensors. Since the
//! size-aware packing rework a plan can also carry the blocks' **measured
//! non-zero weights**:
//!
//! * [`ShardPlan::lpt`] serves blocks in descending-weight order (classic
//!   Longest-Processing-Time list scheduling) on top of the same dynamic
//!   claim counter, so the heaviest blocks land first and the tail of the
//!   queue is all small filler — the greedy bound `max ≤ mean + max_block`
//!   instead of "whatever traversal order left last".
//! * every claim charges the block's weight to the claiming worker, so
//!   [`WorkerStats::nnz`] reports claimed non-zeros, not just block counts.
//!
//! On one worker a plan never reorders (`order == None`): single-worker
//! runs stay bit-reproducible against the frozen reference loops, which is
//! what `tests/engine_parity.rs` pins.
//!
//! The engine executes every pass through a plan so the two update
//! disciplines share one substrate:
//!
//! * **factor passes** — Hogwild writes through [`super::racy::RacyMatrix`]
//!   (no per-worker state to merge);
//! * **core passes** — per-worker gradient accumulators merged after the
//!   pass (the shared-memory-hierarchy analogue of Algorithm 5's global
//!   accumulation).

use super::pool::{
    parallel_reduce_stats_weighted_homed, parallel_reduce_stealing_homed,
    WorkerStats,
};
use super::topo::WorkerHome;

/// A partition of `num_blocks` schedulable blocks over `workers` workers,
/// optionally weight-ordered (LPT) and weight-accounted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Worker threads this plan executes with.
    pub workers: usize,
    /// Schedulable blocks the plan covers.
    pub num_blocks: usize,
    /// Claim order: `order[i]` is the i-th block id served. `None` = id
    /// order (single worker, or no weights supplied).
    order: Option<Vec<u32>>,
    /// Per-block non-zero weights (claimed-nnz accounting); `None` for
    /// weightless plans.
    weights: Option<Vec<u32>>,
}

impl ShardPlan {
    /// Weightless plan: id-order dynamic scheduling, no nnz accounting.
    pub fn new(workers: usize, num_blocks: usize) -> ShardPlan {
        ShardPlan {
            workers: workers.max(1),
            num_blocks,
            order: None,
            weights: None,
        }
    }

    /// Size-aware plan from measured per-block non-zero weights: blocks are
    /// pre-sorted descending by weight (ties broken by block id, so the
    /// order is deterministic) and drained through the dynamic counter.
    /// With one worker the identity order is kept — reordering could not
    /// improve balance and would break bit-reproducibility.
    pub fn lpt(workers: usize, weights: Vec<u32>) -> ShardPlan {
        let workers = workers.max(1);
        let num_blocks = weights.len();
        let order = if workers > 1 && num_blocks > 1 {
            let mut o: Vec<u32> = (0..num_blocks as u32).collect();
            o.sort_unstable_by(|&a, &b| {
                weights[b as usize]
                    .cmp(&weights[a as usize])
                    .then_with(|| a.cmp(&b))
            });
            Some(o)
        } else {
            None
        };
        ShardPlan { workers, num_blocks, order, weights: Some(weights) }
    }

    /// The block id served at queue position `i`.
    #[inline]
    fn block_at(&self, i: usize) -> usize {
        match &self.order {
            Some(o) => o[i] as usize,
            None => i,
        }
    }

    /// Whether this plan carries per-block weights (claimed-nnz accounting
    /// and LPT ordering) — the engine's cache-validity check.
    pub fn weighted(&self) -> bool {
        self.weights.is_some()
    }

    /// The claim order as block ids (tests and diagnostics).
    pub fn claim_order(&self) -> Vec<usize> {
        (0..self.num_blocks).map(|i| self.block_at(i)).collect()
    }

    /// Per-worker steal-queue seed: the LPT claim order dealt greedily onto
    /// the least-loaded queue (classic LPT *assignment* rather than LPT
    /// *list order*), ties broken by the lowest queue id. Each queue ends up
    /// heaviest-first, so owners drain big blocks early and thieves take the
    /// small filler off the back. With one worker the seed is the identity
    /// order — exactly the serial static path, keeping the stealing-1 run
    /// bit-identical to the frozen reference loops.
    ///
    /// The seeding is a pure function of the weights, so it is deterministic
    /// across runs and cacheable alongside the plan.
    pub fn steal_queues(&self) -> Vec<Vec<u32>> {
        if self.workers <= 1 {
            return vec![(0..self.num_blocks as u32).collect()];
        }
        let mut queues: Vec<Vec<u32>> = vec![Vec::new(); self.workers];
        let mut loads: Vec<u64> = vec![0; self.workers];
        for i in 0..self.num_blocks {
            let b = self.block_at(i);
            let w = self
                .weights
                .as_ref()
                .map_or(1, |ws| ws[b] as u64);
            // greedy least-loaded assignment; ties to the lowest queue id
            let (dst, _) = loads
                .iter()
                .enumerate()
                .min_by_key(|&(q, &l)| (l, q))
                .expect("workers >= 2");
            queues[dst].push(b as u32);
            loads[dst] += w;
        }
        queues
    }

    /// [`Self::execute_with_stats`] over the work-stealing substrate:
    /// workers drain their seeded queues and steal whole blocks from the
    /// heaviest remaining queue when idle. `queues` must come from
    /// [`Self::steal_queues`] (the engine caches them with the plan so no
    /// per-pass allocation happens on the hot path).
    pub fn execute_stealing_with_stats<Acc, I, S, M>(
        &self,
        queues: &[Vec<u32>],
        init: I,
        step: S,
        merge: M,
    ) -> (Acc, WorkerStats)
    where
        Acc: Send,
        I: Fn() -> Acc + Sync,
        S: Fn(&mut Acc, usize, usize) + Sync,
        M: Fn(&mut Acc, Acc),
    {
        let (acc, stats, _cross) =
            self.execute_stealing_homed(queues, &[], init, step, merge);
        (acc, stats)
    }

    /// [`Self::execute_stealing_with_stats`] with per-worker
    /// memory-hierarchy homes: workers bind to their home node (and CPU,
    /// when real) at spawn, and the third return value counts steals that
    /// crossed a node boundary — the migration price of rebalancing.
    /// Empty `homes` = unbound, zero migrations (the unhomed path).
    pub fn execute_stealing_homed<Acc, I, S, M>(
        &self,
        queues: &[Vec<u32>],
        homes: &[WorkerHome],
        init: I,
        step: S,
        merge: M,
    ) -> (Acc, WorkerStats, usize)
    where
        Acc: Send,
        I: Fn() -> Acc + Sync,
        S: Fn(&mut Acc, usize, usize) + Sync,
        M: Fn(&mut Acc, Acc),
    {
        debug_assert_eq!(
            queues.iter().map(|q| q.len()).sum::<usize>(),
            self.num_blocks,
            "steal queues must cover the plan's blocks exactly"
        );
        parallel_reduce_stealing_homed(queues, homes, init, step, merge, |b| {
            self.weights.as_ref().map_or(0, |ws| ws[b] as usize)
        })
    }

    /// Run `step(acc, worker, block)` over all blocks with per-worker
    /// accumulators, merging them at the end. Discards stats.
    pub fn execute<Acc, I, S, M>(&self, init: I, step: S, merge: M) -> Acc
    where
        Acc: Send,
        I: Fn() -> Acc + Sync,
        S: Fn(&mut Acc, usize, usize) + Sync,
        M: Fn(&mut Acc, Acc),
    {
        self.execute_with_stats(init, step, merge).0
    }

    /// [`Self::execute`], also returning the measured per-worker stats
    /// (blocks, busy seconds, and claimed nnz when weights are present).
    pub fn execute_with_stats<Acc, I, S, M>(
        &self,
        init: I,
        step: S,
        merge: M,
    ) -> (Acc, WorkerStats)
    where
        Acc: Send,
        I: Fn() -> Acc + Sync,
        S: Fn(&mut Acc, usize, usize) + Sync,
        M: Fn(&mut Acc, Acc),
    {
        self.execute_homed(&[], init, step, merge)
    }

    /// [`Self::execute_with_stats`] with per-worker memory-hierarchy
    /// homes: each spawned worker binds to `homes[w]` before its `init`
    /// runs, so per-worker state is first-touched on the worker's home
    /// node and the worker reads its node's operand replicas. Empty
    /// `homes` = unbound (the unhomed path, bit-for-bit).
    pub fn execute_homed<Acc, I, S, M>(
        &self,
        homes: &[WorkerHome],
        init: I,
        step: S,
        merge: M,
    ) -> (Acc, WorkerStats)
    where
        Acc: Send,
        I: Fn() -> Acc + Sync,
        S: Fn(&mut Acc, usize, usize) + Sync,
        M: Fn(&mut Acc, Acc),
    {
        parallel_reduce_stats_weighted_homed(
            self.workers,
            self.num_blocks,
            homes,
            init,
            |acc, w, i| step(acc, w, self.block_at(i)),
            merge,
            |i| {
                self.weights
                    .as_ref()
                    .map_or(0, |ws| ws[self.block_at(i)] as usize)
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn plan_normalizes_workers() {
        let p = ShardPlan::new(0, 10);
        assert_eq!(p.workers, 1);
        assert_eq!(p.num_blocks, 10);
    }

    #[test]
    fn execute_covers_all_blocks() {
        let p = ShardPlan::new(3, 100);
        let (sum, stats) = p.execute_with_stats(
            || 0usize,
            |acc, _w, b| *acc += b,
            |acc, other| *acc += other,
        );
        assert_eq!(sum, (0..100).sum::<usize>());
        assert_eq!(stats.total_blocks(), 100);
    }

    #[test]
    fn execute_discarding_stats_matches() {
        let p = ShardPlan::new(2, 17);
        let sum = p.execute(|| 0usize, |acc, _w, _b| *acc += 1, |acc, o| *acc += o);
        assert_eq!(sum, 17);
    }

    #[test]
    fn lpt_orders_heaviest_first_deterministically() {
        let p = ShardPlan::lpt(4, vec![5, 80, 80, 1, 40]);
        // descending weight, ties by block id
        assert_eq!(p.claim_order(), vec![1, 2, 4, 0, 3]);
        // same weights → same order, every time
        assert_eq!(
            ShardPlan::lpt(4, vec![5, 80, 80, 1, 40]).claim_order(),
            p.claim_order()
        );
    }

    #[test]
    fn single_worker_lpt_keeps_identity_order() {
        let p = ShardPlan::lpt(1, vec![5, 80, 80, 1, 40]);
        assert_eq!(p.claim_order(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn lpt_covers_every_block_once_and_accounts_nnz() {
        let weights: Vec<u32> = (0..64).map(|b| (b % 7) * 100 + 1).collect();
        let total: usize = weights.iter().map(|&w| w as usize).sum();
        let p = ShardPlan::lpt(4, weights);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        let (_, stats) = p.execute_with_stats(
            || (),
            |_acc, _w, b| {
                hits[b].fetch_add(1, Ordering::Relaxed);
            },
            |_acc, _o| {},
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(stats.total_blocks(), 64);
        assert_eq!(stats.total_nnz(), total);
    }

    #[test]
    fn steal_queues_cover_blocks_and_balance_weight() {
        let weights: Vec<u32> = (0..64).map(|b| (b % 7) * 100 + 1).collect();
        let total: u64 = weights.iter().map(|&w| w as u64).sum();
        let p = ShardPlan::lpt(4, weights.clone());
        let queues = p.steal_queues();
        assert_eq!(queues.len(), 4);
        // every block seeded exactly once
        let mut seen = vec![0usize; 64];
        for q in &queues {
            for &b in q {
                seen[b as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
        // greedy LPT assignment keeps queue loads within one max block
        let loads: Vec<u64> = queues
            .iter()
            .map(|q| q.iter().map(|&b| weights[b as usize] as u64).sum())
            .collect();
        let max_w = *weights.iter().max().unwrap() as u64;
        let mean = total / 4;
        assert!(loads.iter().all(|&l| l <= mean + max_w), "{loads:?}");
        // each queue is heaviest-first
        for q in &queues {
            for pair in q.windows(2) {
                assert!(weights[pair[0] as usize] >= weights[pair[1] as usize]);
            }
        }
        // deterministic re-derivation
        assert_eq!(ShardPlan::lpt(4, weights).steal_queues(), queues);
    }

    #[test]
    fn steal_queues_single_worker_is_identity() {
        let p = ShardPlan::lpt(1, vec![5, 80, 80, 1, 40]);
        assert_eq!(p.steal_queues(), vec![vec![0, 1, 2, 3, 4]]);
    }

    #[test]
    fn execute_stealing_covers_all_blocks_and_nnz() {
        let weights: Vec<u32> = (0..48).map(|b| (b % 5) * 50 + 1).collect();
        let total: usize = weights.iter().map(|&w| w as usize).sum();
        for workers in [1usize, 3, 8] {
            let p = ShardPlan::lpt(workers, weights.clone());
            let queues = p.steal_queues();
            let hits: Vec<AtomicUsize> =
                (0..48).map(|_| AtomicUsize::new(0)).collect();
            let (sum, stats) = p.execute_stealing_with_stats(
                &queues,
                || 0usize,
                |acc, _w, b| {
                    hits[b].fetch_add(1, Ordering::Relaxed);
                    *acc += b;
                },
                |acc, o| *acc += o,
            );
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
            assert_eq!(sum, (0..48).sum::<usize>(), "{workers} workers");
            assert_eq!(stats.total_blocks(), 48);
            assert_eq!(stats.total_nnz(), total);
        }
    }

    #[test]
    fn single_worker_lpt_claims_all_nnz() {
        let p = ShardPlan::lpt(1, vec![3, 7, 11]);
        let (count, stats) = p.execute_with_stats(
            || 0usize,
            |acc, _w, _b| *acc += 1,
            |acc, o| *acc += o,
        );
        assert_eq!(count, 3);
        assert_eq!(stats.nnz, vec![21]);
        assert!((stats.nnz_imbalance() - 1.0).abs() < 1e-9);
    }
}
