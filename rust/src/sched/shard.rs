//! Shard planning for the epoch engine.
//!
//! A [`ShardPlan`] describes how one epoch pass's schedulable blocks are
//! spread over workers: dynamic self-scheduling over block ids, exactly the
//! paper's thread-groups draining a grid of sub-tensors. Since the
//! size-aware packing rework a plan can also carry the blocks' **measured
//! non-zero weights**:
//!
//! * [`ShardPlan::lpt`] serves blocks in descending-weight order (classic
//!   Longest-Processing-Time list scheduling) on top of the same dynamic
//!   claim counter, so the heaviest blocks land first and the tail of the
//!   queue is all small filler — the greedy bound `max ≤ mean + max_block`
//!   instead of "whatever traversal order left last".
//! * every claim charges the block's weight to the claiming worker, so
//!   [`WorkerStats::nnz`] reports claimed non-zeros, not just block counts.
//!
//! On one worker a plan never reorders (`order == None`): single-worker
//! runs stay bit-reproducible against the frozen reference loops, which is
//! what `tests/engine_parity.rs` pins.
//!
//! The engine executes every pass through a plan so the two update
//! disciplines share one substrate:
//!
//! * **factor passes** — Hogwild writes through [`super::racy::RacyMatrix`]
//!   (no per-worker state to merge);
//! * **core passes** — per-worker gradient accumulators merged after the
//!   pass (the shared-memory-hierarchy analogue of Algorithm 5's global
//!   accumulation).

use super::pool::{parallel_reduce_stats_weighted, WorkerStats};

/// A partition of `num_blocks` schedulable blocks over `workers` workers,
/// optionally weight-ordered (LPT) and weight-accounted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Worker threads this plan executes with.
    pub workers: usize,
    /// Schedulable blocks the plan covers.
    pub num_blocks: usize,
    /// Claim order: `order[i]` is the i-th block id served. `None` = id
    /// order (single worker, or no weights supplied).
    order: Option<Vec<u32>>,
    /// Per-block non-zero weights (claimed-nnz accounting); `None` for
    /// weightless plans.
    weights: Option<Vec<u32>>,
}

impl ShardPlan {
    /// Weightless plan: id-order dynamic scheduling, no nnz accounting.
    pub fn new(workers: usize, num_blocks: usize) -> ShardPlan {
        ShardPlan {
            workers: workers.max(1),
            num_blocks,
            order: None,
            weights: None,
        }
    }

    /// Size-aware plan from measured per-block non-zero weights: blocks are
    /// pre-sorted descending by weight (ties broken by block id, so the
    /// order is deterministic) and drained through the dynamic counter.
    /// With one worker the identity order is kept — reordering could not
    /// improve balance and would break bit-reproducibility.
    pub fn lpt(workers: usize, weights: Vec<u32>) -> ShardPlan {
        let workers = workers.max(1);
        let num_blocks = weights.len();
        let order = if workers > 1 && num_blocks > 1 {
            let mut o: Vec<u32> = (0..num_blocks as u32).collect();
            o.sort_unstable_by(|&a, &b| {
                weights[b as usize]
                    .cmp(&weights[a as usize])
                    .then_with(|| a.cmp(&b))
            });
            Some(o)
        } else {
            None
        };
        ShardPlan { workers, num_blocks, order, weights: Some(weights) }
    }

    /// The block id served at queue position `i`.
    #[inline]
    fn block_at(&self, i: usize) -> usize {
        match &self.order {
            Some(o) => o[i] as usize,
            None => i,
        }
    }

    /// Whether this plan carries per-block weights (claimed-nnz accounting
    /// and LPT ordering) — the engine's cache-validity check.
    pub fn weighted(&self) -> bool {
        self.weights.is_some()
    }

    /// The claim order as block ids (tests and diagnostics).
    pub fn claim_order(&self) -> Vec<usize> {
        (0..self.num_blocks).map(|i| self.block_at(i)).collect()
    }

    /// Run `step(acc, worker, block)` over all blocks with per-worker
    /// accumulators, merging them at the end. Discards stats.
    pub fn execute<Acc, I, S, M>(&self, init: I, step: S, merge: M) -> Acc
    where
        Acc: Send,
        I: Fn() -> Acc + Sync,
        S: Fn(&mut Acc, usize, usize) + Sync,
        M: Fn(&mut Acc, Acc),
    {
        self.execute_with_stats(init, step, merge).0
    }

    /// [`Self::execute`], also returning the measured per-worker stats
    /// (blocks, busy seconds, and claimed nnz when weights are present).
    pub fn execute_with_stats<Acc, I, S, M>(
        &self,
        init: I,
        step: S,
        merge: M,
    ) -> (Acc, WorkerStats)
    where
        Acc: Send,
        I: Fn() -> Acc + Sync,
        S: Fn(&mut Acc, usize, usize) + Sync,
        M: Fn(&mut Acc, Acc),
    {
        parallel_reduce_stats_weighted(
            self.workers,
            self.num_blocks,
            init,
            |acc, w, i| step(acc, w, self.block_at(i)),
            merge,
            |i| {
                self.weights
                    .as_ref()
                    .map_or(0, |ws| ws[self.block_at(i)] as usize)
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn plan_normalizes_workers() {
        let p = ShardPlan::new(0, 10);
        assert_eq!(p.workers, 1);
        assert_eq!(p.num_blocks, 10);
    }

    #[test]
    fn execute_covers_all_blocks() {
        let p = ShardPlan::new(3, 100);
        let (sum, stats) = p.execute_with_stats(
            || 0usize,
            |acc, _w, b| *acc += b,
            |acc, other| *acc += other,
        );
        assert_eq!(sum, (0..100).sum::<usize>());
        assert_eq!(stats.total_blocks(), 100);
    }

    #[test]
    fn execute_discarding_stats_matches() {
        let p = ShardPlan::new(2, 17);
        let sum = p.execute(|| 0usize, |acc, _w, _b| *acc += 1, |acc, o| *acc += o);
        assert_eq!(sum, 17);
    }

    #[test]
    fn lpt_orders_heaviest_first_deterministically() {
        let p = ShardPlan::lpt(4, vec![5, 80, 80, 1, 40]);
        // descending weight, ties by block id
        assert_eq!(p.claim_order(), vec![1, 2, 4, 0, 3]);
        // same weights → same order, every time
        assert_eq!(
            ShardPlan::lpt(4, vec![5, 80, 80, 1, 40]).claim_order(),
            p.claim_order()
        );
    }

    #[test]
    fn single_worker_lpt_keeps_identity_order() {
        let p = ShardPlan::lpt(1, vec![5, 80, 80, 1, 40]);
        assert_eq!(p.claim_order(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn lpt_covers_every_block_once_and_accounts_nnz() {
        let weights: Vec<u32> = (0..64).map(|b| (b % 7) * 100 + 1).collect();
        let total: usize = weights.iter().map(|&w| w as usize).sum();
        let p = ShardPlan::lpt(4, weights);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        let (_, stats) = p.execute_with_stats(
            || (),
            |_acc, _w, b| {
                hits[b].fetch_add(1, Ordering::Relaxed);
            },
            |_acc, _o| {},
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(stats.total_blocks(), 64);
        assert_eq!(stats.total_nnz(), total);
    }

    #[test]
    fn single_worker_lpt_claims_all_nnz() {
        let p = ShardPlan::lpt(1, vec![3, 7, 11]);
        let (count, stats) = p.execute_with_stats(
            || 0usize,
            |acc, _w, _b| *acc += 1,
            |acc, o| *acc += o,
        );
        assert_eq!(count, 3);
        assert_eq!(stats.nnz, vec![21]);
        assert!((stats.nnz_imbalance() - 1.0).abs() < 1e-9);
    }
}
