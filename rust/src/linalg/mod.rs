//! Dense linear algebra substrate: a row-major `f32` matrix with the
//! operations the decomposition algorithms need (GEMM for the reusable
//! `C = A·B` tables, dot products, axpy) plus a small symmetric positive
//! definite solver used by the P-Tucker ALS baseline.
//!
//! Layout note (paper §IV-D "Memory Coalescing"): the paper stores factor
//! and core matrices row-major so a warp reads consecutive addresses; we
//! keep the same layout so a worker's row updates are cache-line friendly.

pub mod matrix;
pub mod node_rep;
pub mod simd;
pub mod solve;

pub use matrix::Matrix;
pub use node_rep::NodeReplicated;
pub use simd::{
    dot_lanes, dot_padded, lanes_at, pad_matrix_into, pad_r,
    prefetch_read_f32, prefetch_read_u32, reduce_lanes, LANES,
};
pub use solve::solve_spd;

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: lets LLVM vectorize and reduces the
    // sequential FP dependency chain (hot: called per non-zero).
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for k in 0..chunks {
        let i = k * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// `y = alpha * x + beta * y` (the SGD row-update shape:
/// `a ← a + γ(e·w − λ·a)` is `axpby(γe, w, 1−γλ, a)`).
#[inline]
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = alpha * xi + beta * *yi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn dot_handles_remainder_lengths() {
        for n in 1..17 {
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b = vec![2.0f32; n];
            let expect: f32 = (0..n).map(|i| 2.0 * i as f32).sum();
            assert_eq!(dot(&a, &b), expect, "n={n}");
        }
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0f32, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
    }

    #[test]
    fn axpby_matches_manual() {
        let mut y = vec![2.0f32, 3.0];
        axpby(0.5, &[4.0, 8.0], 0.9, &mut y);
        assert!((y[0] - (0.5 * 4.0 + 0.9 * 2.0)).abs() < 1e-6);
        assert!((y[1] - (0.5 * 8.0 + 0.9 * 3.0)).abs() < 1e-6);
    }
}
