//! Row-major `f32` matrix.

use crate::util::rng::Rng;

/// A dense row-major matrix. Rows are contiguous, which matches the paper's
/// "memory coalescing" layout: a factor row `a_{i_n}` or core column block is
/// one contiguous read.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// All-zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap a row-major buffer (length must equal `rows * cols`).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Uniform random entries in `[lo, hi)` — the paper initializes factor
    /// and core matrices from an "average distribution" (uniform).
    pub fn uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.uniform_f32(lo, hi)).collect();
        Matrix { rows, cols, data }
    }

    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `i` as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable contiguous slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Element `(i, j)`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Overwrite element `(i, j)`.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// The whole row-major backing buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// The whole row-major backing buffer, mutable.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Set every element to `v`.
    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// `self @ other` — straightforward ikj GEMM, used for the reusable
    /// `C^(n) = A^(n) B^(n)` tables when the PJRT path is disabled. Shapes:
    /// `(m×k) @ (k×n) = (m×n)`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for p in 0..k {
                let a = arow[p];
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(p);
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// Write `self @ other` into an existing output matrix (no allocation —
    /// the hot-path variant used for C-table refresh).
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!(out.rows, self.rows);
        assert_eq!(out.cols, other.cols);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        out.data.fill(0.0);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for p in 0..k {
                let a = arow[p];
                let brow = &other.data[p * n..(p + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
    }

    /// Recompute one output row of `self @ other` into `orow`, with
    /// exactly the accumulation order of [`Matrix::matmul_into`] — the
    /// incremental C-refresh relies on the two being bitwise
    /// interchangeable row by row.
    #[inline]
    pub fn matmul_row_into(&self, other: &Matrix, i: usize, orow: &mut [f32]) {
        debug_assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        debug_assert_eq!(orow.len(), other.cols);
        let (k, n) = (self.cols, other.cols);
        let arow = &self.data[i * k..(i + 1) * k];
        orow.fill(0.0);
        for p in 0..k {
            let a = arow[p];
            let brow = &other.data[p * n..(p + 1) * n];
            for j in 0..n {
                orow[j] += a * brow[j];
            }
        }
    }

    /// Transpose (used by tests and the ALS baseline).
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(j, i, self.get(i, j));
            }
        }
        out
    }

    /// Frobenius norm squared.
    pub fn norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Max |elementwise difference| against another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Column `j` copied out (core matrices are accessed column-wise as
    /// `b_{:,r}`; R and J are ≤ 64 so the copy is trivial).
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Rank-padded copy: columns rounded up to [`crate::linalg::LANES`]
    /// with `+0.0` pad entries — the layout the R-blocked kernels stream
    /// with no remainder loop (see `linalg::simd` for why the padding is
    /// value-neutral bit-for-bit).
    pub fn rank_padded(&self) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        super::simd::pad_matrix_into(&mut out, self);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_indexing() {
        let mut m = Matrix::zeros(2, 3);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn from_vec_shape_checked() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.get(0, 1), 2.0);
        assert_eq!(m.get(1, 0), 3.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_rejects_bad_len() {
        let _ = Matrix::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn matmul_known_result() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_into_matches_matmul() {
        let mut rng = Rng::new(5);
        let a = Matrix::uniform(7, 5, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(5, 9, -1.0, 1.0, &mut rng);
        let c1 = a.matmul(&b);
        let mut c2 = Matrix::zeros(7, 9);
        a.matmul_into(&b, &mut c2);
        assert!(c1.max_abs_diff(&c2) < 1e-6);
    }

    #[test]
    fn matmul_row_into_is_bitwise_equal_per_row() {
        let mut rng = Rng::new(21);
        let a = Matrix::uniform(11, 6, -1.0, 1.0, &mut rng);
        let b = Matrix::uniform(6, 4, -1.0, 1.0, &mut rng);
        let mut full = Matrix::zeros(11, 4);
        a.matmul_into(&b, &mut full);
        let mut row = vec![f32::NAN; 4];
        for i in 0..11 {
            a.matmul_row_into(&b, i, &mut row);
            assert_eq!(row, full.row(i), "row {i} must match bitwise");
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(9);
        let a = Matrix::uniform(4, 4, -1.0, 1.0, &mut rng);
        let mut eye = Matrix::zeros(4, 4);
        for i in 0..4 {
            eye.set(i, i, 1.0);
        }
        assert!(a.matmul(&eye).max_abs_diff(&a) < 1e-7);
        assert!(eye.matmul(&a).max_abs_diff(&a) < 1e-7);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Matrix::uniform(3, 6, -1.0, 1.0, &mut rng);
        assert!(a.transpose().transpose().max_abs_diff(&a) < 1e-9);
        assert_eq!(a.transpose().rows(), 6);
    }

    #[test]
    fn uniform_within_bounds() {
        let mut rng = Rng::new(11);
        let m = Matrix::uniform(10, 10, 0.2, 0.4, &mut rng);
        assert!(m.data().iter().all(|&x| (0.2..0.4).contains(&x)));
    }

    #[test]
    fn col_extracts() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.col(1), vec![2.0, 5.0]);
    }

    #[test]
    fn norm_sq_known() {
        let m = Matrix::from_vec(1, 3, vec![1.0, 2.0, 2.0]);
        assert_eq!(m.norm_sq(), 9.0);
    }
}
