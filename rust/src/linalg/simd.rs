//! Rank-lane substrate for the R-blocked hot-path kernels.
//!
//! The paper's kernels keep the chain products `v ∈ R^R` in registers and
//! walk them a warp at a time; the CPU analogue is fixed 8-lane groups that
//! LLVM lowers to AVX registers. Two design rules make the lanes safe to
//! use on the *bitwise-parity* hot path (`tests/engine_parity.rs` demands
//! `max_abs_diff == 0.0` against the frozen reference loops):
//!
//! 1. **Zero padding is value-neutral by construction.** [`lanes_at`]
//!    extends a short row with `+0.0` lanes, so a rank-padded matrix (cols
//!    rounded up to [`LANES`], pad entries `+0.0`) and its unpadded
//!    original produce the *identical* sequence of float operations —
//!    every pad lane contributes `x + 0.0·0.0`, which is exact.
//! 2. **Reductions use one fixed tree.** [`reduce_lanes`] always combines
//!    the 8 lane accumulators in the same association, so the result does
//!    not depend on which code path (padded fast path vs zero-extended
//!    tail path) produced the lanes.

use super::Matrix;
use crate::util::round_up;

/// Lane-group width of the R-blocked kernels (8 × f32 = one AVX register).
pub const LANES: usize = 8;

/// `r` rounded up to the next multiple of [`LANES`] — the stride of the
/// rank-padded scratch buffers and matrix layouts.
#[inline]
pub fn pad_r(r: usize) -> usize {
    round_up(r.max(1), LANES)
}

/// Lane group `k` of `src`, zero-extended past `src.len()`: a short
/// (unpadded) row behaves exactly like its rank-padded copy.
#[inline]
pub fn lanes_at(src: &[f32], k: usize) -> [f32; LANES] {
    let mut out = [0.0f32; LANES];
    let lo = k * LANES;
    if lo < src.len() {
        let n = (src.len() - lo).min(LANES);
        out[..n].copy_from_slice(&src[lo..lo + n]);
    }
    out
}

/// Fixed-association reduction of one lane group:
/// `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))`. Every reducing kernel funnels
/// through this one tree so lane order never silently changes the bits.
#[inline]
pub fn reduce_lanes(a: [f32; LANES]) -> f32 {
    ((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]))
}

/// Fixed-tree dot product of two **rank-padded** rows: equal lengths, a
/// multiple of [`LANES`]. Eight independent lane accumulators walk the
/// rows R-blocked (the remainder-free shape LLVM lowers to straight AVX)
/// and funnel through [`reduce_lanes`] — so the result is **bitwise**
/// identical to [`dot_lanes`] on the unpadded originals. This is the one
/// dot kernel the serving scorer and the engine's `fiber_w` fast path
/// share.
#[inline]
pub fn dot_padded(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len() % LANES, 0);
    let mut acc = [0.0f32; LANES];
    for (ga, gb) in a.chunks_exact(LANES).zip(b.chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += ga[l] * gb[l];
        }
    }
    reduce_lanes(acc)
}

/// Tail-path sibling of [`dot_padded`] for unpadded (or unequal-length)
/// slices: both operands are zero-extended lane group by lane group
/// ([`lanes_at`]), so the accumulators see the exact lane values a
/// rank-padded copy would produce, and the fixed reduction tree returns
/// the identical bits. A missing tail behaves as `+0.0` entries —
/// value-neutral by design rule 1 above.
#[inline]
pub fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    let groups = pad_r(a.len().max(b.len())) / LANES;
    let mut acc = [0.0f32; LANES];
    for k in 0..groups {
        let (ga, gb) = (lanes_at(a, k), lanes_at(b, k));
        for l in 0..LANES {
            acc[l] += ga[l] * gb[l];
        }
    }
    reduce_lanes(acc)
}

/// Copy `src` into `dst` as a rank-padded layout: same rows, columns
/// rounded up to [`LANES`], pad entries `+0.0`. Reuses `dst`'s allocation
/// when the shape already matches (the per-pass resync path allocates
/// nothing after the first epoch).
pub fn pad_matrix_into(dst: &mut Matrix, src: &Matrix) {
    let (rows, cols) = (src.rows(), src.cols());
    let pc = pad_r(cols);
    if dst.rows() != rows || dst.cols() != pc {
        *dst = Matrix::zeros(rows, pc);
    }
    if cols == pc {
        dst.data_mut().copy_from_slice(src.data());
        return;
    }
    for (drow, srow) in dst
        .data_mut()
        .chunks_exact_mut(pc)
        .zip(src.data().chunks_exact(cols))
    {
        drow[..cols].copy_from_slice(srow);
        drow[cols..].fill(0.0);
    }
}

/// Hint the CPU to pull the cache line(s) holding the start of `p` into
/// L1 ahead of use — the CPU analogue of the paper's explicit
/// shared-memory staging of the next sub-tensor's operands. Purely a
/// performance hint: a prefetch has **no architectural effect**, so every
/// kernel that issues one stays bitwise-identical to the kernel that
/// doesn't. Compiles to a no-op off x86_64 (the only arch gate the
/// prefetch intrinsic lives behind; CI checks it stays here).
#[inline(always)]
pub fn prefetch_read_f32(p: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if let Some(first) = p.first() {
        // SAFETY: the pointer comes from a live slice; _mm_prefetch has
        // no memory effects and tolerates any address.
        unsafe {
            core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(
                (first as *const f32).cast::<i8>(),
            );
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

/// [`prefetch_read_f32`] for index arrays (B-CSF leaf coordinates).
#[inline(always)]
pub fn prefetch_read_u32(p: &[u32]) {
    #[cfg(target_arch = "x86_64")]
    if let Some(first) = p.first() {
        // SAFETY: as in prefetch_read_f32 — hint only, no memory effects.
        unsafe {
            core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(
                (first as *const u32).cast::<i8>(),
            );
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pad_r_rounds_to_lane_multiples() {
        assert_eq!(pad_r(1), 8);
        assert_eq!(pad_r(8), 8);
        assert_eq!(pad_r(9), 16);
        assert_eq!(pad_r(32), 32);
        // degenerate zero still yields one full lane group
        assert_eq!(pad_r(0), 8);
    }

    #[test]
    fn lanes_at_zero_extends() {
        let src = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(lanes_at(&src, 0), [1.0, 2.0, 3.0, 4.0, 5.0, 0.0, 0.0, 0.0]);
        assert_eq!(lanes_at(&src, 1), [0.0f32; LANES]);
    }

    #[test]
    fn reduce_lanes_is_the_documented_tree() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        assert_eq!(reduce_lanes(a), ((1.0 + 2.0) + (3.0 + 4.0)) + ((5.0 + 6.0) + (7.0 + 8.0)));
    }

    #[test]
    fn dot_padded_and_dot_lanes_are_bitwise_equal() {
        let mut rng = Rng::new(11);
        for r in [1usize, 3, 5, 8, 9, 13, 16, 31] {
            let a: Vec<f32> = (0..r).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
            let b: Vec<f32> = (0..r).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
            let stride = pad_r(r);
            let mut ap = a.clone();
            ap.resize(stride, 0.0);
            let mut bp = b.clone();
            bp.resize(stride, 0.0);
            let fast = dot_padded(&ap, &bp);
            let tail = dot_lanes(&a, &b);
            assert_eq!(
                fast.to_bits(),
                tail.to_bits(),
                "r={r}: padded fast path vs zero-extended tail path"
            );
            // unequal lengths zero-extend the shorter operand
            assert_eq!(dot_lanes(&a, &bp).to_bits(), tail.to_bits(), "r={r}");
        }
        // degenerate empties reduce to +0.0
        assert_eq!(dot_lanes(&[], &[]).to_bits(), 0.0f32.to_bits());
        assert_eq!(dot_padded(&[], &[]).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn dot_padded_uses_the_fixed_reduction_tree() {
        // one full lane group: the dot *is* the documented tree
        let a: Vec<f32> = (1..=8).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (1..=8).map(|i| i as f32 * 0.3).collect();
        let lanes: [f32; LANES] =
            std::array::from_fn(|l| a[l] * b[l]);
        assert_eq!(dot_padded(&a, &b).to_bits(), reduce_lanes(lanes).to_bits());
    }

    #[test]
    fn pad_matrix_into_pads_and_reuses_allocation() {
        let mut rng = Rng::new(3);
        let src = Matrix::uniform(4, 5, -1.0, 1.0, &mut rng);
        let mut dst = Matrix::zeros(0, 0);
        pad_matrix_into(&mut dst, &src);
        assert_eq!(dst.rows(), 4);
        assert_eq!(dst.cols(), 8);
        for i in 0..4 {
            assert_eq!(&dst.row(i)[..5], src.row(i));
            assert!(dst.row(i)[5..].iter().all(|&x| x == 0.0));
        }
        // overwrite in place with new contents, shape unchanged
        let src2 = Matrix::uniform(4, 5, -1.0, 1.0, &mut rng);
        let ptr = dst.data().as_ptr();
        pad_matrix_into(&mut dst, &src2);
        assert_eq!(ptr, dst.data().as_ptr(), "resync must not reallocate");
        assert_eq!(&dst.row(2)[..5], src2.row(2));
    }

    #[test]
    fn prefetch_is_a_pure_hint() {
        // no architectural effect and no panic on any slice shape
        prefetch_read_f32(&[]);
        prefetch_read_u32(&[]);
        let xs = [1.0f32, 2.0, 3.0];
        let before = xs;
        prefetch_read_f32(&xs);
        assert_eq!(xs, before);
        prefetch_read_u32(&[7, 8, 9]);
    }

    #[test]
    fn pad_matrix_into_exact_multiple_is_a_plain_copy() {
        let mut rng = Rng::new(4);
        let src = Matrix::uniform(3, 8, -1.0, 1.0, &mut rng);
        let mut dst = Matrix::zeros(0, 0);
        pad_matrix_into(&mut dst, &src);
        assert_eq!(dst.data(), src.data());
    }
}
