//! Symmetric positive-definite solver (Cholesky), used by the P-Tucker
//! baseline: each factor row solves the `J×J` normal equations
//! `(H + λI) a = g` built from the non-zeros of its slice.

use super::Matrix;

/// Error for a non-SPD system (P-Tucker regularizes with `λI`, so this only
/// fires on pathological inputs; callers treat it as a skipped row).
#[derive(Debug, PartialEq)]
pub struct NotSpd;

impl std::fmt::Display for NotSpd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix is not symmetric positive definite")
    }
}
impl std::error::Error for NotSpd {}

/// Solve `A x = b` for symmetric positive definite `A` via Cholesky
/// (`A = L Lᵀ`). `a` is consumed as the workspace. Returns `x`.
pub fn solve_spd(a: &Matrix, b: &[f32]) -> Result<Vec<f32>, NotSpd> {
    let n = a.rows();
    assert_eq!(a.cols(), n, "solve_spd needs a square matrix");
    assert_eq!(b.len(), n);
    // Cholesky in f64 for stability (J ≤ 64 so cost is negligible).
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j) as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 || !sum.is_finite() {
                    return Err(NotSpd);
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    // forward substitution L y = b
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    // back substitution Lᵀ x = y
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    Ok(x.into_iter().map(|v| v as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_solve() {
        let mut eye = Matrix::zeros(3, 3);
        for i in 0..3 {
            eye.set(i, i, 1.0);
        }
        let x = solve_spd(&eye, &[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(x, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn random_spd_roundtrip() {
        let mut rng = Rng::new(42);
        for trial in 0..20 {
            let n = 2 + (trial % 6);
            let m = Matrix::uniform(n, n, -1.0, 1.0, &mut rng);
            // SPD: MᵀM + I
            let mt = m.transpose();
            let mut spd = mt.matmul(&m);
            for i in 0..n {
                spd.set(i, i, spd.get(i, i) + 1.0);
            }
            let xtrue: Vec<f32> = (0..n).map(|i| (i as f32) - 1.5).collect();
            // b = spd @ xtrue
            let b: Vec<f32> =
                (0..n).map(|i| crate::linalg::dot(spd.row(i), &xtrue)).collect();
            let x = solve_spd(&spd, &b).unwrap();
            for (xi, ti) in x.iter().zip(xtrue.iter()) {
                assert!((xi - ti).abs() < 1e-3, "trial {trial}: {x:?} vs {xtrue:?}");
            }
        }
    }

    #[test]
    fn non_spd_detected() {
        // negative definite
        let mut m = Matrix::zeros(2, 2);
        m.set(0, 0, -1.0);
        m.set(1, 1, -1.0);
        assert_eq!(solve_spd(&m, &[1.0, 1.0]).unwrap_err(), NotSpd);
    }

    #[test]
    fn singular_detected() {
        let m = Matrix::zeros(2, 2);
        assert!(solve_spd(&m, &[0.0, 0.0]).is_err());
    }
}
