//! Per-NUMA-node replicas of read-mostly pass operands.
//!
//! The hot factor/core kernels stream the same rank-padded `C^(n)` tables
//! and core copies from every worker; on a multi-socket machine that
//! means one socket's memory serves every other socket's reads across the
//! interconnect. [`NodeReplicated`] keeps one **primary** copy (node 0 —
//! always present, always the one mutated) plus byte-identical mirrors
//! for the remaining nodes; a worker indexes its home node
//! ([`crate::sched::topo::current_node`]) and reads purely node-local
//! memory.
//!
//! Coherence discipline: callers mutate the primary only, then push the
//! change to the mirrors with [`NodeReplicated::sync_with`] — the engine
//! keys that push off the same `DirtyRows` machinery the incremental
//! refresh uses, so a refresh generation re-replicates only the dirty
//! 64-row blocks. Because mirrors are bitwise copies, *which* replica a
//! worker reads can never change the math — the parity suites run
//! unchanged with replication on.

/// One primary value plus per-node mirrors (mirror `i` serves node
/// `i + 1`). Degenerates to a plain `T` (no mirrors, no overhead beyond
/// an empty `Vec`) on single-node topologies.
#[derive(Clone, Debug, Default)]
pub struct NodeReplicated<T> {
    /// Node 0's copy — the one all writes target.
    primary: T,
    /// Copies for nodes `1..=mirrors.len()`, refreshed via
    /// [`NodeReplicated::sync_with`].
    mirrors: Vec<T>,
}

impl<T> NodeReplicated<T> {
    /// Wrap a value with no mirrors (single-node).
    pub fn new(primary: T) -> NodeReplicated<T> {
        NodeReplicated { primary, mirrors: Vec::new() }
    }

    /// Number of replicas (primary + mirrors) — the node count this value
    /// is provisioned for (≥ 1).
    pub fn nodes(&self) -> usize {
        1 + self.mirrors.len()
    }

    /// Node `node`'s replica; out-of-range nodes clamp to the primary
    /// (an unprovisioned node reads correct — if remote — data rather
    /// than panicking).
    #[inline]
    pub fn get(&self, node: usize) -> &T {
        if node == 0 {
            &self.primary
        } else {
            self.mirrors.get(node - 1).unwrap_or(&self.primary)
        }
    }

    /// The primary (node 0) replica.
    #[inline]
    pub fn primary(&self) -> &T {
        &self.primary
    }

    /// Mutable access to the primary — the only replica callers write.
    /// After mutating, push to the mirrors with
    /// [`NodeReplicated::sync_with`] (or they serve stale data).
    #[inline]
    pub fn primary_mut(&mut self) -> &mut T {
        &mut self.primary
    }

    /// Provision replicas for `nodes` nodes: grows by cloning the current
    /// primary, shrinks by dropping surplus mirrors. Idempotent at the
    /// current count (no allocation, no copies).
    pub fn set_nodes(&mut self, nodes: usize)
    where
        T: Clone,
    {
        let want = nodes.max(1) - 1;
        if self.mirrors.len() > want {
            self.mirrors.truncate(want);
        }
        while self.mirrors.len() < want {
            self.mirrors.push(self.primary.clone());
        }
    }

    /// Propagate the primary into every mirror through `sync`, called as
    /// `sync(&primary, &mut mirror)` per mirror. The caller chooses the
    /// copy granularity — a full overwrite, or a dirty-block copy that
    /// reuses the mirror's allocation (the engine's steady-state path,
    /// which allocates nothing).
    pub fn sync_with<F: FnMut(&T, &mut T)>(&mut self, mut sync: F) {
        for m in &mut self.mirrors {
            sync(&self.primary, m);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_node_is_just_the_primary() {
        let r = NodeReplicated::new(vec![1, 2, 3]);
        assert_eq!(r.nodes(), 1);
        assert_eq!(r.get(0), &vec![1, 2, 3]);
        // unprovisioned nodes clamp to the primary
        assert_eq!(r.get(5), &vec![1, 2, 3]);
    }

    #[test]
    fn set_nodes_clones_and_truncates() {
        let mut r = NodeReplicated::new(7u32);
        r.set_nodes(3);
        assert_eq!(r.nodes(), 3);
        assert_eq!((*r.get(0), *r.get(1), *r.get(2)), (7, 7, 7));
        // mutating the primary leaves mirrors stale until a sync
        *r.primary_mut() = 9;
        assert_eq!(*r.get(0), 9);
        assert_eq!(*r.get(1), 7);
        r.sync_with(|p, m| *m = *p);
        assert_eq!(*r.get(1), 9);
        assert_eq!(*r.get(2), 9);
        r.set_nodes(1);
        assert_eq!(r.nodes(), 1);
        // idempotent re-provision
        r.set_nodes(1);
        assert_eq!(r.nodes(), 1);
        // zero clamps to one
        r.set_nodes(0);
        assert_eq!(r.nodes(), 1);
    }

    #[test]
    fn sync_with_reuses_mirror_allocations() {
        let mut r = NodeReplicated::new(vec![1.0f32; 64]);
        r.set_nodes(2);
        let ptr = r.get(1).as_ptr();
        r.primary_mut()[3] = 5.0;
        r.sync_with(|p, m| m.copy_from_slice(p));
        assert_eq!(r.get(1)[3], 5.0);
        assert_eq!(r.get(1).as_ptr(), ptr, "dirty-copy sync must not reallocate");
    }
}
