//! Pass backends — the execution seam between [`crate::coordinator::Session`]
//! and the epoch engine.
//!
//! The paper's core claim is that FasterTucker's factor/core **sweeps are
//! the unit worth accelerating on a device** (its GPU kernels own whole
//! passes, not individual matmuls). This module makes that boundary a
//! first-class layer: a [`PassBackend`] owns the execution of one entire
//! factor or core pass — input: prepared storage + engine state + a
//! [`PassRequest`] descriptor; output: the pass's measured
//! [`WorkerStats`] — and the session delegates every pass to whichever
//! backend it was opened with (`--backend cpu|pjrt`,
//! [`crate::config::Backend`]).
//!
//! Two backends ship:
//!
//! * [`CpuShardBackend`] — the in-crate [`crate::sched::ShardPlan`] sweep,
//!   extracted verbatim from the pre-backend session path and proven
//!   **bit-identical** to it (`tests/engine_parity.rs` runs unchanged
//!   through this backend; `benches/microbench.rs` bounds its dispatch
//!   overhead against the frozen pre-backend path).
//! * [`PjrtPassBackend`] — routes a pass's dense work through the AOT
//!   artifact manifest (today: the per-mode `C^(n) = A^(n) B^(n)` refresh
//!   via the `matmul` artifact, replacing the session's old
//!   `RefreshC`-only hook; whole-pass artifacts slot into the same seam
//!   when the manifest grows them). Stub-backed when the `xla` feature is
//!   off: every artifact call falls back to the in-crate kernels, so the
//!   backend is selectable in every build.
//!
//! The trait is object-safe over the session's concrete
//! [`PreparedStorage`] (the one storage every engine session owns), so a
//! `Session` carries a `Box<dyn PassBackend>` without infecting the
//! monomorphized hot path: inside [`PassBackend::run_pass`] the backend
//! calls the generic [`crate::algo::engine::run_epoch_with`], and the
//! storage × sink × target pipeline inlines exactly as before — the `dyn`
//! boundary is two virtual calls per epoch, not per block or leaf.
//!
//! Custom backends (tests wrap [`CpuShardBackend`] with a rendezvous
//! decorator to force concurrent leased passes; an accelerator plugin
//! would own device buffers here) implement the trait and attach with
//! [`crate::coordinator::Session::set_backend`].

pub mod cpu;
pub mod pjrt;

pub use cpu::CpuShardBackend;
pub use pjrt::{refresh_c, PjrtPassBackend};

use crate::algo::engine::{EngineState, UpdateKind};
use crate::config::{Backend, TrainConfig};
use crate::model::ModelState;
use crate::runtime::PjrtRuntime;
use crate::sched::pool::WorkerStats;
use crate::tensor::prepared::PreparedStorage;

/// Everything one factor/core pass needs, borrowed from the session for
/// the duration of the pass: the trainable model, the once-built storage
/// (which carries its paired [`crate::algo::engine::ChainStrategy`]), the
/// persistent engine buffers, and the pass descriptor.
pub struct PassRequest<'a> {
    /// The FastTucker-family model the pass updates.
    pub model: &'a mut ModelState,
    /// The session's cached `(storage, chain)` instantiation.
    pub storage: &'a PreparedStorage,
    /// Which module runs: factor-row SGD or core-gradient update.
    pub kind: UpdateKind,
    /// Run config with the epoch's decayed learning rates and the pass's
    /// effective worker count (the lease size, when one is leased) already
    /// resolved.
    pub cfg: &'a TrainConfig,
    /// Skip the per-mode `C^(n)` refresh entirely (the FastTucker baseline
    /// keeps no `C` tables during training).
    pub skip_refresh: bool,
    /// The session's attached PJRT runtime, whenever one is loaded. Each
    /// backend decides whether to use it — the CPU backend ignores it by
    /// contract, the PJRT backend routes its dense work through it — so a
    /// backend injected via `set_backend` is never silently starved of it.
    pub runtime: Option<&'a PjrtRuntime>,
    /// The session's persistent scratch pool, padded operands, and cached
    /// shard plans.
    pub state: &'a mut EngineState,
}

/// Owns the execution of one entire factor or core pass.
///
/// Implementations must preserve the engine's determinism contract: for a
/// given `(model, storage, cfg)` the pass result may depend only on the
/// request (in particular `cfg.workers`), never on *where* it runs —
/// leases change which executor slots host a pass, not its math. `Send`
/// because sessions (and the boxed backend inside them) migrate across
/// threads in multi-tenant runs.
pub trait PassBackend: Send {
    /// Stable backend name (diagnostics, bench labels).
    fn name(&self) -> &'static str;
    /// Whether this backend routes dense work through an attached PJRT
    /// runtime when one is present. The session keys its evaluation path
    /// and serving-snapshot `C`-table refresh on this answer, so those
    /// stay bit-consistent with the refresh its passes actually perform —
    /// a backend that consumes [`PassRequest::runtime`] must return
    /// `true`; the default is `false` (decorators that delegate to the
    /// CPU backend keep the default).
    fn uses_runtime(&self) -> bool {
        false
    }
    /// Execute the requested pass to completion and return its measured
    /// per-worker stats.
    fn run_pass(&self, req: PassRequest<'_>) -> WorkerStats;
}

/// The backend a config selects ([`Backend::resolve`]): the CPU shard
/// sweep by default, the PJRT manifest router for `--backend pjrt` (or
/// the legacy `--compute pjrt`).
pub fn backend_for(cfg: &TrainConfig) -> Box<dyn PassBackend> {
    match Backend::resolve(cfg) {
        Backend::Cpu => Box::new(CpuShardBackend),
        Backend::Pjrt => Box::new(PjrtPassBackend::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Compute;

    #[test]
    fn backend_selection_follows_config() {
        let mut cfg = TrainConfig::default();
        assert_eq!(backend_for(&cfg).name(), "cpu");
        cfg.backend = Backend::Pjrt;
        assert_eq!(backend_for(&cfg).name(), "pjrt");
        cfg.backend = Backend::Cpu;
        cfg.compute = Compute::Pjrt;
        assert_eq!(backend_for(&cfg).name(), "pjrt");
    }

    /// The runtime-consumption declaration the session keys evaluation and
    /// serving refreshes on: only the PJRT backend claims the runtime.
    #[test]
    fn uses_runtime_declarations() {
        assert!(!CpuShardBackend.uses_runtime());
        assert!(PjrtPassBackend::new().uses_runtime());
    }
}
