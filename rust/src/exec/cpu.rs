//! The default pass backend: the in-crate `ShardPlan` sweep.

use super::{PassBackend, PassRequest};
use crate::algo::engine;
use crate::config::RefreshMode;
use crate::model::ModelState;
use crate::sched::Executor;
use crate::sched::pool::WorkerStats;

/// Executes passes exactly as the pre-backend session did: the generic
/// epoch engine over the session's cached storage, LPT-ordered dynamic
/// scheduling, and the in-crate GEMM for the per-mode `C^(n)` refresh.
///
/// Bit-identical to the frozen pre-backend path by construction — it calls
/// the very same [`engine::run_epoch_with`] with the very same refresh
/// functions — and proven so by `tests/engine_parity.rs` (which runs
/// unchanged through sessions carrying this backend) plus the
/// `backend` comparison in `benches/microbench.rs` (dispatch overhead
/// bounded against a direct engine invocation).
pub struct CpuShardBackend;

impl PassBackend for CpuShardBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn run_pass(&self, req: PassRequest<'_>) -> WorkerStats {
        let PassRequest { model, storage, kind, cfg, skip_refresh, runtime: _, state } = req;
        // By contract the CPU backend never touches the runtime: its
        // refresh is the in-crate GEMM (full or dirty-row incremental, per
        // the refresh knob; both bitwise equal), or nothing for the
        // table-less FastTucker baseline.
        let chain = storage.chain();
        if skip_refresh {
            let refresh = &engine::refresh_none;
            return engine::run_epoch_with(model, storage, chain, kind, cfg, refresh, state);
        }
        if cfg.refresh == RefreshMode::Full {
            let refresh = &engine::refresh_rust;
            return engine::run_epoch_with(model, storage, chain, kind, cfg, refresh, state);
        }
        let workers = cfg.effective_workers();
        if workers > 1 {
            // a transient pool private to this pass: the refresh fan-out
            // must never take extra leases on the session's shared
            // executor (lease accounting stays one lease per pass)
            let pool = Executor::new(workers);
            let refresh = |m: &mut ModelState, n: usize| m.refresh_c_dirty(n, Some(&pool));
            engine::run_epoch_with(model, storage, chain, kind, cfg, &refresh, state)
        } else {
            let refresh = |m: &mut ModelState, n: usize| m.refresh_c_dirty(n, None);
            engine::run_epoch_with(model, storage, chain, kind, cfg, &refresh, state)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::{EngineState, UpdateKind};
    use crate::algo::Algo;
    use crate::config::TrainConfig;
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::model::ModelState;
    use crate::tensor::prepared::PreparedStorage;

    /// `--refresh full` and `--refresh incremental` must be
    /// indistinguishable to the math: same passes, same bits.
    #[test]
    fn refresh_modes_are_bitwise_identical_through_the_backend() {
        let t = recommender(&RecommenderSpec::tiny(), 27);
        let mut cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 6,
            r: 5,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 1,
            block_nnz: 256,
            fiber_threshold: 16,
            ..TrainConfig::default()
        };
        let storage = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        let m0 = ModelState::init(&cfg, 31);

        let mut m_inc = m0.clone();
        let mut st_inc = EngineState::new();
        let mut m_full = m0;
        let mut st_full = EngineState::new();
        for kind in [UpdateKind::Factor, UpdateKind::Core, UpdateKind::Factor] {
            cfg.refresh = RefreshMode::Incremental;
            CpuShardBackend.run_pass(PassRequest {
                model: &mut m_inc,
                storage: &storage,
                kind,
                cfg: &cfg,
                skip_refresh: false,
                runtime: None,
                state: &mut st_inc,
            });
            cfg.refresh = RefreshMode::Full;
            CpuShardBackend.run_pass(PassRequest {
                model: &mut m_full,
                storage: &storage,
                kind,
                cfg: &cfg,
                skip_refresh: false,
                runtime: None,
                state: &mut st_full,
            });
        }
        for n in 0..3 {
            assert_eq!(m_inc.factors[n].max_abs_diff(&m_full.factors[n]), 0.0);
            assert_eq!(m_inc.cores[n].max_abs_diff(&m_full.cores[n]), 0.0);
            assert_eq!(m_inc.c_tables[n].max_abs_diff(&m_full.c_tables[n]), 0.0);
        }
    }

    /// The backend must be a pure delegation: one pass through
    /// `CpuShardBackend` equals one direct `run_epoch_with` call, bitwise.
    #[test]
    fn cpu_backend_is_bit_identical_to_direct_engine_calls() {
        let t = recommender(&RecommenderSpec::tiny(), 21);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 6,
            r: 5,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 1,
            block_nnz: 256,
            fiber_threshold: 16,
            ..TrainConfig::default()
        };
        let storage = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        let m0 = ModelState::init(&cfg, 5);

        let mut m_backend = m0.clone();
        let mut st_backend = EngineState::new();
        let mut m_direct = m0;
        let mut st_direct = EngineState::new();
        let backend = CpuShardBackend;
        for kind in [UpdateKind::Factor, UpdateKind::Core, UpdateKind::Factor] {
            backend.run_pass(PassRequest {
                model: &mut m_backend,
                storage: &storage,
                kind,
                cfg: &cfg,
                skip_refresh: false,
                runtime: None,
                state: &mut st_backend,
            });
            engine::run_epoch_with(
                &mut m_direct,
                &storage,
                storage.chain(),
                kind,
                &cfg,
                &engine::refresh_rust,
                &mut st_direct,
            );
        }
        for n in 0..3 {
            assert_eq!(m_backend.factors[n].max_abs_diff(&m_direct.factors[n]), 0.0);
            assert_eq!(m_backend.cores[n].max_abs_diff(&m_direct.cores[n]), 0.0);
            assert_eq!(m_backend.c_tables[n].max_abs_diff(&m_direct.c_tables[n]), 0.0);
        }
    }
}
