//! The default pass backend: the in-crate `ShardPlan` sweep.

use super::{PassBackend, PassRequest};
use crate::algo::engine::{self, RefreshC};
use crate::sched::pool::WorkerStats;

/// Executes passes exactly as the pre-backend session did: the generic
/// epoch engine over the session's cached storage, LPT-ordered dynamic
/// scheduling, and the in-crate GEMM for the per-mode `C^(n)` refresh.
///
/// Bit-identical to the frozen pre-backend path by construction — it calls
/// the very same [`engine::run_epoch_with`] with the very same refresh
/// functions — and proven so by `tests/engine_parity.rs` (which runs
/// unchanged through sessions carrying this backend) plus the
/// `backend` comparison in `benches/microbench.rs` (dispatch overhead
/// bounded against a direct engine invocation).
pub struct CpuShardBackend;

impl PassBackend for CpuShardBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn run_pass(&self, req: PassRequest<'_>) -> WorkerStats {
        let PassRequest { model, storage, kind, cfg, skip_refresh, runtime: _, state } = req;
        // By contract the CPU backend never touches the runtime: its
        // refresh is the in-crate GEMM (or nothing, for the table-less
        // FastTucker baseline).
        let refresh: &RefreshC = if skip_refresh {
            &engine::refresh_none
        } else {
            &engine::refresh_rust
        };
        engine::run_epoch_with(model, storage, storage.chain(), kind, cfg, refresh, state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::{EngineState, UpdateKind};
    use crate::algo::Algo;
    use crate::config::TrainConfig;
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::model::ModelState;
    use crate::tensor::prepared::PreparedStorage;

    /// The backend must be a pure delegation: one pass through
    /// `CpuShardBackend` equals one direct `run_epoch_with` call, bitwise.
    #[test]
    fn cpu_backend_is_bit_identical_to_direct_engine_calls() {
        let t = recommender(&RecommenderSpec::tiny(), 21);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 6,
            r: 5,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 1,
            block_nnz: 256,
            fiber_threshold: 16,
            ..TrainConfig::default()
        };
        let storage = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        let m0 = ModelState::init(&cfg, 5);

        let mut m_backend = m0.clone();
        let mut st_backend = EngineState::new();
        let mut m_direct = m0;
        let mut st_direct = EngineState::new();
        let backend = CpuShardBackend;
        for kind in [UpdateKind::Factor, UpdateKind::Core, UpdateKind::Factor] {
            backend.run_pass(PassRequest {
                model: &mut m_backend,
                storage: &storage,
                kind,
                cfg: &cfg,
                skip_refresh: false,
                runtime: None,
                state: &mut st_backend,
            });
            engine::run_epoch_with(
                &mut m_direct,
                &storage,
                storage.chain(),
                kind,
                &cfg,
                &engine::refresh_rust,
                &mut st_direct,
            );
        }
        for n in 0..3 {
            assert_eq!(m_backend.factors[n].max_abs_diff(&m_direct.factors[n]), 0.0);
            assert_eq!(m_backend.cores[n].max_abs_diff(&m_direct.cores[n]), 0.0);
            assert_eq!(m_backend.c_tables[n].max_abs_diff(&m_direct.c_tables[n]), 0.0);
        }
    }
}
