//! The device pass backend: passes routed through the AOT artifact
//! manifest.
//!
//! The boundary follows the hybrid-platform framing of the related FPGA
//! work: the *pass* is the offload unit, and the host decides per pass
//! which pieces the device executes. Today the manifest carries dense
//! artifacts only (`matmul`, `predict`, `core_grad`), so the backend
//! streams the sparse sweep on the in-crate shard substrate and offloads
//! the per-mode `C^(n) = A^(n) B^(n)` refresh through the `matmul`
//! artifact — precisely the work the session's old `RefreshC`-only hook
//! routed, now owned by the backend layer where whole-pass artifacts can
//! take over without another session change.
//!
//! Stub-backed degradation: without an attached runtime (no `--compute
//! pjrt` artifacts loaded, or a build without the `xla` feature, whose
//! stub runtime errors on every call) each artifact call falls back to the
//! in-crate kernel — the same fallback, same one-time warning, the session
//! used before.

use super::{PassBackend, PassRequest};
use crate::algo::engine;
use crate::config::RefreshMode;
use crate::model::ModelState;
use crate::runtime::PjrtRuntime;
use crate::sched::pool::WorkerStats;

/// Routes each pass's dense work through the runtime manifest, falling
/// back to the in-crate kernels artifact-by-artifact. Selected by
/// `--backend pjrt` (or the legacy `--compute pjrt`); see
/// [`crate::config::Backend::resolve`].
#[derive(Default)]
pub struct PjrtPassBackend;

impl PjrtPassBackend {
    /// A manifest-routing backend (the runtime itself stays owned by the
    /// session and arrives per pass in the [`PassRequest`]).
    pub fn new() -> PjrtPassBackend {
        PjrtPassBackend
    }
}

impl PassBackend for PjrtPassBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn uses_runtime(&self) -> bool {
        true
    }

    fn run_pass(&self, req: PassRequest<'_>) -> WorkerStats {
        let PassRequest { model, storage, kind, cfg, skip_refresh, runtime, state } = req;
        let refresh = move |m: &mut ModelState, n: usize| {
            if skip_refresh {
                return;
            }
            // the artifact path always recomputes the whole table (that is
            // the offload unit); only the runtimeless CPU fallback honours
            // the incremental refresh knob
            if runtime.is_none() && cfg.refresh == RefreshMode::Incremental {
                m.refresh_c_dirty(n, None);
            } else {
                refresh_c(m, n, runtime);
            }
        };
        engine::run_epoch_with(model, storage, storage.chain(), kind, cfg, &refresh, state)
    }
}

/// Refresh `C^(n)`: the PJRT `matmul` artifact when a runtime is supplied,
/// else the in-crate GEMM. A failing artifact call (including every call
/// in stub builds) falls back to the GEMM and surfaces the failure once
/// per process.
pub fn refresh_c(m: &mut ModelState, n: usize, rt: Option<&PjrtRuntime>) {
    if let Some(rt) = rt {
        match rt.matmul(&m.factors[n], &m.cores[n]) {
            Ok(c) => {
                m.c_tables[n] = c;
                // the artifact recomputed every row: nothing stays stale
                m.dirty[n].clear();
                // ...and every row may differ from the last published
                // snapshot (same conservative handoff as `refresh_c`)
                m.publish_dirty[n].mark_all();
                return;
            }
            Err(e) => {
                // fall back but surface the failure once per process
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!("warning: PJRT C-refresh failed ({e}); using Rust GEMM");
                });
            }
        }
    }
    m.refresh_c(n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::{EngineState, UpdateKind};
    use crate::algo::Algo;
    use crate::config::TrainConfig;
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::exec::CpuShardBackend;
    use crate::tensor::prepared::PreparedStorage;

    /// Without a runtime the PJRT backend degrades to exactly the CPU
    /// path: same engine, same GEMM refresh, bit for bit.
    #[test]
    fn runtimeless_pjrt_backend_matches_cpu_backend() {
        let t = recommender(&RecommenderSpec::tiny(), 23);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 1,
            block_nnz: 256,
            fiber_threshold: 16,
            ..TrainConfig::default()
        };
        let storage = PreparedStorage::prepare(Algo::FasterTuckerCoo, &cfg, &t).unwrap();
        let m0 = crate::model::ModelState::init(&cfg, 9);

        let mut m_pjrt = m0.clone();
        let mut st_pjrt = EngineState::new();
        let mut m_cpu = m0;
        let mut st_cpu = EngineState::new();
        for kind in [UpdateKind::Factor, UpdateKind::Core] {
            PjrtPassBackend::new().run_pass(PassRequest {
                model: &mut m_pjrt,
                storage: &storage,
                kind,
                cfg: &cfg,
                skip_refresh: false,
                runtime: None,
                state: &mut st_pjrt,
            });
            CpuShardBackend.run_pass(PassRequest {
                model: &mut m_cpu,
                storage: &storage,
                kind,
                cfg: &cfg,
                skip_refresh: false,
                runtime: None,
                state: &mut st_cpu,
            });
        }
        for n in 0..3 {
            assert_eq!(m_pjrt.factors[n].max_abs_diff(&m_cpu.factors[n]), 0.0);
            assert_eq!(m_pjrt.cores[n].max_abs_diff(&m_cpu.cores[n]), 0.0);
            assert_eq!(m_pjrt.c_tables[n].max_abs_diff(&m_cpu.c_tables[n]), 0.0);
        }
    }
}
