//! The hardened serving read path: concurrent batched top-k over
//! **delta-published**, copy-on-write snapshots of the `C` tables, scored
//! by the shared 8-lane SIMD dot kernel with exact norm-bound pruning.
//!
//! The paper's pitch is that a trained FastTucker model is tiny — the
//! factor/core state and the reusable tables `C^(n) = A^(n) B^(n)` fit in
//! memory next to training — so a decomposition can *serve* scores while it
//! keeps training. Mid-pass, though, the live `c_tables` are torn: the
//! engine refreshes them mode by mode, so a reader could combine a
//! just-updated `C^(0)` with a stale `C^(2)` and score against a state that
//! never existed. The serving layer therefore publishes an immutable
//! [`ServingSnapshot`] only at **epoch boundaries**. Three mechanisms make
//! that read path fleet-scale:
//!
//! * **Delta publication.** A snapshot stores each mode's table as
//!   [`BLOCK_ROWS`]-row blocks behind `Arc`s — the same word-aligned
//!   64-row granule the dirty-row refresh uses. `Session::epoch` publishes
//!   with [`ServingSnapshot::capture_delta`], which recopies only blocks
//!   containing rows in `ModelState::publish_dirty` and shares every clean
//!   block with the previous snapshot (an `Arc` clone, zero bytes). On a
//!   sparse-touch epoch the publish cost drops from `O(Σ_n I_n·R)` to the
//!   touched blocks; [`SnapshotStats`] makes the claim measurable.
//! * **SIMD scoring.** Block rows are stored rank-padded (stride
//!   [`crate::linalg::simd::pad_r`]`(R)`, pad lanes `+0.0` — the same
//!   padding contract as the engine's `EngineState`), so every candidate
//!   scores through [`crate::linalg::simd::dot_padded`] — the identical
//!   fixed-tree kernel the training engine's `fiber_w` fast path runs,
//!   bitwise-equal to its zero-extended scalar tail path by construction.
//!   [`ServingHandle::top_k_batch`] memoizes the chain vector across
//!   queries sharing `(mode, fixed)` and can fan a batch out over a leased
//!   executor worker subset ([`ServingHandle::set_executor`]).
//! * **Pruned selection.** Publication caches per-row Euclidean norms and
//!   per-block max-norms (accumulated in `f64`). A query keeps a size-k
//!   min-heap and skips any block — or row — whose Cauchy–Schwarz upper
//!   bound `max_norm · ‖v‖` (inflated by a rigorous rounding slack) cannot
//!   beat the current k-th score. Because blocks are scanned in ascending
//!   index order and ties break toward the lower index, a candidate can
//!   only enter the heap by *strictly* beating the k-th score, so the skip
//!   is **exact**, not approximate: the result is bitwise the exhaustive
//!   sort's ([`ServingSnapshot::top_k_exhaustive`]). The full
//!   `O(I log I)` sort becomes `O(I + k log k)` minus the skipped blocks;
//!   [`PruneStats`] exports the effectiveness counters.
//!
//! The publication protocol is unchanged:
//!
//! * [`crate::coordinator::Session::serving_handle`] captures the current
//!   state and returns a cloneable [`ServingHandle`];
//! * every completed [`crate::coordinator::Session::epoch`] publishes a
//!   fresh snapshot — the (delta) capture runs *outside* the publication
//!   lock, which is held only for the `Arc` swap;
//! * readers resolve a query batch against **one** snapshot — the model
//!   exactly as it was after the last completed epoch, never a torn
//!   mid-pass view. `tests/registry_serving.rs` proves the scores match a
//!   from-checkpoint recompute of that epoch bit for bit, while training
//!   steps run concurrently — which, since the recompute is a from-scratch
//!   [`ServingSnapshot::capture`], is also the proof that a chain of delta
//!   publications never serves a stale shared block.
//!
//! Scoring uses the paper's reusable-intermediate trick directly: for a
//! query that fixes every mode but one, the chain product
//! `v_r = Π_{m≠n} C^(m)[i_m, r]` is computed once and every candidate `i`
//! of the open mode scores as the dot `C^(n)[i, :] · v` — `O(I_n · R)` per
//! query instead of the full `Σ_r Π_n` per candidate (and less once the
//! norm bounds start skipping blocks).

use crate::linalg::simd;
use crate::linalg::Matrix;
use crate::model::ModelState;
use crate::sched::Executor;
use anyhow::{bail, Result};
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Rows per copy-on-write snapshot block: exactly one `DirtyRows` word, so
/// the delta-publication granule and the parallel-refresh granule are the
/// same word-aligned 64-row range.
pub const BLOCK_ROWS: usize = 64;

/// One top-k query: fix every mode except `mode`, rank that mode's indices.
#[derive(Clone, Debug)]
pub struct TopKQuery {
    /// The open mode whose indices are ranked.
    pub mode: usize,
    /// Coordinates of the other modes, in ascending mode order with `mode`
    /// skipped (the `infer` CLI's `--fixed i1,i2,..` convention).
    pub fixed: Vec<u32>,
    /// How many top-scoring indices to return.
    pub k: usize,
}

/// A ranked answer: the snapshot epoch it was computed against plus the
/// top-k `(index, score)` pairs, best first (ties broken by lower index).
#[derive(Clone, Debug)]
pub struct TopKResult {
    /// Global epoch of the snapshot that produced these scores.
    pub epoch: usize,
    /// `(index, predicted score)` pairs, descending score.
    pub items: Vec<(usize, f32)>,
}

/// How a snapshot publication was assembled — the measurable form of the
/// delta claim. `rows_copied + rows_shared` always equals the total row
/// count over every mode's `C` table.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SnapshotStats {
    /// Rows whose 64-row block was (re)copied into this snapshot.
    pub rows_copied: usize,
    /// Rows shared with the previous snapshot — an `Arc` clone of the
    /// block, zero bytes moved.
    pub rows_shared: usize,
    /// Bytes newly allocated by this publication (row data + norm caches
    /// of the copied blocks; shared blocks cost nothing).
    pub bytes: usize,
}

/// Pruning-effectiveness counters of one [`ServingSnapshot::top_k`]
/// evaluation. `blocks_skipped + blocks_scanned` equals the open mode's
/// block count (for `k > 0`); `rows_scored` is how many candidates
/// actually paid for a dot product.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Blocks skipped whole: their max-norm bound could not beat the
    /// current k-th score.
    pub blocks_skipped: usize,
    /// Blocks scanned row by row.
    pub blocks_scanned: usize,
    /// Rows inside scanned blocks skipped by the per-row norm bound.
    pub rows_pruned: usize,
    /// Rows scored with the SIMD dot kernel.
    pub rows_scored: usize,
}

/// One [`BLOCK_ROWS`]-row copy-on-write unit of a published `C` table:
/// rank-padded row data plus the norm cache the pruned top-k reads.
struct Block {
    /// Row-major rank-padded rows; row stride is the mode's padded rank,
    /// pad lanes `+0.0`.
    data: Vec<f32>,
    /// Per-row Euclidean norms, accumulated in `f64` at publish time so
    /// the pruning bound's own rounding is far below the `f32` slack.
    norms: Vec<f64>,
    /// `max(norms)` — the whole-block skip bound.
    max_norm: f64,
}

impl Block {
    /// Copy rows `[row_lo, row_hi)` of `table` into a padded block and
    /// compute its norm cache.
    fn build(table: &Matrix, row_lo: usize, row_hi: usize, stride: usize) -> Block {
        let r = table.cols();
        let rows = row_hi - row_lo;
        let mut data = vec![0.0f32; rows * stride];
        let mut norms = Vec::with_capacity(rows);
        let mut max_norm = 0.0f64;
        for (k, i) in (row_lo..row_hi).enumerate() {
            let src = table.row(i);
            data[k * stride..k * stride + r].copy_from_slice(src);
            let mut sq = 0.0f64;
            for &x in src {
                sq += f64::from(x) * f64::from(x);
            }
            let norm = sq.sqrt();
            max_norm = max_norm.max(norm);
            norms.push(norm);
        }
        Block { data, norms, max_norm }
    }

    /// Row `k` of this block (rank-padded, length `stride`).
    #[inline]
    fn row(&self, k: usize, stride: usize) -> &[f32] {
        &self.data[k * stride..(k + 1) * stride]
    }

    /// Heap bytes this block owns (the copy cost [`SnapshotStats`] counts).
    fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
            + self.norms.len() * std::mem::size_of::<f64>()
    }
}

/// One mode's published table: blocked, rank-padded, norm-cached.
struct ModeTable {
    /// Logical rows `I_n` (rankable indices).
    rows: usize,
    /// Logical rank R.
    r: usize,
    /// Row stride: `pad_r(r)`.
    stride: usize,
    /// `ceil(rows / BLOCK_ROWS)` blocks, shared with prior snapshots where
    /// clean.
    blocks: Vec<Arc<Block>>,
}

/// The rank-padded chain product of one query's fixed coordinates, plus
/// its `f64` norm (the query side of the pruning bound). Memoized across a
/// batch by [`ServingHandle::top_k_batch`].
struct ChainVec {
    /// `v_r = Π_{m≠mode} C^(m)[i_m, r]`, length = the open mode's stride.
    v: Vec<f32>,
    /// `‖v‖` over the logical R entries, accumulated in `f64`.
    norm: f64,
}

/// Multiplicative inflation of the Cauchy–Schwarz bound so it upper-bounds
/// the *computed* `f32` dot, not just the exact one: the classic forward
/// error of an n-term `f32` accumulation is `≤ γ_n·‖c‖‖v‖` with
/// `γ_n ≈ n·2⁻²⁴`; `32×` headroom also swallows the (much smaller) `f64`
/// norm rounding. Pruning with this slack can never drop a true winner.
#[inline]
fn prune_slack(stride: usize) -> f64 {
    1.0 + stride as f64 * 32.0 * f64::from(f32::EPSILON)
}

/// "Strictly weaker" under the serving total order: lower score, or an
/// equal score with a *higher* index — the exact mirror of the exhaustive
/// sort's descending `total_cmp` with the lower-index tie-break.
#[inline]
fn weaker(a: (f32, usize), b: (f32, usize)) -> bool {
    match a.0.total_cmp(&b.0) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => a.1 > b.1,
    }
}

/// Restore the min-heap property upward from leaf `i` (root = weakest).
fn heap_sift_up(heap: &mut [(f32, usize)], mut i: usize) {
    while i > 0 {
        let p = (i - 1) / 2;
        if weaker(heap[i], heap[p]) {
            heap.swap(i, p);
            i = p;
        } else {
            break;
        }
    }
}

/// Restore the min-heap property downward from node `i`.
fn heap_sift_down(heap: &mut [(f32, usize)], mut i: usize) {
    loop {
        let (l, r) = (2 * i + 1, 2 * i + 2);
        let mut m = i;
        if l < heap.len() && weaker(heap[l], heap[m]) {
            m = l;
        }
        if r < heap.len() && weaker(heap[r], heap[m]) {
            m = r;
        }
        if m == i {
            break;
        }
        heap.swap(i, m);
        i = m;
    }
}

/// An immutable, block-structured copy of the model's `C` tables as of one
/// completed epoch — the unit of consistency every read resolves against.
/// Blocks untouched since the previous publication are shared with it via
/// `Arc` ([`ServingSnapshot::capture_delta`]).
pub struct ServingSnapshot {
    epoch: usize,
    modes: Vec<ModeTable>,
    stats: SnapshotStats,
}

impl ServingSnapshot {
    /// Snapshot the model's current `C` tables from scratch, labelled with
    /// the global epoch they correspond to. Every row is copied
    /// (rank-padded) and norm-cached, so two captures of the same state
    /// score identically — this is also the reference the delta chain is
    /// tested against.
    pub fn capture(model: &ModelState, epoch: usize) -> ServingSnapshot {
        let mut stats = SnapshotStats::default();
        let modes = model
            .c_tables
            .iter()
            .map(|t| Self::full_mode(t, &mut stats))
            .collect();
        ServingSnapshot { epoch, modes, stats }
    }

    /// Delta publication: recopy only blocks containing rows marked in
    /// `model.publish_dirty` (the refresh paths maintain those sets; see
    /// [`ModelState::publish_dirty`]) and share every clean block with
    /// `prev` via `Arc`. A mode that **grew** since `prev` (online
    /// ingestion appending row indices) still delta-copies: every prev
    /// block that covers the same row range in the grown table and is
    /// clean is shared, and only the partial tail plus the brand-new
    /// blocks are built. Falls back to a full per-mode copy when the rank
    /// changed or the mode shrank. Scores bitwise like
    /// [`ServingSnapshot::capture`] of the same state — by the soundness
    /// invariant that every `C` mutation since `prev` was published is
    /// recorded in `publish_dirty` (grown rows are marked at grow time).
    ///
    /// The caller owns the clear: after publishing the returned snapshot,
    /// call [`ModelState::clear_publish_dirty`]. Clearing without
    /// publishing would let the *next* delta share blocks that were never
    /// copied out; forgetting to clear merely over-copies.
    pub fn capture_delta(
        model: &ModelState,
        epoch: usize,
        prev: &ServingSnapshot,
    ) -> ServingSnapshot {
        if prev.modes.len() != model.c_tables.len() {
            return Self::capture(model, epoch);
        }
        let mut stats = SnapshotStats::default();
        let mut modes = Vec::with_capacity(model.c_tables.len());
        for (n, table) in model.c_tables.iter().enumerate() {
            let prev_mode = &prev.modes[n];
            let (rows, r) = (table.rows(), table.cols());
            if prev_mode.r != r || prev_mode.rows > rows {
                modes.push(Self::full_mode(table, &mut stats));
                continue;
            }
            let dirty = &model.publish_dirty[n];
            let stride = prev_mode.stride;
            let nblocks = crate::util::ceil_div(rows, BLOCK_ROWS);
            let mut blocks = Vec::with_capacity(nblocks);
            for b in 0..nblocks {
                let lo = b * BLOCK_ROWS;
                let hi = (lo + BLOCK_ROWS).min(rows);
                // shareable iff the prev block holds exactly this row range
                // (false for the old partial tail of a grown mode, whose
                // range now extends past what prev copied) and no row in it
                // was republished-dirty since `prev`
                let shareable = hi <= prev_mode.rows
                    && b < prev_mode.blocks.len()
                    && !dirty.word_dirty(b);
                if shareable {
                    stats.rows_shared += hi - lo;
                    blocks.push(Arc::clone(&prev_mode.blocks[b]));
                } else {
                    let blk = Block::build(table, lo, hi, stride);
                    stats.rows_copied += hi - lo;
                    stats.bytes += blk.bytes();
                    blocks.push(Arc::new(blk));
                }
            }
            modes.push(ModeTable { rows, r, stride, blocks });
        }
        ServingSnapshot { epoch, modes, stats }
    }

    /// Build one mode's table from scratch, charging every block to
    /// `stats`.
    fn full_mode(table: &Matrix, stats: &mut SnapshotStats) -> ModeTable {
        let (rows, r) = (table.rows(), table.cols());
        let stride = simd::pad_r(r);
        let nblocks = crate::util::ceil_div(rows, BLOCK_ROWS);
        let mut blocks = Vec::with_capacity(nblocks);
        let mut lo = 0;
        while lo < rows {
            let hi = (lo + BLOCK_ROWS).min(rows);
            let blk = Block::build(table, lo, hi, stride);
            stats.rows_copied += hi - lo;
            stats.bytes += blk.bytes();
            blocks.push(Arc::new(blk));
            lo = hi;
        }
        ModeTable { rows, r, stride, blocks }
    }

    /// Global epoch this snapshot reflects.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Tensor order N.
    pub fn order(&self) -> usize {
        self.modes.len()
    }

    /// Size of mode `n` (number of rankable indices).
    pub fn dim(&self, n: usize) -> usize {
        self.modes[n].rows
    }

    /// How this snapshot's publication was assembled (copied vs shared
    /// rows, bytes actually moved) — a from-scratch
    /// [`ServingSnapshot::capture`] reports everything copied, a
    /// [`ServingSnapshot::capture_delta`] only the stale blocks.
    pub fn stats(&self) -> SnapshotStats {
        self.stats
    }

    /// The published, rank-padded row `C^(mode)[i, :]`: length is the
    /// mode's padded stride, lanes past R are `+0.0`. This is the exact
    /// data the scorer reads, so bitwise-comparing published rows is how
    /// the delta-vs-scratch tests prove block sharing never serves stale
    /// values.
    pub fn c_row(&self, mode: usize, i: usize) -> &[f32] {
        let mt = &self.modes[mode];
        mt.blocks[i / BLOCK_ROWS].row(i % BLOCK_ROWS, mt.stride)
    }

    /// Validate a `(mode, fixed)` pair and build its chain vector.
    fn chain(&self, mode: usize, fixed: &[u32]) -> Result<ChainVec> {
        let order = self.order();
        if mode >= order {
            bail!("query mode {mode} out of range for order {order}");
        }
        if fixed.len() != order - 1 {
            bail!(
                "query fixes {} coordinates, order-{order} needs {}",
                fixed.len(),
                order - 1
            );
        }
        let open = &self.modes[mode];
        let mut v = vec![1.0f32; open.stride];
        let mut k = 0;
        for m in 0..order {
            if m == mode {
                continue;
            }
            let c = fixed[k] as usize;
            k += 1;
            let mt = &self.modes[m];
            if c >= mt.rows {
                bail!("fixed coordinate {c} out of range for mode {m}");
            }
            // every mode shares R, hence the stride: multiplying by a
            // padded row zeroes the pad lanes after the first fixed mode
            let row = mt.blocks[c / BLOCK_ROWS].row(c % BLOCK_ROWS, mt.stride);
            for (vr, cr) in v.iter_mut().zip(row) {
                *vr *= *cr;
            }
        }
        let mut sq = 0.0f64;
        for &x in &v[..open.r] {
            sq += f64::from(x) * f64::from(x);
        }
        Ok(ChainVec { v, norm: sq.sqrt() })
    }

    /// Score every index of `query.mode` with the other coordinates fixed:
    /// chain the fixed modes' `C` rows into `v`, then dot each candidate
    /// row of `C^(mode)` against it with the SIMD kernel. Returns the full
    /// score vector (no pruning — this is the scorer behind the exhaustive
    /// reference path).
    pub fn score_mode(&self, query: &TopKQuery) -> Result<Vec<f32>> {
        let chain = self.chain(query.mode, &query.fixed)?;
        let mt = &self.modes[query.mode];
        let mut out = Vec::with_capacity(mt.rows);
        for blk in &mt.blocks {
            for k in 0..blk.norms.len() {
                out.push(simd::dot_padded(blk.row(k, mt.stride), &chain.v));
            }
        }
        Ok(out)
    }

    /// Answer one top-k query against this snapshot through the pruned
    /// heap path. Deterministic: descending score with ties broken by
    /// lower index — bitwise the answer of
    /// [`ServingSnapshot::top_k_exhaustive`].
    pub fn top_k(&self, query: &TopKQuery) -> Result<TopKResult> {
        self.top_k_with_stats(query).map(|(res, _)| res)
    }

    /// [`ServingSnapshot::top_k`] plus the pruning-effectiveness counters
    /// of this evaluation.
    pub fn top_k_with_stats(&self, query: &TopKQuery) -> Result<(TopKResult, PruneStats)> {
        let chain = self.chain(query.mode, &query.fixed)?;
        Ok(self.top_k_prepared(query, &chain))
    }

    /// Reference top-k: score every candidate, fully sort, truncate. Same
    /// result as [`ServingSnapshot::top_k`] bit for bit — the oracle the
    /// pruned path is property-tested against, and the "full" side of
    /// `benches/serving.rs`.
    pub fn top_k_exhaustive(&self, query: &TopKQuery) -> Result<TopKResult> {
        let scores = self.score_mode(query)?;
        let k = query.k.min(scores.len());
        let mut ranked: Vec<(usize, f32)> = scores.into_iter().enumerate().collect();
        ranked.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        ranked.truncate(k);
        Ok(TopKResult { epoch: self.epoch, items: ranked })
    }

    /// The infallible core: query already validated, chain vector in hand.
    fn top_k_prepared(&self, query: &TopKQuery, chain: &ChainVec) -> (TopKResult, PruneStats) {
        let mut stats = PruneStats::default();
        let items = self.top_k_pruned(query.mode, query.k, chain, &mut stats);
        (TopKResult { epoch: self.epoch, items }, stats)
    }

    /// Norm-bound-pruned heap selection over the open mode's blocks.
    ///
    /// Exactness argument: blocks are scanned in ascending index order, so
    /// every new candidate's index exceeds every heap entry's. Under the
    /// tie-break (equal scores rank the lower index first) a candidate can
    /// therefore only displace the weakest heap entry by scoring
    /// *strictly* above the k-th score; any row whose inflated
    /// Cauchy–Schwarz bound `‖c‖·‖v‖·slack` is `≤` that score — and a
    /// fortiori any block whose max-norm bound is — cannot, so skipping it
    /// cannot change the answer.
    fn top_k_pruned(
        &self,
        mode: usize,
        k: usize,
        chain: &ChainVec,
        stats: &mut PruneStats,
    ) -> Vec<(usize, f32)> {
        let mt = &self.modes[mode];
        let k = k.min(mt.rows);
        if k == 0 {
            // satellite fix: no allocation, no scan, no sort for k = 0
            return Vec::new();
        }
        let slack = prune_slack(mt.stride);
        let mut heap: Vec<(f32, usize)> = Vec::with_capacity(k);
        for (b, blk) in mt.blocks.iter().enumerate() {
            if heap.len() == k && blk.max_norm * chain.norm * slack <= f64::from(heap[0].0) {
                stats.blocks_skipped += 1;
                continue;
            }
            stats.blocks_scanned += 1;
            let base = b * BLOCK_ROWS;
            for (kk, &norm) in blk.norms.iter().enumerate() {
                if heap.len() == k && norm * chain.norm * slack <= f64::from(heap[0].0) {
                    stats.rows_pruned += 1;
                    continue;
                }
                let s = simd::dot_padded(blk.row(kk, mt.stride), &chain.v);
                stats.rows_scored += 1;
                let cand = (s, base + kk);
                if heap.len() < k {
                    heap.push(cand);
                    heap_sift_up(&mut heap, heap.len() - 1);
                } else if weaker(heap[0], cand) {
                    heap[0] = cand;
                    heap_sift_down(&mut heap, 0);
                }
            }
        }
        // drain weakest-first into the tail: O(k log k), best lands first
        let mut out = vec![(0usize, 0.0f32); heap.len()];
        for slot in out.iter_mut().rev() {
            *slot = (heap[0].1, heap[0].0);
            let last = heap.pop().expect("heap drains one per slot");
            if !heap.is_empty() {
                heap[0] = last;
                heap_sift_down(&mut heap, 0);
            }
        }
        out
    }
}

/// The publication slot shared between a training session (writer) and its
/// cloned handles (readers): one `Arc` swap per completed epoch.
pub(crate) struct ServingShared {
    snap: Mutex<Arc<ServingSnapshot>>,
}

impl ServingShared {
    pub(crate) fn new(snapshot: ServingSnapshot) -> ServingShared {
        ServingShared { snap: Mutex::new(Arc::new(snapshot)) }
    }

    /// Publish a new epoch snapshot (called by the session at the end of
    /// every completed epoch). The snapshot arrives pre-built — capture
    /// (and the `Arc` allocation) happen in the caller, so the lock is
    /// held **only for the pointer swap**; even the previous snapshot's
    /// drop (potentially the last reference to many blocks) runs outside
    /// the critical section. Readers holding the previous `Arc` keep a
    /// consistent view until they next resolve.
    pub(crate) fn publish(&self, snapshot: Arc<ServingSnapshot>) {
        let prev = {
            let mut slot = self.snap.lock().unwrap();
            std::mem::replace(&mut *slot, snapshot)
        };
        drop(prev);
    }

    /// The latest published snapshot — also the `prev` a delta capture
    /// shares clean blocks with.
    pub(crate) fn current(&self) -> Arc<ServingSnapshot> {
        self.snap.lock().unwrap().clone()
    }
}

/// A cloneable, thread-safe reader over a session's published snapshots.
///
/// Cheap to clone (`Arc`s); hand one to every serving thread. All queries
/// of a [`ServingHandle::top_k_batch`] call resolve against a single
/// snapshot, so a batch is internally consistent even while the owning
/// session trains concurrently. A batch memoizes the chain vector across
/// queries sharing `(mode, fixed)`, and can fan out over a leased worker
/// subset of a shared [`Executor`] ([`ServingHandle::set_executor`]) —
/// results are identical at any worker count.
///
/// # Examples
///
/// ```
/// use fastertucker::config::TrainConfig;
/// use fastertucker::coordinator::{ServingHandle, TopKQuery};
/// use fastertucker::model::ModelState;
///
/// let cfg = TrainConfig {
///     order: 3, dims: vec![6, 5, 4], j: 4, r: 2, ..TrainConfig::default()
/// };
/// let model = ModelState::init(&cfg, 7);
/// let handle = ServingHandle::from_model(&model);
/// let top = handle
///     .top_k(&TopKQuery { mode: 1, fixed: vec![0, 3], k: 3 })
///     .unwrap();
/// assert_eq!(top.items.len(), 3);
/// assert!(top.items[0].1 >= top.items[1].1);
/// ```
#[derive(Clone)]
pub struct ServingHandle {
    shared: Arc<ServingShared>,
    /// Batch fan-out pool; `None` answers batches on the calling thread.
    executor: Option<Arc<Executor>>,
    /// Lease size for batch fan-out; `0` requests the full budget.
    lease_workers: usize,
}

impl ServingHandle {
    pub(crate) fn from_shared(shared: Arc<ServingShared>) -> ServingHandle {
        ServingHandle { shared, executor: None, lease_workers: 0 }
    }

    /// A standalone handle over a fixed model state (no live training
    /// session) — the `infer` CLI path, serving straight from a loaded
    /// checkpoint. The snapshot is labelled epoch 0.
    pub fn from_model(model: &ModelState) -> ServingHandle {
        ServingHandle {
            shared: Arc::new(ServingShared::new(ServingSnapshot::capture(model, 0))),
            executor: None,
            lease_workers: 0,
        }
    }

    /// Fan [`ServingHandle::top_k_batch`] out over a leased subset of
    /// `executor`'s worker budget: each batch takes **one** lease of
    /// `workers` slots (`0` = the full budget), splits the queries into
    /// contiguous per-worker chunks via [`Executor::run_indexed`], and
    /// releases the lease when the batch returns — so serving shares the
    /// registry's pool with training passes without touching their budget
    /// guarantees (leases are disjoint and FIFO-fair). Answers are
    /// **identical at any worker count**: each query is resolved
    /// independently against the one batch snapshot, with the memoized
    /// chain vectors computed before the fan-out. The setting is
    /// per-handle: clones taken before this call keep serving on the
    /// caller's thread.
    pub fn set_executor(&mut self, executor: Arc<Executor>, workers: usize) {
        self.executor = Some(executor);
        self.lease_workers = workers;
    }

    /// The most recently published snapshot. Holding the returned `Arc`
    /// pins that epoch's view for as long as the caller needs it.
    pub fn snapshot(&self) -> Arc<ServingSnapshot> {
        self.shared.current()
    }

    /// Global epoch of the most recently published snapshot.
    pub fn epoch(&self) -> usize {
        self.snapshot().epoch
    }

    /// Answer one query against the latest snapshot.
    pub fn top_k(&self, query: &TopKQuery) -> Result<TopKResult> {
        self.snapshot().top_k(query)
    }

    /// Answer a whole batch against **one** snapshot: every result carries
    /// the same epoch, so the batch can never mix two model states. The
    /// chain vector is computed once per distinct `(mode, fixed)` in the
    /// batch (the `infer` CLI's repeated-user batches hit this hard), and
    /// scoring fans out over a leased worker subset when
    /// [`ServingHandle::set_executor`] configured one. Any malformed query
    /// fails the whole batch before any scoring work starts.
    pub fn top_k_batch(&self, queries: &[TopKQuery]) -> Result<Vec<TopKResult>> {
        let snap = self.snapshot();
        // memoize chain vectors across queries sharing (mode, fixed) —
        // also the validation pass, so the parallel region is infallible
        let mut chains: Vec<ChainVec> = Vec::new();
        let mut chain_of: Vec<usize> = Vec::with_capacity(queries.len());
        let mut memo: HashMap<(usize, &[u32]), usize> = HashMap::new();
        for q in queries {
            let id = match memo.entry((q.mode, q.fixed.as_slice())) {
                Entry::Occupied(e) => *e.get(),
                Entry::Vacant(e) => {
                    let id = chains.len();
                    chains.push(snap.chain(q.mode, &q.fixed)?);
                    e.insert(id);
                    id
                }
            };
            chain_of.push(id);
        }
        let mut slots: Vec<Option<TopKResult>> = Vec::new();
        slots.resize_with(queries.len(), || None);
        let run = |i: usize, slot: &mut Option<TopKResult>| {
            let (res, _) = snap.top_k_prepared(&queries[i], &chains[chain_of[i]]);
            *slot = Some(res);
        };
        match &self.executor {
            Some(ex) if queries.len() > 1 => {
                let n = if self.lease_workers == 0 {
                    ex.workers()
                } else {
                    self.lease_workers
                };
                // Non-blocking admission: a reader batch never queues
                // behind the training FIFO line — if no lease is grantable
                // right now it degrades to an inline scan (identical
                // answers, bounded latency) instead of waiting for a flood
                // of queued training passes to drain.
                ex.run_indexed_nonblocking(n, &mut slots, run);
            }
            _ => {
                for (i, slot) in slots.iter_mut().enumerate() {
                    run(i, slot);
                }
            }
        }
        Ok(slots
            .into_iter()
            .map(|s| s.expect("every query answered"))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::util::rng::Rng;

    fn model() -> ModelState {
        let cfg = TrainConfig {
            order: 3,
            dims: vec![8, 6, 4],
            j: 4,
            r: 3,
            ..TrainConfig::default()
        };
        ModelState::init(&cfg, 11)
    }

    /// A model big enough to span several 64-row blocks, with signed
    /// factors so scores go negative.
    fn big_signed_model(seed: u64, r: usize) -> ModelState {
        let cfg = TrainConfig {
            order: 3,
            dims: vec![167, 80, 40],
            j: 6,
            r,
            ..TrainConfig::default()
        };
        let mut m = ModelState::init(&cfg, seed);
        let mut rng = Rng::new(seed ^ 0xBEEF);
        for f in &mut m.factors {
            for x in f.data_mut() {
                *x = rng.uniform_f32(-1.0, 1.0);
            }
        }
        m.refresh_all_c();
        m
    }

    fn assert_items_bitwise(a: &TopKResult, b: &TopKResult, what: &str) {
        assert_eq!(a.epoch, b.epoch, "{what}: epoch");
        assert_eq!(a.items.len(), b.items.len(), "{what}: length");
        for (x, y) in a.items.iter().zip(b.items.iter()) {
            assert_eq!(x.0, y.0, "{what}: index");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "{what}: score bits");
        }
    }

    #[test]
    fn scores_match_model_predict() {
        let m = model();
        let snap = ServingSnapshot::capture(&m, 5);
        assert_eq!(snap.epoch(), 5);
        assert_eq!(snap.order(), 3);
        assert_eq!(snap.dim(1), 6);
        let q = TopKQuery { mode: 1, fixed: vec![2, 3], k: 6 };
        let scores = snap.score_mode(&q).unwrap();
        for (i, &s) in scores.iter().enumerate() {
            let direct = m.predict(&[2, i as u32, 3]);
            assert!(
                (s - direct).abs() < 1e-5,
                "index {i}: serving {s} vs predict {direct}"
            );
        }
    }

    #[test]
    fn top_k_is_sorted_and_truncated() {
        let m = model();
        let handle = ServingHandle::from_model(&m);
        let res = handle.top_k(&TopKQuery { mode: 0, fixed: vec![1, 2], k: 3 }).unwrap();
        assert_eq!(res.items.len(), 3);
        assert!(res.items[0].1 >= res.items[1].1);
        assert!(res.items[1].1 >= res.items[2].1);
        // k beyond the dim clamps to the dim
        let all = handle.top_k(&TopKQuery { mode: 2, fixed: vec![0, 0], k: 99 }).unwrap();
        assert_eq!(all.items.len(), 4);
        // k = 0 short-circuits to an empty (but epoch-labelled) result
        let none = handle.top_k(&TopKQuery { mode: 2, fixed: vec![0, 0], k: 0 }).unwrap();
        assert!(none.items.is_empty());
        assert_eq!(none.epoch, 0);
    }

    #[test]
    fn k_zero_does_no_scoring_work() {
        let m = model();
        let snap = ServingSnapshot::capture(&m, 0);
        let (res, stats) = snap
            .top_k_with_stats(&TopKQuery { mode: 0, fixed: vec![0, 0], k: 0 })
            .unwrap();
        assert!(res.items.is_empty());
        assert_eq!(stats, PruneStats::default(), "k=0 must not scan or score");
        // malformed queries still error even at k = 0
        assert!(snap.top_k(&TopKQuery { mode: 9, fixed: vec![0, 0], k: 0 }).is_err());
    }

    #[test]
    fn pruned_matches_exhaustive_and_counts_prunes() {
        let m = big_signed_model(21, 8);
        let snap = ServingSnapshot::capture(&m, 3);
        let q = TopKQuery { mode: 0, fixed: vec![7, 31], k: 5 };
        let pruned = snap.top_k(&q).unwrap();
        let exhaustive = snap.top_k_exhaustive(&q).unwrap();
        assert_items_bitwise(&pruned, &exhaustive, "pruned vs exhaustive");
        let (_, stats) = snap.top_k_with_stats(&q).unwrap();
        let nblocks = crate::util::ceil_div(snap.dim(0), BLOCK_ROWS);
        assert_eq!(stats.blocks_scanned + stats.blocks_skipped, nblocks);
        // every candidate is scored, row-pruned, or inside a skipped block
        assert!(stats.rows_scored + stats.rows_pruned <= snap.dim(0));
        assert!(stats.rows_scored >= q.k, "at least k rows must be scored");
    }

    #[test]
    fn delta_capture_shares_clean_blocks_and_matches_scratch() {
        let mut m = big_signed_model(31, 5);
        let prev = ServingSnapshot::capture(&m, 1);
        m.clear_publish_dirty();

        // touch rows 3 and 70 of mode 0: blocks 0 and 1 go stale, block 2
        // (rows 128..167) and every other mode stay clean
        m.dirty[0].ensure(m.factors[0].rows());
        for row in [3usize, 70] {
            m.factors[0].row_mut(row)[0] += 0.5;
            m.dirty[0].mark(row);
        }
        m.refresh_c_dirty(0, None);

        let delta = ServingSnapshot::capture_delta(&m, 2, &prev);
        let scratch = ServingSnapshot::capture(&m, 2);
        for n in 0..m.order() {
            for i in 0..delta.dim(n) {
                let (a, b) = (delta.c_row(n, i), scratch.c_row(n, i));
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "mode {n} row {i}");
                }
            }
        }
        // block sharing is physical: clean blocks are the same allocation
        assert!(
            Arc::ptr_eq(&delta.modes[0].blocks[2], &prev.modes[0].blocks[2]),
            "clean block must be shared, not recopied"
        );
        assert!(!Arc::ptr_eq(&delta.modes[0].blocks[0], &prev.modes[0].blocks[0]));
        assert!(!Arc::ptr_eq(&delta.modes[0].blocks[1], &prev.modes[0].blocks[1]));
        for n in 1..3 {
            for (db, pb) in delta.modes[n].blocks.iter().zip(&prev.modes[n].blocks) {
                assert!(Arc::ptr_eq(db, pb), "untouched mode {n} fully shared");
            }
        }
        // and the accounting matches: blocks 0+1 of mode 0 recopied
        let st = delta.stats();
        assert_eq!(st.rows_copied, 128);
        assert_eq!(st.rows_shared, (167 - 128) + 80 + 40);
        assert!(st.bytes > 0 && st.bytes < scratch.stats().bytes);
        // a from-scratch capture reports everything copied
        assert_eq!(scratch.stats().rows_shared, 0);
        assert_eq!(scratch.stats().rows_copied, 167 + 80 + 40);
    }

    #[test]
    fn delta_capture_full_copies_on_shape_change_or_all_flag() {
        let m = big_signed_model(41, 5);
        let prev = ServingSnapshot::capture(&m, 1);

        // whole-mode invalidation (e.g. a core step): no sharing for it
        let mut m2 = m.clone();
        m2.clear_publish_dirty();
        m2.cores[1].row_mut(0)[0] += 0.25;
        m2.refresh_c(1);
        let delta = ServingSnapshot::capture_delta(&m2, 2, &prev);
        assert_eq!(delta.stats().rows_copied, 80, "mode 1 fully recopied");
        assert_eq!(delta.stats().rows_shared, 167 + 40);

        // a differently-shaped model falls back to a full capture
        let other = model();
        let full = ServingSnapshot::capture_delta(&other, 2, &prev);
        assert_eq!(full.stats().rows_shared, 0);
        assert_eq!(full.stats().rows_copied, 8 + 6 + 4);
    }

    #[test]
    fn grown_mode_delta_copies_only_touched_blocks() {
        let mut m = big_signed_model(61, 5);
        let prev = ServingSnapshot::capture(&m, 1);
        m.clear_publish_dirty();

        // grow mode 0 from 167 to 230 rows: blocks 0 and 1 (full, clean)
        // must be shared; the old partial tail (block 2, rows 128..167)
        // and the new block 3 must be rebuilt
        m.grow_mode(0, 230, 61);
        let delta = ServingSnapshot::capture_delta(&m, 2, &prev);
        let scratch = ServingSnapshot::capture(&m, 2);
        assert_eq!(delta.dim(0), 230);
        for n in 0..m.order() {
            for i in 0..delta.dim(n) {
                let (a, b) = (delta.c_row(n, i), scratch.c_row(n, i));
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "mode {n} row {i}");
                }
            }
        }
        assert!(Arc::ptr_eq(&delta.modes[0].blocks[0], &prev.modes[0].blocks[0]));
        assert!(Arc::ptr_eq(&delta.modes[0].blocks[1], &prev.modes[0].blocks[1]));
        assert!(!Arc::ptr_eq(&delta.modes[0].blocks[2], &prev.modes[0].blocks[2]));
        assert_eq!(delta.modes[0].blocks.len(), 4);
        for n in 1..3 {
            for (db, pb) in delta.modes[n].blocks.iter().zip(&prev.modes[n].blocks) {
                assert!(Arc::ptr_eq(db, pb), "untouched mode {n} fully shared");
            }
        }
        // accounting: mode 0 recopies rows 128..230, shares 0..128
        let st = delta.stats();
        assert_eq!(st.rows_copied, 230 - 128);
        assert_eq!(st.rows_shared, 128 + 80 + 40);

        // pruned top-k over the grown mode (winners can sit in the new
        // rows) still matches the exhaustive oracle bitwise
        let q = TopKQuery { mode: 0, fixed: vec![7, 13], k: 9 };
        let pruned = delta.top_k(&q).unwrap();
        let exhaustive = delta.top_k_exhaustive(&q).unwrap();
        assert_items_bitwise(&pruned, &exhaustive, "grown-mode top-k");
    }

    #[test]
    fn batch_resolves_against_one_snapshot() {
        let m = model();
        let shared = Arc::new(ServingShared::new(ServingSnapshot::capture(&m, 1)));
        let handle = ServingHandle::from_shared(shared.clone());
        let qs = vec![
            TopKQuery { mode: 0, fixed: vec![0, 0], k: 2 },
            TopKQuery { mode: 1, fixed: vec![5, 1], k: 2 },
        ];
        let res = handle.top_k_batch(&qs).unwrap();
        assert!(res.iter().all(|r| r.epoch == 1));
        // a publish between batches moves the epoch; within a batch it can't
        shared.publish(Arc::new(ServingSnapshot::capture(&m, 2)));
        assert_eq!(handle.epoch(), 2);
    }

    #[test]
    fn batch_memoizes_duplicates_and_fans_out_identically() {
        let m = big_signed_model(51, 8);
        let handle = ServingHandle::from_model(&m);
        // heavy (mode, fixed) duplication — the memoized shape
        let mut qs = Vec::new();
        for i in 0..12u32 {
            qs.push(TopKQuery { mode: 0, fixed: vec![i % 3, 7], k: 4 });
            qs.push(TopKQuery { mode: 1, fixed: vec![9, i % 2], k: 6 });
        }
        let serial = handle.top_k_batch(&qs).unwrap();
        // duplicates must answer identically
        assert_items_bitwise(&serial[0], &serial[6], "duplicate queries");
        for workers in [1usize, 2, 3] {
            let ex = Arc::new(Executor::new(3));
            let mut fanned = handle.clone();
            fanned.set_executor(ex.clone(), workers);
            let par = fanned.top_k_batch(&qs).unwrap();
            for (a, b) in serial.iter().zip(par.iter()) {
                assert_items_bitwise(a, b, &format!("fan-out ×{workers}"));
            }
            // the batch took exactly one lease and no training pass
            assert_eq!(ex.leases_granted(), 1);
            assert_eq!(ex.passes_executed(), 0);
            assert_eq!(ex.concurrent_leases(), 0, "lease released");
        }
        // the pre-set_executor clone still answers serially and identically
        let again = handle.top_k_batch(&qs).unwrap();
        for (a, b) in serial.iter().zip(again.iter()) {
            assert_items_bitwise(a, b, "serial reproducibility");
        }
    }

    #[test]
    fn malformed_queries_are_errors() {
        let handle = ServingHandle::from_model(&model());
        assert!(handle.top_k(&TopKQuery { mode: 3, fixed: vec![0, 0], k: 1 }).is_err());
        assert!(handle.top_k(&TopKQuery { mode: 0, fixed: vec![0], k: 1 }).is_err());
        assert!(handle
            .top_k(&TopKQuery { mode: 0, fixed: vec![0, 99], k: 1 })
            .is_err());
        // one malformed query fails the whole batch
        let qs = vec![
            TopKQuery { mode: 0, fixed: vec![0, 0], k: 1 },
            TopKQuery { mode: 0, fixed: vec![0, 99], k: 1 },
        ];
        assert!(handle.top_k_batch(&qs).is_err());
    }

    #[test]
    fn readers_see_published_epochs_not_torn_state() {
        let m = model();
        let shared = Arc::new(ServingShared::new(ServingSnapshot::capture(&m, 0)));
        let handle = ServingHandle::from_shared(shared.clone());
        let pinned = handle.snapshot();
        shared.publish(Arc::new(ServingSnapshot::capture(&m, 1)));
        // the pinned Arc still reads epoch 0; a fresh resolve sees epoch 1
        assert_eq!(pinned.epoch(), 0);
        assert_eq!(handle.epoch(), 1);
    }
}
