//! The hardened serving path: concurrent batched top-k over the `C` tables
//! with **epoch-snapshot** semantics.
//!
//! The paper's pitch is that a trained FastTucker model is tiny — the
//! factor/core state and the reusable tables `C^(n) = A^(n) B^(n)` fit in
//! memory next to training — so a decomposition can *serve* scores while it
//! keeps training. Mid-pass, though, the live `c_tables` are torn: the
//! engine refreshes them mode by mode, so a reader could combine a
//! just-updated `C^(0)` with a stale `C^(2)` and score against a state that
//! never existed. The serving layer therefore publishes an immutable
//! [`ServingSnapshot`] only at **epoch boundaries**:
//!
//! * [`crate::coordinator::Session::serving_handle`] captures the current
//!   state and returns a cloneable [`ServingHandle`];
//! * every completed [`crate::coordinator::Session::epoch`] publishes a
//!   fresh snapshot (an atomic `Arc` swap under a short mutex);
//! * readers resolve a query batch against **one** snapshot — the model
//!   exactly as it was after the last completed epoch, never a torn
//!   mid-pass view. `tests/registry_serving.rs` proves the scores match a
//!   from-checkpoint recompute of that epoch bit for bit, while training
//!   steps run concurrently.
//!
//! Scoring uses the paper's reusable-intermediate trick directly: for a
//! query that fixes every mode but one, the chain product
//! `v_r = Π_{m≠n} C^(m)[i_m, r]` is computed once and every candidate `i`
//! of the open mode scores as the dot `C^(n)[i, :] · v` — `O(I_n · R)` per
//! query instead of the full `Σ_r Π_n` per candidate.

use crate::linalg::Matrix;
use crate::model::ModelState;
use anyhow::{bail, Result};
use std::sync::{Arc, Mutex};

/// One top-k query: fix every mode except `mode`, rank that mode's indices.
#[derive(Clone, Debug)]
pub struct TopKQuery {
    /// The open mode whose indices are ranked.
    pub mode: usize,
    /// Coordinates of the other modes, in ascending mode order with `mode`
    /// skipped (the `infer` CLI's `--fixed i1,i2,..` convention).
    pub fixed: Vec<u32>,
    /// How many top-scoring indices to return.
    pub k: usize,
}

/// A ranked answer: the snapshot epoch it was computed against plus the
/// top-k `(index, score)` pairs, best first (ties broken by lower index).
#[derive(Clone, Debug)]
pub struct TopKResult {
    /// Global epoch of the snapshot that produced these scores.
    pub epoch: usize,
    /// `(index, predicted score)` pairs, descending score.
    pub items: Vec<(usize, f32)>,
}

/// An immutable copy of the model's `C` tables as of one completed epoch —
/// the unit of consistency every read resolves against.
pub struct ServingSnapshot {
    epoch: usize,
    c_tables: Vec<Matrix>,
}

impl ServingSnapshot {
    /// Snapshot the model's current `C` tables, labelled with the global
    /// epoch they correspond to. The tables are copied bit-for-bit, so two
    /// captures of the same state score identically.
    pub fn capture(model: &ModelState, epoch: usize) -> ServingSnapshot {
        ServingSnapshot { epoch, c_tables: model.c_tables.clone() }
    }

    /// Global epoch this snapshot reflects.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Tensor order N.
    pub fn order(&self) -> usize {
        self.c_tables.len()
    }

    /// Size of mode `n` (number of rankable indices).
    pub fn dim(&self, n: usize) -> usize {
        self.c_tables[n].rows()
    }

    /// Score every index of `query.mode` with the other coordinates fixed:
    /// chain the fixed modes' `C` rows into `v`, then dot each candidate
    /// row of `C^(mode)` against it. Returns the full score vector.
    pub fn score_mode(&self, query: &TopKQuery) -> Result<Vec<f32>> {
        let order = self.order();
        let TopKQuery { mode, fixed, .. } = query;
        if *mode >= order {
            bail!("query mode {mode} out of range for order {order}");
        }
        if fixed.len() != order - 1 {
            bail!(
                "query fixes {} coordinates, order-{order} needs {}",
                fixed.len(),
                order - 1
            );
        }
        let r = self.c_tables[*mode].cols();
        let mut v = vec![1.0f32; r];
        let mut k = 0;
        for m in 0..order {
            if m == *mode {
                continue;
            }
            let c = fixed[k] as usize;
            k += 1;
            if c >= self.c_tables[m].rows() {
                bail!("fixed coordinate {c} out of range for mode {m}");
            }
            for (vr, cr) in v.iter_mut().zip(self.c_tables[m].row(c)) {
                *vr *= *cr;
            }
        }
        let table = &self.c_tables[*mode];
        Ok((0..table.rows())
            .map(|i| crate::linalg::dot(table.row(i), &v))
            .collect())
    }

    /// Answer one top-k query against this snapshot. Deterministic:
    /// descending score with ties broken by lower index.
    pub fn top_k(&self, query: &TopKQuery) -> Result<TopKResult> {
        let scores = self.score_mode(query)?;
        let mut ranked: Vec<(usize, f32)> = scores.into_iter().enumerate().collect();
        ranked.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        ranked.truncate(query.k);
        Ok(TopKResult { epoch: self.epoch, items: ranked })
    }
}

/// The publication slot shared between a training session (writer) and its
/// cloned handles (readers): one `Arc` swap per completed epoch.
pub(crate) struct ServingShared {
    snap: Mutex<Arc<ServingSnapshot>>,
}

impl ServingShared {
    pub(crate) fn new(snapshot: ServingSnapshot) -> ServingShared {
        ServingShared { snap: Mutex::new(Arc::new(snapshot)) }
    }

    /// Publish a new epoch snapshot (called by the session at the end of
    /// every completed epoch). Readers holding the previous `Arc` keep a
    /// consistent view until they next resolve.
    pub(crate) fn publish(&self, snapshot: ServingSnapshot) {
        *self.snap.lock().unwrap() = Arc::new(snapshot);
    }

    fn current(&self) -> Arc<ServingSnapshot> {
        self.snap.lock().unwrap().clone()
    }
}

/// A cloneable, thread-safe reader over a session's published snapshots.
///
/// Cheap to clone (one `Arc`); hand one to every serving thread. All
/// queries of a [`ServingHandle::top_k_batch`] call resolve against a
/// single snapshot, so a batch is internally consistent even while the
/// owning session trains concurrently.
///
/// # Examples
///
/// ```
/// use fastertucker::config::TrainConfig;
/// use fastertucker::coordinator::{ServingHandle, TopKQuery};
/// use fastertucker::model::ModelState;
///
/// let cfg = TrainConfig {
///     order: 3, dims: vec![6, 5, 4], j: 4, r: 2, ..TrainConfig::default()
/// };
/// let model = ModelState::init(&cfg, 7);
/// let handle = ServingHandle::from_model(&model);
/// let top = handle
///     .top_k(&TopKQuery { mode: 1, fixed: vec![0, 3], k: 3 })
///     .unwrap();
/// assert_eq!(top.items.len(), 3);
/// assert!(top.items[0].1 >= top.items[1].1);
/// ```
#[derive(Clone)]
pub struct ServingHandle {
    shared: Arc<ServingShared>,
}

impl ServingHandle {
    pub(crate) fn from_shared(shared: Arc<ServingShared>) -> ServingHandle {
        ServingHandle { shared }
    }

    /// A standalone handle over a fixed model state (no live training
    /// session) — the `infer` CLI path, serving straight from a loaded
    /// checkpoint. The snapshot is labelled epoch 0.
    pub fn from_model(model: &ModelState) -> ServingHandle {
        ServingHandle {
            shared: Arc::new(ServingShared::new(ServingSnapshot::capture(model, 0))),
        }
    }

    /// The most recently published snapshot. Holding the returned `Arc`
    /// pins that epoch's view for as long as the caller needs it.
    pub fn snapshot(&self) -> Arc<ServingSnapshot> {
        self.shared.current()
    }

    /// Global epoch of the most recently published snapshot.
    pub fn epoch(&self) -> usize {
        self.snapshot().epoch
    }

    /// Answer one query against the latest snapshot.
    pub fn top_k(&self, query: &TopKQuery) -> Result<TopKResult> {
        self.snapshot().top_k(query)
    }

    /// Answer a whole batch against **one** snapshot: every result carries
    /// the same epoch, so the batch can never mix two model states.
    pub fn top_k_batch(&self, queries: &[TopKQuery]) -> Result<Vec<TopKResult>> {
        let snap = self.snapshot();
        queries.iter().map(|q| snap.top_k(q)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn model() -> ModelState {
        let cfg = TrainConfig {
            order: 3,
            dims: vec![8, 6, 4],
            j: 4,
            r: 3,
            ..TrainConfig::default()
        };
        ModelState::init(&cfg, 11)
    }

    #[test]
    fn scores_match_model_predict() {
        let m = model();
        let snap = ServingSnapshot::capture(&m, 5);
        assert_eq!(snap.epoch(), 5);
        assert_eq!(snap.order(), 3);
        assert_eq!(snap.dim(1), 6);
        let q = TopKQuery { mode: 1, fixed: vec![2, 3], k: 6 };
        let scores = snap.score_mode(&q).unwrap();
        for (i, &s) in scores.iter().enumerate() {
            let direct = m.predict(&[2, i as u32, 3]);
            assert!(
                (s - direct).abs() < 1e-5,
                "index {i}: serving {s} vs predict {direct}"
            );
        }
    }

    #[test]
    fn top_k_is_sorted_and_truncated() {
        let m = model();
        let handle = ServingHandle::from_model(&m);
        let res = handle.top_k(&TopKQuery { mode: 0, fixed: vec![1, 2], k: 3 }).unwrap();
        assert_eq!(res.items.len(), 3);
        assert!(res.items[0].1 >= res.items[1].1);
        assert!(res.items[1].1 >= res.items[2].1);
        // k beyond the dim clamps to the dim
        let all = handle.top_k(&TopKQuery { mode: 2, fixed: vec![0, 0], k: 99 }).unwrap();
        assert_eq!(all.items.len(), 4);
    }

    #[test]
    fn batch_resolves_against_one_snapshot() {
        let m = model();
        let shared = Arc::new(ServingShared::new(ServingSnapshot::capture(&m, 1)));
        let handle = ServingHandle::from_shared(shared.clone());
        let qs = vec![
            TopKQuery { mode: 0, fixed: vec![0, 0], k: 2 },
            TopKQuery { mode: 1, fixed: vec![5, 1], k: 2 },
        ];
        let res = handle.top_k_batch(&qs).unwrap();
        assert!(res.iter().all(|r| r.epoch == 1));
        // a publish between batches moves the epoch; within a batch it can't
        shared.publish(ServingSnapshot::capture(&m, 2));
        assert_eq!(handle.epoch(), 2);
    }

    #[test]
    fn malformed_queries_are_errors() {
        let handle = ServingHandle::from_model(&model());
        assert!(handle.top_k(&TopKQuery { mode: 3, fixed: vec![0, 0], k: 1 }).is_err());
        assert!(handle.top_k(&TopKQuery { mode: 0, fixed: vec![0], k: 1 }).is_err());
        assert!(handle
            .top_k(&TopKQuery { mode: 0, fixed: vec![0, 99], k: 1 })
            .is_err());
    }

    #[test]
    fn readers_see_published_epochs_not_torn_state() {
        let m = model();
        let shared = Arc::new(ServingShared::new(ServingSnapshot::capture(&m, 0)));
        let handle = ServingHandle::from_shared(shared.clone());
        let pinned = handle.snapshot();
        shared.publish(ServingSnapshot::capture(&m, 1));
        // the pinned Arc still reads epoch 0; a fresh resolve sees epoch 1
        assert_eq!(pinned.epoch(), 0);
        assert_eq!(handle.epoch(), 1);
    }
}
