//! The training coordinator (L3 leader): owns the prepared data structures,
//! the model, the epoch loop, and convergence tracking.
//!
//! All FastTucker-family training flows through ONE path: the generic
//! [`crate::algo::engine`]. The coordinator's only per-variant knowledge is
//! `fast_setup` — the single table mapping an [`Algo`] to its
//! `(storage, chain)` instantiation — plus a single `RefreshC` hook that
//! routes the `C^(n) = A^(n) B^(n)` refresh to the in-crate GEMM or the
//! AOT/PJRT kernel. The full-core baselines (`cuTucker`, `P-Tucker`) keep
//! their own model type and loops. Every engine pass also records
//! per-worker [`WorkerStats`], so load balance is observable from benches
//! and tests.

use crate::algo::engine::{self, ChainStrategy, SparseStorage, UpdateKind};
use crate::algo::Algo;
use crate::baselines::cutucker::{self, CuTuckerModel};
use crate::baselines::ptucker::{self, SliceIndex};
use crate::config::{Compute, TrainConfig};
use crate::linalg::Matrix;
use crate::metrics::{rmse_mae, Convergence, EpochRecord};
use crate::model::ModelState;
use crate::runtime::PjrtRuntime;
use crate::sched::pool::WorkerStats;
use crate::tensor::bcsf::{BcsfPerElement, BcsfShared, BcsfTensor};
use crate::tensor::coo::{CooBlocks, CooTensor};
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use anyhow::Result;

/// The model being trained (FastTucker family vs full-core baselines).
pub enum TrainerModel {
    Fast(ModelState),
    Full(CuTuckerModel),
}

impl TrainerModel {
    pub fn as_fast(&self) -> Option<&ModelState> {
        match self {
            TrainerModel::Fast(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_full(&self) -> Option<&CuTuckerModel> {
        match self {
            TrainerModel::Full(m) => Some(m),
            _ => None,
        }
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub algo_name: String,
    pub convergence: Convergence,
    /// Seconds spent building B-CSF / slice indices before epoch 0.
    pub prep_seconds: f64,
}

impl TrainReport {
    pub fn last_rmse(&self) -> f64 {
        self.convergence.last_rmse()
    }
    pub fn mean_epoch_seconds(&self) -> f64 {
        self.convergence.mean_epoch_seconds()
    }
}

/// Per-epoch timing split (the paper reports factor and core modules
/// separately — Table V has `(Factor)` and `(Core)` rows).
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochTimings {
    pub factor_seconds: f64,
    pub core_seconds: f64,
}

/// The coordinator.
pub struct Trainer {
    pub algo: Algo,
    pub cfg: TrainConfig,
    pub model: TrainerModel,
    /// Shuffled training data (COO traversal order for the COO algorithms).
    coo: CooTensor,
    /// Per-mode B-CSF rotations (FasterTucker only).
    bcsf: Option<Vec<BcsfTensor>>,
    /// Per-mode slice index (P-Tucker only).
    slice_index: Option<SliceIndex>,
    /// Optional PJRT engine for the dense kernels.
    runtime: Option<PjrtRuntime>,
    pub prep_seconds: f64,
    /// Per-worker stats of the most recent engine factor / core pass
    /// (`None` before the first pass and for the full-core baselines).
    last_factor_stats: Option<WorkerStats>,
    last_core_stats: Option<WorkerStats>,
}

/// The single dispatch table from algorithm to engine instantiation:
/// which storage walks the non-zeros and where the chain scalars come from.
fn fast_setup<'a>(
    algo: Algo,
    coo: &'a CooTensor,
    bcsf: Option<&'a [BcsfTensor]>,
    cfg: &TrainConfig,
) -> (Box<dyn SparseStorage + 'a>, ChainStrategy) {
    match algo {
        Algo::FastTucker => (
            Box::new(CooBlocks::new(coo, cfg.block_nnz)),
            ChainStrategy::OnTheFly,
        ),
        Algo::FasterTuckerCoo => (
            Box::new(CooBlocks::new(coo, cfg.block_nnz)),
            ChainStrategy::Tables,
        ),
        Algo::FasterTuckerBcsf => (
            Box::new(BcsfPerElement::new(bcsf.expect("bcsf prepared in new()"))),
            ChainStrategy::Tables,
        ),
        Algo::FasterTucker => (
            Box::new(BcsfShared::new(bcsf.expect("bcsf prepared in new()"))),
            ChainStrategy::TablesPrefixCached,
        ),
        Algo::CuTucker | Algo::PTucker => {
            unreachable!("full-core baselines do not run on the epoch engine")
        }
    }
}

impl Trainer {
    /// Prepare data structures and initialize the model.
    pub fn new(algo: Algo, cfg: TrainConfig, train: &CooTensor) -> Result<Trainer> {
        cfg.validate()?;
        let timer = Timer::start();
        let mut coo = train.clone();
        // one up-front shuffle so COO SGD sees a random element order, as the
        // paper's random sampling sets do
        coo.shuffle(&mut Rng::new(cfg.seed ^ 0x5088));
        let bcsf = match algo {
            Algo::FasterTucker | Algo::FasterTuckerBcsf => Some(
                (0..cfg.order)
                    .map(|n| {
                        BcsfTensor::build(train, n, cfg.fiber_threshold, cfg.block_nnz)
                    })
                    .collect(),
            ),
            _ => None,
        };
        let slice_index = match algo {
            Algo::PTucker => Some(SliceIndex::build(train)),
            _ => None,
        };
        let model = match algo {
            Algo::CuTucker | Algo::PTucker => {
                TrainerModel::Full(CuTuckerModel::init(&cfg, cfg.seed))
            }
            _ => TrainerModel::Fast(ModelState::init(&cfg, cfg.seed)),
        };
        let prep_seconds = timer.seconds();
        Ok(Trainer {
            algo,
            cfg,
            model,
            coo,
            bcsf,
            slice_index,
            runtime: None,
            prep_seconds,
            last_factor_stats: None,
            last_core_stats: None,
        })
    }

    /// Attach a PJRT runtime (used when `cfg.compute == Compute::Pjrt`).
    pub fn with_runtime(mut self, rt: PjrtRuntime) -> Trainer {
        self.runtime = Some(rt);
        self
    }

    /// Whether the PJRT engine is active.
    pub fn pjrt_active(&self) -> bool {
        self.runtime.is_some() && self.cfg.compute == Compute::Pjrt
    }

    /// Run one engine pass (`kind`) for the FastTucker family, through the
    /// single `RefreshC` hook: no-op for FastTucker (it keeps no `C` tables
    /// during training), PJRT matmul when active, in-crate GEMM otherwise.
    fn engine_pass(&mut self, kind: UpdateKind) -> WorkerStats {
        let (storage, chain) =
            fast_setup(self.algo, &self.coo, self.bcsf.as_deref(), &self.cfg);
        let use_pjrt = self.runtime.is_some() && self.cfg.compute == Compute::Pjrt;
        let runtime = self.runtime.as_ref();
        let skip_refresh = matches!(self.algo, Algo::FastTucker);
        let refresh = move |m: &mut ModelState, n: usize| {
            if skip_refresh {
                return;
            }
            refresh_c(m, n, if use_pjrt { runtime } else { None })
        };
        let m = match &mut self.model {
            TrainerModel::Fast(m) => m,
            TrainerModel::Full(_) => unreachable!("model/algo mismatch"),
        };
        engine::run_epoch(m, storage.as_ref(), chain, kind, &self.cfg, &refresh)
    }

    /// Run the factor-update module once (all modes). Returns seconds.
    pub fn factor_pass(&mut self) -> f64 {
        let t = Timer::start();
        match self.algo {
            Algo::CuTucker => match &mut self.model {
                TrainerModel::Full(m) => cutucker::factor_epoch(m, &self.coo, &self.cfg),
                TrainerModel::Fast(_) => unreachable!("model/algo mismatch"),
            },
            Algo::PTucker => {
                let idx = self.slice_index.as_ref().expect("slice index prepared");
                match &mut self.model {
                    TrainerModel::Full(m) => {
                        ptucker::als_factor_sweep(m, &self.coo, idx, &self.cfg);
                    }
                    TrainerModel::Fast(_) => unreachable!("model/algo mismatch"),
                }
            }
            _ => {
                let stats = self.engine_pass(UpdateKind::Factor);
                self.last_factor_stats = Some(stats);
            }
        }
        t.seconds()
    }

    /// Run the core-update module once (all modes). Returns seconds.
    /// P-Tucker has no core module in Table IV; it is a no-op there.
    pub fn core_pass(&mut self) -> f64 {
        let t = Timer::start();
        match self.algo {
            Algo::CuTucker => match &mut self.model {
                TrainerModel::Full(m) => cutucker::core_epoch(m, &self.coo, &self.cfg),
                TrainerModel::Fast(_) => unreachable!("model/algo mismatch"),
            },
            Algo::PTucker => {
                debug_assert!(matches!(self.model, TrainerModel::Full(_)));
            }
            _ => {
                let stats = self.engine_pass(UpdateKind::Core);
                self.last_core_stats = Some(stats);
            }
        }
        t.seconds()
    }

    /// One full epoch (factor module + optional core module).
    pub fn epoch(&mut self) -> EpochTimings {
        let factor_seconds = self.factor_pass();
        let core_seconds = if self.cfg.update_cores { self.core_pass() } else { 0.0 };
        // FastTucker keeps no C tables during training; sync them so that
        // evaluation (which reads them) is correct.
        if matches!(self.algo, Algo::FastTucker) {
            if let TrainerModel::Fast(m) = &mut self.model {
                m.refresh_all_c();
            }
        }
        EpochTimings { factor_seconds, core_seconds }
    }

    /// Evaluate RMSE/MAE on `data` with the current model. Routes through
    /// the PJRT `predict` artifact when active, else the in-crate path.
    pub fn evaluate(&self, data: &CooTensor) -> (f64, f64) {
        match &self.model {
            TrainerModel::Fast(m) => {
                if self.pjrt_active() {
                    if let Ok(res) =
                        eval_rmse_pjrt(m, data, self.runtime.as_ref().unwrap())
                    {
                        return res;
                    }
                }
                rmse_mae(m, data, self.cfg.effective_workers())
            }
            TrainerModel::Full(m) => m.rmse_mae(data),
        }
    }

    /// Train for `epochs`, recording a convergence series against `test`
    /// (falls back to the training data when no test set is supplied).
    pub fn run(&mut self, epochs: usize, test: Option<&CooTensor>) -> TrainReport {
        let mut convergence = Convergence::default();
        for ep in 0..epochs {
            let t = Timer::start();
            let timings = self.epoch();
            let seconds = t.seconds();
            let (rmse, mae) = match test {
                Some(ts) => self.evaluate(ts),
                None => {
                    let sample = &self.coo;
                    self.evaluate(sample)
                }
            };
            convergence.push(EpochRecord {
                epoch: ep,
                seconds,
                factor_seconds: timings.factor_seconds,
                core_seconds: timings.core_seconds,
                rmse,
                mae,
            });
        }
        TrainReport {
            algo_name: self.algo.name().to_string(),
            convergence,
            prep_seconds: self.prep_seconds,
        }
    }

    /// B-CSF balance statistics (FasterTucker only).
    pub fn balance_stats(&self) -> Option<Vec<crate::tensor::bcsf::BalanceStats>> {
        self.bcsf
            .as_ref()
            .map(|v| v.iter().map(|b| b.stats.clone()).collect())
    }

    /// Per-worker scheduling stats of the most recent engine factor pass
    /// (summed over the epoch's per-mode passes). `None` before the first
    /// pass and for the full-core baselines.
    pub fn factor_worker_stats(&self) -> Option<&WorkerStats> {
        self.last_factor_stats.as_ref()
    }

    /// Per-worker scheduling stats of the most recent engine core pass.
    pub fn core_worker_stats(&self) -> Option<&WorkerStats> {
        self.last_core_stats.as_ref()
    }
}

/// Refresh `C^(n)`: PJRT matmul artifact when available, else in-crate GEMM.
fn refresh_c(m: &mut ModelState, n: usize, rt: Option<&PjrtRuntime>) {
    if let Some(rt) = rt {
        match rt.matmul(&m.factors[n], &m.cores[n]) {
            Ok(c) => {
                m.c_tables[n] = c;
                return;
            }
            Err(e) => {
                // fall back but surface the failure once per process
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!("warning: PJRT C-refresh failed ({e}); using Rust GEMM");
                });
            }
        }
    }
    m.refresh_c(n);
}

/// Test-set RMSE/MAE through the PJRT `predict` artifact: gather the C rows
/// of every test element into `N` dense `B×R` blocks and run the batched
/// chain-product kernel.
fn eval_rmse_pjrt(
    m: &ModelState,
    data: &CooTensor,
    rt: &PjrtRuntime,
) -> Result<(f64, f64)> {
    let nnz = data.nnz();
    if nnz == 0 {
        return Ok((0.0, 0.0));
    }
    let order = m.order();
    let r = m.r();
    let mut crows: Vec<Matrix> = (0..order).map(|_| Matrix::zeros(nnz, r)).collect();
    for e in 0..nnz {
        let coords = data.index(e);
        for n in 0..order {
            let src = m.c_tables[n].row(coords[n] as usize);
            crows[n].row_mut(e).copy_from_slice(src);
        }
    }
    let xhat = rt.predict_batch(&crows)?;
    let (mut se, mut ae) = (0.0f64, 0.0f64);
    for e in 0..nnz {
        let err = (data.value(e) - xhat[e]) as f64;
        se += err * err;
        ae += err.abs();
    }
    Ok(((se / nnz as f64).sqrt(), ae / nnz as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::data::split::train_test;

    fn cfg_for(t: &CooTensor) -> TrainConfig {
        TrainConfig {
            order: t.order(),
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 2,
            block_nnz: 512,
            fiber_threshold: 32,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn every_algorithm_trains_and_improves() {
        let t = recommender(&RecommenderSpec::tiny(), 51);
        let (train, test) = train_test(&t, 0.2, 3);
        for algo in [
            Algo::FastTucker,
            Algo::FasterTuckerCoo,
            Algo::FasterTuckerBcsf,
            Algo::FasterTucker,
            Algo::CuTucker,
            Algo::PTucker,
        ] {
            let mut cfg = cfg_for(&train);
            if algo == Algo::CuTucker || algo == Algo::PTucker {
                cfg.j = 4; // keep the J^N core tensor small in tests
            }
            let mut trainer = Trainer::new(algo, cfg, &train).unwrap();
            let report = trainer.run(3, Some(&test));
            assert_eq!(report.convergence.records.len(), 3);
            assert!(
                report.convergence.improved(),
                "{} did not improve: {:?}",
                algo.name(),
                report
                    .convergence
                    .records
                    .iter()
                    .map(|r| r.rmse)
                    .collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn factor_and_core_passes_timed_separately() {
        let t = recommender(&RecommenderSpec::tiny(), 52);
        let mut trainer = Trainer::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        let timings = trainer.epoch();
        assert!(timings.factor_seconds > 0.0);
        assert!(timings.core_seconds > 0.0);
    }

    #[test]
    fn update_cores_false_skips_core_pass() {
        let t = recommender(&RecommenderSpec::tiny(), 53);
        let mut cfg = cfg_for(&t);
        cfg.update_cores = false;
        let mut trainer = Trainer::new(Algo::FasterTucker, cfg, &t).unwrap();
        let timings = trainer.epoch();
        assert_eq!(timings.core_seconds, 0.0);
    }

    #[test]
    fn balance_stats_only_for_bcsf() {
        let t = recommender(&RecommenderSpec::tiny(), 54);
        let a = Trainer::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        assert_eq!(a.balance_stats().unwrap().len(), 3);
        let b = Trainer::new(Algo::FastTucker, cfg_for(&t), &t).unwrap();
        assert!(b.balance_stats().is_none());
    }

    #[test]
    fn engine_passes_record_worker_stats() {
        let t = recommender(&RecommenderSpec::tiny(), 57);
        let mut trainer = Trainer::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        assert!(trainer.factor_worker_stats().is_none());
        trainer.epoch();
        let fs = trainer.factor_worker_stats().expect("factor stats recorded");
        assert!(fs.total_blocks() > 0);
        assert!(fs.imbalance() >= 1.0 - 1e-9);
        assert!(trainer.core_worker_stats().is_some());

        // full-core baselines bypass the engine and record nothing
        let mut cfg = cfg_for(&t);
        cfg.j = 4;
        cfg.r = 4;
        let mut base = Trainer::new(Algo::CuTucker, cfg, &t).unwrap();
        base.epoch();
        assert!(base.factor_worker_stats().is_none());
    }

    #[test]
    fn invalid_config_rejected() {
        let t = recommender(&RecommenderSpec::tiny(), 55);
        let mut cfg = cfg_for(&t);
        cfg.j = 0;
        assert!(Trainer::new(Algo::FasterTucker, cfg, &t).is_err());
    }

    #[test]
    fn fastucker_eval_sees_fresh_c_tables() {
        let t = recommender(&RecommenderSpec::tiny(), 56);
        let mut trainer = Trainer::new(Algo::FastTucker, cfg_for(&t), &t).unwrap();
        trainer.epoch();
        if let TrainerModel::Fast(m) = &trainer.model {
            for n in 0..3 {
                let expect = m.factors[n].matmul(&m.cores[n]);
                assert!(expect.max_abs_diff(&m.c_tables[n]) < 1e-5);
            }
        }
    }
}
