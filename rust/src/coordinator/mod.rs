//! The **Session** layer — layer 3 of `Dataset → PreparedStorage →
//! Session`.
//!
//! A [`Session`] owns a model, the once-built prepared structures, and a
//! *resumable* training loop: warm-start from a checkpointed
//! [`ModelState`], advance with [`Session::step`] or [`Session::run_until`]
//! (early stopping, per-epoch LR decay, periodic eval cadence), and read a
//! [`SessionReport`] at any point. With one worker and a fixed seed, a
//! warm-started session is bitwise-identical to an uninterrupted run
//! (`tests/session_resume.rs`).
//!
//! All FastTucker-family training flows through ONE path: the session
//! delegates every factor/core pass to its [`PassBackend`]
//! (`--backend cpu|pjrt`, [`crate::exec`]) over the cached
//! [`PreparedStorage`] — built once in the constructor, never on the
//! epoch path (its `PrepStats::builds` counter stays at 1 unless a
//! registry eviction forces a transparent rebuild). The backend owns the
//! whole pass, including the per-mode `C^(n) = A^(n) B^(n)` refresh
//! (in-crate GEMM on the CPU backend, AOT/PJRT artifacts on the PJRT
//! one). The full-core baselines (`cuTucker`, `P-Tucker`) keep their own
//! model type and loops. Every engine pass records per-worker
//! [`WorkerStats`], so load balance is observable from benches and tests.
//!
//! Two submodules extend the session into a serving system:
//!
//! * [`registry`] — a process-wide [`SessionRegistry`] owning many named
//!   sessions at once: one shared [`crate::sched::Executor`] worker pool
//!   for every training pass (leasable in disjoint worker subsets so
//!   tenants overlap — [`Session::set_lease_workers`]), and a
//!   size/frequency-scored byte budget over the per-session prepared
//!   caches (evicted sessions rebuild transparently on the next step —
//!   [`Session::ensure_prepared`]).
//! * [`serving`] — a [`ServingHandle`] cloned out of a session that
//!   answers batched top-k queries from concurrent reader threads while
//!   training runs, with epoch-snapshot consistency (readers always see
//!   the state as of the last completed epoch, never a torn mid-pass
//!   view).

pub mod registry;
pub mod serving;

pub use registry::{QosPolicy, SessionRegistry};
pub use serving::{
    PruneStats, ServingHandle, ServingSnapshot, SnapshotStats, TopKQuery, TopKResult,
};

use crate::algo::engine::{EngineState, UpdateKind};
use crate::algo::Algo;
use crate::baselines::cutucker::{self, CuTuckerModel};
use crate::baselines::ptucker::{self, SliceIndex};
use crate::config::TrainConfig;
use crate::exec::{self, PassBackend, PassRequest};
use crate::linalg::Matrix;
use crate::metrics::{rmse_mae, Convergence, EpochRecord, QosStats};
use crate::model::ModelState;
use crate::runtime::PjrtRuntime;
use crate::sched::pool::WorkerStats;
use crate::sched::topo::{Topology, WorkerHome};
use crate::sched::Executor;
use crate::tensor::bcsf::BalanceStats;
use crate::tensor::coo::CooTensor;
use crate::tensor::prepared::{PrepStats, PreparedStorage};
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use anyhow::{bail, Result};
use serving::ServingShared;
use std::path::Path;
use std::sync::Arc;

/// The model being trained (FastTucker family vs full-core baselines).
pub enum SessionModel {
    /// FastTucker-family state: factors, core matrices, `C` tables.
    Fast(ModelState),
    /// Full-core baseline state (cuTucker / P-Tucker): factors + `G ∈ R^{J^N}`.
    Full(CuTuckerModel),
}

impl SessionModel {
    /// The FastTucker-family state, if that is what is being trained.
    pub fn as_fast(&self) -> Option<&ModelState> {
        match self {
            SessionModel::Fast(m) => Some(m),
            _ => None,
        }
    }
    /// The full-core baseline state, if that is what is being trained.
    pub fn as_full(&self) -> Option<&CuTuckerModel> {
        match self {
            SessionModel::Full(m) => Some(m),
            _ => None,
        }
    }
}

/// Per-algo prepared data, built exactly once per session.
enum PreparedData {
    /// FastTucker family: the cached `(storage, chain)` instantiation.
    Engine(PreparedStorage),
    /// Full-core baselines keep their own structures.
    Baseline {
        /// Shuffled training data (COO traversal order).
        coo: CooTensor,
        /// Per-mode slice index (P-Tucker only).
        slice_index: Option<SliceIndex>,
    },
}

/// Result of (part of) a training session — a superset of the old
/// `TrainReport`: convergence series plus staging accounting and the
/// resumable-loop state.
#[derive(Clone, Debug)]
pub struct SessionReport {
    /// Paper-style display name of the trained algorithm.
    pub algo_name: String,
    /// Per-epoch convergence series recorded so far.
    pub convergence: Convergence,
    /// Seconds spent building prepared structures before epoch 0.
    pub prep_seconds: f64,
    /// Staging breakdown (shuffle vs B-CSF) and the build counter.
    pub prep: PrepStats,
    /// Global epoch the session started at (warm starts resume mid-count).
    pub start_epoch: usize,
    /// Global epochs completed so far.
    pub epochs_completed: usize,
    /// Whether the early-stopping rule ended the last `run`/`run_until`.
    pub early_stopped: bool,
}

impl SessionReport {
    /// RMSE of the most recent recorded epoch.
    pub fn last_rmse(&self) -> f64 {
        self.convergence.last_rmse()
    }
    /// Mean wall-clock seconds per epoch (warm-up excluded when possible).
    pub fn mean_epoch_seconds(&self) -> f64 {
        self.convergence.mean_epoch_seconds()
    }
}

/// Per-epoch timing split (the paper reports factor and core modules
/// separately — Table V has `(Factor)` and `(Core)` rows).
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochTimings {
    /// Seconds spent in the factor-update module (all modes).
    pub factor_seconds: f64,
    /// Seconds spent in the core-update module (0 when skipped).
    pub core_seconds: f64,
}

/// What one [`Session::ingest`] absorbed: the delta size, any mode growth,
/// and how much of the B-CSF staging work the incremental restage skipped.
#[derive(Clone, Debug, Default)]
pub struct IngestReport {
    /// Non-zeros appended by the delta (before duplicate merging).
    pub added_nnz: usize,
    /// Modes the delta grew, as `(mode, old_rows, new_rows)`.
    pub grown: Vec<(usize, usize, usize)>,
    /// B-CSF blocks carried over bitwise-unchanged from the previous
    /// staging (the clean prefix ahead of the first delta-touched
    /// element), summed across mode rotations.
    pub blocks_reused: usize,
    /// B-CSF blocks rebuilt because the delta dirtied them.
    pub blocks_rebuilt: usize,
}

/// A resumable training session.
pub struct Session {
    /// Which algorithm this session trains.
    pub algo: Algo,
    /// Base configuration (epoch-0 learning rates; the decay schedule is
    /// applied on top, per epoch).
    pub cfg: TrainConfig,
    /// The trainable model state.
    pub model: SessionModel,
    /// Pristine training tensor, retained (only) when the session must be
    /// able to rebuild an evicted prepared cache bit-identically — the
    /// staging shuffle and B-CSF builds are pure functions of
    /// `(train, cfg)`. `None` for plain [`Session::new`] sessions, which
    /// therefore pay no extra copy and are simply never evicted;
    /// [`Session::new_shared`] (and the registry's `open`/`open_shared`)
    /// retain an `Arc`, sharing the caller's allocation.
    train: Option<Arc<CooTensor>>,
    /// Once-built prepared structures; `None` while evicted by a registry
    /// budget (rebuilt transparently by [`Session::ensure_prepared`]).
    prepared: Option<PreparedData>,
    /// Post-ingest warm-up: `(delta-only storage, epochs left)`. While
    /// set, engine passes sweep only the freshly ingested non-zeros (with
    /// their own plan-cache key, so full-sweep plans are not clobbered);
    /// after the configured epochs it drops and training blends back to
    /// full sweeps over the merged storage.
    ingest_warm: Option<(PreparedStorage, usize)>,
    /// Optional PJRT engine for the dense kernels.
    runtime: Option<PjrtRuntime>,
    /// The pass backend every factor/core pass of this session delegates
    /// to, chosen from `cfg.backend` at build time
    /// ([`crate::exec::backend_for`]) and swappable with
    /// [`Session::set_backend`].
    backend: Box<dyn PassBackend>,
    /// Optional shared pass executor (set by [`SessionRegistry`]): when
    /// present, every training pass runs on its worker budget — the whole
    /// budget exclusively by default, or a [`crate::sched::WorkerLease`]d
    /// subset when [`Session::set_lease_workers`] configures one.
    executor: Option<Arc<Executor>>,
    /// Lease size for executor-gated passes: `Some(n)` leases `n` workers
    /// per pass (overlapping with other tenants), `None` takes the full
    /// budget exclusively.
    lease_workers: Option<usize>,
    /// Snapshot publication slot, created lazily by
    /// [`Session::serving_handle`]; every completed epoch publishes here.
    serving: Option<Arc<ServingShared>>,
    /// Global epoch counter (continues across warm starts).
    epoch: usize,
    start_epoch: usize,
    /// `(lr_a, lr_b)` with the decay schedule applied for the current
    /// epoch; everything else is always read from `cfg`.
    cur_lr: (f32, f32),
    convergence: Convergence,
    /// Capped deterministic training-set sample for self-evaluation
    /// (`None` = the full training set is small enough, or capping is off).
    eval_sample: Option<CooTensor>,
    prep: PrepStats,
    best_rmse: f64,
    stall: usize,
    early_stopped: bool,
    /// Per-worker stats of the most recent engine factor / core pass
    /// (`None` before the first pass and for the full-core baselines).
    last_factor_stats: Option<WorkerStats>,
    last_core_stats: Option<WorkerStats>,
    /// Persistent engine buffers: the per-worker scratch pool and the
    /// rank-padded kernel operands, reused across every pass of the
    /// session (`tests/hotpath_alloc.rs` pins the no-reallocation claim).
    engine_state: EngineState,
    /// Per-tenant QoS telemetry (pass latency / queue wait EWMAs), updated
    /// once per engine pass; the registry's lease-rebalancing policy reads
    /// it between passes.
    qos: QosStats,
}

impl Session {
    /// Fresh session: prepare data structures once and initialize the
    /// model randomly from `cfg.seed`. No copy of `train` is retained, so
    /// this session's prepared cache is **not evictable** by a registry
    /// budget — use [`Session::new_shared`] (or open through a
    /// [`SessionRegistry`]) for evictable sessions.
    ///
    /// # Examples
    ///
    /// ```
    /// use fastertucker::algo::Algo;
    /// use fastertucker::config::TrainConfig;
    /// use fastertucker::coordinator::Session;
    /// use fastertucker::tensor::coo::CooTensor;
    ///
    /// let mut t = CooTensor::new(vec![4, 3, 2]);
    /// t.push(&[0, 0, 0], 2.0);
    /// t.push(&[1, 2, 1], 4.0);
    /// t.push(&[3, 1, 0], 3.0);
    /// let cfg = TrainConfig {
    ///     order: 3, dims: vec![4, 3, 2], j: 2, r: 2,
    ///     workers: 1, eval_sample_nnz: 0, ..TrainConfig::default()
    /// };
    /// let mut session = Session::new(Algo::FasterTucker, cfg, &t).unwrap();
    /// let report = session.run(2, None);
    /// assert_eq!(report.epochs_completed, 2);
    /// assert_eq!(session.prep_stats().builds, 1);
    /// ```
    pub fn new(algo: Algo, cfg: TrainConfig, train: &CooTensor) -> Result<Session> {
        Session::build(algo, cfg, train, None, None, 0)
    }

    /// [`Session::new`] that retains the caller's `Arc` as its pristine
    /// rebuild source — copy-free, and the resulting session's prepared
    /// cache is evictable by a registry budget (an eviction rebuilds
    /// bit-identically from the retained tensor).
    pub fn new_shared(
        algo: Algo,
        cfg: TrainConfig,
        train: Arc<CooTensor>,
    ) -> Result<Session> {
        let retain = Some(train.clone());
        Session::build(algo, cfg, &train, retain, None, 0)
    }

    /// Warm-start from a previously trained model (e.g. a checkpoint
    /// loaded with [`ModelState::load`]). `start_epoch` is the number of
    /// epochs the model has already been trained for, so epoch numbering
    /// and the LR decay schedule continue seamlessly. FastTucker family
    /// only.
    pub fn warm_start(
        algo: Algo,
        cfg: TrainConfig,
        train: &CooTensor,
        mut model: ModelState,
        start_epoch: usize,
    ) -> Result<Session> {
        if matches!(algo, Algo::CuTucker | Algo::PTucker) {
            bail!("warm start is supported for the FastTucker family only");
        }
        // validate before indexing factors by dims: a malformed config must
        // be an Err, not an out-of-bounds panic
        cfg.validate()?;
        if model.order() != cfg.order {
            bail!("checkpoint order {} != config order {}", model.order(), cfg.order);
        }
        if model.j() != cfg.j || model.r() != cfg.r {
            bail!(
                "checkpoint ranks J={} R={} != config J={} R={}",
                model.j(),
                model.r(),
                cfg.j,
                cfg.r
            );
        }
        for (n, &d) in cfg.dims.iter().enumerate() {
            if model.factors[n].rows() != d {
                bail!(
                    "checkpoint mode {n} has {} rows, config expects {d}",
                    model.factors[n].rows()
                );
            }
        }
        // re-derive the C tables through the same GEMM the training loop
        // uses, so a resumed run is bitwise-identical to an uninterrupted
        // one
        model.refresh_all_c();
        Session::build(algo, cfg, train, None, Some(model), start_epoch)
    }

    /// [`Session::warm_start`] straight from a checkpoint file.
    pub fn resume(
        algo: Algo,
        cfg: TrainConfig,
        train: &CooTensor,
        checkpoint: &Path,
        start_epoch: usize,
    ) -> Result<Session> {
        let model = ModelState::load(checkpoint)?;
        Session::warm_start(algo, cfg, train, model, start_epoch)
    }

    /// Build the per-algo prepared structures from pristine training data.
    /// Deterministic: the same `(algo, cfg, train)` always yields the same
    /// structures, which is what makes eviction + rebuild bit-transparent.
    fn build_prepared(
        algo: Algo,
        cfg: &TrainConfig,
        train: &CooTensor,
    ) -> Result<(PreparedData, PrepStats)> {
        match algo {
            Algo::CuTucker | Algo::PTucker => {
                let total = Timer::start();
                let t = Timer::start();
                let coo = train.training_shuffle(cfg.seed);
                let shuffle_seconds = t.seconds();
                let slice_index =
                    (algo == Algo::PTucker).then(|| SliceIndex::build(train));
                let resident_bytes = coo.heap_bytes()
                    + slice_index.as_ref().map_or(0, SliceIndex::heap_bytes);
                let prep = PrepStats {
                    shuffle_seconds,
                    total_seconds: total.seconds(),
                    builds: 1,
                    resident_bytes,
                    peak_resident_bytes: resident_bytes,
                    stage_workers: 1,
                    ..PrepStats::default()
                };
                Ok((PreparedData::Baseline { coo, slice_index }, prep))
            }
            _ => {
                let storage = PreparedStorage::prepare(algo, cfg, train)?;
                let prep = storage.prep().clone();
                Ok((PreparedData::Engine(storage), prep))
            }
        }
    }

    fn build(
        algo: Algo,
        cfg: TrainConfig,
        train: &CooTensor,
        retain: Option<Arc<CooTensor>>,
        warm: Option<ModelState>,
        start_epoch: usize,
    ) -> Result<Session> {
        cfg.validate()?;
        let (prepared, prep) = Session::build_prepared(algo, &cfg, train)?;
        let model = match warm {
            Some(m) => SessionModel::Fast(m),
            None => match algo {
                Algo::CuTucker | Algo::PTucker => {
                    SessionModel::Full(CuTuckerModel::init(&cfg, cfg.seed))
                }
                _ => SessionModel::Fast(ModelState::init(&cfg, cfg.seed)),
            },
        };
        let train_coo = match &prepared {
            PreparedData::Engine(p) => p.coo(),
            PreparedData::Baseline { coo, .. } => coo,
        };
        let eval_sample = build_eval_sample(train_coo, &cfg);
        let backend = exec::backend_for(&cfg);
        let mut session = Session {
            algo,
            cfg,
            model,
            train: retain,
            prepared: Some(prepared),
            ingest_warm: None,
            runtime: None,
            backend,
            executor: None,
            lease_workers: None,
            serving: None,
            epoch: start_epoch,
            start_epoch,
            cur_lr: (0.0, 0.0),
            convergence: Convergence::default(),
            eval_sample,
            prep,
            best_rmse: f64::INFINITY,
            stall: 0,
            early_stopped: false,
            last_factor_stats: None,
            last_core_stats: None,
            engine_state: EngineState::new(),
            qos: QosStats::default(),
        };
        // memory-hierarchy homes for the session's own (non-executor)
        // passes, detected once at build time so the epoch path never
        // touches /sys; executor-gated passes override these per lease.
        // `NumaMode::Auto` on a single-node machine (and `Off` anywhere)
        // yields all-local homes — the exact topology-blind behaviour.
        let homes = Topology::detect(session.cfg.numa)
            .assign_homes(session.cfg.effective_workers());
        session.engine_state.set_worker_homes(homes);
        session.apply_lr_schedule();
        Ok(session)
    }

    /// Attach a PJRT runtime (used when the config resolves to the PJRT
    /// pass backend — `--backend pjrt` or the legacy `--compute pjrt`).
    pub fn with_runtime(mut self, rt: PjrtRuntime) -> Session {
        self.runtime = Some(rt);
        self
    }

    /// Replace the session's pass backend. Accelerator plugins (and tests
    /// that decorate [`crate::exec::CpuShardBackend`]) inject custom
    /// [`PassBackend`] implementations here; every subsequent factor/core
    /// pass delegates to the new backend.
    pub fn set_backend(&mut self, backend: Box<dyn PassBackend>) {
        self.backend = backend;
    }

    /// The active pass backend's name (`"cpu"`, `"pjrt"`, or a plugin's).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Whether the PJRT engine is active: a runtime is attached and the
    /// *installed* pass backend declares it routes dense work through it
    /// ([`PassBackend::uses_runtime`]) — asking the backend rather than
    /// the config keeps evaluation and serving snapshots bit-consistent
    /// with the refresh path training actually uses, even after
    /// [`Session::set_backend`] swaps the backend.
    pub fn pjrt_active(&self) -> bool {
        self.pjrt_backend_active()
    }

    /// Same predicate, private spelling used on the non-pass paths.
    fn pjrt_backend_active(&self) -> bool {
        self.runtime.is_some() && self.backend.uses_runtime()
    }

    /// Effective learning rates for the current epoch (base rates with the
    /// decay schedule applied).
    pub fn current_lr(&self) -> (f32, f32) {
        self.cur_lr
    }

    /// Global epochs completed so far (includes warm-start offset).
    pub fn epochs_completed(&self) -> usize {
        self.epoch
    }

    /// Total staging seconds (structures built before epoch 0).
    pub fn prep_seconds(&self) -> f64 {
        self.prep.total_seconds
    }

    /// Staging breakdown + build counter.
    pub fn prep_stats(&self) -> &PrepStats {
        &self.prep
    }

    /// The capped self-evaluation sample, when one is in effect.
    pub fn eval_sample(&self) -> Option<&CooTensor> {
        self.eval_sample.as_ref()
    }

    /// Non-zeros of the retained pristine training tensor (base plus every
    /// ingested delta), when the session retains one — `None` for plain
    /// [`Session::new`] sessions, which hold no rebuild source.
    pub fn train_nnz(&self) -> Option<usize> {
        self.train.as_ref().map(|t| t.nnz())
    }

    fn apply_lr_schedule(&mut self) {
        let decay = self.cfg.lr_decay.powi(self.epoch as i32);
        self.cur_lr = (self.cfg.lr_a * decay, self.cfg.lr_b * decay);
    }

    /// The config a pass runs under: `cfg` with the current decayed
    /// learning rates overlaid.
    fn run_cfg(&self) -> TrainConfig {
        let mut c = self.cfg.clone();
        c.lr_a = self.cur_lr.0;
        c.lr_b = self.cur_lr.1;
        c
    }

    /// Run one engine pass (`kind`) for the FastTucker family by
    /// delegating to the session's [`PassBackend`] over the cached
    /// storage. The backend owns the whole pass, including the per-mode
    /// `C^(n)` refresh (skipped for FastTucker, which keeps no `C` tables
    /// during training). When a shared [`Executor`] is attached, the pass
    /// runs on its budget — a leased worker subset if
    /// [`Session::set_lease_workers`] configured one (overlapping with
    /// other tenants), the full budget exclusively otherwise.
    fn engine_pass(&mut self, kind: UpdateKind) -> WorkerStats {
        let (run_cfg, exec, lease) = self.pass_cfg();
        let slots = run_cfg.workers;
        // the backend decides whether to use an attached runtime (the CPU
        // backend ignores it by contract), so an injected backend is never
        // silently starved of it
        let runtime = self.runtime.as_ref();
        let skip_refresh = matches!(self.algo, Algo::FastTucker);
        // post-ingest warm-up epochs sweep the delta-only storage instead
        // of the merged one
        let warm_active = self.ingest_warm.is_some();
        let storage = if let Some((s, _)) = &self.ingest_warm {
            s
        } else {
            match self.prepared.as_ref().expect("prepared resident") {
                PreparedData::Engine(p) => p,
                PreparedData::Baseline { .. } => {
                    unreachable!("full-core baselines do not run on the epoch engine")
                }
            }
        };
        let m = match &mut self.model {
            SessionModel::Fast(m) => m,
            SessionModel::Full(_) => unreachable!("model/algo mismatch"),
        };
        // cached shard plans (and their steal-queue seeds) are pure
        // functions of the prepared storage; a post-eviction rebuild bumps
        // `builds`, which must drop them before they can go stale. Warm-up
        // passes run over a different storage, so they key the cache in a
        // disjoint (high-bit) namespace instead of poisoning the full-sweep
        // plans for their build generation.
        let plan_key = if warm_active {
            self.prep.builds as u64 | (1u64 << 63)
        } else {
            self.prep.builds as u64
        };
        self.engine_state.set_storage_epoch(plan_key);
        let state = &mut self.engine_state;
        let backend = self.backend.as_ref();
        // executor-gated passes run on the lease's slots, whose
        // memory-hierarchy homes are only known once the lease is granted —
        // the pass closure installs them right before the pass so workers
        // bind (and read node replicas) where their slots live; inline
        // passes keep the session's build-time homes
        let pass = move |homes: Option<Vec<WorkerHome>>| {
            if let Some(h) = homes {
                state.set_worker_homes(h);
            }
            backend.run_pass(PassRequest {
                model: m,
                storage,
                kind,
                cfg: &run_cfg,
                skip_refresh,
                runtime,
                state,
            })
        };
        // queue wait = time from requesting admission to the gate actually
        // running the pass closure; pass latency = total minus that wait
        let total = Timer::start();
        let wait = std::cell::Cell::new(0.0f64);
        let stats = match exec {
            Some(e) => {
                let (w, t) = (&wait, &total);
                // a `None` lease is the exclusive full-budget pass
                let n = lease.unwrap_or_else(|| e.workers());
                e.run_leased_on(n, move |wl| {
                    w.set(t.seconds());
                    pass(Some(wl.homes()))
                })
            }
            None => pass(None),
        };
        let queue_wait = wait.get();
        let pass_seconds = (total.seconds() - queue_wait).max(0.0);
        self.qos.record_pass(pass_seconds, queue_wait, &stats, slots);
        let cross_node_steals = self.engine_state.take_cross_node_steals();
        self.qos.record_node_layout(
            &stats,
            self.engine_state.worker_homes(),
            cross_node_steals,
        );
        // refresh time is epoch-path work, accounted separately from
        // staging (`total_seconds` freezes once the structures are built)
        self.prep.refresh_seconds += self.engine_state.take_refresh_seconds();
        stats
    }

    /// The config a training pass runs under, the executor it must be
    /// gated through, and the lease size (if subset leasing is
    /// configured): when an executor is attached, the pass's worker count
    /// is the lease size — or the full budget — instead of `cfg.workers`.
    /// The one contract shared by the engine and the full-core baseline
    /// paths.
    fn pass_cfg(&self) -> (TrainConfig, Option<Arc<Executor>>, Option<usize>) {
        let exec = self.executor.clone();
        let mut run_cfg = self.run_cfg();
        let mut lease = None;
        if let Some(e) = &exec {
            match self.lease_workers {
                Some(n) => {
                    let n = n.clamp(1, e.workers());
                    run_cfg.workers = n;
                    lease = Some(n);
                }
                None => run_cfg.workers = e.workers(),
            }
        }
        (run_cfg, exec, lease)
    }

    /// Run the factor-update module once (all modes). Returns seconds.
    /// Transparently rebuilds the prepared structures first if a registry
    /// eviction dropped them.
    pub fn factor_pass(&mut self) -> f64 {
        self.ensure_prepared();
        let t = Timer::start();
        match self.algo {
            Algo::CuTucker => {
                let (run_cfg, exec, lease) = self.pass_cfg();
                let coo = match self.prepared.as_ref().expect("prepared resident") {
                    PreparedData::Baseline { coo, .. } => coo,
                    _ => unreachable!("model/algo mismatch"),
                };
                let m = match &mut self.model {
                    SessionModel::Full(m) => m,
                    SessionModel::Fast(_) => unreachable!("model/algo mismatch"),
                };
                let pass = move || cutucker::factor_epoch(m, coo, &run_cfg);
                gate_pass(exec, lease, pass);
            }
            Algo::PTucker => {
                let (run_cfg, exec, lease) = self.pass_cfg();
                let (coo, idx) = match self.prepared.as_ref().expect("prepared resident")
                {
                    PreparedData::Baseline { coo, slice_index } => {
                        (coo, slice_index.as_ref().expect("slice index prepared"))
                    }
                    _ => unreachable!("model/algo mismatch"),
                };
                let m = match &mut self.model {
                    SessionModel::Full(m) => m,
                    SessionModel::Fast(_) => unreachable!("model/algo mismatch"),
                };
                let pass = move || {
                    ptucker::als_factor_sweep(m, coo, idx, &run_cfg);
                };
                gate_pass(exec, lease, pass);
            }
            _ => {
                let stats = self.engine_pass(UpdateKind::Factor);
                self.last_factor_stats = Some(stats);
            }
        }
        t.seconds()
    }

    /// Run the core-update module once (all modes). Returns seconds.
    /// P-Tucker has no core module in Table IV; it is a no-op there.
    pub fn core_pass(&mut self) -> f64 {
        self.ensure_prepared();
        let t = Timer::start();
        match self.algo {
            Algo::CuTucker => {
                let (run_cfg, exec, lease) = self.pass_cfg();
                let coo = match self.prepared.as_ref().expect("prepared resident") {
                    PreparedData::Baseline { coo, .. } => coo,
                    _ => unreachable!("model/algo mismatch"),
                };
                let m = match &mut self.model {
                    SessionModel::Full(m) => m,
                    SessionModel::Fast(_) => unreachable!("model/algo mismatch"),
                };
                let pass = move || cutucker::core_epoch(m, coo, &run_cfg);
                gate_pass(exec, lease, pass);
            }
            Algo::PTucker => {
                debug_assert!(matches!(self.model, SessionModel::Full(_)));
            }
            _ => {
                let stats = self.engine_pass(UpdateKind::Core);
                self.last_core_stats = Some(stats);
            }
        }
        t.seconds()
    }

    /// One full epoch (factor module + optional core module). Advances the
    /// global epoch counter and the LR schedule; does not evaluate — use
    /// [`Session::step`] for the recorded loop.
    pub fn epoch(&mut self) -> EpochTimings {
        let factor_seconds = self.factor_pass();
        let core_seconds =
            if self.cfg.update_cores { self.core_pass() } else { 0.0 };
        // FastTucker keeps no C tables during training; sync them so that
        // evaluation (which reads them) is correct.
        if matches!(self.algo, Algo::FastTucker) {
            if let SessionModel::Fast(m) = &mut self.model {
                m.refresh_all_c();
            }
        }
        // count down the post-ingest warm-up window; when it closes, the
        // next epoch blends back to full sweeps over the merged storage
        if let Some((_, left)) = &mut self.ingest_warm {
            *left -= 1;
            if *left == 0 {
                self.ingest_warm = None;
            }
        }
        self.epoch += 1;
        self.apply_lr_schedule();
        // Epoch boundary = publication point: every C table is consistent
        // with the final factors/cores of this epoch, so concurrent readers
        // may now see it (the epoch-snapshot serving contract). The delta
        // capture recopies only blocks whose rows were refreshed since the
        // previous publication and shares the rest; it runs *outside* the
        // publication lock, which is held only for the Arc swap.
        if let (Some(shared), SessionModel::Fast(m)) = (&self.serving, &mut self.model) {
            let prev = shared.current();
            let snap = Arc::new(ServingSnapshot::capture_delta(m, self.epoch, &prev));
            m.clear_publish_dirty();
            shared.publish(snap);
        }
        EpochTimings { factor_seconds, core_seconds }
    }

    /// Evaluate RMSE/MAE on `data` with the current model. Routes through
    /// the PJRT `predict` artifact when active, else the in-crate path.
    pub fn evaluate(&self, data: &CooTensor) -> (f64, f64) {
        match &self.model {
            SessionModel::Fast(m) => {
                if self.pjrt_active() {
                    if let Ok(res) =
                        eval_rmse_pjrt(m, data, self.runtime.as_ref().unwrap())
                    {
                        return res;
                    }
                }
                rmse_mae(m, data, self.cfg.effective_workers())
            }
            SessionModel::Full(m) => m.rmse_mae(data),
        }
    }

    /// The data self-evaluation runs against when no test set is supplied:
    /// the capped deterministic sample, or the full training set when it is
    /// already within the cap.
    fn self_eval_data(&self) -> &CooTensor {
        if let Some(s) = &self.eval_sample {
            return s;
        }
        match self.prepared.as_ref().expect("prepared resident") {
            PreparedData::Engine(p) => p.coo(),
            PreparedData::Baseline { coo, .. } => coo,
        }
    }

    /// One epoch plus a (cadenced) evaluation, appended to the convergence
    /// series. Returns the record. Epoch numbering is global: a
    /// warm-started session continues where the checkpoint left off.
    pub fn step(&mut self, test: Option<&CooTensor>) -> EpochRecord {
        // a post-eviction rebuild happens here, OUTSIDE the epoch timer:
        // staging cost must never leak into the recorded epoch seconds
        // (the "epoch wall-time excludes staging" invariant)
        self.ensure_prepared();
        let t = Timer::start();
        let timings = self.epoch();
        let seconds = t.seconds();
        let done_here = self.epoch - self.start_epoch;
        let do_eval = done_here % self.cfg.eval_every == 0
            || self.convergence.records.is_empty();
        let (rmse, mae) = if do_eval {
            let v = match test {
                Some(ts) => self.evaluate(ts),
                None => self.evaluate(self.self_eval_data()),
            };
            self.track_early_stop(v.0);
            v
        } else {
            let last = self.convergence.records.last().expect("non-empty checked");
            (last.rmse, last.mae)
        };
        let rec = EpochRecord {
            epoch: self.epoch - 1,
            seconds,
            factor_seconds: timings.factor_seconds,
            core_seconds: timings.core_seconds,
            rmse,
            mae,
        };
        self.convergence.push(rec.clone());
        rec
    }

    fn track_early_stop(&mut self, rmse: f64) {
        if self.cfg.early_stop_patience > 0 {
            if self.best_rmse - rmse > self.cfg.early_stop_min_delta {
                self.stall = 0;
            } else {
                self.stall += 1;
                if self.stall >= self.cfg.early_stop_patience {
                    self.early_stopped = true;
                }
            }
        }
        if rmse < self.best_rmse {
            self.best_rmse = rmse;
        }
    }

    /// Train until the *global* epoch counter reaches `target_epoch` (or
    /// early stopping fires), recording the convergence series against
    /// `test` (falls back to the capped training sample when no test set
    /// is supplied).
    pub fn run_until(
        &mut self,
        target_epoch: usize,
        test: Option<&CooTensor>,
    ) -> SessionReport {
        while self.epoch < target_epoch && !self.early_stopped {
            self.step(test);
        }
        self.report()
    }

    /// Train for `epochs` more epochs — the resumable replacement for the
    /// old closed `Trainer::run` loop; calling it again continues the same
    /// series.
    pub fn run(&mut self, epochs: usize, test: Option<&CooTensor>) -> SessionReport {
        let target = self.epoch + epochs;
        self.run_until(target, test)
    }

    /// Snapshot of the session's progress so far.
    pub fn report(&self) -> SessionReport {
        SessionReport {
            algo_name: self.algo.name().to_string(),
            convergence: self.convergence.clone(),
            prep_seconds: self.prep.total_seconds,
            prep: self.prep.clone(),
            start_epoch: self.start_epoch,
            epochs_completed: self.epoch,
            early_stopped: self.early_stopped,
        }
    }

    /// Save the model as an `FTCK` checkpoint (FastTucker family only).
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        match &self.model {
            SessionModel::Fast(m) => m.save(path),
            SessionModel::Full(_) => {
                bail!("checkpointing is supported for the FastTucker family only")
            }
        }
    }

    /// B-CSF balance statistics (B-CSF layouts only; `None` while the
    /// prepared structures are evicted).
    pub fn balance_stats(&self) -> Option<Vec<BalanceStats>> {
        match self.prepared.as_ref()? {
            PreparedData::Engine(p) => p.balance_stats(),
            PreparedData::Baseline { .. } => None,
        }
    }

    /// Whether the prepared structures are currently resident (a registry
    /// eviction drops them; the next pass rebuilds them transparently).
    pub fn prepared_resident(&self) -> bool {
        self.prepared.is_some()
    }

    /// Bytes the resident prepared structures are charged at against a
    /// registry eviction budget (0 while evicted).
    pub fn prepared_bytes(&self) -> usize {
        if self.prepared.is_some() {
            self.prep.resident_bytes
        } else {
            0
        }
    }

    /// Whether this session retains a pristine rebuild source and can
    /// therefore have its prepared cache evicted ([`Session::new_shared`]
    /// and registry-opened sessions can; plain [`Session::new`] sessions
    /// cannot and are skipped by the registry's budget).
    pub fn evictable(&self) -> bool {
        self.train.is_some()
    }

    /// Drop the prepared structures (shuffled traversal + B-CSF rotations),
    /// returning the bytes freed. The model state is untouched; the next
    /// `step`/pass rebuilds the structures deterministically from the
    /// retained pristine tensor ([`Session::ensure_prepared`]). A no-op
    /// (returns 0) for sessions without a retained rebuild source.
    pub fn evict_prepared(&mut self) -> usize {
        if self.train.is_none() {
            return 0;
        }
        match self.prepared.take() {
            Some(_) => self.prep.resident_bytes,
            None => 0,
        }
    }

    /// Rebuild the prepared structures if an eviction dropped them; no-op
    /// while resident. The rebuild re-derives bit-identical structures
    /// (the staging shuffle and B-CSF builds are pure functions of
    /// `(train, cfg)` — the same guarantee warm-start resume relies on),
    /// accumulates its staging seconds into [`PrepStats`], and increments
    /// `PrepStats::builds`, which is how tests prove an eviction happened.
    pub fn ensure_prepared(&mut self) {
        if self.prepared.is_some() {
            return;
        }
        let train = self
            .train
            .clone()
            .expect("evicted sessions always retain a rebuild source");
        let (prepared, prep) =
            Session::build_prepared(self.algo, &self.cfg, &train)
                .expect("rebuild cannot fail: the same inputs built once already");
        self.prep.shuffle_seconds += prep.shuffle_seconds;
        self.prep.bcsf_seconds += prep.bcsf_seconds;
        self.prep.bcsf_cpu_seconds += prep.bcsf_cpu_seconds;
        self.prep.total_seconds += prep.total_seconds;
        self.prep.builds += prep.builds;
        self.prep.resident_bytes = prep.resident_bytes;
        self.prep.stage_workers = prep.stage_workers;
        self.prepared = Some(prepared);
    }

    /// Absorb appended non-zeros into a live session (FastTucker family
    /// only). The delta may repeat existing coordinates (their values
    /// fold onto the stored ones, exactly as a cold load of the
    /// concatenated tensor would merge them) and may carry row indices
    /// past any mode's current end, which **grows** that mode: the factor
    /// matrix gains deterministically-seeded rows (bitwise what a cold
    /// init of the larger mode would have drawn) and the grown rows are
    /// marked publication-dirty so the next epoch's snapshot delta-copies
    /// exactly the touched blocks.
    ///
    /// Staging is incremental: each existing B-CSF rotation absorbs the
    /// delta by a sorted merge instead of a full re-sort, and the result
    /// is bitwise identical to a cold `Session` over `base ∪ delta`
    /// (`tests/ingest_parity.rs`). `PrepStats::builds` bumps by one and
    /// `blocks_reused`/`blocks_rebuilt` record how much staging work the
    /// clean prefix skipped.
    ///
    /// Nothing is published here — concurrent readers keep the pre-ingest
    /// snapshot until the next completed epoch. With
    /// `cfg.ingest_warm_epochs > 0`, that many subsequent epochs sweep
    /// only the delta non-zeros (warm start) before blending back to full
    /// sweeps.
    ///
    /// All fallible work happens before any state mutates: on `Err` the
    /// session — model, stats, prepared cache — is unchanged.
    pub fn ingest(&mut self, delta: CooTensor) -> Result<IngestReport> {
        if matches!(self.model, SessionModel::Full(_)) {
            bail!("ingestion is supported for the FastTucker family only");
        }
        delta
            .validate()
            .map_err(|e| anyhow::anyhow!("invalid delta tensor: {e}"))?;
        if delta.nnz() == 0 {
            return Ok(IngestReport::default());
        }
        if delta.order() != self.cfg.order {
            bail!(
                "delta order {} != session order {}",
                delta.order(),
                self.cfg.order
            );
        }
        let Some(base) = self.train.clone() else {
            bail!(
                "ingestion needs a retained pristine tensor: open the session \
                 with Session::new_shared or through a SessionRegistry"
            );
        };
        // dims after growth: the larger of the session's and the delta's
        let new_dims: Vec<usize> = self
            .cfg
            .dims
            .iter()
            .zip(delta.dims())
            .map(|(&d, &g)| d.max(g))
            .collect();
        // re-dimension the delta so every derived structure (concat,
        // delta-only warm-up storage) agrees on the grown shape
        let mut delta_full =
            CooTensor::with_capacity(new_dims.clone(), delta.nnz());
        for e in 0..delta.nnz() {
            delta_full.push(delta.index(e), delta.value(e));
        }
        let mut concat =
            CooTensor::with_capacity(new_dims.clone(), base.nnz() + delta.nnz());
        for e in 0..base.nnz() {
            concat.push(base.index(e), base.value(e));
        }
        for e in 0..delta_full.nnz() {
            concat.push(delta_full.index(e), delta_full.value(e));
        }
        let mut new_cfg = self.cfg.clone();
        new_cfg.dims = new_dims.clone();
        self.ensure_prepared();
        let staged = match self.prepared.as_ref().expect("just ensured") {
            PreparedData::Engine(p) => p.restage(&new_cfg, &concat, &delta_full)?,
            PreparedData::Baseline { .. } => unreachable!("rejected above"),
        };
        let warm = if self.cfg.ingest_warm_epochs > 0 {
            Some((
                PreparedStorage::prepare(self.algo, &new_cfg, &delta_full)?,
                self.cfg.ingest_warm_epochs,
            ))
        } else {
            None
        };
        // --- commit point: nothing below can fail ---
        let mut grown = Vec::new();
        if let SessionModel::Fast(m) = &mut self.model {
            for (n, &d) in new_dims.iter().enumerate() {
                if d > self.cfg.dims[n] {
                    grown.push((n, self.cfg.dims[n], d));
                    m.grow_mode(n, d, self.cfg.seed);
                }
            }
        }
        let added_nnz = delta.nnz();
        let sp = staged.prep().clone();
        self.prep.shuffle_seconds += sp.shuffle_seconds;
        self.prep.bcsf_seconds += sp.bcsf_seconds;
        self.prep.bcsf_cpu_seconds += sp.bcsf_cpu_seconds;
        self.prep.total_seconds += sp.total_seconds;
        self.prep.builds += sp.builds;
        self.prep.resident_bytes = sp.resident_bytes;
        self.prep.peak_resident_bytes =
            self.prep.peak_resident_bytes.max(sp.peak_resident_bytes);
        self.prep.blocks_reused += sp.blocks_reused;
        self.prep.blocks_rebuilt += sp.blocks_rebuilt;
        self.cfg.dims = new_dims;
        self.eval_sample = build_eval_sample(staged.coo(), &self.cfg);
        self.prepared = Some(PreparedData::Engine(staged));
        self.train = Some(Arc::new(concat));
        self.ingest_warm = warm;
        Ok(IngestReport {
            added_nnz,
            grown,
            blocks_reused: sp.blocks_reused,
            blocks_rebuilt: sp.blocks_rebuilt,
        })
    }

    /// [`Session::ingest`] straight from a FROSTT-style `.tns` text file
    /// (dims inferred from the data). The file is parsed and validated
    /// **before** any session state is touched, so a truncated or garbage
    /// file rejects the whole delta atomically.
    pub fn ingest_file(&mut self, path: &Path, one_based: bool) -> Result<IngestReport> {
        let delta = crate::tensor::io::read_text(path, None, one_based)?;
        self.ingest(delta)
    }

    /// Attach (or detach, with `None`) a shared pass executor. While
    /// attached, every training pass — engine and full-core baseline
    /// alike — runs under the executor's admission gate with its worker
    /// budget — the [`SessionRegistry`] sets this so all registered
    /// sessions share one pool.
    pub fn set_executor(&mut self, executor: Option<Arc<Executor>>) {
        self.executor = executor;
    }

    /// The attached shared executor, if any.
    pub fn executor(&self) -> Option<&Arc<Executor>> {
        self.executor.as_ref()
    }

    /// Configure worker-subset leasing for executor-gated passes:
    /// `Some(n)` makes every pass request an `n`-worker
    /// [`crate::sched::WorkerLease`] (clamped to the budget) so passes of
    /// different tenants overlap when their lease sizes fit the budget
    /// together; `None` (the default) takes the full budget exclusively.
    /// No effect while no executor is attached. The lease size — not the
    /// slot placement — determines the pass's math, so per-session results
    /// are deterministic for a fixed lease size (bit-reproducible at
    /// `n = 1`, proven in `tests/concurrent_passes.rs`).
    pub fn set_lease_workers(&mut self, lease: Option<usize>) {
        self.lease_workers = lease;
    }

    /// The configured pass lease size, if worker-subset leasing is on.
    pub fn lease_workers(&self) -> Option<usize> {
        self.lease_workers
    }

    /// Whether the early-stopping rule has ended this session's run.
    pub fn early_stopped(&self) -> bool {
        self.early_stopped
    }

    /// A cloneable, thread-safe [`ServingHandle`] over this session
    /// (FastTucker family only). The first call refreshes the `C` tables
    /// and publishes the current state as the initial snapshot; afterwards
    /// every completed [`Session::epoch`] publishes a fresh one, so
    /// concurrent readers always score against the last completed epoch —
    /// never a torn mid-pass view.
    pub fn serving_handle(&mut self) -> Result<ServingHandle> {
        if matches!(self.model, SessionModel::Full(_)) {
            bail!("serving is supported for the FastTucker family only");
        }
        if self.serving.is_none() {
            // Re-derive the tables through the session's ACTIVE refresh
            // path — PJRT artifact when active, in-crate GEMM otherwise —
            // so the initial snapshot matches the tables training
            // maintains bit-for-bit and attaching a handle mid-training
            // never perturbs the trajectory under either backend.
            let use_pjrt = self.pjrt_backend_active();
            let runtime = self.runtime.as_ref();
            if let SessionModel::Fast(m) = &mut self.model {
                for n in 0..m.order() {
                    exec::refresh_c(m, n, if use_pjrt { runtime } else { None });
                }
            }
            // the tables were rewritten outside the engine's refresh hook
            self.engine_state.invalidate_tables();
            let snapshot = match &mut self.model {
                SessionModel::Fast(m) => {
                    let snap = ServingSnapshot::capture(m, self.epoch);
                    // the full capture copied every block, so the next
                    // epoch's delta starts from a clean slate
                    m.clear_publish_dirty();
                    snap
                }
                SessionModel::Full(_) => unreachable!("rejected above"),
            };
            self.serving = Some(Arc::new(ServingShared::new(snapshot)));
        }
        Ok(ServingHandle::from_shared(
            self.serving.clone().expect("just created"),
        ))
    }

    /// Per-worker scheduling stats of the most recent engine factor pass
    /// (summed over the epoch's per-mode passes). `None` before the first
    /// pass and for the full-core baselines.
    pub fn factor_worker_stats(&self) -> Option<&WorkerStats> {
        self.last_factor_stats.as_ref()
    }

    /// Per-worker scheduling stats of the most recent engine core pass.
    pub fn core_worker_stats(&self) -> Option<&WorkerStats> {
        self.last_core_stats.as_ref()
    }

    /// Per-tenant QoS telemetry: EWMAs of pass latency and claimed nnz,
    /// cumulative admission-gate wait, stolen blocks, and the most recent
    /// pass's slots/imbalances. Updated once per engine pass; the
    /// registry's lease-rebalancing policy reads it between passes.
    pub fn qos_stats(&self) -> &QosStats {
        &self.qos
    }

    /// The prepared-build generation the engine's cached shard plans (and
    /// steal-queue seeds) are keyed to. After any engine pass it equals
    /// `PrepStats::builds`, so a post-eviction rebuild observably re-keyed
    /// the plan cache instead of reusing plans built against the dropped
    /// storage.
    pub fn engine_storage_epoch(&self) -> u64 {
        self.engine_state.storage_epoch()
    }

    /// Block counts of the engine's cached per-mode shard plans (empty
    /// until the first engine pass, and right after a storage rebuild
    /// dropped the cache).
    pub fn engine_plan_block_counts(&self) -> Vec<usize> {
        self.engine_state.plan_block_counts()
    }
}

/// Deterministic capped sample of the training set for self-evaluation:
/// full-set RMSE per epoch costs as much as another training pass on big
/// tensors, so `test: None` sessions evaluate on at most
/// `cfg.eval_sample_nnz` elements chosen once per `(train, seed)`.
///
/// Sparse partial Fisher–Yates: only the displaced slots are stored, so
/// the transient cost is O(cap) regardless of nnz (the cap exists
/// precisely for tensors where an O(nnz) id array would hurt).
fn build_eval_sample(train: &CooTensor, cfg: &TrainConfig) -> Option<CooTensor> {
    let cap = cfg.eval_sample_nnz;
    let nnz = train.nnz();
    if cap == 0 || nnz <= cap {
        return None;
    }
    let mut rng = Rng::new(cfg.seed ^ 0xE7A1_5A3B);
    let mut displaced = std::collections::HashMap::<usize, usize>::new();
    let mut sample = CooTensor::with_capacity(train.dims().to_vec(), cap);
    for k in 0..cap {
        let j = k + rng.next_below(nnz - k);
        // the value "at" slot j (identity unless a previous swap moved one)
        let pick = displaced.get(&j).copied().unwrap_or(j);
        let at_k = displaced.get(&k).copied().unwrap_or(k);
        displaced.insert(j, at_k);
        sample.push(train.index(pick), train.value(pick));
    }
    Some(sample)
}

/// Gate one stats-less (full-core baseline) pass through the shared
/// executor, honoring the session's lease configuration; runs inline when
/// no executor is attached.
fn gate_pass(exec: Option<Arc<Executor>>, lease: Option<usize>, pass: impl FnOnce()) {
    match (exec, lease) {
        (Some(e), Some(n)) => e.run_quiet_leased(n, |_workers| pass()),
        (Some(e), None) => e.run_quiet(|_workers| pass()),
        (None, _) => pass(),
    }
}

/// Test-set RMSE/MAE through the PJRT `predict` artifact: gather the C rows
/// of every test element into `N` dense `B×R` blocks and run the batched
/// chain-product kernel.
fn eval_rmse_pjrt(
    m: &ModelState,
    data: &CooTensor,
    rt: &PjrtRuntime,
) -> Result<(f64, f64)> {
    let nnz = data.nnz();
    if nnz == 0 {
        return Ok((0.0, 0.0));
    }
    let order = m.order();
    let r = m.r();
    let mut crows: Vec<Matrix> = (0..order).map(|_| Matrix::zeros(nnz, r)).collect();
    for e in 0..nnz {
        let coords = data.index(e);
        for n in 0..order {
            let src = m.c_tables[n].row(coords[n] as usize);
            crows[n].row_mut(e).copy_from_slice(src);
        }
    }
    let xhat = rt.predict_batch(&crows)?;
    let (mut se, mut ae) = (0.0f64, 0.0f64);
    for e in 0..nnz {
        let err = (data.value(e) - xhat[e]) as f64;
        se += err * err;
        ae += err.abs();
    }
    Ok(((se / nnz as f64).sqrt(), ae / nnz as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::split::train_test;
    use crate::data::synthetic::{recommender, RecommenderSpec};

    fn cfg_for(t: &CooTensor) -> TrainConfig {
        TrainConfig {
            order: t.order(),
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 2,
            block_nnz: 512,
            fiber_threshold: 32,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn every_algorithm_trains_and_improves() {
        let t = recommender(&RecommenderSpec::tiny(), 51);
        let (train, test) = train_test(&t, 0.2, 3);
        for algo in [
            Algo::FastTucker,
            Algo::FasterTuckerCoo,
            Algo::FasterTuckerBcsf,
            Algo::FasterTucker,
            Algo::CuTucker,
            Algo::PTucker,
        ] {
            let mut cfg = cfg_for(&train);
            if algo == Algo::CuTucker || algo == Algo::PTucker {
                cfg.j = 4; // keep the J^N core tensor small in tests
            }
            let mut session = Session::new(algo, cfg, &train).unwrap();
            let report = session.run(3, Some(&test));
            assert_eq!(report.convergence.records.len(), 3);
            assert_eq!(report.epochs_completed, 3);
            assert!(
                report.convergence.improved(),
                "{} did not improve: {:?}",
                algo.name(),
                report
                    .convergence
                    .records
                    .iter()
                    .map(|r| r.rmse)
                    .collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn factor_and_core_passes_timed_separately() {
        let t = recommender(&RecommenderSpec::tiny(), 52);
        let mut session = Session::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        let timings = session.epoch();
        assert!(timings.factor_seconds > 0.0);
        assert!(timings.core_seconds > 0.0);
    }

    #[test]
    fn update_cores_false_skips_core_pass() {
        let t = recommender(&RecommenderSpec::tiny(), 53);
        let mut cfg = cfg_for(&t);
        cfg.update_cores = false;
        let mut session = Session::new(Algo::FasterTucker, cfg, &t).unwrap();
        let timings = session.epoch();
        assert_eq!(timings.core_seconds, 0.0);
    }

    #[test]
    fn balance_stats_only_for_bcsf() {
        let t = recommender(&RecommenderSpec::tiny(), 54);
        let a = Session::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        assert_eq!(a.balance_stats().unwrap().len(), 3);
        let b = Session::new(Algo::FastTucker, cfg_for(&t), &t).unwrap();
        assert!(b.balance_stats().is_none());
    }

    #[test]
    fn engine_passes_record_worker_stats() {
        let t = recommender(&RecommenderSpec::tiny(), 57);
        let mut session = Session::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        assert!(session.factor_worker_stats().is_none());
        session.epoch();
        let fs = session.factor_worker_stats().expect("factor stats recorded");
        assert!(fs.total_blocks() > 0);
        assert!(fs.imbalance() >= 1.0 - 1e-9);
        assert!(session.core_worker_stats().is_some());

        // full-core baselines bypass the engine and record nothing
        let mut cfg = cfg_for(&t);
        cfg.j = 4;
        cfg.r = 4;
        let mut base = Session::new(Algo::CuTucker, cfg, &t).unwrap();
        base.epoch();
        assert!(base.factor_worker_stats().is_none());
    }

    #[test]
    fn invalid_config_rejected() {
        let t = recommender(&RecommenderSpec::tiny(), 55);
        let mut cfg = cfg_for(&t);
        cfg.j = 0;
        assert!(Session::new(Algo::FasterTucker, cfg, &t).is_err());
    }

    #[test]
    fn fastucker_eval_sees_fresh_c_tables() {
        let t = recommender(&RecommenderSpec::tiny(), 56);
        let mut session = Session::new(Algo::FastTucker, cfg_for(&t), &t).unwrap();
        session.epoch();
        if let SessionModel::Fast(m) = &session.model {
            for n in 0..3 {
                let expect = m.factors[n].matmul(&m.cores[n]);
                assert!(expect.max_abs_diff(&m.c_tables[n]) < 1e-5);
            }
        }
    }

    #[test]
    fn storages_built_once_across_epochs_and_passes() {
        let t = recommender(&RecommenderSpec::tiny(), 58);
        let mut session = Session::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        let staged = session.prep_stats().clone();
        assert_eq!(staged.builds, 1);
        session.factor_pass();
        session.core_pass();
        session.run(2, None);
        // nothing on the epoch path may rebuild or re-time the staging
        assert_eq!(session.prep_stats().builds, 1);
        assert_eq!(session.prep_stats().total_seconds, staged.total_seconds);
    }

    #[test]
    fn self_eval_sample_is_capped_and_deterministic() {
        let t = recommender(&RecommenderSpec::tiny(), 59);
        let mut cfg = cfg_for(&t);
        cfg.eval_sample_nnz = 500;
        let a = Session::new(Algo::FasterTucker, cfg.clone(), &t).unwrap();
        let b = Session::new(Algo::FasterTucker, cfg.clone(), &t).unwrap();
        let sa = a.eval_sample().expect("capped sample built");
        let sb = b.eval_sample().expect("capped sample built");
        assert_eq!(sa.nnz(), 500);
        assert_eq!(sa.canonical_elements(), sb.canonical_elements());
        // distinct elements (sample without replacement)
        let mut elems = sa.canonical_elements();
        elems.dedup_by(|x, y| x.0 == y.0);
        assert_eq!(elems.len(), 500);
        // cap at or above the training size disables sampling
        cfg.eval_sample_nnz = t.nnz();
        let c = Session::new(Algo::FasterTucker, cfg, &t).unwrap();
        assert!(c.eval_sample().is_none());
    }

    #[test]
    fn eval_cadence_carries_metrics_between_evals() {
        let t = recommender(&RecommenderSpec::tiny(), 60);
        let (train, test) = train_test(&t, 0.2, 4);
        let mut cfg = cfg_for(&train);
        cfg.eval_every = 2;
        let mut session = Session::new(Algo::FasterTucker, cfg, &train).unwrap();
        let report = session.run(4, Some(&test));
        let r = &report.convergence.records;
        assert_eq!(r.len(), 4);
        // epoch 1 (count 1) evaluates because the series is empty; epoch 3
        // (count 3, 3 % 2 != 0) must carry epoch 2's metrics forward
        assert_eq!(r[2].rmse, r[1].rmse);
        assert_eq!(r[2].mae, r[1].mae);
    }

    #[test]
    fn early_stopping_ends_the_run() {
        let t = recommender(&RecommenderSpec::tiny(), 65);
        let mut cfg = cfg_for(&t);
        cfg.early_stop_patience = 1;
        cfg.early_stop_min_delta = 1e9; // nothing ever counts as improving
        let mut session = Session::new(Algo::FasterTucker, cfg, &t).unwrap();
        let report = session.run(10, None);
        // first eval seeds best (inf -> finite passes any delta), second
        // stalls and trips the patience-1 rule
        assert!(report.early_stopped);
        assert_eq!(report.convergence.records.len(), 2);
        assert_eq!(report.epochs_completed, 2);
    }

    #[test]
    fn lr_decay_schedule_advances_per_epoch() {
        let t = recommender(&RecommenderSpec::tiny(), 66);
        let mut cfg = cfg_for(&t);
        cfg.lr_decay = 0.5;
        let mut session = Session::new(Algo::FasterTucker, cfg.clone(), &t).unwrap();
        assert_eq!(session.current_lr().0, cfg.lr_a);
        session.epoch();
        session.epoch();
        assert_eq!(session.current_lr().0, cfg.lr_a * 0.25);
        assert_eq!(session.current_lr().1, cfg.lr_b * 0.25);
    }

    #[test]
    fn warm_start_rejects_mismatched_shapes() {
        let t = recommender(&RecommenderSpec::tiny(), 67);
        let cfg = cfg_for(&t);
        let model = ModelState::init(&cfg, 1);
        let mut other = cfg.clone();
        other.j = cfg.j * 2;
        assert!(Session::warm_start(Algo::FasterTucker, other, &t, model.clone(), 0)
            .is_err());
        // malformed dims list must be an Err, not an index panic
        let mut longer = cfg.clone();
        longer.dims.push(50);
        assert!(Session::warm_start(Algo::FasterTucker, longer, &t, model.clone(), 0)
            .is_err());
        assert!(Session::warm_start(Algo::PTucker, cfg.clone(), &t, model.clone(), 0)
            .is_err());
        assert!(Session::warm_start(Algo::FasterTucker, cfg, &t, model, 3).is_ok());
    }

    #[test]
    fn evicted_prepared_rebuilds_transparently() {
        let t = recommender(&RecommenderSpec::tiny(), 69);
        // plain `new` retains no rebuild source: never evictable, no copy
        let mut plain = Session::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        assert!(!plain.evictable());
        assert_eq!(plain.evict_prepared(), 0);
        assert!(plain.prepared_resident());

        // `new_shared` shares the caller's Arc and is evictable
        let arc = std::sync::Arc::new(t.clone());
        let mut s =
            Session::new_shared(Algo::FasterTucker, cfg_for(&t), arc.clone()).unwrap();
        assert!(s.evictable());
        assert!(std::sync::Arc::strong_count(&arc) >= 2);
        assert!(s.prepared_resident());
        assert!(s.prepared_bytes() > 0);
        let freed = s.evict_prepared();
        assert!(freed > 0);
        assert!(!s.prepared_resident());
        assert_eq!(s.prepared_bytes(), 0);
        assert_eq!(s.evict_prepared(), 0, "double eviction frees nothing");
        // the next step rebuilds without any caller involvement
        s.step(None);
        assert!(s.prepared_resident());
        assert_eq!(s.prep_stats().builds, 2);
    }

    #[test]
    fn serving_handle_tracks_completed_epochs() {
        let t = recommender(&RecommenderSpec::tiny(), 70);
        let mut s = Session::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        let h = s.serving_handle().unwrap();
        assert_eq!(h.epoch(), 0);
        s.step(None);
        assert_eq!(h.epoch(), 1);
        s.step(None);
        assert_eq!(h.epoch(), 2);
        // a second call returns a handle over the same publication slot
        let h2 = s.serving_handle().unwrap();
        assert_eq!(h2.epoch(), 2);
        // full-core baselines cannot serve from C tables
        let mut cfg = cfg_for(&t);
        cfg.j = 4;
        let mut base = Session::new(Algo::CuTucker, cfg, &t).unwrap();
        assert!(base.serving_handle().is_err());
    }

    #[test]
    fn attached_executor_runs_every_engine_pass() {
        use crate::sched::Executor;
        use std::sync::Arc;
        let t = recommender(&RecommenderSpec::tiny(), 71);
        let mut s = Session::new(Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        let ex = Arc::new(Executor::new(1));
        s.set_executor(Some(ex.clone()));
        assert!(s.executor().is_some());
        s.epoch();
        // factor + core pass, both through the shared executor
        assert_eq!(ex.passes_executed(), 2);
        s.set_executor(None);
        s.epoch();
        assert_eq!(ex.passes_executed(), 2, "detached sessions run privately");
    }

    #[test]
    fn leased_passes_run_lease_sized_and_attribute_leased_slots() {
        use crate::sched::Executor;
        use std::sync::Arc;
        let t = recommender(&RecommenderSpec::tiny(), 72);
        let mut s = Session::new(Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        assert_eq!(s.backend_name(), "cpu");
        assert_eq!(s.lease_workers(), None);
        let ex = Arc::new(Executor::new(4));
        s.set_executor(Some(ex.clone()));
        s.set_lease_workers(Some(2));
        assert_eq!(s.lease_workers(), Some(2));
        s.epoch();
        // per-lease stats: the pass ran with exactly the lease's workers
        let fs = s.factor_worker_stats().expect("factor stats recorded");
        assert_eq!(fs.blocks.len(), 2);
        assert!(fs.nnz_imbalance() >= 1.0 - 1e-9);
        assert_eq!(ex.leases_granted(), 2);
        // sequential leases reuse the first free slots; the budget's other
        // slots never see work
        let total = ex.total_stats();
        assert_eq!(total.blocks.len(), 4);
        assert_eq!(total.blocks[2] + total.blocks[3], 0);
        let core_blocks = s.core_worker_stats().unwrap().total_blocks();
        assert_eq!(total.total_blocks(), fs.total_blocks() + core_blocks);
    }

    #[test]
    fn run_is_resumable_across_calls() {
        let t = recommender(&RecommenderSpec::tiny(), 68);
        let mut session = Session::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        session.run(2, None);
        let report = session.run(3, None);
        assert_eq!(report.convergence.records.len(), 5);
        assert_eq!(report.epochs_completed, 5);
        let epochs: Vec<usize> =
            report.convergence.records.iter().map(|r| r.epoch).collect();
        assert_eq!(epochs, vec![0, 1, 2, 3, 4]);
    }
}
