//! Multi-tensor sessions: a process-wide [`SessionRegistry`] serving many
//! decompositions from one process.
//!
//! The ROADMAP's "multi-tensor sessions" item, made concrete:
//!
//! * **One registry, many sessions** — sessions are keyed by dataset name
//!   and owned by the registry; callers address them by name
//!   ([`SessionRegistry::step`], [`SessionRegistry::run`],
//!   [`SessionRegistry::serving_handle`]).
//! * **One shared worker pool** — the registry owns a single
//!   [`Executor`] and attaches it to every admitted session, so every
//!   training pass in the process — engine and full-core baseline alike —
//!   runs on the same worker budget (one `ShardPlan` executor reused
//!   across sessions) instead of each session bringing
//!   `TrainConfig::workers` threads of its own.
//! * **An eviction budget** — each session's
//!   [`crate::tensor::prepared::PreparedStorage`] cache
//!   (shuffled traversal + B-CSF rotations) is charged by its measured
//!   bytes (`PrepStats::resident_bytes`). When the resident total exceeds
//!   the budget, caches are evicted by a **size/frequency-aware score**
//!   (GDSF-style: `hits / resident_bytes`, deterministic tie-break on
//!   name — so a big, rarely-touched cache goes before a small, hot one,
//!   where pure LRU would only look at recency); an evicted session
//!   rebuilds **transparently** on its next `step` (deterministically
//!   identical structures — the staging shuffle and B-CSF builds are pure
//!   functions of `(train, cfg)`), and its `PrepStats::builds` counter
//!   increments so eviction is observable. The model state
//!   (factors/cores/C tables — the paper's point is that these are
//!   *small*) is never evicted; only the heavy prepared structures are.
//! * **Optional pass overlap** — [`SessionRegistry::set_pass_lease`]
//!   plumbs a worker-subset lease size through the admission policy to
//!   every admitted session, so tenants' passes overlap on disjoint
//!   leased subsets of the executor budget instead of serializing behind
//!   the full-budget gate (see [`crate::sched::Executor`] and
//!   `tests/concurrent_passes.rs` for the bitwise-parity proof).
//!
//! The active session is always allowed residency even if it alone
//! exceeds the budget — a budget too small for one session degrades to
//! "evict everything else", never to a livelock.

use super::serving::ServingHandle;
use super::{IngestReport, Session};
use crate::algo::Algo;
use crate::config::{NumaMode, TrainConfig};
use crate::metrics::EpochRecord;
use crate::sched::topo::Topology;
use crate::sched::Executor;
use crate::tensor::coo::CooTensor;
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// QoS policy for adaptive lease sizing and admission backpressure.
///
/// While set ([`SessionRegistry::set_qos_policy`]), the registry resizes
/// every tenant's pass lease before each step from an EWMA of the
/// tenant's measured pass latency (claimed-nnz EWMA as the cold-start
/// proxy): heavy tenants get more of the shared worker budget, but no
/// tenant ever drops below the fairness floor. `max_pending` bounds the
/// executor's admission queue so a flood of training passes is refused
/// ([`crate::sched::Backpressure`]) instead of growing the wait line
/// without bound.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QosPolicy {
    /// Minimum lease size any tenant may be shrunk to (clamped to at
    /// least 1, and to an equal split when the budget is too small to
    /// give every tenant this many).
    pub fairness_floor: usize,
    /// Admission-queue bound applied to the shared executor: a pass that
    /// cannot start immediately while this many tickets already wait is
    /// refused with backpressure. `usize::MAX` = never refuse.
    pub max_pending: usize,
}

impl Default for QosPolicy {
    fn default() -> QosPolicy {
        QosPolicy { fairness_floor: 1, max_pending: usize::MAX }
    }
}

/// Split `budget` worker slots across tenants proportionally to
/// `weights`, with a per-tenant floor. Deterministic: fractional slots go
/// by largest remainder, ties to the lowest index. The floor is clamped
/// to an equal split when `floor * k` exceeds the budget (every tenant
/// still gets at least 1; leases then overlap via executor queuing).
fn lease_split(weights: &[f64], budget: usize, floor: usize) -> Vec<usize> {
    let k = weights.len();
    if k == 0 {
        return Vec::new();
    }
    let budget = budget.max(1);
    let floor = floor.max(1).min((budget / k).max(1));
    let mut leases = vec![floor; k];
    let extra = budget.saturating_sub(floor * k);
    if extra == 0 {
        return leases;
    }
    let total: f64 = weights.iter().map(|w| w.max(0.0)).sum();
    let exact: Vec<f64> = if total > 0.0 {
        weights.iter().map(|w| extra as f64 * w.max(0.0) / total).collect()
    } else {
        vec![extra as f64 / k as f64; k]
    };
    let mut handed = 0usize;
    for (l, e) in leases.iter_mut().zip(&exact) {
        let whole = e.floor() as usize;
        *l += whole;
        handed += whole;
    }
    // largest fractional remainder gets the leftover slots, ties to the
    // lowest index (sort is stable, so equal keys keep index order)
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| {
        let (fa, fb) = (exact[a] - exact[a].floor(), exact[b] - exact[b].floor());
        fb.total_cmp(&fa)
    });
    for &i in order.iter().take(extra - handed) {
        leases[i] += 1;
    }
    leases
}

/// One admitted session plus its eviction-score bookkeeping.
struct Entry {
    name: String,
    session: Session,
    /// Touches (admission, step/run, get_mut) — the frequency half of the
    /// GDSF eviction score.
    hits: u64,
}

impl Entry {
    /// GDSF-style eviction score: touches per resident byte. The cheapest
    /// cache to lose — big and cold — scores lowest and goes first; ties
    /// break deterministically on name.
    fn score(&self) -> f64 {
        self.hits as f64 / self.session.prepared_bytes().max(1) as f64
    }
}

/// A process-wide registry of named [`Session`]s sharing one worker pool
/// and one prepared-storage eviction budget.
///
/// # Examples
///
/// ```
/// use fastertucker::algo::Algo;
/// use fastertucker::config::TrainConfig;
/// use fastertucker::coordinator::SessionRegistry;
/// use fastertucker::tensor::coo::CooTensor;
///
/// let mut t = CooTensor::new(vec![4, 3, 2]);
/// t.push(&[0, 0, 0], 2.0);
/// t.push(&[1, 2, 1], 4.0);
/// t.push(&[3, 1, 0], 3.0);
/// t.push(&[2, 2, 1], 5.0);
/// let cfg = TrainConfig {
///     order: 3, dims: vec![4, 3, 2], j: 2, r: 2,
///     lr_a: 0.01, lr_b: 1e-4, workers: 1, eval_sample_nnz: 0,
///     ..TrainConfig::default()
/// };
/// // 1 worker, unlimited budget (0)
/// let mut reg = SessionRegistry::new(1, 0);
/// reg.open("ratings", Algo::FasterTuckerCoo, cfg, &t).unwrap();
/// let rec = reg.step("ratings", None).unwrap();
/// assert_eq!(rec.epoch, 0);
/// assert!(reg.executor().passes_executed() >= 1);
/// ```
pub struct SessionRegistry {
    executor: Arc<Executor>,
    /// Resident-bytes budget over all prepared caches; `0` = unlimited.
    budget_bytes: usize,
    entries: Vec<Entry>,
    /// Worker-subset lease size applied to every admitted session
    /// (`None` = exclusive full-budget passes).
    lease_workers: Option<usize>,
    /// Adaptive lease sizing + admission backpressure; while set, it
    /// overrides the static `lease_workers` per tenant before each step.
    qos: Option<QosPolicy>,
    evictions: usize,
}

impl SessionRegistry {
    /// Registry with a shared worker budget (`workers`, `0` = all cores)
    /// and a prepared-cache byte budget (`budget_bytes`, `0` = unlimited).
    pub fn new(workers: usize, budget_bytes: usize) -> SessionRegistry {
        SessionRegistry::with_numa(workers, budget_bytes, NumaMode::Off)
    }

    /// [`SessionRegistry::new`] with an explicit NUMA mode for the shared
    /// executor: the worker slots get memory-hierarchy homes from
    /// [`Topology::detect`], lease allocation becomes node-compact, and
    /// leased passes pin their workers to the homes' CPUs.
    /// [`NumaMode::Off`] (what [`SessionRegistry::new`] uses) is the
    /// topology-blind pre-NUMA executor.
    pub fn with_numa(
        workers: usize,
        budget_bytes: usize,
        numa: NumaMode,
    ) -> SessionRegistry {
        let topo = Topology::detect(numa);
        SessionRegistry {
            executor: Arc::new(Executor::with_topology(workers, &topo)),
            budget_bytes,
            entries: Vec::new(),
            lease_workers: None,
            qos: None,
            evictions: 0,
        }
    }

    /// Install (or clear, with `None`) the QoS policy. While installed,
    /// [`SessionRegistry::rebalance_leases`] runs before every
    /// [`SessionRegistry::step`], resizing each tenant's lease from its
    /// measured pass-latency EWMA (bounded below by the fairness floor),
    /// and the shared executor refuses passes with backpressure once
    /// `max_pending` tickets wait at the admission gate.
    pub fn set_qos_policy(&mut self, policy: Option<QosPolicy>) {
        self.qos = policy;
        self.executor
            .set_max_pending(policy.map_or(usize::MAX, |p| p.max_pending));
        if policy.is_none() {
            // restore the static lease configuration adaptive sizing
            // had been overriding
            for e in &mut self.entries {
                e.session.set_lease_workers(self.lease_workers);
            }
        }
    }

    /// The installed QoS policy, if any.
    pub fn qos_policy(&self) -> Option<QosPolicy> {
        self.qos
    }

    /// Resize every tenant's pass lease from the QoS telemetry: each
    /// tenant's weight is its pass-latency EWMA (claimed-nnz EWMA before
    /// latency data exists; tenants with no passes yet get the mean
    /// measured weight so cold tenants start at a fair middle share), and
    /// the shared budget is split proportionally with
    /// `policy.fairness_floor` as the per-tenant minimum. Deterministic
    /// for fixed telemetry. No-op while no policy is installed or the
    /// registry is empty.
    pub fn rebalance_leases(&mut self) {
        let Some(policy) = self.qos else { return };
        if self.entries.is_empty() {
            return;
        }
        let raw: Vec<Option<f64>> = self
            .entries
            .iter()
            .map(|e| {
                let q = e.session.qos_stats();
                if q.passes == 0 {
                    None
                } else if q.pass_latency_ewma > 0.0 {
                    Some(q.pass_latency_ewma)
                } else if q.nnz_ewma > 0.0 {
                    Some(q.nnz_ewma)
                } else {
                    None
                }
            })
            .collect();
        let measured: Vec<f64> = raw.iter().copied().flatten().collect();
        let fallback = if measured.is_empty() {
            1.0
        } else {
            measured.iter().sum::<f64>() / measured.len() as f64
        };
        let weights: Vec<f64> =
            raw.into_iter().map(|w| w.unwrap_or(fallback)).collect();
        let leases =
            lease_split(&weights, self.executor.workers(), policy.fairness_floor);
        // node-compact cap: no adaptive lease is ever sized past the
        // biggest single node's slot count, so a resized lease can always
        // be placed without straddling nodes (on a single-node executor
        // the cap equals the budget and changes nothing)
        let cap = self.executor.max_node_slots().max(1);
        for (e, &n) in self.entries.iter_mut().zip(&leases) {
            e.session.set_lease_workers(Some(n.min(cap)));
        }
    }

    /// Per-tenant QoS telemetry plus the shared executor's admission
    /// counters, as one JSON report (the registry's stats export).
    pub fn qos_report(&self) -> Json {
        let tenants: BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut t = match e.session.qos_stats().to_json() {
                    Json::Obj(m) => m,
                    _ => unreachable!("QosStats::to_json returns an object"),
                };
                t.insert(
                    "lease_workers".to_string(),
                    e.session
                        .lease_workers()
                        .map_or(Json::Null, |n| Json::num(n as f64)),
                );
                (e.name.clone(), Json::Obj(t))
            })
            .collect();
        Json::obj(vec![
            ("tenants", Json::Obj(tenants)),
            (
                "executor",
                Json::obj(vec![
                    ("workers", Json::num(self.executor.workers() as f64)),
                    (
                        "queue_wait_seconds",
                        Json::num(self.executor.queue_wait_seconds()),
                    ),
                    (
                        "admission_rejections",
                        Json::num(self.executor.admission_rejections() as f64),
                    ),
                    (
                        "pending_tickets",
                        Json::num(self.executor.pending_tickets() as f64),
                    ),
                ]),
            ),
        ])
    }

    /// Admission-policy knob for pass overlap: lease `n` of the shared
    /// budget's workers to every pass of every admitted session (current
    /// and future); `None` restores exclusive full-budget passes. See
    /// [`Session::set_lease_workers`].
    ///
    /// The registry's own `step`/`run` methods take `&mut self` and are
    /// therefore serial; the overlap comes from driving leased sessions
    /// on separate threads while they share this registry's executor —
    /// extract tenants with [`SessionRegistry::take_attached`] (which
    /// keeps the executor attachment and lease), run them concurrently,
    /// and re-[`SessionRegistry::insert`] them afterwards.
    /// `tests/concurrent_passes.rs` proves the overlapped result bitwise
    /// equal to serialized runs.
    pub fn set_pass_lease(&mut self, lease: Option<usize>) {
        self.lease_workers = lease;
        for e in &mut self.entries {
            e.session.set_lease_workers(lease);
        }
    }

    /// The lease size the admission policy applies to admitted sessions.
    pub fn pass_lease(&self) -> Option<usize> {
        self.lease_workers
    }

    /// The shared pass executor every admitted session runs on.
    pub fn executor(&self) -> &Arc<Executor> {
        &self.executor
    }

    /// The prepared-cache byte budget (`0` = unlimited).
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Prepared-cache evictions performed so far.
    pub fn evictions(&self) -> usize {
        self.evictions
    }

    /// Number of registered sessions.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the registry holds no sessions.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Registered names, in insertion order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// Total bytes of currently-resident prepared caches.
    pub fn resident_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.session.prepared_bytes()).sum()
    }

    /// Admit an existing session under `name`. The session is switched
    /// onto the registry's shared executor; duplicate names are an error.
    /// Admission may evict older sessions' caches to fit the budget. Note
    /// that a session built with plain [`Session::new`] retains no rebuild
    /// source ([`Session::evictable`] is false) and is skipped by the
    /// budget — prefer [`SessionRegistry::open`]/
    /// [`SessionRegistry::open_shared`], which admit evictable sessions.
    pub fn insert(&mut self, name: &str, session: Session) -> Result<()> {
        if self.try_insert(name, session).is_err() {
            bail!("registry already holds a session named '{name}'");
        }
        Ok(())
    }

    /// [`SessionRegistry::insert`] that hands the session back instead of
    /// dropping it when the name is already taken — the non-lossy
    /// spelling for sessions carrying trained state the caller cannot
    /// rebuild.
    pub fn try_insert(
        &mut self,
        name: &str,
        mut session: Session,
    ) -> std::result::Result<(), Session> {
        if self.entries.iter().any(|e| e.name == name) {
            return Err(session);
        }
        session.set_executor(Some(self.executor.clone()));
        session.set_lease_workers(self.lease_workers);
        self.entries.push(Entry {
            name: name.to_string(),
            session,
            hits: 1,
        });
        let keep = self.entries.len() - 1;
        self.enforce_budget(keep);
        Ok(())
    }

    /// Build a fresh [`Session`] and admit it — the one-call path from a
    /// dataset name to a registered, steppable decomposition.
    pub fn open(
        &mut self,
        name: &str,
        algo: Algo,
        cfg: TrainConfig,
        train: &CooTensor,
    ) -> Result<()> {
        // retain a rebuild source so the session is evictable (the point
        // of admitting it to a budgeted registry)
        let session = Session::new_shared(algo, cfg, Arc::new(train.clone()))?;
        self.insert(name, session)
    }

    /// [`SessionRegistry::open`] without the defensive tensor copy: the
    /// session keeps the caller's `Arc` as its pristine rebuild source
    /// (see [`Session::new_shared`]) — the cheap path when many tenants
    /// are opened from tensors the caller already holds.
    pub fn open_shared(
        &mut self,
        name: &str,
        algo: Algo,
        cfg: TrainConfig,
        train: Arc<CooTensor>,
    ) -> Result<()> {
        let session = Session::new_shared(algo, cfg, train)?;
        self.insert(name, session)
    }

    /// Remove and return a session (its executor attachment is cleared so
    /// it schedules independently again). `None` if the name is unknown.
    pub fn remove(&mut self, name: &str) -> Option<Session> {
        let idx = self.entries.iter().position(|e| e.name == name)?;
        let mut entry = self.entries.remove(idx);
        entry.session.set_executor(None);
        entry.session.set_lease_workers(None);
        Some(entry.session)
    }

    /// Remove and return a session **without** detaching it from the
    /// shared executor or clearing its lease — the route to actual pass
    /// overlap: extract two leased tenants, drive each from its own
    /// thread, and their passes share (and overlap on) this registry's
    /// worker budget; re-[`SessionRegistry::insert`] them when done.
    /// `None` if the name is unknown.
    pub fn take_attached(&mut self, name: &str) -> Option<Session> {
        let idx = self.entries.iter().position(|e| e.name == name)?;
        Some(self.entries.remove(idx).session)
    }

    /// Read-only access to a session (does not count as a touch for the
    /// eviction score).
    pub fn get(&self, name: &str) -> Option<&Session> {
        self.entries.iter().find(|e| e.name == name).map(|e| &e.session)
    }

    /// Mutable access to a session; counts as a touch for the eviction
    /// score.
    pub fn get_mut(&mut self, name: &str) -> Option<&mut Session> {
        self.entries.iter_mut().find(|e| e.name == name).map(|e| {
            e.hits += 1;
            &mut e.session
        })
    }

    /// One training epoch + cadenced evaluation for the named session
    /// (see [`Session::step`]). Rebuilds the session's prepared cache
    /// first if a previous eviction dropped it, then re-enforces the byte
    /// budget against the other sessions.
    pub fn step(&mut self, name: &str, test: Option<&CooTensor>) -> Result<EpochRecord> {
        let idx = self.touch(name)?;
        // adaptive lease sizing runs between passes, from the telemetry
        // of the passes already recorded (no-op without a QoS policy)
        self.rebalance_leases();
        self.entries[idx].session.ensure_prepared();
        self.enforce_budget(idx);
        Ok(self.entries[idx].session.step(test))
    }

    /// Train the named session for `epochs` more epochs (see
    /// [`Session::run`]), stepping through the registry so the budget is
    /// enforced and the eviction score's touch counts maintained per
    /// epoch.
    pub fn run(
        &mut self,
        name: &str,
        epochs: usize,
        test: Option<&CooTensor>,
    ) -> Result<super::SessionReport> {
        for _ in 0..epochs {
            let idx = self.entries.iter().position(|e| e.name == name);
            let Some(idx) = idx else { bail!("no session named '{name}'") };
            if self.entries[idx].session.early_stopped() {
                break;
            }
            self.step(name, test)?;
        }
        let Some(session) = self.get(name) else { bail!("no session named '{name}'") };
        Ok(session.report())
    }

    /// A concurrent [`ServingHandle`] over the named session (FastTucker
    /// family only) — see [`Session::serving_handle`].
    pub fn serving_handle(&mut self, name: &str) -> Result<ServingHandle> {
        let Some(session) = self.get_mut(name) else {
            bail!("no session named '{name}'")
        };
        session.serving_handle()
    }

    /// Absorb a COO delta into the named session (see [`Session::ingest`]):
    /// only dirty B-CSF blocks re-stage, grown modes get deterministically
    /// seeded factor rows, and readers keep the pre-ingest snapshot until
    /// the next stepped epoch publishes. Counts as a touch for the eviction
    /// score, and re-enforces the byte budget afterwards — an ingest that
    /// grows the session's prepared cache may evict colder tenants' caches
    /// to fit.
    pub fn ingest(&mut self, name: &str, delta: CooTensor) -> Result<IngestReport> {
        let idx = self.touch(name)?;
        self.entries[idx].session.ensure_prepared();
        let report = self.entries[idx].session.ingest(delta)?;
        self.enforce_budget(idx);
        Ok(report)
    }

    /// Mark `name` touched and return its index.
    fn touch(&mut self, name: &str) -> Result<usize> {
        let Some(idx) = self.entries.iter().position(|e| e.name == name) else {
            bail!("no session named '{name}'")
        };
        self.entries[idx].hits += 1;
        Ok(idx)
    }

    /// Evict the lowest-scoring prepared caches (GDSF:
    /// `hits / resident_bytes`, ties on name) until the resident total
    /// fits the budget. The entry at `keep` is never evicted — the active
    /// session always stays resident, so a budget smaller than one session
    /// degrades to "evict everything else" rather than thrashing forever.
    /// Eviction choice affects *when* caches rebuild, never the math: the
    /// rebuild is bitwise-transparent regardless of victim order.
    fn enforce_budget(&mut self, keep: usize) {
        if self.budget_bytes == 0 {
            return;
        }
        while self.resident_bytes() > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter(|(i, e)| {
                    *i != keep
                        && e.session.prepared_resident()
                        && e.session.evictable()
                })
                .min_by(|(_, a), (_, b)| {
                    a.score()
                        .total_cmp(&b.score())
                        .then_with(|| a.name.cmp(&b.name))
                })
                .map(|(i, _)| i);
            let Some(v) = victim else { break };
            self.entries[v].session.evict_prepared();
            self.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};

    fn cfg_for(t: &CooTensor) -> TrainConfig {
        TrainConfig {
            order: t.order(),
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 1,
            block_nnz: 512,
            fiber_threshold: 32,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn try_insert_hands_the_session_back_on_duplicate() {
        let t = recommender(&RecommenderSpec::tiny(), 44);
        let mut reg = SessionRegistry::new(1, 0);
        reg.open("a", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        let dup = Session::new(Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        let got_back = reg.try_insert("a", dup).expect_err("duplicate name");
        // the caller's session survives the rejection, untouched
        assert_eq!(got_back.algo, Algo::FasterTucker);
        assert!(got_back.executor().is_none());
        reg.try_insert("b", got_back).expect("fresh name admits");
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn take_attached_keeps_executor_and_lease() {
        let t = recommender(&RecommenderSpec::tiny(), 45);
        let mut reg = SessionRegistry::new(2, 0);
        reg.set_pass_lease(Some(1));
        reg.open("a", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        let s = reg.take_attached("a").unwrap();
        assert!(s.executor().is_some());
        assert_eq!(s.lease_workers(), Some(1));
        assert!(reg.take_attached("a").is_none());
        // the extracted tenant still runs on the registry's pool
        let mut s = s;
        s.epoch();
        assert_eq!(reg.executor().passes_executed(), 2);
        // and can come home
        reg.insert("a", s).unwrap();
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn registry_basics_insert_get_remove() {
        let t = recommender(&RecommenderSpec::tiny(), 31);
        let mut reg = SessionRegistry::new(1, 0);
        assert!(reg.is_empty());
        reg.open("a", Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        reg.open("b", Algo::FastTucker, cfg_for(&t), &t).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.names(), vec!["a", "b"]);
        assert!(reg.get("a").is_some());
        assert!(reg.get("missing").is_none());
        // duplicate names rejected
        assert!(reg.open("a", Algo::FastTucker, cfg_for(&t), &t).is_err());
        let s = reg.remove("a").unwrap();
        assert_eq!(s.algo, Algo::FasterTucker);
        assert_eq!(reg.len(), 1);
        assert!(reg.remove("a").is_none());
    }

    #[test]
    fn sessions_share_the_executor() {
        let t = recommender(&RecommenderSpec::tiny(), 32);
        let mut reg = SessionRegistry::new(1, 0);
        reg.open("a", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        reg.open("b", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        reg.step("a", None).unwrap();
        reg.step("b", None).unwrap();
        // each step = 1 factor pass + 1 core pass, from two sessions, all
        // through one executor
        assert_eq!(reg.executor().passes_executed(), 4);
        assert!(reg.executor().total_stats().total_blocks() > 0);
    }

    #[test]
    fn baseline_sessions_share_the_executor_too() {
        let t = recommender(&RecommenderSpec::tiny(), 36);
        let mut cfg = cfg_for(&t);
        cfg.j = 4; // keep the J^N full core small
        let mut reg = SessionRegistry::new(1, 0);
        reg.open("base", Algo::CuTucker, cfg, &t).unwrap();
        reg.step("base", None).unwrap();
        // factor + core pass of the full-core baseline, both gated and
        // counted by the shared executor
        assert_eq!(reg.executor().passes_executed(), 2);
    }

    #[test]
    fn open_shared_avoids_the_defensive_copy() {
        let t = std::sync::Arc::new(recommender(&RecommenderSpec::tiny(), 37));
        let mut reg = SessionRegistry::new(1, 0);
        reg.open_shared("s", Algo::FasterTuckerCoo, cfg_for(&t), t.clone())
            .unwrap();
        // the session holds the same allocation, not a copy
        assert!(std::sync::Arc::strong_count(&t) >= 2);
        reg.step("s", None).unwrap();
    }

    #[test]
    fn unknown_names_error() {
        let mut reg = SessionRegistry::new(1, 0);
        assert!(reg.step("nope", None).is_err());
        assert!(reg.run("nope", 1, None).is_err());
        assert!(reg.serving_handle("nope").is_err());
        assert!(reg.ingest("nope", CooTensor::new(vec![2, 2, 2])).is_err());
    }

    /// Registry-routed ingestion: the delta lands in the named session (a
    /// fresh restage, observable through `builds`), the touch counts toward
    /// its eviction score, and the report surfaces what changed.
    #[test]
    fn ingest_routes_through_the_named_session() {
        let t = recommender(&RecommenderSpec::tiny(), 47);
        let mut reg = SessionRegistry::new(1, 0);
        reg.open("a", Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        reg.step("a", None).unwrap();
        let mut delta = CooTensor::new(t.dims().to_vec());
        delta.push(&[0, 0, 0], 1.5);
        let report = reg.ingest("a", delta).unwrap();
        assert_eq!(report.added_nnz, 1);
        assert!(report.grown.is_empty());
        let s = reg.get("a").unwrap();
        assert_eq!(s.prep_stats().builds, 2);
        assert_eq!(s.train_nnz(), Some(t.nnz() + 1));
        // the session keeps training through the registry afterwards
        reg.step("a", None).unwrap();
    }

    #[test]
    fn unlimited_budget_never_evicts() {
        let t = recommender(&RecommenderSpec::tiny(), 33);
        let mut reg = SessionRegistry::new(1, 0);
        reg.open("a", Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        reg.open("b", Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        reg.step("a", None).unwrap();
        reg.step("b", None).unwrap();
        reg.step("a", None).unwrap();
        assert_eq!(reg.evictions(), 0);
        assert_eq!(reg.get("a").unwrap().prep_stats().builds, 1);
        assert_eq!(reg.get("b").unwrap().prep_stats().builds, 1);
    }

    #[test]
    fn tight_budget_evicts_lru_and_rebuilds() {
        let t = recommender(&RecommenderSpec::tiny(), 34);
        // budget of 1 byte: only the active session may be resident
        let mut reg = SessionRegistry::new(1, 1);
        reg.open("a", Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        reg.open("b", Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        // admitting b evicted a (equal hits, only non-active candidate)
        assert_eq!(reg.evictions(), 1);
        assert!(!reg.get("a").unwrap().prepared_resident());
        assert!(reg.get("b").unwrap().prepared_resident());
        // stepping a rebuilds it transparently and evicts b
        reg.step("a", None).unwrap();
        assert_eq!(reg.get("a").unwrap().prep_stats().builds, 2);
        assert!(!reg.get("b").unwrap().prepared_resident());
        assert!(reg.resident_bytes() > 0);
    }

    /// Frequency-awareness: where pure LRU would evict the least-recently
    /// touched cache, the GDSF score (`hits / resident_bytes`) keeps the
    /// hot session resident and evicts the cold one — even though the cold
    /// one was touched more recently.
    #[test]
    fn score_evicts_cold_session_where_lru_would_evict_hot() {
        let t = recommender(&RecommenderSpec::tiny(), 38);
        let cfg = cfg_for(&t);
        // same tensor + same algo + same cfg shape → identical bytes, so
        // the score difference is purely the hit counts
        let probe = Session::new_shared(
            Algo::FasterTuckerCoo,
            cfg.clone(),
            std::sync::Arc::new(t.clone()),
        )
        .unwrap();
        let bytes = probe.prepared_bytes();
        assert!(bytes > 0);
        // budget holds exactly two caches
        let mut reg = SessionRegistry::new(1, 2 * bytes);
        reg.open("hot", Algo::FasterTuckerCoo, cfg.clone(), &t).unwrap();
        reg.open("cold", Algo::FasterTuckerCoo, cfg.clone(), &t).unwrap();
        for _ in 0..3 {
            reg.step("hot", None).unwrap();
        }
        // cold is the most recently touched of the two...
        reg.step("cold", None).unwrap();
        // ...but has fewer hits per byte, so admitting a third tenant
        // evicts cold, not hot (LRU would have evicted hot here)
        reg.open("new", Algo::FasterTuckerCoo, cfg, &t).unwrap();
        assert_eq!(reg.evictions(), 1);
        assert!(reg.get("hot").unwrap().prepared_resident());
        assert!(!reg.get("cold").unwrap().prepared_resident());
        assert!(reg.get("new").unwrap().prepared_resident());
        // the evicted session still rebuilds transparently
        reg.step("cold", None).unwrap();
        assert_eq!(reg.get("cold").unwrap().prep_stats().builds, 2);
    }

    /// Size-awareness: at equal hit counts, the bigger cache has the lower
    /// `hits / resident_bytes` score and is evicted first.
    #[test]
    fn score_evicts_bigger_cache_at_equal_hits() {
        let t = recommender(&RecommenderSpec::tiny(), 39);
        // B-CSF rotations make the FasterTucker cache strictly bigger than
        // the COO-only one
        let small = Session::new_shared(
            Algo::FasterTuckerCoo,
            cfg_for(&t),
            std::sync::Arc::new(t.clone()),
        )
        .unwrap();
        let big = Session::new_shared(
            Algo::FasterTucker,
            cfg_for(&t),
            std::sync::Arc::new(t.clone()),
        )
        .unwrap();
        assert!(big.prepared_bytes() > small.prepared_bytes());
        let budget = small.prepared_bytes() + big.prepared_bytes();
        let mut reg = SessionRegistry::new(1, budget);
        reg.insert("small", small).unwrap();
        reg.insert("big", big).unwrap();
        // both resident, both at 1 hit; a third tenant forces one out
        let t2 = recommender(&RecommenderSpec::tiny(), 40);
        reg.open("third", Algo::FasterTuckerCoo, cfg_for(&t2), &t2).unwrap();
        assert!(!reg.get("big").unwrap().prepared_resident(), "bigger cache goes first");
        assert!(reg.get("small").unwrap().prepared_resident());
    }

    /// The admission policy plumbs lease sizing to every session, current
    /// and future, and passes then run lease-sized.
    #[test]
    fn pass_lease_plumbs_through_admission() {
        let t = recommender(&RecommenderSpec::tiny(), 42);
        let mut reg = SessionRegistry::new(2, 0);
        assert_eq!(reg.pass_lease(), None);
        reg.open("before", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        reg.set_pass_lease(Some(1));
        reg.open("after", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        assert_eq!(reg.get("before").unwrap().lease_workers(), Some(1));
        assert_eq!(reg.get("after").unwrap().lease_workers(), Some(1));
        reg.step("before", None).unwrap();
        // the pass ran on a 1-worker lease, not the 2-worker budget
        let ws = reg.get("before").unwrap().factor_worker_stats().unwrap();
        assert_eq!(ws.blocks.len(), 1);
        assert_eq!(reg.executor().leases_granted(), 2);
        // removal detaches both the executor and the lease config
        let s = reg.remove("after").unwrap();
        assert_eq!(s.lease_workers(), None);
        assert!(s.executor().is_none());
    }

    #[test]
    fn lease_split_is_proportional_with_floor() {
        assert_eq!(lease_split(&[3.0, 1.0], 4, 1), vec![3, 1]);
        // the fairness floor caps the skew a heavy tenant can cause
        assert_eq!(lease_split(&[100.0, 1.0], 4, 2), vec![2, 2]);
        // budget too small for the floor: everyone still gets at least 1
        assert_eq!(lease_split(&[1.0, 1.0, 1.0], 2, 2), vec![1, 1, 1]);
        // deterministic tie-break: the leftover slot goes to the lowest index
        assert_eq!(lease_split(&[1.0, 1.0], 3, 1), vec![2, 1]);
        // zero weights degrade to an even split
        assert_eq!(lease_split(&[0.0, 0.0], 4, 1), vec![2, 2]);
        assert!(lease_split(&[], 4, 1).is_empty());
    }

    #[test]
    fn qos_policy_rebalances_leases_and_bounds_admission() {
        let t = recommender(&RecommenderSpec::tiny(), 46);
        let mut reg = SessionRegistry::new(4, 0);
        reg.open("a", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        reg.open("b", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        assert_eq!(reg.qos_policy(), None);
        let policy = QosPolicy { fairness_floor: 1, max_pending: 8 };
        reg.set_qos_policy(Some(policy));
        assert_eq!(reg.qos_policy(), Some(policy));
        assert_eq!(reg.executor().max_pending(), 8);
        reg.step("a", None).unwrap();
        reg.step("a", None).unwrap();
        reg.step("b", None).unwrap();
        // every tenant holds an adaptive lease: at least the floor each,
        // and together they cover the whole budget
        let leases: Vec<usize> = ["a", "b"]
            .iter()
            .map(|n| reg.get(n).unwrap().lease_workers().unwrap())
            .collect();
        assert!(leases.iter().all(|&n| n >= 1));
        assert_eq!(leases.iter().sum::<usize>(), 4);
        // telemetry recorded per tenant (factor + core pass per step)
        assert!(reg.get("a").unwrap().qos_stats().passes >= 4);
        let report = reg.qos_report();
        let a = report.get("tenants").unwrap().get("a").unwrap();
        assert!(a.get("passes").unwrap().as_usize().unwrap() >= 4);
        assert!(a.get("lease_workers").unwrap().as_usize().is_some());
        assert_eq!(
            report.get("executor").unwrap().get("workers").unwrap().as_usize(),
            Some(4)
        );
        // clearing the policy restores the static lease config (none here)
        reg.set_qos_policy(None);
        assert_eq!(reg.get("a").unwrap().lease_workers(), None);
        assert_eq!(reg.executor().max_pending(), usize::MAX);
    }

    /// The adaptive-lease node cap: on a 2-node executor, a tenant whose
    /// latency weight would otherwise hand it the whole 4-slot budget is
    /// capped at one node's worth of slots, so its resized lease acquires
    /// without straddling nodes whenever a single-node fit exists.
    #[test]
    fn rebalanced_leases_never_straddle_nodes_when_a_fit_exists() {
        let t = recommender(&RecommenderSpec::tiny(), 48);
        let mut reg = SessionRegistry::with_numa(4, 0, NumaMode::Force(2));
        assert_eq!(reg.executor().nodes(), 2);
        assert_eq!(reg.executor().max_node_slots(), 2);
        reg.open("solo", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        reg.set_qos_policy(Some(QosPolicy {
            fairness_floor: 1,
            max_pending: usize::MAX,
        }));
        // as the only tenant, an uncapped rebalance would hand "solo" all
        // 4 slots — a forced straddle on a 2+2 topology
        reg.step("solo", None).unwrap();
        reg.step("solo", None).unwrap();
        let lease = reg.get("solo").unwrap().lease_workers().unwrap();
        assert!(
            lease <= 2,
            "adaptive lease {lease} exceeds the 2-slot node capacity"
        );
        // and a lease of that size lands entirely on one node
        let wl = reg.executor().acquire(lease);
        let homes = wl.homes();
        assert!(
            homes.iter().all(|h| h.node == homes[0].node),
            "capped lease straddles nodes: {homes:?}"
        );
    }

    #[test]
    fn run_trains_through_the_registry() {
        let t = recommender(&RecommenderSpec::tiny(), 35);
        let mut reg = SessionRegistry::new(1, 0);
        reg.open("a", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        let report = reg.run("a", 3, None).unwrap();
        assert_eq!(report.epochs_completed, 3);
        assert_eq!(report.convergence.records.len(), 3);
    }
}
