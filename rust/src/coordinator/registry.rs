//! Multi-tensor sessions: a process-wide [`SessionRegistry`] serving many
//! decompositions from one process.
//!
//! The ROADMAP's "multi-tensor sessions" item, made concrete:
//!
//! * **One registry, many sessions** — sessions are keyed by dataset name
//!   and owned by the registry; callers address them by name
//!   ([`SessionRegistry::step`], [`SessionRegistry::run`],
//!   [`SessionRegistry::serving_handle`]).
//! * **One shared worker pool** — the registry owns a single
//!   [`Executor`] and attaches it to every admitted session, so every
//!   training pass in the process — engine and full-core baseline alike —
//!   runs on the same worker budget (one `ShardPlan` executor reused
//!   across sessions) instead of each session bringing
//!   `TrainConfig::workers` threads of its own.
//! * **An eviction budget** — each session's
//!   [`crate::tensor::prepared::PreparedStorage`] cache
//!   (shuffled traversal + B-CSF rotations) is charged by its measured
//!   bytes (`PrepStats::resident_bytes`). When the resident total exceeds
//!   the budget, the least-recently-used sessions' caches are evicted;
//!   an evicted session rebuilds **transparently** on its next `step`
//!   (deterministically identical structures — the staging shuffle and
//!   B-CSF builds are pure functions of `(train, cfg)`), and its
//!   `PrepStats::builds` counter increments so eviction is observable.
//!   The model state (factors/cores/C tables — the paper's point is that
//!   these are *small*) is never evicted; only the heavy prepared
//!   structures are.
//!
//! The active session is always allowed residency even if it alone
//! exceeds the budget — a budget too small for one session degrades to
//! "evict everything else", never to a livelock.

use super::serving::ServingHandle;
use super::Session;
use crate::algo::Algo;
use crate::config::TrainConfig;
use crate::metrics::EpochRecord;
use crate::sched::Executor;
use crate::tensor::coo::CooTensor;
use anyhow::{bail, Result};
use std::sync::Arc;

/// One admitted session plus its LRU bookkeeping.
struct Entry {
    name: String,
    session: Session,
    /// Logical clock value of the last touch (step/run/get_mut).
    last_used: u64,
}

/// A process-wide registry of named [`Session`]s sharing one worker pool
/// and one prepared-storage eviction budget.
///
/// # Examples
///
/// ```
/// use fastertucker::algo::Algo;
/// use fastertucker::config::TrainConfig;
/// use fastertucker::coordinator::SessionRegistry;
/// use fastertucker::tensor::coo::CooTensor;
///
/// let mut t = CooTensor::new(vec![4, 3, 2]);
/// t.push(&[0, 0, 0], 2.0);
/// t.push(&[1, 2, 1], 4.0);
/// t.push(&[3, 1, 0], 3.0);
/// t.push(&[2, 2, 1], 5.0);
/// let cfg = TrainConfig {
///     order: 3, dims: vec![4, 3, 2], j: 2, r: 2,
///     lr_a: 0.01, lr_b: 1e-4, workers: 1, eval_sample_nnz: 0,
///     ..TrainConfig::default()
/// };
/// // 1 worker, unlimited budget (0)
/// let mut reg = SessionRegistry::new(1, 0);
/// reg.open("ratings", Algo::FasterTuckerCoo, cfg, &t).unwrap();
/// let rec = reg.step("ratings", None).unwrap();
/// assert_eq!(rec.epoch, 0);
/// assert!(reg.executor().passes_executed() >= 1);
/// ```
pub struct SessionRegistry {
    executor: Arc<Executor>,
    /// Resident-bytes budget over all prepared caches; `0` = unlimited.
    budget_bytes: usize,
    entries: Vec<Entry>,
    /// Logical LRU clock, bumped on every touch.
    clock: u64,
    evictions: usize,
}

impl SessionRegistry {
    /// Registry with a shared worker budget (`workers`, `0` = all cores)
    /// and a prepared-cache byte budget (`budget_bytes`, `0` = unlimited).
    pub fn new(workers: usize, budget_bytes: usize) -> SessionRegistry {
        SessionRegistry {
            executor: Arc::new(Executor::new(workers)),
            budget_bytes,
            entries: Vec::new(),
            clock: 0,
            evictions: 0,
        }
    }

    /// The shared pass executor every admitted session runs on.
    pub fn executor(&self) -> &Arc<Executor> {
        &self.executor
    }

    /// The prepared-cache byte budget (`0` = unlimited).
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Prepared-cache evictions performed so far.
    pub fn evictions(&self) -> usize {
        self.evictions
    }

    /// Number of registered sessions.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the registry holds no sessions.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Registered names, in insertion order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// Total bytes of currently-resident prepared caches.
    pub fn resident_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.session.prepared_bytes()).sum()
    }

    /// Admit an existing session under `name`. The session is switched
    /// onto the registry's shared executor; duplicate names are an error.
    /// Admission may evict older sessions' caches to fit the budget. Note
    /// that a session built with plain [`Session::new`] retains no rebuild
    /// source ([`Session::evictable`] is false) and is skipped by the
    /// budget — prefer [`SessionRegistry::open`]/
    /// [`SessionRegistry::open_shared`], which admit evictable sessions.
    pub fn insert(&mut self, name: &str, mut session: Session) -> Result<()> {
        if self.entries.iter().any(|e| e.name == name) {
            bail!("registry already holds a session named '{name}'");
        }
        session.set_executor(Some(self.executor.clone()));
        self.clock += 1;
        self.entries.push(Entry {
            name: name.to_string(),
            session,
            last_used: self.clock,
        });
        let keep = self.entries.len() - 1;
        self.enforce_budget(keep);
        Ok(())
    }

    /// Build a fresh [`Session`] and admit it — the one-call path from a
    /// dataset name to a registered, steppable decomposition.
    pub fn open(
        &mut self,
        name: &str,
        algo: Algo,
        cfg: TrainConfig,
        train: &CooTensor,
    ) -> Result<()> {
        // retain a rebuild source so the session is evictable (the point
        // of admitting it to a budgeted registry)
        let session = Session::new_shared(algo, cfg, Arc::new(train.clone()))?;
        self.insert(name, session)
    }

    /// [`SessionRegistry::open`] without the defensive tensor copy: the
    /// session keeps the caller's `Arc` as its pristine rebuild source
    /// (see [`Session::new_shared`]) — the cheap path when many tenants
    /// are opened from tensors the caller already holds.
    pub fn open_shared(
        &mut self,
        name: &str,
        algo: Algo,
        cfg: TrainConfig,
        train: Arc<CooTensor>,
    ) -> Result<()> {
        let session = Session::new_shared(algo, cfg, train)?;
        self.insert(name, session)
    }

    /// Remove and return a session (its executor attachment is cleared so
    /// it schedules independently again). `None` if the name is unknown.
    pub fn remove(&mut self, name: &str) -> Option<Session> {
        let idx = self.entries.iter().position(|e| e.name == name)?;
        let mut entry = self.entries.remove(idx);
        entry.session.set_executor(None);
        Some(entry.session)
    }

    /// Read-only access to a session (does not touch the LRU order).
    pub fn get(&self, name: &str) -> Option<&Session> {
        self.entries.iter().find(|e| e.name == name).map(|e| &e.session)
    }

    /// Mutable access to a session; counts as a use for LRU purposes.
    pub fn get_mut(&mut self, name: &str) -> Option<&mut Session> {
        self.clock += 1;
        let clock = self.clock;
        self.entries.iter_mut().find(|e| e.name == name).map(|e| {
            e.last_used = clock;
            &mut e.session
        })
    }

    /// One training epoch + cadenced evaluation for the named session
    /// (see [`Session::step`]). Rebuilds the session's prepared cache
    /// first if a previous eviction dropped it, then re-enforces the byte
    /// budget against the other sessions.
    pub fn step(&mut self, name: &str, test: Option<&CooTensor>) -> Result<EpochRecord> {
        let idx = self.touch(name)?;
        self.entries[idx].session.ensure_prepared();
        self.enforce_budget(idx);
        Ok(self.entries[idx].session.step(test))
    }

    /// Train the named session for `epochs` more epochs (see
    /// [`Session::run`]), stepping through the registry so the budget is
    /// enforced and the LRU order maintained per epoch.
    pub fn run(
        &mut self,
        name: &str,
        epochs: usize,
        test: Option<&CooTensor>,
    ) -> Result<super::SessionReport> {
        for _ in 0..epochs {
            let idx = self.entries.iter().position(|e| e.name == name);
            let Some(idx) = idx else { bail!("no session named '{name}'") };
            if self.entries[idx].session.early_stopped() {
                break;
            }
            self.step(name, test)?;
        }
        let Some(session) = self.get(name) else { bail!("no session named '{name}'") };
        Ok(session.report())
    }

    /// A concurrent [`ServingHandle`] over the named session (FastTucker
    /// family only) — see [`Session::serving_handle`].
    pub fn serving_handle(&mut self, name: &str) -> Result<ServingHandle> {
        let Some(session) = self.get_mut(name) else {
            bail!("no session named '{name}'")
        };
        session.serving_handle()
    }

    /// Mark `name` used and return its index.
    fn touch(&mut self, name: &str) -> Result<usize> {
        let Some(idx) = self.entries.iter().position(|e| e.name == name) else {
            bail!("no session named '{name}'")
        };
        self.clock += 1;
        self.entries[idx].last_used = self.clock;
        Ok(idx)
    }

    /// Evict least-recently-used prepared caches until the resident total
    /// fits the budget. The entry at `keep` is never evicted — the active
    /// session always stays resident, so a budget smaller than one session
    /// degrades to "evict everything else" rather than thrashing forever.
    fn enforce_budget(&mut self, keep: usize) {
        if self.budget_bytes == 0 {
            return;
        }
        while self.resident_bytes() > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter(|(i, e)| {
                    *i != keep
                        && e.session.prepared_resident()
                        && e.session.evictable()
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i);
            let Some(v) = victim else { break };
            self.entries[v].session.evict_prepared();
            self.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};

    fn cfg_for(t: &CooTensor) -> TrainConfig {
        TrainConfig {
            order: t.order(),
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            lr_a: 0.01,
            lr_b: 1e-4,
            workers: 1,
            block_nnz: 512,
            fiber_threshold: 32,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn registry_basics_insert_get_remove() {
        let t = recommender(&RecommenderSpec::tiny(), 31);
        let mut reg = SessionRegistry::new(1, 0);
        assert!(reg.is_empty());
        reg.open("a", Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        reg.open("b", Algo::FastTucker, cfg_for(&t), &t).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.names(), vec!["a", "b"]);
        assert!(reg.get("a").is_some());
        assert!(reg.get("missing").is_none());
        // duplicate names rejected
        assert!(reg.open("a", Algo::FastTucker, cfg_for(&t), &t).is_err());
        let s = reg.remove("a").unwrap();
        assert_eq!(s.algo, Algo::FasterTucker);
        assert_eq!(reg.len(), 1);
        assert!(reg.remove("a").is_none());
    }

    #[test]
    fn sessions_share_the_executor() {
        let t = recommender(&RecommenderSpec::tiny(), 32);
        let mut reg = SessionRegistry::new(1, 0);
        reg.open("a", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        reg.open("b", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        reg.step("a", None).unwrap();
        reg.step("b", None).unwrap();
        // each step = 1 factor pass + 1 core pass, from two sessions, all
        // through one executor
        assert_eq!(reg.executor().passes_executed(), 4);
        assert!(reg.executor().total_stats().total_blocks() > 0);
    }

    #[test]
    fn baseline_sessions_share_the_executor_too() {
        let t = recommender(&RecommenderSpec::tiny(), 36);
        let mut cfg = cfg_for(&t);
        cfg.j = 4; // keep the J^N full core small
        let mut reg = SessionRegistry::new(1, 0);
        reg.open("base", Algo::CuTucker, cfg, &t).unwrap();
        reg.step("base", None).unwrap();
        // factor + core pass of the full-core baseline, both gated and
        // counted by the shared executor
        assert_eq!(reg.executor().passes_executed(), 2);
    }

    #[test]
    fn open_shared_avoids_the_defensive_copy() {
        let t = std::sync::Arc::new(recommender(&RecommenderSpec::tiny(), 37));
        let mut reg = SessionRegistry::new(1, 0);
        reg.open_shared("s", Algo::FasterTuckerCoo, cfg_for(&t), t.clone())
            .unwrap();
        // the session holds the same allocation, not a copy
        assert!(std::sync::Arc::strong_count(&t) >= 2);
        reg.step("s", None).unwrap();
    }

    #[test]
    fn unknown_names_error() {
        let mut reg = SessionRegistry::new(1, 0);
        assert!(reg.step("nope", None).is_err());
        assert!(reg.run("nope", 1, None).is_err());
        assert!(reg.serving_handle("nope").is_err());
    }

    #[test]
    fn unlimited_budget_never_evicts() {
        let t = recommender(&RecommenderSpec::tiny(), 33);
        let mut reg = SessionRegistry::new(1, 0);
        reg.open("a", Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        reg.open("b", Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        reg.step("a", None).unwrap();
        reg.step("b", None).unwrap();
        reg.step("a", None).unwrap();
        assert_eq!(reg.evictions(), 0);
        assert_eq!(reg.get("a").unwrap().prep_stats().builds, 1);
        assert_eq!(reg.get("b").unwrap().prep_stats().builds, 1);
    }

    #[test]
    fn tight_budget_evicts_lru_and_rebuilds() {
        let t = recommender(&RecommenderSpec::tiny(), 34);
        // budget of 1 byte: only the active session may be resident
        let mut reg = SessionRegistry::new(1, 1);
        reg.open("a", Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        reg.open("b", Algo::FasterTucker, cfg_for(&t), &t).unwrap();
        // admitting b evicted a (LRU)
        assert_eq!(reg.evictions(), 1);
        assert!(!reg.get("a").unwrap().prepared_resident());
        assert!(reg.get("b").unwrap().prepared_resident());
        // stepping a rebuilds it transparently and evicts b
        reg.step("a", None).unwrap();
        assert_eq!(reg.get("a").unwrap().prep_stats().builds, 2);
        assert!(!reg.get("b").unwrap().prepared_resident());
        assert!(reg.resident_bytes() > 0);
    }

    #[test]
    fn run_trains_through_the_registry() {
        let t = recommender(&RecommenderSpec::tiny(), 35);
        let mut reg = SessionRegistry::new(1, 0);
        reg.open("a", Algo::FasterTuckerCoo, cfg_for(&t), &t).unwrap();
        let report = reg.run("a", 3, None).unwrap();
        assert_eq!(report.epochs_completed, 3);
        assert_eq!(report.convergence.records.len(), 3);
    }
}
