//! The **Dataset** layer — layer 1 of `Dataset → PreparedStorage →
//! Session`.
//!
//! One abstraction over every way a training tensor enters the system:
//! already-materialized memory, FROSTT-style `.tns` text / `.ftns` binary
//! files (streamed through `tensor::io` so large files are materialized
//! exactly once), and the synthetic generator families of the paper's
//! evaluation (§V-A). Deterministic shuffling and train/test splitting are
//! dataset *operations* here, not trainer internals, so every downstream
//! consumer (CLI, examples, benches, sessions) gets identical data from
//! identical `(source, seed)` descriptions.

use crate::data::split::{filter_cold, train_test};
use crate::data::synthetic::{self, RecommenderSpec};
use crate::tensor::coo::CooTensor;
use crate::tensor::io;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// A synthetic workload family (paper §V-A), reproducible from the spec
/// plus a seed.
#[derive(Clone, Debug)]
pub enum SyntheticSpec {
    /// Recommender-style power-law tensor (netflix/yahoo/tiny shapes).
    Recommender(RecommenderSpec),
    /// Fig. 4(a) order sweep: `order`-way, every mode `dim` long.
    Order { order: usize, dim: usize, nnz: usize },
    /// Fig. 4(b,c) sparsity sweep: 3-order `dim³` cells.
    Sparsity { dim: usize, nnz: usize },
}

/// Where a training tensor comes from.
#[derive(Clone, Debug)]
pub enum Dataset {
    /// Already materialized (programmatic use, tests).
    Memory(CooTensor),
    /// File-backed: `.tns` FROSTT-style text (streamed, optionally
    /// 1-based, dims inferred unless given) or `.ftns` binary.
    File {
        path: PathBuf,
        one_based: bool,
        dims: Option<Vec<usize>>,
    },
    /// Synthetic generator.
    Synthetic { spec: SyntheticSpec, seed: u64 },
}

impl Dataset {
    /// File-backed dataset; the format is chosen by extension
    /// (`.tns` → text, anything else → binary).
    pub fn from_path(path: impl Into<PathBuf>, one_based: bool) -> Dataset {
        Dataset::File { path: path.into(), one_based, dims: None }
    }

    /// Synthetic dataset from the CLI's `--kind` vocabulary.
    ///
    /// # Examples
    ///
    /// ```
    /// use fastertucker::data::dataset::Dataset;
    ///
    /// let ds = Dataset::synthetic("tiny", 1_000, 3, 0, 7).unwrap();
    /// let t = ds.load().unwrap();
    /// assert_eq!(t.order(), 3);
    /// let (train, test) = ds.load_split(0.2, 7).unwrap();
    /// assert!(train.nnz() > 0 && test.unwrap().nnz() > 0);
    /// assert!(Dataset::synthetic("galaxy", 0, 0, 0, 0).is_err());
    /// ```
    pub fn synthetic(
        kind: &str,
        nnz: usize,
        order: usize,
        dim: usize,
        seed: u64,
    ) -> Result<Dataset> {
        let spec = match kind {
            "netflix" => SyntheticSpec::Recommender(RecommenderSpec::netflix_like(nnz)),
            "yahoo" => SyntheticSpec::Recommender(RecommenderSpec::yahoo_like(nnz)),
            "tiny" => SyntheticSpec::Recommender(RecommenderSpec::tiny()),
            "order" => SyntheticSpec::Order { order, dim, nnz },
            "sparsity" => SyntheticSpec::Sparsity { dim, nnz },
            other => bail!("unknown --kind '{other}'"),
        };
        Ok(Dataset::Synthetic { spec, seed })
    }

    /// Short human-readable description for logs and reports.
    pub fn name(&self) -> String {
        match self {
            Dataset::Memory(t) => {
                format!("memory[{} nnz, dims {:?}]", t.nnz(), t.dims())
            }
            Dataset::File { path, .. } => format!("file[{}]", path.display()),
            Dataset::Synthetic { spec, seed } => match spec {
                SyntheticSpec::Recommender(s) => {
                    format!("recommender[dims {:?}, seed {seed}]", s.dims)
                }
                SyntheticSpec::Order { order, dim, nnz } => {
                    format!("order-sweep[N={order}, I={dim}, nnz {nnz}, seed {seed}]")
                }
                SyntheticSpec::Sparsity { dim, nnz } => {
                    format!("sparsity-sweep[I={dim}, nnz {nnz}, seed {seed}]")
                }
            },
        }
    }

    /// Materialize the tensor.
    pub fn load(&self) -> Result<CooTensor> {
        match self {
            Dataset::Memory(t) => Ok(t.clone()),
            Dataset::File { path, one_based, dims } => {
                if path.extension().and_then(|e| e.to_str()) == Some("tns") {
                    io::read_text(path, dims.clone(), *one_based)
                } else {
                    io::read_binary(path)
                }
            }
            Dataset::Synthetic { spec, seed } => Ok(match spec {
                SyntheticSpec::Recommender(s) => synthetic::recommender(s, *seed),
                SyntheticSpec::Order { order, dim, nnz } => {
                    synthetic::order_sweep(*order, *dim, *nnz, *seed)
                }
                SyntheticSpec::Sparsity { dim, nnz } => {
                    synthetic::sparsity_sweep(*dim, *nnz, *seed)
                }
            }),
        }
    }

    /// Materialize with the deterministic staging shuffle (the SGD
    /// sampling order; same `(dataset, seed)` → same order — the same
    /// [`CooTensor::training_shuffle`] every session uses).
    pub fn load_shuffled(&self, seed: u64) -> Result<CooTensor> {
        Ok(self.load()?.training_shuffle(seed))
    }

    /// Materialize and split off a held-out test fraction (deterministic
    /// per seed). The test side is filtered of cold coordinates — rows
    /// never seen in training have only their random initialization to
    /// predict with and would dominate the error. `test_frac <= 0` keeps
    /// everything in the training side.
    pub fn load_split(
        &self,
        test_frac: f64,
        seed: u64,
    ) -> Result<(CooTensor, Option<CooTensor>)> {
        let tensor = self.load()?;
        if test_frac <= 0.0 {
            return Ok((tensor, None));
        }
        let (train, test) = train_test(&tensor, test_frac, seed);
        let test = filter_cold(&test, &train);
        Ok((train, Some(test)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("ft_dataset_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{}", std::process::id(), name))
    }

    fn tiny() -> Dataset {
        Dataset::Synthetic {
            spec: SyntheticSpec::Recommender(RecommenderSpec::tiny()),
            seed: 9,
        }
    }

    #[test]
    fn synthetic_is_deterministic() {
        let a = tiny().load().unwrap();
        let b = tiny().load().unwrap();
        assert_eq!(a.canonical_elements(), b.canonical_elements());
    }

    #[test]
    fn file_dataset_roundtrips_both_formats() {
        let t = tiny().load().unwrap();
        for (name, one_based) in [("ds.ftns", false), ("ds.tns", true)] {
            let p = tmpfile(name);
            if name.ends_with(".tns") {
                io::write_text(&t, &p, one_based).unwrap();
            } else {
                io::write_binary(&t, &p).unwrap();
            }
            let back = Dataset::from_path(&p, one_based).load().unwrap();
            assert_eq!(back.nnz(), t.nnz());
            assert_eq!(back.order(), t.order());
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn shuffle_is_deterministic_and_preserves_elements() {
        let ds = tiny();
        let a = ds.load_shuffled(3).unwrap();
        let b = ds.load_shuffled(3).unwrap();
        let c = ds.load_shuffled(4).unwrap();
        assert_eq!(a.index(0), b.index(0));
        assert_eq!(a.canonical_elements(), c.canonical_elements());
    }

    #[test]
    fn split_op_partitions_and_filters_cold() {
        let ds = tiny();
        let (train, test) = ds.load_split(0.2, 5).unwrap();
        let test = test.expect("test side requested");
        let total = ds.load().unwrap().nnz();
        // cold filtering may drop test elements but never train elements
        assert!(train.nnz() + test.nnz() <= total);
        assert!(train.nnz() >= total * 7 / 10);
        let (all, none) = ds.load_split(0.0, 5).unwrap();
        assert_eq!(all.nnz(), total);
        assert!(none.is_none());
    }

    #[test]
    fn synthetic_cli_vocabulary() {
        assert!(Dataset::synthetic("tiny", 1000, 3, 50, 1).is_ok());
        assert!(Dataset::synthetic("order", 1000, 4, 20, 1).is_ok());
        assert!(Dataset::synthetic("sparsity", 1000, 3, 30, 1).is_ok());
        assert!(Dataset::synthetic("galaxy", 1000, 3, 30, 1).is_err());
    }

    #[test]
    fn names_are_descriptive() {
        assert!(tiny().name().starts_with("recommender["));
        assert!(Dataset::from_path("/x/y.tns", true).name().contains("y.tns"));
    }
}
