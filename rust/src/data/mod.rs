//! Workload generation and dataset handling — the **Dataset** layer.
//!
//! The paper evaluates on Netflix / Yahoo!Music (not redistributable) and
//! two synthetic families. We generate structurally faithful substitutes:
//! recommender-style tensors with power-law user/item marginals (the skew is
//! what makes B-CSF matter), an order sweep (Fig. 4a) and a sparsity sweep
//! (Fig. 4b/c). See DESIGN.md §2 for the substitution rationale.
//!
//! [`dataset::Dataset`] unifies these generators with file-backed tensors
//! (`.tns` text / `.ftns` binary via `tensor::io`) and exposes the
//! deterministic shuffle/split operations every consumer shares.

pub mod synthetic;
pub mod split;
pub mod dataset;
