//! Train/test splitting for accuracy evaluation (Fig. 2/3 use held-out test
//! RMSE/MAE, `|Γ|` in the paper's Table II).

use crate::tensor::coo::CooTensor;
use crate::util::rng::Rng;

/// Randomly split `test_frac` of the non-zeros into a held-out test tensor.
/// Deterministic per seed. Returns `(train, test)`.
pub fn train_test(tensor: &CooTensor, test_frac: f64, seed: u64) -> (CooTensor, CooTensor) {
    assert!((0.0..1.0).contains(&test_frac));
    let mut rng = Rng::new(seed ^ 0x7E57_5E7);
    let nnz = tensor.nnz();
    let n_test = (nnz as f64 * test_frac).round() as usize;
    // choose n_test distinct element ids via partial Fisher-Yates
    let mut ids: Vec<u32> = (0..nnz as u32).collect();
    for k in 0..n_test.min(nnz) {
        let j = k + rng.next_below(nnz - k);
        ids.swap(k, j);
    }
    let mut is_test = vec![false; nnz];
    for &e in &ids[..n_test.min(nnz)] {
        is_test[e as usize] = true;
    }
    let (test, train) = tensor.partition(&is_test);
    (train, test)
}

/// Filter a test tensor down to elements whose every coordinate also appears
/// in the training tensor (cold rows have no trained factor and would
/// dominate the error with their random initialization).
pub fn filter_cold(test: &CooTensor, train: &CooTensor) -> CooTensor {
    let n = train.order();
    let mut seen: Vec<Vec<bool>> = train.dims().iter().map(|&d| vec![false; d]).collect();
    for (c, _) in train.iter() {
        for k in 0..n {
            seen[k][c[k] as usize] = true;
        }
    }
    let mask: Vec<bool> = (0..test.nnz())
        .map(|e| {
            test.index(e)
                .iter()
                .enumerate()
                .all(|(k, &c)| seen[k][c as usize])
        })
        .collect();
    let (kept, _) = test.partition(&mask);
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};

    #[test]
    fn split_sizes_add_up() {
        let t = recommender(&RecommenderSpec::tiny(), 1);
        let (train, test) = train_test(&t, 0.2, 42);
        assert_eq!(train.nnz() + test.nnz(), t.nnz());
        let expected = (t.nnz() as f64 * 0.2).round() as usize;
        assert_eq!(test.nnz(), expected);
    }

    #[test]
    fn split_is_deterministic() {
        let t = recommender(&RecommenderSpec::tiny(), 2);
        let (a, _) = train_test(&t, 0.1, 7);
        let (b, _) = train_test(&t, 0.1, 7);
        assert_eq!(a.canonical_elements(), b.canonical_elements());
    }

    #[test]
    fn split_partitions_disjointly() {
        let t = recommender(&RecommenderSpec::tiny(), 3);
        let (train, test) = train_test(&t, 0.3, 1);
        let mut all = train.canonical_elements();
        all.extend(test.canonical_elements());
        all.sort_by(|a, b| a.0.cmp(&b.0));
        assert_eq!(all, t.canonical_elements());
    }

    #[test]
    fn zero_frac_keeps_everything() {
        let t = recommender(&RecommenderSpec::tiny(), 4);
        let (train, test) = train_test(&t, 0.0, 1);
        assert_eq!(train.nnz(), t.nnz());
        assert_eq!(test.nnz(), 0);
    }

    #[test]
    fn filter_cold_removes_unseen_coords() {
        let mut train = CooTensor::new(vec![5, 5]);
        train.push(&[0, 0], 1.0);
        train.push(&[1, 1], 1.0);
        let mut test = CooTensor::new(vec![5, 5]);
        test.push(&[0, 1], 1.0); // both coords seen
        test.push(&[4, 0], 1.0); // row 4 never trained
        let kept = filter_cold(&test, &train);
        assert_eq!(kept.nnz(), 1);
        assert_eq!(kept.index(0), &[0, 1]);
    }
}
