//! Synthetic HOHDST (high-order, high-dimension, sparse tensor) generators.
//!
//! Three families, matching the paper's evaluation §V-A:
//!
//! * [`recommender`] — Netflix/Yahoo-like 3-order `(user, item, time)`
//!   rating tensors: Zipf-distributed user/item activity (real rating data
//!   follows a power law, which is the entire motivation for B-CSF's
//!   fiber splitting), ratings in `[min_value, max_value]` built from a
//!   low-rank planted model plus noise so the decomposition has signal to
//!   recover (the paper's convergence plots need a learnable tensor).
//! * [`order_sweep`] — fixed dim length and nnz, order 3..=10 (Fig. 4a).
//! * [`sparsity_sweep`] — 3-order, I=1000, nnz 20M..100M scaled (Fig. 4b/c).

use crate::tensor::coo::CooTensor;
use crate::util::rng::Rng;
use std::collections::HashSet;

/// Parameters for the recommender-style generator.
#[derive(Clone, Debug)]
pub struct RecommenderSpec {
    /// Mode sizes, e.g. `[users, items, times]`.
    pub dims: Vec<usize>,
    /// Number of distinct observed entries to generate.
    pub nnz: usize,
    /// Zipf exponent per mode (0 = uniform). Real ratings: ~1.0 for users,
    /// ~1.2 for items, mild for time.
    pub zipf: Vec<f64>,
    /// Planted rank for the signal component.
    pub rank: usize,
    /// Noise stddev added to the planted ratings.
    pub noise: f32,
    /// Value clamp lower bound (paper: Netflix 1, normalized Yahoo 0.025).
    pub min_value: f32,
    /// Value clamp upper bound (paper: 5 for both rating datasets).
    pub max_value: f32,
    /// Round values to integers (Netflix-style star ratings).
    pub integer_values: bool,
}

impl RecommenderSpec {
    /// Netflix-shaped, scaled to CPU budget: 48k×5k×200, ~1M nnz.
    pub fn netflix_like(nnz: usize) -> Self {
        RecommenderSpec {
            dims: vec![48_019, 5_077, 218],
            nnz,
            zipf: vec![0.9, 1.2, 0.3],
            rank: 8,
            noise: 0.4,
            min_value: 1.0,
            max_value: 5.0,
            integer_values: true,
        }
    }

    /// Yahoo!Music-shaped (more users/items, denser head), scaled.
    pub fn yahoo_like(nnz: usize) -> Self {
        RecommenderSpec {
            dims: vec![100_099, 62_496, 307],
            nnz,
            zipf: vec![1.0, 1.3, 0.3],
            rank: 8,
            noise: 0.5,
            min_value: 0.025,
            max_value: 5.0,
            integer_values: false,
        }
    }

    /// Tiny instance for unit tests and the quickstart example.
    pub fn tiny() -> Self {
        RecommenderSpec {
            dims: vec![200, 150, 20],
            nnz: 4_000,
            zipf: vec![0.8, 1.0, 0.0],
            rank: 4,
            noise: 0.2,
            min_value: 1.0,
            max_value: 5.0,
            integer_values: false,
        }
    }
}

/// Generate a recommender-style sparse tensor with planted low-rank signal.
pub fn recommender(spec: &RecommenderSpec, seed: u64) -> CooTensor {
    let n = spec.dims.len();
    assert!(n >= 2);
    assert!(spec.zipf.len() == n, "need one zipf exponent per mode");
    let mut rng = Rng::new(seed);

    // Planted factors: per mode, dim × rank, small positive entries so the
    // chain product stays in a sane range.
    let scale = ((spec.max_value as f64 - spec.min_value as f64) / spec.rank as f64)
        .powf(1.0 / n as f64) as f32;
    let factors: Vec<Vec<f32>> = spec
        .dims
        .iter()
        .map(|&d| {
            (0..d * spec.rank)
                .map(|_| rng.uniform_f32(0.0, 1.0) * scale)
                .collect()
        })
        .collect();

    // Per-mode random permutations so the Zipf head isn't always index 0
    // (prevents the head elements from all sharing low coordinates, which
    // would make the tensor unrealistically blocky).
    let perms: Vec<Vec<u32>> = spec.dims.iter().map(|&d| rng.permutation(d)).collect();

    let mut tensor = CooTensor::with_capacity(spec.dims.clone(), spec.nnz);
    let mut seen = DedupSet::new(&spec.dims);
    let mut coords = vec![0u32; n];
    let mut attempts = 0usize;
    let max_attempts = spec.nnz.saturating_mul(20).max(1024);
    while tensor.nnz() < spec.nnz && attempts < max_attempts {
        attempts += 1;
        for (k, c) in coords.iter_mut().enumerate() {
            let raw = rng.zipf(spec.dims[k], spec.zipf[k]);
            *c = perms[k][raw];
        }
        if !seen.insert(&coords) {
            continue;
        }
        // planted value: sum over rank of product over modes
        let mut v = 0.0f32;
        for r in 0..spec.rank {
            let mut p = 1.0f32;
            for (k, &c) in coords.iter().enumerate() {
                p *= factors[k][c as usize * spec.rank + r];
            }
            v += p;
        }
        v += spec.min_value + spec.noise * rng.normal_f32();
        let mut v = v.clamp(spec.min_value, spec.max_value);
        if spec.integer_values {
            v = v.round().clamp(spec.min_value, spec.max_value);
        }
        tensor.push(&coords, v);
    }
    assert!(
        tensor.nnz() as f64 >= spec.nnz as f64 * 0.5,
        "generator saturated: got {} of {} requested nnz (tensor too dense?)",
        tensor.nnz(),
        spec.nnz
    );
    tensor
}

/// Fig. 4(a) workload: `order`-way tensor, every mode of length `dim`,
/// exactly `nnz` distinct uniform entries, values in `[1,5]`.
pub fn order_sweep(order: usize, dim: usize, nnz: usize, seed: u64) -> CooTensor {
    let dims = vec![dim; order];
    uniform_tensor(&dims, nnz, seed)
}

/// Fig. 4(b,c) workload: 3-order, `dim^3` cells, `nnz` distinct entries.
pub fn sparsity_sweep(dim: usize, nnz: usize, seed: u64) -> CooTensor {
    uniform_tensor(&[dim, dim, dim], nnz, seed)
}

/// Uniform random distinct coordinates with values in `[1, 5]`.
pub fn uniform_tensor(dims: &[usize], nnz: usize, seed: u64) -> CooTensor {
    let total: f64 = dims.iter().map(|&d| d as f64).product();
    assert!(
        (nnz as f64) <= total * 0.5,
        "requested nnz {} exceeds half the {} cells",
        nnz,
        total
    );
    let mut rng = Rng::new(seed);
    let n = dims.len();
    let mut tensor = CooTensor::with_capacity(dims.to_vec(), nnz);
    let mut seen = DedupSet::new(dims);
    let mut coords = vec![0u32; n];
    while tensor.nnz() < nnz {
        for (k, c) in coords.iter_mut().enumerate() {
            *c = rng.next_below(dims[k]) as u32;
        }
        if seen.insert(&coords) {
            tensor.push(&coords, rng.uniform_f32(1.0, 5.0));
        }
    }
    tensor
}

/// Coordinate de-duplication. Packs coordinates into a `u128` when the
/// combined bit width fits (covers every workload in this repo: order ≤ 10 ×
/// ≤ 12 bits, or 3 × ≤ 40 bits); falls back to hashing the coordinate tuple.
enum DedupSet {
    Packed { bits: Vec<u32>, set: HashSet<u128> },
    Exact(HashSet<Vec<u32>>),
}

impl DedupSet {
    fn new(dims: &[usize]) -> Self {
        let bits: Vec<u32> = dims
            .iter()
            .map(|&d| (usize::BITS - (d.max(2) - 1).leading_zeros()).max(1))
            .collect();
        let total: u32 = bits.iter().sum();
        if total <= 128 {
            DedupSet::Packed { bits, set: HashSet::new() }
        } else {
            DedupSet::Exact(HashSet::new())
        }
    }

    /// Returns true if the coordinate was new.
    fn insert(&mut self, coords: &[u32]) -> bool {
        match self {
            DedupSet::Packed { bits, set } => {
                let mut key: u128 = 0;
                for (&c, &b) in coords.iter().zip(bits.iter()) {
                    key = (key << b) | c as u128;
                }
                set.insert(key)
            }
            DedupSet::Exact(set) => set.insert(coords.to_vec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recommender_tiny_has_requested_shape() {
        let spec = RecommenderSpec::tiny();
        let t = recommender(&spec, 1);
        assert_eq!(t.dims(), &[200, 150, 20]);
        assert_eq!(t.nnz(), 4_000);
        t.validate().unwrap();
    }

    #[test]
    fn recommender_values_in_range() {
        let spec = RecommenderSpec::tiny();
        let t = recommender(&spec, 2);
        for (_, v) in t.iter() {
            assert!((spec.min_value..=spec.max_value).contains(&v));
        }
    }

    #[test]
    fn recommender_integer_mode_rounds() {
        let mut spec = RecommenderSpec::tiny();
        spec.integer_values = true;
        let t = recommender(&spec, 3);
        for (_, v) in t.iter() {
            assert_eq!(v, v.round());
        }
    }

    #[test]
    fn recommender_no_duplicate_coords() {
        let t = recommender(&RecommenderSpec::tiny(), 4);
        let mut elems: Vec<Vec<u32>> = t.iter().map(|(c, _)| c.to_vec()).collect();
        let before = elems.len();
        elems.sort();
        elems.dedup();
        assert_eq!(elems.len(), before);
    }

    #[test]
    fn recommender_is_deterministic_per_seed() {
        let spec = RecommenderSpec::tiny();
        let a = recommender(&spec, 5);
        let b = recommender(&spec, 5);
        assert_eq!(a.canonical_elements(), b.canonical_elements());
        let c = recommender(&spec, 6);
        assert_ne!(a.canonical_elements(), c.canonical_elements());
    }

    #[test]
    fn recommender_is_skewed() {
        let spec = RecommenderSpec::tiny();
        let t = recommender(&spec, 7);
        // mode-1 (items, zipf 1.0): top-10% of items should hold well over
        // 10% of the nnz
        let mut counts = vec![0usize; t.dims()[1]];
        for (c, _) in t.iter() {
            counts[c[1] as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top: usize = counts[..counts.len() / 10].iter().sum();
        assert!(top * 100 > t.nnz() * 25, "top decile held {top} of {}", t.nnz());
    }

    #[test]
    fn order_sweep_shapes() {
        for order in [3usize, 5, 8, 10] {
            let t = order_sweep(order, 30, 500, 11);
            assert_eq!(t.order(), order);
            assert_eq!(t.nnz(), 500);
            t.validate().unwrap();
        }
    }

    #[test]
    fn sparsity_sweep_density() {
        let t = sparsity_sweep(50, 2_500, 12);
        assert!((t.density() - 2_500.0 / (50.0f64.powi(3))).abs() < 1e-12);
    }

    #[test]
    fn uniform_tensor_distinct_coords() {
        let t = uniform_tensor(&[10, 10], 50, 13);
        let mut coords: Vec<Vec<u32>> = t.iter().map(|(c, _)| c.to_vec()).collect();
        coords.sort();
        let n = coords.len();
        coords.dedup();
        assert_eq!(coords.len(), n);
    }

    #[test]
    #[should_panic(expected = "exceeds half")]
    fn uniform_tensor_rejects_oversubscription() {
        let _ = uniform_tensor(&[4, 4], 9, 1);
    }

    #[test]
    fn dedup_high_order_uses_exact_path() {
        // 12 modes × 2^30 would exceed 128 bits → exact fallback
        let dims = vec![1 << 30; 12];
        let mut set = DedupSet::new(&dims);
        assert!(matches!(set, DedupSet::Exact(_)));
        let c = vec![5u32; 12];
        assert!(set.insert(&c));
        assert!(!set.insert(&c));
    }

    #[test]
    fn dedup_packed_distinguishes_neighbors() {
        let dims = vec![1000, 1000, 1000];
        let mut set = DedupSet::new(&dims);
        assert!(set.insert(&[1, 2, 3]));
        assert!(set.insert(&[1, 2, 4]));
        assert!(set.insert(&[1, 3, 3]));
        assert!(!set.insert(&[1, 2, 3]));
    }
}
