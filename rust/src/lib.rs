//! # fastertucker — parallel sparse FastTucker/FasterTucker decomposition
//!
//! A reproduction of *"cuFasterTucker: A Stochastic Optimization Strategy
//! for Parallel Sparse FastTucker Decomposition on GPU Platform"*
//! (Li, Duan, Yang, Li; 2022) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordination contribution, organized as
//!   `Dataset → PreparedStorage → Session`: dataset ingestion (synthetic
//!   generators + file-backed tensors), sparse tensor storage (COO / CSF /
//!   B-CSF) staged once per session, the worker-parallel SGD executor that
//!   plays the role of the paper's CUDA thread-groups, the FastTucker and
//!   FasterTucker inner loops driven by resumable sessions, baselines
//!   (cuTucker full-core SGD, P-Tucker ALS), metrics, config, CLI, and the
//!   experiment harness.
//! * **L2/L1 (python/, build-time only)** — the dense building blocks
//!   (`C = A·B` precompute, batched chain-product prediction, core-gradient
//!   matmul) authored as JAX + Pallas kernels and AOT-lowered to HLO text,
//!   loaded and executed from Rust through the PJRT C API ([`runtime`]).
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.
//!
//! ## Model
//!
//! An N-order sparse tensor `X` is approximated with factor matrices
//! `A^(n) ∈ R^{I_n×J_n}` and core matrices `B^(n) ∈ R^{J_n×R}`:
//!
//! ```text
//! x̂_{i1..iN} = Σ_{r=1..R}  Π_{n=1..N}  ( a_{i_n}^(n) · b_{:,r}^(n) )
//! ```
//!
//! FasterTucker (the paper's contribution) accelerates the SGD by
//! (1) precomputing the *reusable* tables `C^(n) = A^(n) B^(n)` and
//! (2) *sharing* the per-fiber invariant `w = B^(n) v` across all
//! non-zeros of a mode-n fiber, stored in B-CSF for load balance.

// Style lints we deliberately do not chase in numeric hot-loop code: index
// loops often mirror the paper's pseudocode, and the CI gate compiles clippy
// with `-D warnings`.
#![warn(missing_docs)]
#![allow(unknown_lints)]
#![allow(
    clippy::needless_range_loop,
    clippy::needless_lifetimes,
    clippy::manual_div_ceil,
    clippy::too_many_arguments,
    clippy::uninlined_format_args,
    clippy::result_large_err
)]

pub mod util;
pub mod linalg;
pub mod tensor;
pub mod data;
pub mod model;
pub mod sched;
pub mod algo;
pub mod baselines;
pub mod metrics;
pub mod config;
pub mod runtime;
pub mod exec;
pub mod coordinator;
pub mod bench;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::algo::Algo;
    pub use crate::config::TrainConfig;
    pub use crate::coordinator::{
        IngestReport, ServingHandle, Session, SessionModel, SessionRegistry,
        SessionReport, TopKQuery,
    };
    pub use crate::data::dataset::{Dataset, SyntheticSpec};
    pub use crate::exec::{CpuShardBackend, PassBackend, PjrtPassBackend};
    pub use crate::linalg::Matrix;
    pub use crate::model::ModelState;
    pub use crate::sched::Executor;
    pub use crate::tensor::bcsf::BcsfTensor;
    pub use crate::tensor::coo::CooTensor;
    pub use crate::tensor::prepared::PreparedStorage;
}
