//! Model state: the factor matrices `A^(n)`, core matrices `B^(n)`, and the
//! paper's *reusable intermediate* tables `C^(n) = A^(n) B^(n)`
//! (§III-A — the heart of FasterTucker's complexity reduction).

use crate::config::TrainConfig;
use crate::linalg::Matrix;
use crate::sched::Executor;
use crate::util::bitset::DirtyRows;
use crate::util::bytes;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Trainable state of a FastTucker decomposition.
#[derive(Clone, Debug)]
pub struct ModelState {
    /// `A^(n) ∈ R^{I_n×J}` per mode.
    pub factors: Vec<Matrix>,
    /// `B^(n) ∈ R^{J×R}` per mode.
    pub cores: Vec<Matrix>,
    /// Reusable intermediates `C^(n) = A^(n) B^(n) ∈ R^{I_n×R}` per mode.
    /// Kept in sync by [`ModelState::refresh_c`] /
    /// [`ModelState::refresh_c_dirty`].
    pub c_tables: Vec<Matrix>,
    /// Per-mode dirty-row sets: which rows of `A^(n)` changed since
    /// `C^(n)` was last refreshed. Transient bookkeeping — never
    /// serialized; checkpoints reload with everything clean because
    /// [`ModelState::load`] recomputes the C tables from scratch.
    pub dirty: Vec<DirtyRows>,
    /// Per-mode "changed since the last serving publication" sets — the
    /// handoff from refresh to the snapshot layer. `dirty` is consumed
    /// (cleared) by every [`ModelState::refresh_c_dirty`] at pass end,
    /// *before* the epoch boundary publishes a snapshot, so the delta
    /// publication needs its own accumulator: every refresh merges the
    /// rows it rewrote in here (a full [`ModelState::refresh_c`] marks
    /// the whole mode — it cannot know which rows actually changed), and
    /// only the publisher clears it, per successful snapshot
    /// ([`ModelState::clear_publish_dirty`]). Starts fully marked, so a
    /// first publication is always a full copy. Transient like `dirty`:
    /// never serialized.
    pub publish_dirty: Vec<DirtyRows>,
}

impl ModelState {
    /// Random initialization. The paper draws factors and cores from a
    /// uniform ("average") distribution; we scale so the initial prediction
    /// `Σ_r Π_n (a·b_r)` lands near the middle of the value range.
    ///
    /// Every factor row and every core matrix is drawn from its **own
    /// forked RNG stream**, keyed only by `(mode, row)` resp. `mode` — not
    /// by the mode sizes. That makes initialization *growth-stable*: row
    /// `i` of mode `n` gets the same bits whether the mode was born with
    /// `i+1` rows or grew past `i` later via [`ModelState::grow_mode`],
    /// which is what lets an ingesting session stay bitwise-equal to a
    /// cold session built from the already-grown tensor.
    pub fn init(cfg: &TrainConfig, seed: u64) -> ModelState {
        let n = cfg.order;
        let base = Rng::new(seed ^ 0x0DE1_5EED);
        let s = init_scale(n, cfg.j, cfg.r);
        let factors = cfg
            .dims
            .iter()
            .enumerate()
            .map(|(mode, &d)| {
                Matrix::from_vec(d, cfg.j, factor_rows(&base, mode, 0, d, cfg.j, s))
            })
            .collect::<Vec<_>>();
        let cores = (0..n)
            .map(|mode| {
                let mut rng = core_rng(&base, mode);
                Matrix::uniform(cfg.j, cfg.r, 0.0, s, &mut rng)
            })
            .collect::<Vec<_>>();
        let c_tables = factors
            .iter()
            .zip(cores.iter())
            .map(|(a, b)| a.matmul(b))
            .collect();
        let dirty = (0..n).map(|_| DirtyRows::new()).collect();
        let publish_dirty = (0..n).map(|_| all_marked()).collect();
        ModelState { factors, cores, c_tables, dirty, publish_dirty }
    }

    /// Grow mode `n` to `new_rows` rows (online ingestion discovered new
    /// indices). Appended factor rows are drawn from the same per-row
    /// forked streams as [`ModelState::init`], so the result is bitwise
    /// what `init` would have produced for the larger mode; appended C
    /// rows are computed with the row kernel that replays `matmul_into`'s
    /// accumulation order. Existing rows are untouched. The grown rows
    /// are marked publication-dirty so the next snapshot copies them out.
    pub fn grow_mode(&mut self, n: usize, new_rows: usize, seed: u64) {
        let old = self.factors[n].rows();
        assert!(new_rows >= old, "grow_mode cannot shrink ({old} -> {new_rows})");
        if new_rows == old {
            return;
        }
        let (j, r) = (self.j(), self.r());
        let base = Rng::new(seed ^ 0x0DE1_5EED);
        let s = init_scale(self.order(), j, r);
        let mut data = self.factors[n].data().to_vec();
        data.extend(factor_rows(&base, n, old, new_rows, j, s));
        self.factors[n] = Matrix::from_vec(new_rows, j, data);
        let mut cdata = self.c_tables[n].data().to_vec();
        cdata.resize(new_rows * r, 0.0);
        self.c_tables[n] = Matrix::from_vec(new_rows, r, cdata);
        let ModelState { factors, cores, c_tables, .. } = self;
        let (a, b, c) = (&factors[n], &cores[n], &mut c_tables[n]);
        for i in old..new_rows {
            a.matmul_row_into(b, i, c.row_mut(i));
        }
        self.dirty[n].ensure(new_rows);
        self.publish_dirty[n].ensure(new_rows);
        for i in old..new_rows {
            self.publish_dirty[n].mark(i);
        }
    }

    /// Number of modes.
    #[inline]
    pub fn order(&self) -> usize {
        self.factors.len()
    }

    /// Factor rank J (uniform across modes).
    #[inline]
    pub fn j(&self) -> usize {
        self.cores[0].rows()
    }

    /// Core rank R.
    #[inline]
    pub fn r(&self) -> usize {
        self.cores[0].cols()
    }

    /// Recompute `C^(n) = A^(n) B^(n)` after mode `n`'s factor or core
    /// changed (Algorithm 3 in the paper). This is the dense kernel that the
    /// PJRT path can also execute; see `runtime::engine`. Recomputes every
    /// row and clears mode `n`'s dirty set.
    pub fn refresh_c(&mut self, n: usize) {
        let (a, b) = (&self.factors[n], &self.cores[n]);
        a.matmul_into(b, &mut self.c_tables[n]);
        self.dirty[n].clear();
        // the full recompute rewrites every row; without per-row tracking
        // the only safe handoff to the snapshot layer is "all stale"
        self.publish_dirty[n].mark_all();
    }

    /// Incremental sibling of [`ModelState::refresh_c`]: recompute only
    /// the rows recorded in `dirty[n]`, then clear the set. **Bitwise
    /// identical** to a full refresh at any worker count, because each C
    /// row is a pure function of its factor row and the per-row kernel
    /// ([`Matrix::matmul_row_into`]) replays `matmul_into`'s exact
    /// accumulation order.
    ///
    /// With `pool = Some(executor)` the recompute is row-blocked on
    /// **word-aligned** 64-row boundaries (see
    /// [`crate::util::bitset::DirtyRows`]) and fanned out over leased
    /// workers; `None` runs the allocation-free serial path.
    pub fn refresh_c_dirty(&mut self, n: usize, pool: Option<&Executor>) {
        if self.dirty[n].is_all() {
            self.refresh_c(n);
            return;
        }
        if !self.dirty[n].any() {
            return;
        }
        // exactly the rows recomputed below now differ from the last
        // published snapshot — the word-OR that makes delta publication
        // sound (merged *before* the set is consumed and cleared)
        self.publish_dirty[n].merge_from(&self.dirty[n]);
        let ModelState { factors, cores, c_tables, dirty, .. } = self;
        let (a, b, c) = (&factors[n], &cores[n], &mut c_tables[n]);
        let d = &dirty[n];
        let r = b.cols();
        let lanes = pool
            .map_or(1, Executor::workers)
            .min(d.words().len())
            .max(1);
        if lanes <= 1 {
            d.for_each_row(|i| a.matmul_row_into(b, i, c.row_mut(i)));
        } else {
            let words = d.words();
            let chunk_words = crate::util::ceil_div(words.len(), lanes);
            let chunk_rows = chunk_words * 64;
            let mut chunks: Vec<(usize, &mut [f32])> =
                c.data_mut().chunks_mut(chunk_rows * r).enumerate().collect();
            pool.expect("lanes > 1 implies a pool").run_indexed(
                lanes,
                &mut chunks,
                |_, (ci, slice)| {
                    let base = *ci * chunk_rows;
                    // a trailing chunk of C may sit past the dirty set's
                    // last word when the set was ensured short; clamp so
                    // the word window degenerates to empty instead of
                    // panicking
                    let wlo = (*ci * chunk_words).min(words.len());
                    let whi = (wlo + chunk_words).min(words.len());
                    for (w, &word) in words[wlo..whi].iter().enumerate() {
                        let mut bits = word;
                        while bits != 0 {
                            let bit = bits.trailing_zeros() as usize;
                            let row = ((wlo + w) << 6) | bit;
                            let lo = (row - base) * r;
                            a.matmul_row_into(b, row, &mut slice[lo..lo + r]);
                            bits &= bits - 1;
                        }
                    }
                },
            );
        }
        dirty[n].clear();
    }

    /// Refresh every mode's C table.
    pub fn refresh_all_c(&mut self) {
        for n in 0..self.order() {
            self.refresh_c(n);
        }
    }

    /// Reset every mode's publication dirty set — called by the snapshot
    /// publisher immediately after a successful delta capture (and only
    /// then: clearing without publishing would let the next delta share
    /// blocks that were never copied out). Forgetting to clear is merely
    /// conservative — the next delta over-copies but stays correct.
    pub fn clear_publish_dirty(&mut self) {
        for d in &mut self.publish_dirty {
            d.clear();
        }
    }

    /// Predict one element from the C tables:
    /// `x̂ = Σ_r Π_n C^(n)[i_n, r]`.
    pub fn predict(&self, coords: &[u32]) -> f32 {
        debug_assert_eq!(coords.len(), self.order());
        let r = self.r();
        let mut acc = 0.0f32;
        for rr in 0..r {
            let mut p = 1.0f32;
            for (n, &c) in coords.iter().enumerate() {
                p *= self.c_tables[n].get(c as usize, rr);
            }
            acc += p;
        }
        acc
    }

    /// Predict from factors/cores directly (no C tables) — the FastTucker
    /// baseline's code path; also the oracle the tests compare against.
    pub fn predict_direct(&self, coords: &[u32]) -> f32 {
        let r = self.r();
        let mut acc = 0.0f32;
        for rr in 0..r {
            let mut p = 1.0f32;
            for (n, &c) in coords.iter().enumerate() {
                let a = self.factors[n].row(c as usize);
                let mut dot = 0.0f32;
                for j in 0..self.j() {
                    dot += a[j] * self.cores[n].get(j, rr);
                }
                p *= dot;
            }
            acc += p;
        }
        acc
    }

    /// Parameter count (factors + cores).
    pub fn num_params(&self) -> usize {
        self.factors.iter().map(|m| m.rows() * m.cols()).sum::<usize>()
            + self.cores.iter().map(|m| m.rows() * m.cols()).sum::<usize>()
    }

    /// Save a binary checkpoint.
    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(b"FTCK")?;
        w.write_all(&(self.order() as u32).to_le_bytes())?;
        w.write_all(&(self.j() as u32).to_le_bytes())?;
        w.write_all(&(self.r() as u32).to_le_bytes())?;
        for m in &self.factors {
            w.write_all(&(m.rows() as u64).to_le_bytes())?;
            bytes::write_f32s(&mut w, m.data())?;
        }
        for m in &self.cores {
            bytes::write_f32s(&mut w, m.data())?;
        }
        w.flush()?;
        Ok(())
    }

    /// Load a checkpoint written by [`ModelState::save`].
    pub fn load(path: &Path) -> Result<ModelState> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"FTCK" {
            bail!("not a fastertucker checkpoint");
        }
        let order = read_u32(&mut r)? as usize;
        let j = read_u32(&mut r)? as usize;
        let rr = read_u32(&mut r)? as usize;
        if order == 0 || order > 64 || j == 0 || rr == 0 {
            bail!("implausible checkpoint header");
        }
        let mut factors = Vec::with_capacity(order);
        for _ in 0..order {
            let rows = read_u64(&mut r)? as usize;
            if rows == 0 || rows.checked_mul(j).is_none() {
                bail!("implausible factor shape {rows}x{j}");
            }
            let mut data = vec![0f32; rows * j];
            bytes::read_f32s(&mut r, &mut data).context("truncated checkpoint")?;
            factors.push(Matrix::from_vec(rows, j, data));
        }
        let mut cores = Vec::with_capacity(order);
        for _ in 0..order {
            let mut data = vec![0f32; j * rr];
            bytes::read_f32s(&mut r, &mut data).context("truncated checkpoint")?;
            cores.push(Matrix::from_vec(j, rr, data));
        }
        let c_tables = factors
            .iter()
            .zip(cores.iter())
            .map(|(a, b)| a.matmul(b))
            .collect();
        let dirty = (0..order).map(|_| DirtyRows::new()).collect();
        let publish_dirty = (0..order).map(|_| all_marked()).collect();
        Ok(ModelState { factors, cores, c_tables, dirty, publish_dirty })
    }
}

/// Init scale `s`: per-mode contribution chosen so E[x̂] ≈ 1..few:
///   x̂ = Σ_R Π_N (Σ_J a*b); with a,b ~ U(0,s): E[a·b_r] ≈ J s²/4.
/// pick s so that (J s²/4)^N * R ≈ 2.5 (mid-range rating). Depends only
/// on (N, J, R) — never on the mode sizes — so growing a mode cannot
/// change the scale of rows drawn before or after the growth.
fn init_scale(n: usize, j: usize, r: usize) -> f32 {
    let target = 2.5f64;
    let per_mode = (target / r as f64).powf(1.0 / n as f64);
    (4.0 * per_mode / j as f64).sqrt() as f32
}

/// Draw factor rows `lo..hi` of mode `mode` (row-major, `j` columns per
/// row), each row from its own forked stream keyed by `(mode, row)`.
/// The domain tags keep factor-row forks disjoint from core forks.
fn factor_rows(base: &Rng, mode: usize, lo: usize, hi: usize, j: usize, s: f32) -> Vec<f32> {
    let mut data = Vec::with_capacity((hi - lo) * j);
    for row in lo..hi {
        let mut rng = base.fork((1u64 << 62) | ((mode as u64) << 40) | row as u64);
        data.extend((0..j).map(|_| rng.uniform_f32(0.0, s)));
    }
    data
}

/// The forked stream core matrix `B^(n)` is drawn from.
fn core_rng(base: &Rng, mode: usize) -> Rng {
    base.fork((2u64 << 62) | mode as u64)
}

/// A fresh dirty set with the whole-table flag raised — the safe initial
/// publication state (nothing has been published yet).
fn all_marked() -> DirtyRows {
    let mut d = DirtyRows::new();
    d.mark_all();
    d
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TrainConfig {
        TrainConfig {
            order: 3,
            dims: vec![30, 20, 10],
            j: 8,
            r: 4,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn init_shapes() {
        let m = ModelState::init(&cfg(), 1);
        assert_eq!(m.order(), 3);
        assert_eq!(m.factors[0].rows(), 30);
        assert_eq!(m.factors[2].rows(), 10);
        assert_eq!(m.factors[0].cols(), 8);
        assert_eq!(m.cores[1].rows(), 8);
        assert_eq!(m.cores[1].cols(), 4);
        assert_eq!(m.c_tables[0].rows(), 30);
        assert_eq!(m.c_tables[0].cols(), 4);
    }

    #[test]
    fn init_prediction_scale_reasonable() {
        let m = ModelState::init(&cfg(), 2);
        let p = m.predict(&[0, 0, 0]);
        assert!(p > 0.05 && p < 50.0, "initial prediction {p} out of range");
    }

    #[test]
    fn predict_matches_direct() {
        let m = ModelState::init(&cfg(), 3);
        for coords in [[0u32, 0, 0], [29, 19, 9], [5, 7, 3]] {
            let a = m.predict(&coords);
            let b = m.predict_direct(&coords);
            assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn refresh_c_tracks_factor_change() {
        let mut m = ModelState::init(&cfg(), 4);
        m.factors[1].row_mut(3)[0] += 1.0;
        let before = m.predict(&[0, 3, 0]);
        m.refresh_c(1);
        let after = m.predict(&[0, 3, 0]);
        assert_ne!(before, after);
        assert!((after - m.predict_direct(&[0, 3, 0])).abs() < 1e-4);
    }

    #[test]
    fn incremental_refresh_is_bitwise_full_refresh() {
        let mut m = ModelState::init(&cfg(), 7);
        m.dirty[0].ensure(m.factors[0].rows());
        for row in [0usize, 7, 29] {
            m.factors[0].row_mut(row)[2] += 0.25;
            m.dirty[0].mark(row);
        }
        let mut full = m.clone();
        full.refresh_c(0);
        m.refresh_c_dirty(0, None);
        assert_eq!(m.c_tables[0].max_abs_diff(&full.c_tables[0]), 0.0);
        assert!(!m.dirty[0].any(), "incremental refresh clears the set");
        // a core change invalidates the whole table: mark_all must fall
        // back to the full path
        let mut m2 = full.clone();
        m2.cores[1].row_mut(0)[0] += 0.5;
        m2.dirty[1].mark_all();
        let mut f2 = m2.clone();
        f2.refresh_c(1);
        m2.refresh_c_dirty(1, None);
        assert_eq!(m2.c_tables[1].max_abs_diff(&f2.c_tables[1]), 0.0);
        // a clean set is a no-op
        let snapshot = m.c_tables[0].clone();
        m.refresh_c_dirty(0, None);
        assert_eq!(m.c_tables[0].max_abs_diff(&snapshot), 0.0);
    }

    #[test]
    fn parallel_incremental_refresh_matches_serial_bitwise() {
        let big = TrainConfig {
            order: 3,
            dims: vec![350, 150, 80],
            j: 8,
            r: 4,
            ..TrainConfig::default()
        };
        let mut base = ModelState::init(&big, 8);
        let mut rng = Rng::new(99);
        base.dirty[0].ensure(350);
        for _ in 0..60 {
            let row = rng.next_below(350);
            base.factors[0].row_mut(row)[1] -= 0.125;
            base.dirty[0].mark(row);
        }
        let mut serial = base.clone();
        serial.refresh_c_dirty(0, None);
        for workers in [2, 3, 5, 16] {
            let mut par = base.clone();
            let pool = Executor::new(workers);
            par.refresh_c_dirty(0, Some(&pool));
            assert_eq!(
                par.c_tables[0].max_abs_diff(&serial.c_tables[0]),
                0.0,
                "×{workers} parallel refresh must be bitwise serial"
            );
            assert!(!par.dirty[0].any());
        }
    }

    #[test]
    fn publish_dirty_accumulates_until_cleared() {
        let mut m = ModelState::init(&cfg(), 9);
        // a fresh model has everything publication-stale
        assert!(m.publish_dirty.iter().all(DirtyRows::is_all));
        m.clear_publish_dirty();
        assert!(m.publish_dirty.iter().all(|d| !d.any()));

        // incremental refreshes accumulate their rows across *several*
        // refresh cycles, even though `dirty` is cleared by each one
        m.dirty[0].ensure(m.factors[0].rows());
        m.factors[0].row_mut(3)[0] += 1.0;
        m.dirty[0].mark(3);
        m.refresh_c_dirty(0, None);
        assert!(!m.dirty[0].any(), "refresh consumes the per-pass set");
        m.dirty[0].ensure(m.factors[0].rows());
        m.factors[0].row_mut(17)[1] -= 0.5;
        m.dirty[0].mark(17);
        m.refresh_c_dirty(0, None);
        let mut rows = Vec::new();
        m.publish_dirty[0].for_each_row(|r| rows.push(r));
        assert_eq!(rows, vec![3, 17], "both cycles visible to the publisher");
        assert!(!m.publish_dirty[0].is_all());
        assert!(!m.publish_dirty[1].any(), "untouched modes stay clean");

        // a clean incremental refresh is publication-invisible
        m.clear_publish_dirty();
        m.refresh_c_dirty(0, None);
        assert!(!m.publish_dirty[0].any());

        // a full refresh cannot know which rows changed: whole mode stale
        m.refresh_c(1);
        assert!(m.publish_dirty[1].is_all());
        assert!(!m.publish_dirty[0].any());
    }

    #[test]
    fn grow_mode_is_bitwise_cold_init_of_larger_dims() {
        let small = cfg();
        let big = TrainConfig { dims: vec![30, 47, 10], ..cfg() };
        let mut grown = ModelState::init(&small, 11);
        grown.clear_publish_dirty();
        grown.grow_mode(1, 47, 11);
        let cold = ModelState::init(&big, 11);
        for n in 0..3 {
            assert_eq!(
                grown.factors[n].max_abs_diff(&cold.factors[n]),
                0.0,
                "mode {n} factor must match cold init bitwise"
            );
            assert_eq!(grown.cores[n].max_abs_diff(&cold.cores[n]), 0.0);
            assert_eq!(
                grown.c_tables[n].max_abs_diff(&cold.c_tables[n]),
                0.0,
                "mode {n} C table must match cold init bitwise"
            );
        }
        // exactly the appended rows become publication-dirty
        let mut rows = Vec::new();
        grown.publish_dirty[1].for_each_row(|r| rows.push(r));
        assert_eq!(rows, (20..47).collect::<Vec<_>>());
        assert!(!grown.publish_dirty[0].any());
        // growing to the current size is a no-op
        let before = grown.factors[1].clone();
        grown.grow_mode(1, 47, 11);
        assert_eq!(grown.factors[1].max_abs_diff(&before), 0.0);
    }

    #[test]
    fn init_rows_are_insertion_order_independent() {
        // the same seed must give mode 2's rows the same bits whether
        // mode 1 is 20 or 2000 rows tall — per-row forking, not one
        // sequential stream
        let a = ModelState::init(&cfg(), 13);
        let wide = TrainConfig { dims: vec![30, 2000, 10], ..cfg() };
        let b = ModelState::init(&wide, 13);
        assert_eq!(a.factors[2].max_abs_diff(&b.factors[2]), 0.0);
        assert_eq!(a.factors[0].max_abs_diff(&b.factors[0]), 0.0);
        assert_eq!(a.cores[2].max_abs_diff(&b.cores[2]), 0.0);
    }

    #[test]
    fn num_params_counts() {
        let m = ModelState::init(&cfg(), 5);
        assert_eq!(m.num_params(), (30 + 20 + 10) * 8 + 3 * 8 * 4);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let m = ModelState::init(&cfg(), 6);
        let p = std::env::temp_dir()
            .join(format!("ft_ckpt_{}.bin", std::process::id()));
        m.save(&p).unwrap();
        let m2 = ModelState::load(&p).unwrap();
        assert_eq!(m.order(), m2.order());
        for n in 0..3 {
            assert!(m.factors[n].max_abs_diff(&m2.factors[n]) == 0.0);
            assert!(m.cores[n].max_abs_diff(&m2.cores[n]) == 0.0);
            assert!(m.c_tables[n].max_abs_diff(&m2.c_tables[n]) < 1e-6);
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn load_rejects_bad_magic() {
        let p = std::env::temp_dir()
            .join(format!("ft_badck_{}.bin", std::process::id()));
        std::fs::write(&p, b"XXXX0000").unwrap();
        assert!(ModelState::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
