//! PJRT runtime — loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text**, see `/opt/xla-example/README.md`: serialized protos from
//! jax ≥ 0.5 are rejected by xla_extension 0.5.1) and executes them on the
//! PJRT CPU client from the request path. Python never runs here.
//!
//! Artifacts (see `artifacts/manifest.json`):
//!
//! * `matmul_*` — `C = A·B` (the reusable-intermediate refresh, L1 Pallas
//!   kernel `precompute_c`), compiled per row bucket so any `I_n` can be
//!   served by zero-padding to the next bucket.
//! * `predict_*` — batched chain-product prediction
//!   `x̂_b = Σ_r Π_n Crows[n][b,r]` (L1 kernel `predict`).
//! * `core_grad_*` — `G = (e·A)ᵀ V` (L1 kernel `core_grad`).
//!
//! The XLA-backed implementation lives in the `pjrt` submodule and is gated
//! behind the `xla` cargo feature (which implies `pjrt` and needs the
//! `xla_extension` bindings added locally — the offline container has
//! none); every other build — default **and** `--features pjrt`, the CI
//! feature-matrix's stub configuration — gets an API-identical stub whose
//! `load` errors so callers (including
//! [`crate::exec::PjrtPassBackend`]) fall back to the in-crate kernels.

pub mod manifest;

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::PjrtRuntime;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::PjrtRuntime;

/// Locate the artifacts directory: `$FT_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("FT_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    // Integration tests that need real artifacts live in
    // rust/tests/runtime_integration.rs (they skip when artifacts/ is
    // absent). Unit tests here cover the dispatch logic that works in both
    // the stub and the XLA-backed build.
    use super::*;
    use std::path::Path;

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("FT_ARTIFACTS", "/tmp/xyz");
        assert_eq!(default_artifacts_dir(), std::path::PathBuf::from("/tmp/xyz"));
        std::env::remove_var("FT_ARTIFACTS");
        assert_eq!(default_artifacts_dir(), std::path::PathBuf::from("artifacts"));
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(PjrtRuntime::load(Path::new("/nonexistent/xyz")).is_err());
    }
}
