//! Stub PJRT runtime for builds without the `xla` feature.
//!
//! The offline container ships no `xla_extension`, so every build short of
//! `--features xla` — including the CI feature-matrix's `--features pjrt`
//! stub configuration — compiles this API-identical stub instead. `load`
//! always errors, which every caller already handles: the PJRT pass
//! backend falls back to the in-crate GEMM/predict kernels, and
//! `cargo test` self-skips the artifact tests.

use crate::linalg::Matrix;
use anyhow::{bail, Result};
use std::path::Path;

use super::manifest::Manifest;

const MSG: &str =
    "PJRT support not compiled in (build with `--features xla` and the `xla_extension` bindings)";

/// API-compatible placeholder for the PJRT runtime.
pub struct PjrtRuntime {
    /// Parsed artifact manifest (empty in stub builds — `load` errors
    /// before one is ever constructed).
    pub manifest: Manifest,
}

impl PjrtRuntime {
    /// Always errors in stub builds (after surfacing manifest problems first,
    /// so failure-injection tests see the same early diagnostics).
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        // Preserve the real runtime's first failure mode: a missing or
        // malformed manifest reports as such, not as a feature error.
        let _ = Manifest::load(&dir.join("manifest.json"))?;
        bail!(MSG)
    }

    /// Platform string (`"stub"`).
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Number of loaded artifacts (always 0 in stub builds).
    pub fn num_artifacts(&self) -> usize {
        0
    }

    /// `C = A·B` — always errors in stub builds.
    pub fn matmul(&self, _a: &Matrix, _b: &Matrix) -> Result<Matrix> {
        bail!(MSG)
    }

    /// Batched chain-product prediction — always errors in stub builds.
    pub fn predict_batch(&self, _crows: &[Matrix]) -> Result<Vec<f32>> {
        bail!(MSG)
    }

    /// Core-gradient matmul — always errors in stub builds.
    pub fn core_grad(&self, _ea: &Matrix, _v: &Matrix) -> Result<Matrix> {
        bail!(MSG)
    }
}
