//! Real PJRT runtime (feature `pjrt`): loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** — serialized protos from jax ≥ 0.5
//! are rejected by xla_extension 0.5.1) and executes them on the PJRT CPU
//! client from the request path. Python never runs here.
//!
//! Requires the `xla` bindings crate (xla_extension); see `runtime::stub`
//! for the no-dependency build.

use crate::linalg::Matrix;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

use super::manifest::{Manifest, ManifestEntry};

/// A PJRT CPU runtime holding every compiled artifact.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Parsed artifact manifest the executables were compiled from.
    pub manifest: Manifest,
}

impl PjrtRuntime {
    /// Load `manifest.json` + every listed HLO text file from `dir` and
    /// compile them on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        let mut executables = HashMap::new();
        for entry in &manifest.entries {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(to_anyhow)
            .with_context(|| format!("parse HLO {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(to_anyhow)
                .with_context(|| format!("compile {}", entry.name))?;
            executables.insert(entry.name.clone(), exe);
        }
        Ok(PjrtRuntime { client, executables, manifest })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of compiled artifacts resident on the client.
    pub fn num_artifacts(&self) -> usize {
        self.executables.len()
    }

    fn entry_for(&self, op: &str, pred: impl Fn(&ManifestEntry) -> bool) -> Option<&ManifestEntry> {
        self.manifest
            .entries
            .iter()
            .filter(|e| e.op == op && pred(e))
            .min_by_key(|e| e.param("i").unwrap_or(usize::MAX))
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        let result = exe.execute::<xla::Literal>(inputs).map_err(to_anyhow)?;
        let lit = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        lit.to_tuple1().map_err(to_anyhow)
    }

    /// `C = A·B` via the smallest matmul artifact whose row bucket fits,
    /// zero-padding A's rows and slicing the result back.
    pub fn matmul(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let (rows, j) = (a.rows(), a.cols());
        let r = b.cols();
        if b.rows() != j {
            bail!("matmul shape mismatch: {}x{} @ {}x{}", rows, j, b.rows(), r);
        }
        let entry = self
            .entry_for("matmul", |e| {
                e.param("j") == Some(j)
                    && e.param("r") == Some(r)
                    && e.param("i").map_or(false, |i| i >= rows)
            })
            .ok_or_else(|| {
                anyhow!("no matmul artifact for I>={rows}, J={j}, R={r} (re-run `make artifacts`)")
            })?;
        let ipad = entry.param("i").unwrap();
        let mut a_pad = vec![0.0f32; ipad * j];
        a_pad[..rows * j].copy_from_slice(a.data());
        let a_lit = xla::Literal::vec1(&a_pad)
            .reshape(&[ipad as i64, j as i64])
            .map_err(to_anyhow)?;
        let b_lit = xla::Literal::vec1(b.data())
            .reshape(&[j as i64, r as i64])
            .map_err(to_anyhow)?;
        let out = self.run(&entry.name, &[a_lit, b_lit])?;
        let data: Vec<f32> = out.to_vec().map_err(to_anyhow)?;
        if data.len() != ipad * r {
            bail!("matmul artifact returned {} values, expected {}", data.len(), ipad * r);
        }
        Ok(Matrix::from_vec(rows, r, data[..rows * r].to_vec()))
    }

    /// Batched chain-product prediction: `xhat[b] = Σ_r Π_n crows[n][b,r]`.
    /// `crows` is one `B×R` matrix per mode. Pads the batch to the artifact
    /// size; runs in chunks if the batch exceeds the largest artifact.
    pub fn predict_batch(&self, crows: &[Matrix]) -> Result<Vec<f32>> {
        let n = crows.len();
        let batch = crows[0].rows();
        let r = crows[0].cols();
        for c in crows {
            if c.rows() != batch || c.cols() != r {
                bail!("predict_batch: ragged crows inputs");
            }
        }
        let entry = self
            .entry_for("predict", |e| {
                e.param("n") == Some(n) && e.param("r") == Some(r)
            })
            .ok_or_else(|| {
                anyhow!("no predict artifact for N={n}, R={r} (re-run `make artifacts`)")
            })?;
        let bcap = entry.param("b").unwrap_or(0);
        if bcap == 0 {
            bail!("predict artifact missing batch param");
        }
        let mut out = Vec::with_capacity(batch);
        let mut lo = 0usize;
        while lo < batch {
            let hi = (lo + bcap).min(batch);
            let chunk = hi - lo;
            let mut inputs = Vec::with_capacity(n);
            for c in crows {
                let mut pad = vec![0.0f32; bcap * r];
                pad[..chunk * r]
                    .copy_from_slice(&c.data()[lo * r..hi * r]);
                inputs.push(
                    xla::Literal::vec1(&pad)
                        .reshape(&[bcap as i64, r as i64])
                        .map_err(to_anyhow)?,
                );
            }
            let lit = self.run(&entry.name, &inputs)?;
            let data: Vec<f32> = lit.to_vec().map_err(to_anyhow)?;
            out.extend_from_slice(&data[..chunk]);
            lo = hi;
        }
        Ok(out)
    }

    /// Core gradient `G = (ea)ᵀ·v` where `ea` is `B×J` (error-scaled factor
    /// rows) and `v` is `B×R` chain products. Chunks + accumulates if the
    /// batch exceeds the artifact size.
    pub fn core_grad(&self, ea: &Matrix, v: &Matrix) -> Result<Matrix> {
        let batch = ea.rows();
        let j = ea.cols();
        let r = v.cols();
        if v.rows() != batch {
            bail!("core_grad: batch mismatch");
        }
        let entry = self
            .entry_for("core_grad", |e| {
                e.param("j") == Some(j) && e.param("r") == Some(r)
            })
            .ok_or_else(|| {
                anyhow!("no core_grad artifact for J={j}, R={r} (re-run `make artifacts`)")
            })?;
        let bcap = entry.param("b").unwrap_or(0);
        let mut acc = Matrix::zeros(j, r);
        let mut lo = 0usize;
        while lo < batch {
            let hi = (lo + bcap).min(batch);
            let chunk = hi - lo;
            let mut ea_pad = vec![0.0f32; bcap * j];
            ea_pad[..chunk * j].copy_from_slice(&ea.data()[lo * j..hi * j]);
            let mut v_pad = vec![0.0f32; bcap * r];
            v_pad[..chunk * r].copy_from_slice(&v.data()[lo * r..hi * r]);
            let ea_lit = xla::Literal::vec1(&ea_pad)
                .reshape(&[bcap as i64, j as i64])
                .map_err(to_anyhow)?;
            let v_lit = xla::Literal::vec1(&v_pad)
                .reshape(&[bcap as i64, r as i64])
                .map_err(to_anyhow)?;
            let lit = self.run(&entry.name, &[ea_lit, v_lit])?;
            let data: Vec<f32> = lit.to_vec().map_err(to_anyhow)?;
            for (a, &d) in acc.data_mut().iter_mut().zip(data.iter()) {
                *a += d;
            }
            lo = hi;
        }
        Ok(acc)
    }
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}
