//! The AOT artifact manifest (`artifacts/manifest.json`), written by
//! `python/compile/aot.py` and read by [`super::PjrtRuntime`].
//!
//! Schema:
//! ```json
//! {
//!   "version": 1,
//!   "entries": [
//!     {"name": "matmul_i4096_j32_r32", "op": "matmul",
//!      "file": "matmul_i4096_j32_r32.hlo.txt",
//!      "params": {"i": 4096, "j": 32, "r": 32}}
//!   ]
//! }
//! ```

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One AOT-compiled computation.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    /// Unique artifact name (`matmul_i4096_j32_r32`, ...).
    pub name: String,
    /// Operation kind: `matmul`, `predict`, `core_grad`.
    pub op: String,
    /// HLO text file, relative to the artifacts directory.
    pub file: String,
    /// Shape parameters (`i`, `j`, `r`, `b`, `n`, ...).
    pub params: BTreeMap<String, usize>,
}

impl ManifestEntry {
    /// Shape parameter by key (`i`, `j`, `r`, ...).
    pub fn param(&self, key: &str) -> Option<usize> {
        self.params.get(key).copied()
    }
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Schema version (only 1 is supported).
    pub version: usize,
    /// Every AOT-compiled computation listed by the manifest.
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Parse manifest JSON text (schema version 1, unique entry names).
    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = Json::parse(text).context("manifest.json")?;
        let version = doc
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing 'version'"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let raw = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'entries'"))?;
        let mut entries = Vec::with_capacity(raw.len());
        for (i, e) in raw.iter().enumerate() {
            let field = |k: &str| -> Result<String> {
                e.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("entry {i}: missing '{k}'"))
            };
            let mut params = BTreeMap::new();
            if let Some(p) = e.get("params").and_then(Json::as_obj) {
                for (k, v) in p {
                    let n = v
                        .as_usize()
                        .ok_or_else(|| anyhow!("entry {i}: param '{k}' not a number"))?;
                    params.insert(k.clone(), n);
                }
            }
            entries.push(ManifestEntry {
                name: field("name")?,
                op: field("op")?,
                file: field("file")?,
                params,
            });
        }
        let mut names: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        if names.len() != before {
            bail!("manifest contains duplicate entry names");
        }
        Ok(Manifest { version, entries })
    }

    /// Read and parse `manifest.json`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Manifest::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "entries": [
            {"name": "matmul_i1024_j32_r32", "op": "matmul",
             "file": "matmul_i1024_j32_r32.hlo.txt",
             "params": {"i": 1024, "j": 32, "r": 32}},
            {"name": "predict_n3_b8192_r32", "op": "predict",
             "file": "predict_n3_b8192_r32.hlo.txt",
             "params": {"n": 3, "b": 8192, "r": 32}}
        ]
    }"#;

    #[test]
    fn parses_entries_and_params() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.entries[0].op, "matmul");
        assert_eq!(m.entries[0].param("i"), Some(1024));
        assert_eq!(m.entries[1].param("n"), Some(3));
        assert_eq!(m.entries[1].param("missing"), None);
    }

    #[test]
    fn rejects_wrong_version() {
        assert!(Manifest::parse(r#"{"version": 2, "entries": []}"#).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"version": 1, "entries": [{"op": "x"}]}"#).is_err());
        assert!(Manifest::parse(r#"{"entries": []}"#).is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let dup = r#"{"version": 1, "entries": [
            {"name": "a", "op": "matmul", "file": "a.hlo.txt"},
            {"name": "a", "op": "matmul", "file": "b.hlo.txt"}
        ]}"#;
        assert!(Manifest::parse(dup).is_err());
    }

    #[test]
    fn empty_entries_ok() {
        let m = Manifest::parse(r#"{"version": 1, "entries": []}"#).unwrap();
        assert!(m.entries.is_empty());
    }
}
