//! `fastertucker` CLI — the launcher for training, data generation, dataset
//! inspection, evaluation and experiment regeneration.
//!
//! ```text
//! fastertucker gen    --kind netflix|yahoo|tiny|order|sparsity --out t.ftns [...]
//! fastertucker train  --data t.ftns --algo fastertucker --epochs 10 [...]
//! fastertucker info   --data t.ftns [--fiber-threshold 128]
//! fastertucker eval   --data t.ftns --ckpt model.bin
//! fastertucker repro  --exp table4|table5|fig3|fig4a|fig4bc|all
//! fastertucker runtime-check [--artifacts dir]
//! ```

#![allow(unknown_lints)]
#![allow(clippy::uninlined_format_args, clippy::needless_range_loop)]

use anyhow::{bail, Context, Result};
use fastertucker::algo::Algo;
use fastertucker::bench::experiments::{self, BenchScale};
use fastertucker::config::{Backend, Compute, TrainConfig};
use fastertucker::coordinator::{ServingHandle, Session, TopKQuery};
use fastertucker::data::dataset::Dataset;
use fastertucker::model::ModelState;
use fastertucker::runtime::{default_artifacts_dir, PjrtRuntime};
use fastertucker::tensor::bcsf::BcsfTensor;
use fastertucker::tensor::{coo::CooTensor, io};
use fastertucker::util::cli::Args;
use std::path::{Path, PathBuf};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_str() {
        "gen" => cmd_gen(&args),
        "train" => cmd_train(&args),
        "info" => cmd_info(&args),
        "eval" => cmd_eval(&args),
        "repro" => cmd_repro(&args),
        "runtime-check" => cmd_runtime_check(&args),
        "infer" => cmd_infer(&args),
        "convert" => cmd_convert(&args),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "fastertucker — parallel sparse FasterTucker decomposition (paper reproduction)

subcommands:
  gen            generate a synthetic tensor (--kind netflix|yahoo|tiny|order|sparsity
                 --nnz N --order N --dim N --seed S --out file.ftns)
  train          train a decomposition session (--data file.{ftns|tns} | --kind ... ;
                 --algo fastucker|fastertucker-coo|fastertucker|cutucker|ptucker
                 --epochs N --j N --r N --lr-a F --lr-b F --workers N
                 --stage-workers N (0 = all cores; parallel staging lanes)
                 --refresh full|incremental (dirty-row C-refresh; default incremental)
                 --test-frac F --compute rust|pjrt --backend cpu|pjrt
                 --save ckpt.bin --csv out.csv
                 --resume ckpt.bin --start-epoch N --lr-decay F --eval-every N
                 --eval-sample N --patience N --min-delta F
                 --stage-budget BYTES (0 = unbounded; byte-cap for B-CSF staging)
                 --ingest delta.tns --ingest-epochs N (absorb a COO delta after
                 the initial epochs, then keep training; grows modes as needed)
                 --ingest-warm-epochs N (delta-only sweeps right after ingest))
  info           dataset statistics + B-CSF balance report (--data file.ftns)
  eval           evaluate a checkpoint (--data file.ftns --ckpt model.bin)
  repro          regenerate paper tables/figures
                 (--exp table4|table5|fig3|fig4a|fig4bc|ablation|all)
  infer          batched top-k predictions from a checkpoint, served through
                 one consistent snapshot (--ckpt model.bin --mode N --topk K
                 --fixed i1,i2,..[;j1,j2,..]... [--pjrt])
  convert        convert tensor files (--data in.{ftns|tns} --out out.{ftns|tns})
  runtime-check  load + smoke-test the PJRT artifacts (--artifacts dir)"
}

/// Build the Dataset description the subcommand operates on: file-backed
/// when `--data` is given, synthetic otherwise.
fn dataset_from_args(args: &Args) -> Result<Dataset> {
    if let Some(path) = args.get("data") {
        return Ok(Dataset::from_path(path, args.switch("one-based")));
    }
    let kind = args.get_or("kind", "tiny");
    let nnz = args.get_usize("nnz", 100_000)?;
    let seed = args.get_u64("seed", 42)?;
    let (order, dim) = match kind.as_str() {
        "order" => (args.get_usize("order", 4)?, args.get_usize("dim", 1000)?),
        "sparsity" => (3, args.get_usize("dim", 300)?),
        _ => (3, 0),
    };
    Dataset::synthetic(&kind, nnz, order, dim, seed)
}

fn load_or_generate(args: &Args) -> Result<CooTensor> {
    dataset_from_args(args)?.load()
}

fn cmd_gen(args: &Args) -> Result<()> {
    let out = PathBuf::from(
        args.get("out").context("gen requires --out <file.ftns>")?,
    );
    let tensor = load_or_generate(args)?;
    args.finish()?;
    io::write_binary(&tensor, &out)?;
    println!(
        "wrote {} ({} nnz, dims {:?}, density {:.3e})",
        out.display(),
        tensor.nnz(),
        tensor.dims(),
        tensor.density()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let dataset = dataset_from_args(args)?;
    let algo = Algo::parse(&args.get_or("algo", "fastertucker"))?;
    let epochs = args.get_usize("epochs", 10)?;
    let test_frac = args.get_f32("test-frac", 0.1)? as f64;
    let split_seed = args.get_u64("seed", 42)?;
    let (train, test) = dataset.load_split(test_frac, split_seed)?;
    let mut cfg = TrainConfig {
        order: train.order(),
        dims: train.dims().to_vec(),
        ..TrainConfig::default()
    };
    cfg.apply_args(args)?;
    let save_path = args.get("save").map(PathBuf::from);
    let csv_path = args.get("csv").map(PathBuf::from);
    let resume_path = args.get("resume").map(PathBuf::from);
    let start_epoch = args.get_usize("start-epoch", 0)?;
    let ingest_path = args.get("ingest").map(PathBuf::from);
    let ingest_epochs = args.get_usize("ingest-epochs", epochs)?;
    let one_based = args.switch("one-based");
    args.finish()?;

    println!(
        "training {} on {} ({} nnz, dims {:?}), J={} R={}, {} workers, {} epochs",
        algo.name(),
        dataset.name(),
        train.nnz(),
        train.dims(),
        cfg.j,
        cfg.r,
        cfg.effective_workers(),
        epochs
    );
    let mut session = match &resume_path {
        Some(p) => {
            println!("resuming from {} at epoch {start_epoch}", p.display());
            Session::resume(algo, cfg.clone(), &train, p, start_epoch)?
        }
        // ingestion needs the pristine tensor retained as the restage
        // base, so the ingest path opens a shared session
        None if ingest_path.is_some() => Session::new_shared(
            algo,
            cfg.clone(),
            std::sync::Arc::new(train.clone()),
        )?,
        None => Session::new(algo, cfg.clone(), &train)?,
    };
    // Either spelling selects the PJRT pass backend: the new
    // `--backend pjrt` or the legacy `--compute pjrt`. The legacy flag
    // keeps its original contract — PJRT or abort — while the best-effort
    // `--backend pjrt` warns and falls back to the in-crate kernels (the
    // backend's documented degradation, e.g. in stub builds).
    if Backend::resolve(&cfg) == Backend::Pjrt {
        let dir = default_artifacts_dir();
        match PjrtRuntime::load(&dir) {
            Ok(rt) => {
                println!(
                    "PJRT engine: platform={}, {} artifacts",
                    rt.platform(),
                    rt.num_artifacts()
                );
                session = session.with_runtime(rt);
            }
            Err(e) if cfg.compute == Compute::Pjrt => {
                return Err(e).with_context(|| {
                    format!("loading PJRT artifacts from {}", dir.display())
                });
            }
            Err(e) => eprintln!(
                "warning: PJRT artifacts unavailable from {} ({e:#}); \
                 the pjrt backend falls back to the in-crate kernels",
                dir.display()
            ),
        }
    }
    let prep = session.prep_stats();
    println!(
        "prep: {:.3}s (shuffle {:.3}s, B-CSF {:.3}s, {} staging worker{})",
        prep.total_seconds,
        prep.shuffle_seconds,
        prep.bcsf_seconds,
        prep.stage_workers,
        if prep.stage_workers == 1 { "" } else { "s" }
    );
    let mut report = session.run(epochs, test.as_ref());
    for rec in &report.convergence.records {
        println!(
            "epoch {:>3}  {:>8.3}s (factor {:>7.3}s core {:>7.3}s)  RMSE {:.5}  MAE {:.5}",
            rec.epoch, rec.seconds, rec.factor_seconds, rec.core_seconds, rec.rmse, rec.mae
        );
    }
    if let Some(p) = &ingest_path {
        let rep = session
            .ingest_file(p, one_based)
            .with_context(|| format!("ingesting delta from {}", p.display()))?;
        println!(
            "ingested {} (+{} nnz; B-CSF blocks reused {}, rebuilt {})",
            p.display(),
            rep.added_nnz,
            rep.blocks_reused,
            rep.blocks_rebuilt
        );
        for (mode, old_rows, new_rows) in &rep.grown {
            println!("  mode {mode} grew {old_rows} -> {new_rows} rows");
        }
        let printed = report.convergence.records.len();
        report = session.run(ingest_epochs, test.as_ref());
        for rec in &report.convergence.records[printed..] {
            println!(
                "epoch {:>3}  {:>8.3}s (factor {:>7.3}s core {:>7.3}s)  RMSE {:.5}  MAE {:.5}",
                rec.epoch, rec.seconds, rec.factor_seconds, rec.core_seconds, rec.rmse, rec.mae
            );
        }
    }
    if report.early_stopped {
        println!(
            "early-stopped after {} epochs (patience {})",
            report.epochs_completed, cfg.early_stop_patience
        );
    }
    println!(
        "mean iteration: {:.4}s (factor {:.4}s, core {:.4}s)",
        report.convergence.mean_epoch_seconds(),
        report.convergence.mean_factor_seconds(),
        report.convergence.mean_core_seconds()
    );
    if let Some(p) = csv_path {
        std::fs::write(&p, report.convergence.to_csv())?;
        println!("wrote convergence series to {}", p.display());
    }
    if let Some(p) = save_path {
        session.save_checkpoint(&p)?;
        println!("saved checkpoint to {}", p.display());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let tensor = load_or_generate(args)?;
    let threshold = args.get_usize("fiber-threshold", 128)?;
    let block_nnz = args.get_usize("block-nnz", 8192)?;
    args.finish()?;
    println!("order    : {}", tensor.order());
    println!("dims     : {:?}", tensor.dims());
    println!("nnz      : {}", tensor.nnz());
    println!("density  : {:.3e}", tensor.density());
    for n in 0..tensor.order() {
        let b = BcsfTensor::build(&tensor, n, threshold, block_nnz);
        let s = &b.stats;
        println!(
            "mode {n}: {} fibers (max len {}), {} tasks, {} blocks \
             (nnz max/mean {}/{:.1}, cv {:.3})",
            s.num_fibers,
            s.max_fiber_len,
            s.num_tasks,
            s.num_blocks,
            s.max_block_nnz,
            s.mean_block_nnz,
            s.block_cv
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let tensor = load_or_generate(args)?;
    let ckpt = args.get("ckpt").context("eval requires --ckpt model.bin")?;
    let model = ModelState::load(Path::new(ckpt))?;
    let workers = args.get_usize("workers", 0)?;
    args.finish()?;
    let workers = if workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        workers
    };
    let (rmse, mae) = fastertucker::metrics::rmse_mae(&model, &tensor, workers);
    println!("RMSE {rmse:.6}  MAE {mae:.6}  ({} elements)", tensor.nnz());
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let exp = args.get_or("exp", "all");
    args.finish()?;
    let scale = BenchScale::from_env();
    println!("bench scale: {scale:?}\n");
    let run = |name: &str| -> bool { exp == "all" || exp == name };
    if run("table5") {
        println!("{}", experiments::table5(&scale).render());
    }
    if run("table4") {
        println!("{}", experiments::table4(&scale).render());
    }
    if run("fig3") {
        println!("{}", experiments::fig3(&scale).render());
    }
    if run("fig4a") {
        println!("{}", experiments::fig4a(&scale).render());
    }
    if run("fig4bc") {
        println!("{}", experiments::fig4bc(&scale).render());
    }
    if run("ablation") {
        println!("{}", experiments::ablation_threshold(&scale).render());
        println!("{}", experiments::ablation_block_size(&scale).render());
    }
    println!("results persisted under results/");
    Ok(())
}

/// Batched top-k scoring from a checkpoint through the serving layer: every
/// `;`-separated coordinate tuple in `--fixed` becomes one query, and the
/// whole batch resolves against one [`ServingHandle`] snapshot — the same
/// concurrent-reader path a live `SessionRegistry` serves during training.
/// With `--pjrt` the scoring runs through the batched `predict` artifact
/// instead.
fn cmd_infer(args: &Args) -> Result<()> {
    let ckpt = args.get("ckpt").context("infer requires --ckpt model.bin")?;
    let model = ModelState::load(Path::new(ckpt))?;
    let mode = args.get_usize("mode", 1)?;
    let topk = args.get_usize("topk", 10)?;
    let fixed_raw = args
        .get("fixed")
        .context(
            "infer requires --fixed i1,i2,.. (coords of the other modes; \
             separate several queries with ';')",
        )?
        .to_string();
    let use_pjrt = args.switch("pjrt");
    args.finish()?;
    let order = model.order();
    if mode >= order {
        bail!("--mode {mode} out of range for order {order}");
    }
    let queries: Vec<TopKQuery> = fixed_raw
        .split(';')
        .map(|tuple| -> Result<TopKQuery> {
            let fixed = tuple
                .split(',')
                .map(|tok| {
                    tok.trim()
                        .parse::<u32>()
                        .map_err(|_| anyhow::anyhow!("bad coordinate '{tok}'"))
                })
                .collect::<Result<Vec<u32>>>()?;
            if fixed.len() != order - 1 {
                bail!(
                    "--fixed tuple '{tuple}' needs {} coordinates (got {})",
                    order - 1,
                    fixed.len()
                );
            }
            Ok(TopKQuery { mode, fixed, k: topk })
        })
        .collect::<Result<Vec<_>>>()?;

    if use_pjrt {
        let rt = PjrtRuntime::load(&default_artifacts_dir())?;
        for q in &queries {
            let scores = pjrt_score_mode(&model, &rt, q)?;
            let mut ranked: Vec<(usize, f32)> =
                scores.into_iter().enumerate().collect();
            ranked.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
            print_topk(q, &ranked[..topk.min(ranked.len())]);
        }
        return Ok(());
    }

    let handle = ServingHandle::from_model(&model);
    for (q, result) in queries.iter().zip(handle.top_k_batch(&queries)?) {
        print_topk(q, &result.items);
    }
    Ok(())
}

fn print_topk(q: &TopKQuery, items: &[(usize, f32)]) {
    println!("top-{} of mode {} given fixed {:?}:", q.k, q.mode, q.fixed);
    for (i, score) in items {
        println!("  index {i:>8}  score {score:.4}");
    }
}

/// PJRT scoring for one open-mode query: gather the C rows into `N` dense
/// `I_mode×R` blocks and run the batched chain-product `predict` artifact.
fn pjrt_score_mode(
    model: &ModelState,
    rt: &PjrtRuntime,
    q: &TopKQuery,
) -> Result<Vec<f32>> {
    let order = model.order();
    let dim = model.factors[q.mode].rows();
    let r = model.r();
    let mut coords = vec![0u32; order];
    let mut k = 0;
    for m in 0..order {
        if m != q.mode {
            let c = q.fixed[k] as usize;
            if c >= model.factors[m].rows() {
                bail!("fixed coord {c} out of range for mode {m}");
            }
            coords[m] = c as u32;
            k += 1;
        }
    }
    let mut crows: Vec<fastertucker::linalg::Matrix> = (0..order)
        .map(|_| fastertucker::linalg::Matrix::zeros(dim, r))
        .collect();
    for i in 0..dim {
        for m in 0..order {
            let row = if m == q.mode { i } else { coords[m] as usize };
            crows[m].row_mut(i).copy_from_slice(model.c_tables[m].row(row));
        }
    }
    rt.predict_batch(&crows)
}

/// Convert between the binary (.ftns) and FROSTT-style text (.tns) formats.
fn cmd_convert(args: &Args) -> Result<()> {
    let input = args.get("data").context("convert requires --data")?.to_string();
    let out = PathBuf::from(args.get("out").context("convert requires --out")?);
    let one_based = args.switch("one-based");
    let tensor = load_or_generate(args)?;
    args.finish()?;
    match out.extension().and_then(|e| e.to_str()) {
        Some("tns") => io::write_text(&tensor, &out, one_based)?,
        _ => io::write_binary(&tensor, &out)?,
    }
    println!("converted {} -> {} ({} nnz)", input, out.display(), tensor.nnz());
    Ok(())
}

fn cmd_runtime_check(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    args.finish()?;
    let rt = PjrtRuntime::load(&dir)
        .with_context(|| format!("loading artifacts from {}", dir.display()))?;
    println!("platform : {}", rt.platform());
    println!("artifacts: {}", rt.num_artifacts());
    // smoke: C = A·B against the in-crate GEMM
    use fastertucker::linalg::Matrix;
    use fastertucker::util::rng::Rng;
    let mut rng = Rng::new(7);
    let j = rt
        .manifest
        .entries
        .iter()
        .find(|e| e.op == "matmul")
        .and_then(|e| e.param("j"))
        .context("no matmul artifact in manifest")?;
    let r = rt
        .manifest
        .entries
        .iter()
        .find(|e| e.op == "matmul")
        .and_then(|e| e.param("r"))
        .unwrap_or(j);
    let a = Matrix::uniform(100, j, -1.0, 1.0, &mut rng);
    let b = Matrix::uniform(j, r, -1.0, 1.0, &mut rng);
    let c_pjrt = rt.matmul(&a, &b)?;
    let c_rust = a.matmul(&b);
    let diff = c_pjrt.max_abs_diff(&c_rust);
    println!("matmul({j}x{r}) max|Δ| vs rust GEMM: {diff:.2e}");
    if diff > 1e-3 {
        bail!("PJRT matmul deviates from reference by {diff}");
    }
    println!("runtime check OK");
    Ok(())
}
