//! Configuration system: typed training config + a TOML-subset file format.
//!
//! The launcher accepts `--config path.toml` and CLI overrides. The parser
//! covers the subset we emit and document: `[section]` headers, `key = value`
//! with integer / float / boolean / quoted-string / homogeneous-array
//! values, and `#` comments.

pub mod toml;

use crate::util::cli::Args;
use anyhow::{bail, Result};

/// Which engine executes the dense hot-spot kernels (`C = A·B`, batched
/// prediction, core gradient).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compute {
    /// In-crate Rust kernels (default: lowest per-call latency).
    Rust,
    /// AOT-compiled JAX/Pallas artifacts via PJRT (`artifacts/*.hlo.txt`).
    Pjrt,
}

impl Compute {
    /// Parse a CLI/TOML backend name (`rust` | `pjrt`).
    pub fn parse(s: &str) -> Result<Compute> {
        match s {
            "rust" => Ok(Compute::Rust),
            "pjrt" => Ok(Compute::Pjrt),
            other => bail!("unknown compute backend '{other}' (rust|pjrt)"),
        }
    }
}

/// Which [`crate::exec::PassBackend`] executes whole factor/core passes —
/// the coarser sibling of [`Compute`] (which selects only the dense
/// kernels): a backend owns an entire pass, from block scheduling to the
/// per-mode `C^(n)` refresh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// `CpuShardBackend`: the in-crate `ShardPlan` sweep (default) —
    /// bit-identical to the pre-backend engine path.
    Cpu,
    /// `PjrtPassBackend`: passes route their dense work through the AOT
    /// artifact manifest (stub-backed fallback to the in-crate kernels
    /// when no runtime is attached or the `xla` feature is off).
    Pjrt,
}

impl Backend {
    /// Parse a CLI/TOML backend name (`cpu` | `pjrt`).
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "cpu" => Ok(Backend::Cpu),
            "pjrt" => Ok(Backend::Pjrt),
            other => bail!("unknown pass backend '{other}' (cpu|pjrt)"),
        }
    }

    /// The effective backend for a config: `--backend pjrt` selects the
    /// PJRT pass backend, and the legacy `--compute pjrt` implies it (so
    /// pre-backend configs keep routing their refresh through the
    /// artifacts exactly as before).
    pub fn resolve(cfg: &TrainConfig) -> Backend {
        if cfg.backend == Backend::Pjrt || cfg.compute == Compute::Pjrt {
            Backend::Pjrt
        } else {
            Backend::Cpu
        }
    }

    /// Stable display name (`cpu` | `pjrt`).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Cpu => "cpu",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// How the per-mode reuse tables `C^(n) = A^(n) B^(n)` are refreshed
/// between passes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefreshMode {
    /// Recompute every row of every stale table (the pre-PR-6 behaviour).
    Full,
    /// Recompute only the rows whose factor row changed since the last
    /// refresh (dirty-row tracking). Bitwise identical to `Full` because
    /// each C row is a pure function of its factor row — the default.
    Incremental,
}

impl RefreshMode {
    /// Parse a CLI/TOML refresh-mode name (`full` | `incremental`).
    pub fn parse(s: &str) -> Result<RefreshMode> {
        match s {
            "full" => Ok(RefreshMode::Full),
            "incremental" => Ok(RefreshMode::Incremental),
            other => bail!("unknown refresh mode '{other}' (full|incremental)"),
        }
    }

    /// Stable display name (`full` | `incremental`).
    pub fn name(self) -> &'static str {
        match self {
            RefreshMode::Full => "full",
            RefreshMode::Incremental => "incremental",
        }
    }
}

/// How a pass's blocks are scheduled over its workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Dynamic LPT claiming over one shared counter (the pre-stealing
    /// behaviour): workers race to claim the next block of a single
    /// descending-weight queue. Deterministic at 1 worker; at >1 workers
    /// the block→worker partition (and therefore the core-gradient merge
    /// grouping) depends on timing — the default, and the path every
    /// frozen parity reference pins.
    Static,
    /// Block-granular work stealing over per-worker deques seeded by the
    /// LPT plan; idle workers steal whole blocks from the heaviest
    /// remaining queue. Core-gradient partials land in **per-block slots
    /// merged in canonical (ascending block id) order**, so the merged
    /// result is identical for every worker count and every steal
    /// schedule — strictly more deterministic than `Static` at >1
    /// workers.
    Stealing,
}

impl SchedMode {
    /// Parse a CLI/TOML scheduler name (`static` | `stealing`).
    pub fn parse(s: &str) -> Result<SchedMode> {
        match s {
            "static" => Ok(SchedMode::Static),
            "stealing" => Ok(SchedMode::Stealing),
            other => bail!("unknown sched mode '{other}' (static|stealing)"),
        }
    }

    /// Stable display name (`static` | `stealing`).
    pub fn name(self) -> &'static str {
        match self {
            SchedMode::Static => "static",
            SchedMode::Stealing => "stealing",
        }
    }
}

/// How the executor discovers the NUMA topology that worker homes,
/// operand replicas, and node-compact lease allocation are derived from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NumaMode {
    /// Discover the real topology from `/sys/devices/system/node`
    /// (deterministic single-node fallback when the tree is absent or
    /// unreadable) — the default.
    Auto,
    /// Force the single-node topology: no pinning, one replica, the
    /// pre-NUMA lease allocator behaviour bit-for-bit.
    Off,
    /// Force a synthetic `n`-node topology (`--numa N-nodes`): worker
    /// homes and operand replicas behave as on an `n`-socket machine, but
    /// no threads are pinned (the nodes are fictitious). Used by the
    /// benches and parity tests to exercise the multi-node paths on
    /// single-socket hardware.
    Force(usize),
}

impl NumaMode {
    /// Parse a CLI/TOML NUMA mode (`auto` | `off` | `<n>-nodes`).
    pub fn parse(s: &str) -> Result<NumaMode> {
        match s {
            "auto" => Ok(NumaMode::Auto),
            "off" => Ok(NumaMode::Off),
            other => {
                if let Some(n) = other.strip_suffix("-nodes") {
                    let n: usize = n.parse().map_err(|_| {
                        anyhow::anyhow!(
                            "unknown numa mode '{other}' (auto|off|N-nodes)"
                        )
                    })?;
                    if n == 0 {
                        bail!("--numa 0-nodes: node count must be >= 1");
                    }
                    Ok(NumaMode::Force(n))
                } else {
                    bail!("unknown numa mode '{other}' (auto|off|N-nodes)")
                }
            }
        }
    }

    /// Stable display name (`auto` | `off` | `<n>-nodes`).
    pub fn name(self) -> String {
        match self {
            NumaMode::Auto => "auto".to_string(),
            NumaMode::Off => "off".to_string(),
            NumaMode::Force(n) => format!("{n}-nodes"),
        }
    }
}

/// Full training configuration (the paper's hyper-parameters plus the
/// scheduler knobs).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Tensor order N.
    pub order: usize,
    /// Mode sizes `I_1..I_N`.
    pub dims: Vec<usize>,
    /// Factor rank `J_n` (the paper uses a single J for all modes; so do we).
    pub j: usize,
    /// Core rank R.
    pub r: usize,
    /// Factor learning rate γ_A.
    pub lr_a: f32,
    /// Core learning rate γ_B.
    pub lr_b: f32,
    /// Factor regularization λ_A.
    pub lambda_a: f32,
    /// Core regularization λ_B.
    pub lambda_b: f32,
    /// Worker threads (the paper's thread-groups). 0 = all cores.
    pub workers: usize,
    /// B-CSF fiber split threshold (paper: 128).
    pub fiber_threshold: usize,
    /// B-CSF block size target in nnz.
    pub block_nnz: usize,
    /// Staging worker threads for `PreparedStorage::prepare` (per-mode
    /// B-CSF builds + intra-build fiber-run splits). 0 = all cores. Safe
    /// to vary freely: staging output is bit-identical at any count.
    pub stage_workers: usize,
    /// How the per-mode `C^(n)` reuse tables are refreshed between passes
    /// (bitwise-equivalent modes; `Incremental` skips untouched rows).
    pub refresh: RefreshMode,
    /// How a pass's blocks are scheduled over its workers: `Static`
    /// shared-counter LPT claiming (default) or block-granular work
    /// `Stealing` with canonical per-block merge order.
    pub sched: SchedMode,
    /// RNG seed for init and sampling.
    pub seed: u64,
    /// Dense kernel engine.
    pub compute: Compute,
    /// Pass backend: who executes whole factor/core passes
    /// ([`Backend::resolve`] folds the legacy `compute = pjrt` into this).
    pub backend: Backend,
    /// Update core matrices each epoch (both paper modules) or factors only.
    pub update_cores: bool,
    /// When training without a held-out test set, self-evaluate on at most
    /// this many deterministically sampled training non-zeros per epoch
    /// (0 = always use the full training set).
    pub eval_sample_nnz: usize,
    /// Multiplicative per-epoch decay applied to both learning rates
    /// (1.0 = constant rates; schedules continue across warm starts).
    pub lr_decay: f32,
    /// Evaluate every `eval_every` epochs; records in between carry the
    /// last computed RMSE/MAE forward.
    pub eval_every: usize,
    /// Stop a session after this many consecutive evaluations whose RMSE
    /// fails to improve on the best seen by at least
    /// `early_stop_min_delta` (0 disables early stopping).
    pub early_stop_patience: usize,
    /// Minimum RMSE improvement that resets the early-stop counter.
    pub early_stop_min_delta: f64,
    /// Byte budget for staged B-CSF residency (`--stage-budget`).
    /// 0 = unbounded (every rotation stays in RAM, the pre-PR-9
    /// behaviour). When positive, `PreparedStorage` builds rotations
    /// mode-by-mode, spills completed ones to disk, and pages them back
    /// in on demand so resident bytes never exceed the budget
    /// (`PrepStats::peak_resident_bytes` proves the cap held). Staged
    /// output is bitwise identical to unbounded staging at any budget.
    pub stage_budget_bytes: usize,
    /// After `Session::ingest`, run this many warm-up epochs over the
    /// delta non-zeros only before blending back to full sweeps
    /// (`--ingest-warm-epochs`, 0 = train on the full merged tensor
    /// immediately).
    pub ingest_warm_epochs: usize,
    /// NUMA topology mode (`--numa auto|off|N-nodes`): governs worker
    /// pinning, node-local operand replicas, and node-compact lease
    /// allocation. Placement only — every mode is bitwise-identical math.
    pub numa: NumaMode,
    /// Kernel tile size in non-zeros per leaf-run chunk (`--tile-nnz`).
    /// 0 = auto (a small cost model over rank and the SIMD lane width
    /// picks an L2-sized tile); `usize::MAX` effectively disables tiling.
    /// Tiling only chunks the existing traversal order, so every value is
    /// bitwise-identical to the untiled sweep.
    pub tile_nnz: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            order: 3,
            dims: vec![0, 0, 0],
            j: 32,
            r: 32,
            lr_a: 1e-3,
            lr_b: 2e-5,
            lambda_a: 0.01,
            lambda_b: 0.01,
            workers: 0,
            fiber_threshold: 128,
            block_nnz: 8192,
            stage_workers: 0,
            refresh: RefreshMode::Incremental,
            sched: SchedMode::Static,
            seed: 42,
            compute: Compute::Rust,
            backend: Backend::Cpu,
            update_cores: true,
            eval_sample_nnz: 100_000,
            lr_decay: 1.0,
            eval_every: 1,
            early_stop_patience: 0,
            early_stop_min_delta: 0.0,
            stage_budget_bytes: 0,
            ingest_warm_epochs: 0,
            numa: NumaMode::Auto,
            tile_nnz: 0,
        }
    }
}

impl TrainConfig {
    /// Effective worker count.
    pub fn effective_workers(&self) -> usize {
        if self.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.workers
        }
    }

    /// Effective staging worker count (`stage_workers`, 0 = all cores).
    pub fn effective_stage_workers(&self) -> usize {
        if self.stage_workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.stage_workers
        }
    }

    /// Apply CLI overrides (`--j`, `--r`, `--lr-a`, ...).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        self.j = args.get_usize("j", self.j)?;
        self.r = args.get_usize("r", self.r)?;
        self.lr_a = args.get_f32("lr-a", self.lr_a)?;
        self.lr_b = args.get_f32("lr-b", self.lr_b)?;
        self.lambda_a = args.get_f32("lambda-a", self.lambda_a)?;
        self.lambda_b = args.get_f32("lambda-b", self.lambda_b)?;
        self.workers = args.get_usize("workers", self.workers)?;
        self.fiber_threshold =
            args.get_usize("fiber-threshold", self.fiber_threshold)?;
        self.block_nnz = args.get_usize("block-nnz", self.block_nnz)?;
        self.stage_workers = args.get_usize("stage-workers", self.stage_workers)?;
        self.seed = args.get_u64("seed", self.seed)?;
        self.eval_sample_nnz = args.get_usize("eval-sample", self.eval_sample_nnz)?;
        self.lr_decay = args.get_f32("lr-decay", self.lr_decay)?;
        self.eval_every = args.get_usize("eval-every", self.eval_every)?;
        self.early_stop_patience =
            args.get_usize("patience", self.early_stop_patience)?;
        self.early_stop_min_delta =
            args.get_f64("min-delta", self.early_stop_min_delta)?;
        self.stage_budget_bytes =
            args.get_usize("stage-budget", self.stage_budget_bytes)?;
        self.ingest_warm_epochs =
            args.get_usize("ingest-warm-epochs", self.ingest_warm_epochs)?;
        if let Some(c) = args.get("compute") {
            self.compute = Compute::parse(c)?;
        }
        if let Some(b) = args.get("backend") {
            self.backend = Backend::parse(b)?;
        }
        if let Some(m) = args.get("refresh") {
            self.refresh = RefreshMode::parse(m)?;
        }
        if let Some(m) = args.get("sched") {
            self.sched = SchedMode::parse(m)?;
        }
        if let Some(m) = args.get("numa") {
            self.numa = NumaMode::parse(m)?;
        }
        if let Some(t) = args.get("tile-nnz") {
            self.tile_nnz = match t {
                "auto" => 0,
                "off" => usize::MAX,
                n => n.parse().map_err(|_| {
                    anyhow::anyhow!("--tile-nnz: expected auto|off|<nnz>, got '{n}'")
                })?,
            };
        }
        Ok(())
    }

    /// Load overrides from a parsed TOML table (section `[train]`).
    pub fn apply_toml(&mut self, doc: &toml::Doc) -> Result<()> {
        use toml::Value;
        let get = |key: &str| doc.get("train", key);
        macro_rules! set_num {
            ($field:expr, $key:expr, $ty:ty) => {
                if let Some(v) = get($key) {
                    match v {
                        Value::Int(x) => $field = *x as $ty,
                        Value::Float(x) => $field = *x as $ty,
                        _ => bail!("[train] {}: expected a number", $key),
                    }
                }
            };
        }
        set_num!(self.j, "j", usize);
        set_num!(self.r, "r", usize);
        set_num!(self.lr_a, "lr_a", f32);
        set_num!(self.lr_b, "lr_b", f32);
        set_num!(self.lambda_a, "lambda_a", f32);
        set_num!(self.lambda_b, "lambda_b", f32);
        set_num!(self.workers, "workers", usize);
        set_num!(self.fiber_threshold, "fiber_threshold", usize);
        set_num!(self.block_nnz, "block_nnz", usize);
        set_num!(self.stage_workers, "stage_workers", usize);
        set_num!(self.seed, "seed", u64);
        set_num!(self.eval_sample_nnz, "eval_sample_nnz", usize);
        set_num!(self.lr_decay, "lr_decay", f32);
        set_num!(self.eval_every, "eval_every", usize);
        set_num!(self.early_stop_patience, "early_stop_patience", usize);
        set_num!(self.early_stop_min_delta, "early_stop_min_delta", f64);
        set_num!(self.stage_budget_bytes, "stage_budget_bytes", usize);
        set_num!(self.ingest_warm_epochs, "ingest_warm_epochs", usize);
        if let Some(Value::Str(s)) = get("compute") {
            self.compute = Compute::parse(s)?;
        }
        if let Some(Value::Str(s)) = get("backend") {
            self.backend = Backend::parse(s)?;
        }
        if let Some(Value::Str(s)) = get("refresh") {
            self.refresh = RefreshMode::parse(s)?;
        }
        if let Some(Value::Str(s)) = get("sched") {
            self.sched = SchedMode::parse(s)?;
        }
        if let Some(Value::Str(s)) = get("numa") {
            self.numa = NumaMode::parse(s)?;
        }
        set_num!(self.tile_nnz, "tile_nnz", usize);
        if let Some(v) = get("update_cores") {
            match v {
                Value::Bool(b) => self.update_cores = *b,
                _ => bail!("[train] update_cores: expected a boolean"),
            }
        }
        Ok(())
    }

    /// Sanity-check parameter combinations before training.
    pub fn validate(&self) -> Result<()> {
        if self.order < 2 {
            bail!("order must be >= 2");
        }
        if self.dims.len() != self.order {
            bail!("dims length {} != order {}", self.dims.len(), self.order);
        }
        if self.dims.iter().any(|&d| d == 0) {
            bail!("all mode sizes must be positive");
        }
        if self.j == 0 || self.r == 0 {
            bail!("ranks J and R must be positive");
        }
        if self.j > 1024 || self.r > 1024 {
            bail!("ranks above 1024 are not supported");
        }
        if !(self.lr_a > 0.0 && self.lr_b > 0.0) {
            bail!("learning rates must be positive");
        }
        if self.lambda_a < 0.0 || self.lambda_b < 0.0 {
            bail!("regularization must be non-negative");
        }
        if self.fiber_threshold == 0 || self.block_nnz == 0 {
            bail!("B-CSF parameters must be positive");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be >= 1");
        }
        if !(self.lr_decay > 0.0 && self.lr_decay.is_finite()) {
            bail!("lr_decay must be positive and finite");
        }
        if self.early_stop_min_delta < 0.0 {
            bail!("early-stop min delta must be non-negative");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid_once_dims_set() {
        let mut c = TrainConfig::default();
        c.dims = vec![10, 10, 10];
        c.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_configs() {
        let mut c = TrainConfig::default();
        c.dims = vec![10, 10]; // order mismatch
        assert!(c.validate().is_err());
        c.dims = vec![10, 10, 10];
        c.j = 0;
        assert!(c.validate().is_err());
        c.j = 32;
        c.lr_a = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn cli_overrides_apply() {
        let args = Args::parse(
            ["train", "--j", "16", "--r", "8", "--lr-a", "0.005", "--compute", "pjrt"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.j, 16);
        assert_eq!(c.r, 8);
        assert_eq!(c.lr_a, 0.005);
        assert_eq!(c.compute, Compute::Pjrt);
    }

    #[test]
    fn toml_overrides_apply() {
        let doc = toml::Doc::parse(
            "[train]\nj = 8\nlr_a = 0.002\ncompute = \"pjrt\"\nupdate_cores = false\n",
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.j, 8);
        assert_eq!(c.lr_a, 0.002);
        assert_eq!(c.compute, Compute::Pjrt);
        assert!(!c.update_cores);
    }

    #[test]
    fn compute_parse_rejects_unknown() {
        assert!(Compute::parse("gpu").is_err());
    }

    #[test]
    fn backend_parse_and_resolve() {
        assert!(Backend::parse("cuda").is_err());
        let mut c = TrainConfig::default();
        assert_eq!(Backend::resolve(&c), Backend::Cpu);
        // --backend pjrt selects the PJRT pass backend...
        c.backend = Backend::parse("pjrt").unwrap();
        assert_eq!(Backend::resolve(&c), Backend::Pjrt);
        // ...and the legacy --compute pjrt implies it
        c.backend = Backend::Cpu;
        c.compute = Compute::Pjrt;
        assert_eq!(Backend::resolve(&c), Backend::Pjrt);
        assert_eq!(Backend::Pjrt.name(), "pjrt");
        assert_eq!(Backend::Cpu.name(), "cpu");
    }

    #[test]
    fn backend_applies_from_cli_and_toml() {
        let args = Args::parse(
            ["train", "--backend", "pjrt"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.backend, Backend::Pjrt);
        let doc = toml::Doc::parse("[train]\nbackend = \"cpu\"\n").unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.backend, Backend::Cpu);
    }

    #[test]
    fn session_knobs_apply_and_validate() {
        let args = Args::parse(
            [
                "train", "--eval-sample", "5000", "--lr-decay", "0.9",
                "--eval-every", "3", "--patience", "4", "--min-delta", "0.001",
            ]
            .iter()
            .map(|s| s.to_string()),
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.dims = vec![10, 10, 10];
        c.apply_args(&args).unwrap();
        assert_eq!(c.eval_sample_nnz, 5000);
        assert_eq!(c.lr_decay, 0.9);
        assert_eq!(c.eval_every, 3);
        assert_eq!(c.early_stop_patience, 4);
        assert_eq!(c.early_stop_min_delta, 0.001);
        c.validate().unwrap();
        c.eval_every = 0;
        assert!(c.validate().is_err());
        c.eval_every = 1;
        c.lr_decay = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn session_knobs_from_toml() {
        let doc = toml::Doc::parse(
            "[train]\neval_sample_nnz = 2000\nlr_decay = 0.5\neval_every = 2\n\
             early_stop_patience = 3\nearly_stop_min_delta = 0.01\n",
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.eval_sample_nnz, 2000);
        assert_eq!(c.lr_decay, 0.5);
        assert_eq!(c.eval_every, 2);
        assert_eq!(c.early_stop_patience, 3);
        assert_eq!(c.early_stop_min_delta, 0.01);
    }

    #[test]
    fn staging_and_refresh_knobs_apply() {
        assert!(RefreshMode::parse("lazy").is_err());
        assert_eq!(RefreshMode::Incremental.name(), "incremental");
        assert_eq!(RefreshMode::Full.name(), "full");
        let args = Args::parse(
            ["train", "--stage-workers", "4", "--refresh", "full"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let mut c = TrainConfig::default();
        assert_eq!(c.refresh, RefreshMode::Incremental, "incremental is the default");
        c.apply_args(&args).unwrap();
        assert_eq!(c.stage_workers, 4);
        assert_eq!(c.effective_stage_workers(), 4);
        assert_eq!(c.refresh, RefreshMode::Full);
        let doc = toml::Doc::parse(
            "[train]\nstage_workers = 2\nrefresh = \"incremental\"\n",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.stage_workers, 2);
        assert_eq!(c.refresh, RefreshMode::Incremental);
        c.stage_workers = 0;
        assert!(c.effective_stage_workers() >= 1);
    }

    #[test]
    fn sched_mode_applies_from_cli_and_toml() {
        assert!(SchedMode::parse("greedy").is_err());
        assert_eq!(SchedMode::Static.name(), "static");
        assert_eq!(SchedMode::Stealing.name(), "stealing");
        let mut c = TrainConfig::default();
        assert_eq!(c.sched, SchedMode::Static, "static is the default");
        let args = Args::parse(
            ["train", "--sched", "stealing"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.sched, SchedMode::Stealing);
        let doc = toml::Doc::parse("[train]\nsched = \"static\"\n").unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.sched, SchedMode::Static);
    }

    #[test]
    fn ingest_and_budget_knobs_apply() {
        let mut c = TrainConfig::default();
        assert_eq!(c.stage_budget_bytes, 0, "unbounded staging is the default");
        assert_eq!(c.ingest_warm_epochs, 0, "no warm epochs by default");
        let args = Args::parse(
            ["train", "--stage-budget", "1048576", "--ingest-warm-epochs", "2"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.stage_budget_bytes, 1_048_576);
        assert_eq!(c.ingest_warm_epochs, 2);
        let doc = toml::Doc::parse(
            "[train]\nstage_budget_bytes = 4096\ningest_warm_epochs = 1\n",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.stage_budget_bytes, 4096);
        assert_eq!(c.ingest_warm_epochs, 1);
        c.dims = vec![10, 10, 10];
        c.validate().unwrap();
    }

    #[test]
    fn numa_and_tile_knobs_apply() {
        assert!(NumaMode::parse("numa").is_err());
        assert!(NumaMode::parse("0-nodes").is_err());
        assert!(NumaMode::parse("x-nodes").is_err());
        assert_eq!(NumaMode::parse("auto").unwrap(), NumaMode::Auto);
        assert_eq!(NumaMode::parse("off").unwrap(), NumaMode::Off);
        assert_eq!(NumaMode::parse("2-nodes").unwrap(), NumaMode::Force(2));
        assert_eq!(NumaMode::Auto.name(), "auto");
        assert_eq!(NumaMode::Off.name(), "off");
        assert_eq!(NumaMode::Force(4).name(), "4-nodes");
        let mut c = TrainConfig::default();
        assert_eq!(c.numa, NumaMode::Auto, "auto discovery is the default");
        assert_eq!(c.tile_nnz, 0, "auto tile sizing is the default");
        let args = Args::parse(
            ["train", "--numa", "2-nodes", "--tile-nnz", "4096"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.numa, NumaMode::Force(2));
        assert_eq!(c.tile_nnz, 4096);
        let args = Args::parse(
            ["train", "--numa", "off", "--tile-nnz", "off"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.numa, NumaMode::Off);
        assert_eq!(c.tile_nnz, usize::MAX);
        let args = Args::parse(
            ["train", "--tile-nnz", "auto"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.tile_nnz, 0);
        let doc = toml::Doc::parse("[train]\nnuma = \"4-nodes\"\ntile_nnz = 512\n")
            .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.numa, NumaMode::Force(4));
        assert_eq!(c.tile_nnz, 512);
        c.dims = vec![10, 10, 10];
        c.validate().unwrap();
    }

    #[test]
    fn effective_workers_nonzero() {
        let mut c = TrainConfig::default();
        c.workers = 0;
        assert!(c.effective_workers() >= 1);
        c.workers = 3;
        assert_eq!(c.effective_workers(), 3);
    }
}
