//! TOML-subset parser for config files.
//!
//! Supported grammar (documented subset, errors on anything else):
//!
//! ```toml
//! # comment
//! top_level_key = 1
//! [section]
//! int = 42
//! float = 3.5
//! neg = -1e-3
//! flag = true
//! name = "quoted string"
//! list = [1, 2, 3]
//! ```

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed scalar or homogeneous array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Quoted string.
    Str(String),
    /// Homogeneous array.
    Array(Vec<Value>),
}

impl Value {
    /// Non-negative integer view.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(x) if *x >= 0 => Some(*x as usize),
            _ => None,
        }
    }
    /// Numeric view (integers widen to float).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(x) => Some(*x as f64),
            Value::Float(x) => Some(*x),
            _ => None,
        }
    }
}

/// A parsed document: `(section, key) → value`. Top-level keys live in the
/// empty-string section.
#[derive(Debug, Default)]
pub struct Doc {
    entries: BTreeMap<(String, String), Value>,
}

impl Doc {
    /// Parse a TOML-subset document from text.
    pub fn parse(text: &str) -> Result<Doc> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim().to_string();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(line[eq + 1..].trim())
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            doc.entries.insert((section.clone(), key), value);
        }
        Ok(doc)
    }

    /// Read and parse a file.
    pub fn load(path: &std::path::Path) -> Result<Doc> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Doc::parse(&text)
    }

    /// Look up `key` inside `[section]` (`""` = top level).
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    /// Distinct section names, sorted.
    pub fn sections(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|(s, _)| s.as_str()).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

fn strip_comment(line: &str) -> &str {
    // naive: '#' inside quoted strings is not supported by this subset
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(tok: &str) -> Result<Value> {
    if tok.is_empty() {
        bail!("empty value");
    }
    if tok == "true" {
        return Ok(Value::Bool(true));
    }
    if tok == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = tok.strip_prefix('"') {
        let inner = inner.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = tok.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items = inner
            .split(',')
            .map(|s| parse_value(s.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::Array(items));
    }
    if let Ok(i) = tok.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = tok.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse '{tok}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            "top = 1\n[train]\nj = 32\nlr = 1e-3\nflag = true\nname = \"abc\"\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some(&Value::Int(1)));
        assert_eq!(doc.get("train", "j"), Some(&Value::Int(32)));
        assert_eq!(doc.get("train", "lr"), Some(&Value::Float(1e-3)));
        assert_eq!(doc.get("train", "flag"), Some(&Value::Bool(true)));
        assert_eq!(doc.get("train", "name"), Some(&Value::Str("abc".into())));
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let doc = Doc::parse("# header\n\nx = 2 # trailing\n").unwrap();
        assert_eq!(doc.get("", "x"), Some(&Value::Int(2)));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = Doc::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("", "s"), Some(&Value::Str("a#b".into())));
    }

    #[test]
    fn arrays_parse() {
        let doc = Doc::parse("dims = [10, 20, 30]\nempty = []\n").unwrap();
        assert_eq!(
            doc.get("", "dims"),
            Some(&Value::Array(vec![Value::Int(10), Value::Int(20), Value::Int(30)]))
        );
        assert_eq!(doc.get("", "empty"), Some(&Value::Array(vec![])));
    }

    #[test]
    fn negatives_and_floats() {
        let doc = Doc::parse("a = -5\nb = -2.5e-2\n").unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Int(-5)));
        assert_eq!(doc.get("", "b"), Some(&Value::Float(-0.025)));
    }

    #[test]
    fn errors_on_garbage() {
        assert!(Doc::parse("[unterminated\n").is_err());
        assert!(Doc::parse("novalue\n").is_err());
        assert!(Doc::parse("x = \"open\n").is_err());
        assert!(Doc::parse("x = [1, 2\n").is_err());
        assert!(Doc::parse("x = wat\n").is_err());
    }

    #[test]
    fn value_conversions() {
        assert_eq!(Value::Int(5).as_usize(), Some(5));
        assert_eq!(Value::Int(-5).as_usize(), None);
        assert_eq!(Value::Float(2.5).as_f64(), Some(2.5));
        assert_eq!(Value::Int(2).as_f64(), Some(2.0));
        assert_eq!(Value::Bool(true).as_f64(), None);
    }

    #[test]
    fn sections_listed() {
        let doc = Doc::parse("a = 1\n[x]\nb = 2\n[y]\nc = 3\n").unwrap();
        assert_eq!(doc.sections(), vec!["", "x", "y"]);
    }
}
