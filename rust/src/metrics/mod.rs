//! Evaluation metrics: test RMSE / MAE (the paper's Fig. 2/3 accuracy
//! measures), training loss, throughput, and convergence-series recording.

use crate::model::ModelState;
use crate::sched::pool::{parallel_reduce, WorkerStats};
use crate::tensor::coo::CooTensor;
use crate::util::json::Json;

/// RMSE + MAE of the model on a COO element set, evaluated from the C tables
/// (`x̂ = Σ_r Π_n C^(n)[i_n,r]`, the cheap inference path).
pub fn rmse_mae(model: &ModelState, data: &CooTensor, workers: usize) -> (f64, f64) {
    let nnz = data.nnz();
    if nnz == 0 {
        return (0.0, 0.0);
    }
    const CHUNK: usize = 16_384;
    let num_blocks = crate::util::ceil_div(nnz, CHUNK);
    let (se, ae) = parallel_reduce(
        workers,
        num_blocks,
        || (0.0f64, 0.0f64),
        |acc, _w, b| {
            let lo = b * CHUNK;
            let hi = (lo + CHUNK).min(nnz);
            for e in lo..hi {
                let err = (data.value(e) - model.predict(data.index(e))) as f64;
                acc.0 += err * err;
                acc.1 += err.abs();
            }
        },
        |acc, other| {
            acc.0 += other.0;
            acc.1 += other.1;
        },
    );
    ((se / nnz as f64).sqrt(), ae / nnz as f64)
}

/// The regularized training objective (paper eq. 6): Σ errors² + λ‖A‖² + λ‖B‖².
pub fn loss(model: &ModelState, data: &CooTensor, lambda_a: f32, lambda_b: f32) -> f64 {
    let mut se = 0.0f64;
    for (c, x) in data.iter() {
        let err = (x - model.predict(c)) as f64;
        se += err * err;
    }
    let reg_a: f64 = model.factors.iter().map(|m| m.norm_sq()).sum::<f64>();
    let reg_b: f64 = model.cores.iter().map(|m| m.norm_sq()).sum::<f64>();
    se + lambda_a as f64 * reg_a + lambda_b as f64 * reg_b
}

/// One epoch's record in a convergence series.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    /// Global epoch number.
    pub epoch: usize,
    /// Wall-clock seconds for the whole epoch (incl. evaluation).
    pub seconds: f64,
    /// Seconds in the factor-update module.
    pub factor_seconds: f64,
    /// Seconds in the core-update module.
    pub core_seconds: f64,
    /// RMSE after this epoch (carried forward between cadenced evals).
    pub rmse: f64,
    /// MAE after this epoch (carried forward between cadenced evals).
    pub mae: f64,
}

/// A convergence series (Fig. 2/3 regenerator writes these to CSV/JSON).
#[derive(Clone, Debug, Default)]
pub struct Convergence {
    /// Per-epoch records, in training order.
    pub records: Vec<EpochRecord>,
}

impl Convergence {
    /// Append one epoch's record.
    pub fn push(&mut self, rec: EpochRecord) {
        self.records.push(rec);
    }

    /// RMSE of the most recent record (`NaN` when empty).
    pub fn last_rmse(&self) -> f64 {
        self.records.last().map(|r| r.rmse).unwrap_or(f64::NAN)
    }

    /// MAE of the most recent record (`NaN` when empty).
    pub fn last_mae(&self) -> f64 {
        self.records.last().map(|r| r.mae).unwrap_or(f64::NAN)
    }

    /// Mean per-epoch wall time, excluding the first (warm-up) epoch when
    /// there are enough samples — matches the paper's "average time for a
    /// single iteration".
    pub fn mean_epoch_seconds(&self) -> f64 {
        if self.records.len() > 2 {
            let tail = &self.records[1..];
            tail.iter().map(|r| r.seconds).sum::<f64>() / tail.len() as f64
        } else if !self.records.is_empty() {
            self.records.iter().map(|r| r.seconds).sum::<f64>()
                / self.records.len() as f64
        } else {
            f64::NAN
        }
    }

    /// Mean factor-module seconds (warm-up excluded when possible).
    pub fn mean_factor_seconds(&self) -> f64 {
        mean_tail(self.records.iter().map(|r| r.factor_seconds))
    }

    /// Mean core-module seconds (warm-up excluded when possible).
    pub fn mean_core_seconds(&self) -> f64 {
        mean_tail(self.records.iter().map(|r| r.core_seconds))
    }

    /// True if the series is (weakly) improving: final RMSE below first.
    pub fn improved(&self) -> bool {
        match (self.records.first(), self.records.last()) {
            (Some(a), Some(b)) => b.rmse < a.rmse,
            _ => false,
        }
    }

    /// CSV with header, one row per epoch.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("epoch,seconds,factor_seconds,core_seconds,rmse,mae\n");
        for r in &self.records {
            s.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{:.6},{:.6}\n",
                r.epoch, r.seconds, r.factor_seconds, r.core_seconds, r.rmse, r.mae
            ));
        }
        s
    }

    /// JSON array form for the persisted result files.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.records
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("epoch", Json::num(r.epoch as f64)),
                        ("seconds", Json::num(r.seconds)),
                        ("factor_seconds", Json::num(r.factor_seconds)),
                        ("core_seconds", Json::num(r.core_seconds)),
                        ("rmse", Json::num(r.rmse)),
                        ("mae", Json::num(r.mae)),
                    ])
                })
                .collect(),
        )
    }
}

/// EWMA smoothing factor for the QoS latency / load trackers. 0.3 weights
/// recent passes enough to follow load shifts within a few epochs without
/// thrashing lease sizes on one noisy measurement.
pub const QOS_EWMA_ALPHA: f64 = 0.3;

/// Per-tenant scheduling/QoS telemetry, updated once per engine pass.
///
/// The registry's lease-rebalancing policy reads `pass_latency_ewma` and
/// `nnz_ewma` to size leases; everything else is observability (exported
/// through [`QosStats::to_json`] and the registry's tenant-stats report).
#[derive(Clone, Debug, Default)]
pub struct QosStats {
    /// Number of passes recorded.
    pub passes: usize,
    /// EWMA of pass wall-clock seconds (gate wait excluded).
    pub pass_latency_ewma: f64,
    /// Seconds of the most recent pass.
    pub last_pass_seconds: f64,
    /// EWMA of nnz claimed per pass.
    pub nnz_ewma: f64,
    /// Cumulative seconds spent waiting at the executor admission gate.
    pub queue_wait_seconds: f64,
    /// Gate wait of the most recent pass.
    pub last_queue_wait: f64,
    /// Worker slots granted for the most recent pass.
    pub slots_granted: usize,
    /// Cumulative stolen blocks across passes.
    pub steals: usize,
    /// nnz imbalance (max/mean) of the most recent pass.
    pub nnz_imbalance: f64,
    /// Busy-time imbalance (max/mean) of the most recent pass.
    pub latency_imbalance: f64,
    /// Blocks executed per NUMA node in the most recent pass (one entry
    /// on single-node topologies).
    pub node_blocks: Vec<usize>,
    /// Non-zeros claimed per NUMA node in the most recent pass.
    pub node_nnz: Vec<usize>,
    /// Cumulative stolen blocks that crossed a node boundary — the
    /// migration price of dynamic rebalancing (0 without stealing or on
    /// one node).
    pub cross_node_steals: usize,
}

impl QosStats {
    /// Fold one pass's measurements into the series.
    pub fn record_pass(
        &mut self,
        pass_seconds: f64,
        queue_wait: f64,
        stats: &WorkerStats,
        slots: usize,
    ) {
        let nnz = stats.total_nnz() as f64;
        if self.passes == 0 {
            self.pass_latency_ewma = pass_seconds;
            self.nnz_ewma = nnz;
        } else {
            self.pass_latency_ewma +=
                QOS_EWMA_ALPHA * (pass_seconds - self.pass_latency_ewma);
            self.nnz_ewma += QOS_EWMA_ALPHA * (nnz - self.nnz_ewma);
        }
        self.passes += 1;
        self.last_pass_seconds = pass_seconds;
        self.queue_wait_seconds += queue_wait;
        self.last_queue_wait = queue_wait;
        self.slots_granted = slots;
        self.steals += stats.total_steals();
        self.nnz_imbalance = stats.nnz_imbalance();
        self.latency_imbalance = stats.latency_imbalance();
    }

    /// Fold one pass's memory-hierarchy placement into the series: the
    /// per-node block/nnz split (from [`WorkerStats::per_node`] over the
    /// lease's worker homes) and the pass's cross-node steal count.
    pub fn record_node_layout(
        &mut self,
        stats: &WorkerStats,
        homes: &[crate::sched::topo::WorkerHome],
        cross_node_steals: u64,
    ) {
        let (blocks, nnz) = stats.per_node(homes);
        self.node_blocks = blocks;
        self.node_nnz = nnz;
        self.cross_node_steals += cross_node_steals as usize;
    }

    /// JSON form for the registry's per-tenant stats export.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("passes", Json::num(self.passes as f64)),
            ("pass_latency_ewma", Json::num(self.pass_latency_ewma)),
            ("last_pass_seconds", Json::num(self.last_pass_seconds)),
            ("nnz_ewma", Json::num(self.nnz_ewma)),
            ("queue_wait_seconds", Json::num(self.queue_wait_seconds)),
            ("last_queue_wait", Json::num(self.last_queue_wait)),
            ("slots_granted", Json::num(self.slots_granted as f64)),
            ("steals", Json::num(self.steals as f64)),
            ("nnz_imbalance", Json::num(self.nnz_imbalance)),
            ("latency_imbalance", Json::num(self.latency_imbalance)),
            (
                "node_blocks",
                Json::Arr(
                    self.node_blocks
                        .iter()
                        .map(|&b| Json::num(b as f64))
                        .collect(),
                ),
            ),
            (
                "node_nnz",
                Json::Arr(
                    self.node_nnz.iter().map(|&x| Json::num(x as f64)).collect(),
                ),
            ),
            ("cross_node_steals", Json::num(self.cross_node_steals as f64)),
        ])
    }
}

fn mean_tail(xs: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = xs.collect();
    if v.len() > 2 {
        v[1..].iter().sum::<f64>() / (v.len() - 1) as f64
    } else if !v.is_empty() {
        v.iter().sum::<f64>() / v.len() as f64
    } else {
        f64::NAN
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::data::synthetic::{recommender, RecommenderSpec};

    fn setup() -> (ModelState, CooTensor) {
        let t = recommender(&RecommenderSpec::tiny(), 1);
        let cfg = TrainConfig {
            order: 3,
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            ..TrainConfig::default()
        };
        (ModelState::init(&cfg, 2), t)
    }

    #[test]
    fn rmse_mae_nonnegative_and_parallel_matches_serial() {
        let (m, t) = setup();
        let (r1, a1) = rmse_mae(&m, &t, 1);
        let (r4, a4) = rmse_mae(&m, &t, 4);
        assert!(r1 > 0.0 && a1 > 0.0);
        assert!((r1 - r4).abs() < 1e-9);
        assert!((a1 - a4).abs() < 1e-9);
        assert!(a1 <= r1 + 1e-12, "MAE {a1} cannot exceed RMSE {r1}");
    }

    #[test]
    fn empty_test_set_is_zero() {
        let (m, _) = setup();
        let empty = CooTensor::new(vec![200, 150, 20]);
        assert_eq!(rmse_mae(&m, &empty, 2), (0.0, 0.0));
    }

    #[test]
    fn perfect_model_has_zero_error() {
        // craft data equal to the model's own predictions
        let (m, t) = setup();
        let mut exact = CooTensor::new(t.dims().to_vec());
        for (c, _) in t.iter().take(100) {
            exact.push(c, m.predict(c));
        }
        let (rmse, mae) = rmse_mae(&m, &exact, 2);
        assert!(rmse < 1e-6 && mae < 1e-6);
    }

    #[test]
    fn loss_includes_regularization() {
        let (m, t) = setup();
        let l0 = loss(&m, &t, 0.0, 0.0);
        let l1 = loss(&m, &t, 0.1, 0.1);
        assert!(l1 > l0);
    }

    #[test]
    fn convergence_series_accessors() {
        let mut c = Convergence::default();
        for e in 0..4 {
            c.push(EpochRecord {
                epoch: e,
                seconds: 1.0 + e as f64,
                factor_seconds: 0.5,
                core_seconds: 0.4,
                rmse: 2.0 - 0.3 * e as f64,
                mae: 1.5 - 0.2 * e as f64,
            });
        }
        assert!(c.improved());
        assert!((c.last_rmse() - 1.1).abs() < 1e-12);
        // mean excludes first epoch: (2+3+4)/3
        assert!((c.mean_epoch_seconds() - 3.0).abs() < 1e-12);
        let csv = c.to_csv();
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.starts_with("epoch,"));
        assert_eq!(c.to_json().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn qos_stats_ewma_and_json() {
        let mut q = QosStats::default();
        let ws = WorkerStats {
            blocks: vec![3, 1],
            busy: vec![0.3, 0.1],
            nnz: vec![600, 200],
            steals: vec![0, 2],
        };
        q.record_pass(1.0, 0.25, &ws, 2);
        // first pass seeds the EWMAs directly
        assert!((q.pass_latency_ewma - 1.0).abs() < 1e-12);
        assert!((q.nnz_ewma - 800.0).abs() < 1e-12);
        assert_eq!(q.passes, 1);
        assert_eq!(q.steals, 2);
        assert_eq!(q.slots_granted, 2);
        assert!((q.queue_wait_seconds - 0.25).abs() < 1e-12);
        assert!((q.nnz_imbalance - 1.5).abs() < 1e-12);
        assert!((q.latency_imbalance - 1.5).abs() < 1e-12);

        q.record_pass(2.0, 0.0, &ws, 3);
        // 1.0 + 0.3 * (2.0 - 1.0)
        assert!((q.pass_latency_ewma - 1.3).abs() < 1e-12);
        assert!((q.nnz_ewma - 800.0).abs() < 1e-12);
        assert_eq!(q.passes, 2);
        assert_eq!(q.steals, 4);
        assert_eq!(q.slots_granted, 3);
        assert!((q.queue_wait_seconds - 0.25).abs() < 1e-12);

        let j = q.to_json();
        assert_eq!(j.get("passes").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("steals").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("slots_granted").unwrap().as_usize(), Some(3));
        assert!(j.get("pass_latency_ewma").unwrap().as_f64().is_some());
        assert!(j.get("queue_wait_seconds").unwrap().as_f64().is_some());
    }

    #[test]
    fn qos_node_layout_recording() {
        use crate::sched::topo::WorkerHome;
        let mut q = QosStats::default();
        let ws = WorkerStats {
            blocks: vec![3, 1],
            busy: vec![0.3, 0.1],
            nnz: vec![600, 200],
            steals: vec![0, 2],
        };
        let homes: Vec<WorkerHome> = [0usize, 1]
            .iter()
            .map(|&node| WorkerHome { node, cpu: None })
            .collect();
        q.record_node_layout(&ws, &homes, 2);
        assert_eq!(q.node_blocks, vec![3, 1]);
        assert_eq!(q.node_nnz, vec![600, 200]);
        assert_eq!(q.cross_node_steals, 2);
        // an unhomed pass folds to one node; the migration counter
        // accumulates across passes
        q.record_node_layout(&ws, &[], 1);
        assert_eq!(q.node_blocks, vec![4]);
        assert_eq!(q.node_nnz, vec![800]);
        assert_eq!(q.cross_node_steals, 3);
        let j = q.to_json();
        assert_eq!(j.get("cross_node_steals").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("node_blocks").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(j.get("node_nnz").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn empty_series_nan() {
        let c = Convergence::default();
        assert!(c.last_rmse().is_nan());
        assert!(c.mean_epoch_seconds().is_nan());
        assert!(!c.improved());
    }
}
