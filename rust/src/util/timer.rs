//! Wall-clock timing helpers used by the coordinator and bench harness.

use std::time::Instant;

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }
    /// Seconds elapsed since start.
    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    /// Milliseconds elapsed since start.
    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
    /// Return the elapsed seconds and reset the start point.
    pub fn restart(&mut self) -> f64 {
        let s = self.seconds();
        self.start = Instant::now();
        s
    }
}

/// Named phase accumulator: `phases.add("factor", t)` across an epoch, then
/// report a breakdown. Used for the per-phase tables in EXPERIMENTS.md.
#[derive(Default, Debug, Clone)]
pub struct PhaseTimes {
    entries: Vec<(String, f64, u64)>, // (name, total seconds, count)
}

impl PhaseTimes {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `seconds` under `name` (and bump its count).
    pub fn add(&mut self, name: &str, seconds: f64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == name) {
            e.1 += seconds;
            e.2 += 1;
        } else {
            self.entries.push((name.to_string(), seconds, 1));
        }
    }

    /// Time a closure and record it under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.seconds());
        out
    }

    /// Total seconds recorded under `name`.
    pub fn total(&self, name: &str) -> f64 {
        self.entries.iter().find(|e| e.0 == name).map(|e| e.1).unwrap_or(0.0)
    }

    /// How many times `name` was recorded.
    pub fn count(&self, name: &str) -> u64 {
        self.entries.iter().find(|e| e.0 == name).map(|e| e.2).unwrap_or(0)
    }

    /// Mean seconds per recording of `name` (0 when never recorded).
    pub fn mean(&self, name: &str) -> f64 {
        let c = self.count(name);
        if c == 0 {
            0.0
        } else {
            self.total(name) / c as f64
        }
    }

    /// Recorded phase names, in first-seen order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.0.as_str())
    }

    /// Fold another accumulator's totals and counts into this one.
    pub fn merge(&mut self, other: &PhaseTimes) {
        for (name, secs, cnt) in &other.entries {
            if let Some(e) = self.entries.iter_mut().find(|e| &e.0 == name) {
                e.1 += secs;
                e.2 += cnt;
            } else {
                self.entries.push((name.clone(), *secs, *cnt));
            }
        }
    }

    /// Human-readable per-phase breakdown.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, secs, cnt) in &self.entries {
            s.push_str(&format!(
                "  {name:<24} total {secs:>9.4}s  n={cnt:<6} mean {:>9.6}s\n",
                secs / (*cnt).max(1) as f64
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_nonnegative() {
        let t = Timer::start();
        assert!(t.seconds() >= 0.0);
        assert!(t.millis() >= 0.0);
    }

    #[test]
    fn phases_accumulate() {
        let mut p = PhaseTimes::new();
        p.add("x", 1.0);
        p.add("x", 2.0);
        p.add("y", 0.5);
        assert_eq!(p.total("x"), 3.0);
        assert_eq!(p.count("x"), 2);
        assert_eq!(p.mean("x"), 1.5);
        assert_eq!(p.total("missing"), 0.0);
        assert_eq!(p.mean("missing"), 0.0);
    }

    #[test]
    fn time_closure_records() {
        let mut p = PhaseTimes::new();
        let v = p.time("work", || 42);
        assert_eq!(v, 42);
        assert_eq!(p.count("work"), 1);
    }

    #[test]
    fn merge_combines() {
        let mut a = PhaseTimes::new();
        a.add("x", 1.0);
        let mut b = PhaseTimes::new();
        b.add("x", 2.0);
        b.add("z", 1.0);
        a.merge(&b);
        assert_eq!(a.total("x"), 3.0);
        assert_eq!(a.total("z"), 1.0);
    }

    #[test]
    fn report_contains_names() {
        let mut p = PhaseTimes::new();
        p.add("alpha", 0.1);
        assert!(p.report().contains("alpha"));
    }
}
