//! Minimal JSON reader/writer (no serde offline).
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`), experiment result files under `results/`, and
//! model checkpoints' metadata. Supports the full JSON value grammar; numbers
//! are parsed as `f64` (integers round-trip exactly up to 2^53, which covers
//! every count in this project).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — important for reproducible result files.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Numeric view.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    /// Truncating unsigned-integer view.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Boolean view.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Array view.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    /// Object view.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `obj["key"]` access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Number literal.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }
    /// String literal.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    /// Array of numbers from an `f64` slice.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    /// Array of numbers from a `usize` slice.
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_exponent_numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\t\u{1}".to_string());
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = Json::obj(vec![
            ("x", Json::arr_f64(&[1.0, 2.5])),
            ("y", Json::obj(vec![("z", Json::Bool(true))])),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(42.5).to_string(), "42.5");
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }
}
