//! Tiny property-testing framework (no `proptest` crate offline).
//!
//! A property is a closure over a [`Gen`] (a seeded value source). The
//! runner executes it for `cases` different seeds; on failure it reports the
//! failing seed so the case can be replayed deterministically, and performs a
//! light "shrink" by retrying the property with smaller size hints.
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla_extension rpath)
//! use fastertucker::util::proptest::{run, Gen};
//! run("sort is idempotent", 64, |g: &mut Gen| {
//!     let mut v = g.vec_u32(0..50, 0, 1000);
//!     v.sort_unstable();
//!     let w = { let mut w = v.clone(); w.sort_unstable(); w };
//!     assert_eq!(v, w);
//! });
//! ```

use crate::util::rng::Rng;

/// Seeded value source handed to properties. The `size` field is a growth
/// hint: early cases are small, later cases are larger, and shrinking re-runs
/// with reduced size.
pub struct Gen {
    /// The case's seeded generator.
    pub rng: Rng,
    /// Growth hint (later cases draw larger values).
    pub size: usize,
    /// The seed this case runs under (reported on failure for replay).
    pub seed: u64,
}

impl Gen {
    /// Value source for one property case.
    pub fn new(seed: u64, size: usize) -> Self {
        Gen { rng: Rng::new(seed), size, seed }
    }

    /// Integer in `[lo, hi)` (hi exclusive, must be > lo).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.next_below(hi - lo)
    }

    /// Length scaled by the current size hint, within `[lo, hi)`.
    pub fn len(&mut self, lo: usize, hi: usize) -> usize {
        let cap = lo + (hi - lo).min(self.size.max(1));
        self.usize_in(lo, cap.max(lo + 1))
    }

    /// Float in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_f32(lo, hi)
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of u32 with length drawn from `len_range` and values in
    /// `[vlo, vhi)`.
    pub fn vec_u32(&mut self, len_range: std::ops::Range<usize>, vlo: u32, vhi: u32) -> Vec<u32> {
        let n = self.len(len_range.start, len_range.end);
        (0..n).map(|_| vlo + self.rng.next_below((vhi - vlo) as usize) as u32).collect()
    }

    /// Vector of f32 with length drawn from `len_range` and values in
    /// `[lo, hi)`.
    pub fn vec_f32(&mut self, len_range: std::ops::Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.len(len_range.start, len_range.end);
        (0..n).map(|_| self.rng.uniform_f32(lo, hi)).collect()
    }

    /// Tensor dims: `order` in `[2, max_order]`, each dim in `[1, max_dim]`.
    pub fn dims(&mut self, max_order: usize, max_dim: usize) -> Vec<usize> {
        let order = self.usize_in(2, max_order + 1);
        (0..order).map(|_| self.usize_in(1, max_dim + 1)).collect()
    }
}

/// Run `prop` for `cases` random cases. Panics (failing the enclosing
/// `#[test]`) with a replayable seed on the first failure.
pub fn run(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    // honor FT_PROPTEST_SEED for replay
    if let Ok(seed_str) = std::env::var("FT_PROPTEST_SEED") {
        if let Ok(seed) = seed_str.parse::<u64>() {
            let mut g = Gen::new(seed, 64);
            prop(&mut g);
            return;
        }
    }
    for case in 0..cases {
        let seed = 0x5EED_0000u64 ^ hash_name(name).wrapping_add(case);
        let size = 4 + (case as usize * 64) / cases.max(1) as usize;
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, size);
            prop(&mut g);
        });
        if let Err(err) = result {
            // try to shrink: re-run with progressively smaller size hints and
            // report the smallest size that still fails.
            let mut min_fail_size = size;
            for s in [1usize, 2, 4, 8, 16, 32] {
                if s >= size {
                    break;
                }
                let r = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, s);
                    prop(&mut g);
                });
                if r.is_err() {
                    min_fail_size = s;
                    break;
                }
            }
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} \
                 (replay: FT_PROPTEST_SEED={seed}, size {min_fail_size}): {msg}"
            );
        }
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "allclose failed at index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        run("trivially true", 16, |g| {
            let v = g.vec_f32(0..10, -1.0, 1.0);
            assert!(v.len() <= 10);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        run("always fails", 4, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(1, 32);
        for _ in 0..200 {
            let x = g.usize_in(3, 9);
            assert!((3..9).contains(&x));
            let f = g.f32_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&f));
        }
    }

    #[test]
    fn dims_shape_valid() {
        let mut g = Gen::new(2, 32);
        for _ in 0..50 {
            let d = g.dims(6, 20);
            assert!((2..=6).contains(&d.len()));
            assert!(d.iter().all(|&x| (1..=20).contains(&x)));
        }
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6);
    }

    #[test]
    #[should_panic(expected = "allclose failed")]
    fn allclose_rejects_distant() {
        assert_allclose(&[1.0], &[1.1], 1e-5, 1e-6);
    }
}
