//! Fixed-capacity row bitset used by the dirty-row refresh path.
//!
//! [`DirtyRows`] tracks which rows of a factor matrix were touched since
//! the last `C^(n) = A^(n) B^(n)` refresh, so the refresh can recompute
//! only those rows. Two properties the hot path depends on:
//!
//! * **Zero steady-state allocation** — [`DirtyRows::ensure`] only ever
//!   grows the word buffer, so after the first pass over the largest mode
//!   the mark/merge/clear cycle never allocates
//!   (`tests/hotpath_alloc.rs`).
//! * **Word-aligned row blocks** — the storage is `u64` words, so a word
//!   range `[w0, w1)` covers exactly the contiguous rows
//!   `[64*w0, 64*w1)`. The parallel refresh splits work on word
//!   boundaries and hands each worker a disjoint row range.

/// A grow-only bitset over factor-row indices, with an `all` fast path
/// for "every row is stale" (set by the core pass, which invalidates the
/// whole `C` table at once).
#[derive(Clone, Debug, Default)]
pub struct DirtyRows {
    words: Vec<u64>,
    rows: usize,
    all: bool,
}

impl DirtyRows {
    /// Empty set (no capacity reserved yet).
    pub fn new() -> DirtyRows {
        DirtyRows::default()
    }

    /// Grow the capacity to cover `rows` rows. Never shrinks, so repeated
    /// calls with the same (or a smaller) row count are allocation-free.
    pub fn ensure(&mut self, rows: usize) {
        self.rows = self.rows.max(rows);
        let want = crate::util::ceil_div(self.rows, 64);
        if self.words.len() < want {
            self.words.resize(want, 0);
        }
    }

    /// Row capacity this set currently covers.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Mark one row dirty. The row must be within the [`ensure`]d
    /// capacity.
    ///
    /// [`ensure`]: DirtyRows::ensure
    #[inline]
    pub fn mark(&mut self, row: usize) {
        debug_assert!(row < self.words.len() * 64, "mark past ensure()d capacity");
        self.words[row >> 6] |= 1u64 << (row & 63);
    }

    /// Mark every row dirty (O(1): the `all` flag short-circuits the word
    /// scan).
    #[inline]
    pub fn mark_all(&mut self) {
        self.all = true;
    }

    /// Whether the whole-table invalidation flag is set.
    #[inline]
    pub fn is_all(&self) -> bool {
        self.all
    }

    /// Whether any row is marked.
    pub fn any(&self) -> bool {
        self.all || self.words.iter().any(|&w| w != 0)
    }

    /// Number of individually marked rows (ignores the `all` flag).
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// OR another set's marks into this one (used at the pass-end merge
    /// point: per-worker scratch sets fold into the model's per-mode set).
    /// Grows this set if `other` covers more rows.
    pub fn merge_from(&mut self, other: &DirtyRows) {
        if other.all {
            self.all = true;
        }
        self.ensure(other.rows);
        for (dst, &src) in self.words.iter_mut().zip(other.words.iter()) {
            *dst |= src;
        }
    }

    /// Clear every mark (word memset + flag reset; no allocation).
    pub fn clear(&mut self) {
        self.all = false;
        for w in &mut self.words {
            *w = 0;
        }
    }

    /// The backing words; word `w` covers rows `[64*w, 64*w + 64)`.
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Whether any row of word `w`'s aligned 64-row block
    /// `[64*w, 64*w + 64)` is marked. Honours the `all` flag; words past
    /// the [`ensure`]d capacity read clean. This is the per-block query
    /// the copy-on-write snapshot publication keys off, so the delta
    /// granule and the parallel-refresh granule are the same word.
    ///
    /// [`ensure`]: DirtyRows::ensure
    #[inline]
    pub fn word_dirty(&self, w: usize) -> bool {
        self.all || self.words.get(w).copied().unwrap_or(0) != 0
    }

    /// Visit every marked row in increasing order (ignores the `all`
    /// flag — callers handle that fast path first).
    #[inline]
    pub fn for_each_row(&self, mut f: impl FnMut(usize)) {
        for (w, &word) in self.words.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                f((w << 6) | b);
                bits &= bits - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mark_and_enumerate() {
        let mut d = DirtyRows::new();
        d.ensure(200);
        for r in [0usize, 63, 64, 127, 199] {
            d.mark(r);
        }
        let mut seen = Vec::new();
        d.for_each_row(|r| seen.push(r));
        assert_eq!(seen, vec![0, 63, 64, 127, 199]);
        assert_eq!(d.count(), 5);
        assert!(d.any());
    }

    #[test]
    fn clear_resets_without_shrinking() {
        let mut d = DirtyRows::new();
        d.ensure(130);
        d.mark(129);
        d.mark_all();
        let cap = d.words().len();
        d.clear();
        assert!(!d.any());
        assert!(!d.is_all());
        assert_eq!(d.words().len(), cap, "clear must not shrink");
        assert_eq!(d.rows(), 130);
    }

    #[test]
    fn ensure_is_grow_only() {
        let mut d = DirtyRows::new();
        d.ensure(500);
        let cap = d.words().len();
        d.ensure(100);
        assert_eq!(d.words().len(), cap);
        assert_eq!(d.rows(), 500);
        d.ensure(1000);
        assert!(d.words().len() > cap);
    }

    #[test]
    fn merge_unions_and_propagates_all() {
        let mut a = DirtyRows::new();
        a.ensure(64);
        a.mark(3);
        let mut b = DirtyRows::new();
        b.ensure(128);
        b.mark(100);
        a.merge_from(&b);
        let mut seen = Vec::new();
        a.for_each_row(|r| seen.push(r));
        assert_eq!(seen, vec![3, 100]);
        let mut c = DirtyRows::new();
        c.mark_all();
        a.merge_from(&c);
        assert!(a.is_all());
    }

    #[test]
    fn word_blocks_cover_aligned_row_ranges() {
        let mut d = DirtyRows::new();
        d.ensure(70);
        d.mark(65);
        assert_eq!(d.words().len(), 2);
        assert_eq!(d.words()[0], 0);
        assert_eq!(d.words()[1], 2); // row 65 = word 1, bit 1
    }

    #[test]
    fn word_dirty_tracks_blocks_and_all_flag() {
        let mut d = DirtyRows::new();
        d.ensure(130);
        d.mark(65);
        assert!(!d.word_dirty(0));
        assert!(d.word_dirty(1));
        assert!(!d.word_dirty(2));
        // past the ensured capacity reads clean, not a panic
        assert!(!d.word_dirty(1000));
        d.mark_all();
        assert!(d.word_dirty(0));
        assert!(d.word_dirty(1000));
    }
}
