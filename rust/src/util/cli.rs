//! Small CLI argument parser (no `clap` offline).
//!
//! Grammar: `program <subcommand> [--flag] [--key value]...`. Values are
//! typed on demand (`get_usize`, `get_f32`, ...); unknown flags are an
//! error so typos fail fast.

use std::collections::BTreeMap;

/// Parsed command line: one subcommand plus `--key value` / `--switch` pairs.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The first positional token (`train`, `gen`, ...).
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Keys that were actually consumed by the program (for typo detection).
    consumed: std::cell::RefCell<Vec<String>>,
}

/// Errors produced while parsing or reading arguments.
#[derive(Debug, PartialEq)]
pub enum CliError {
    /// No subcommand token was supplied.
    MissingSubcommand,
    /// A `--key` that requires a value had none.
    MissingValue(String),
    /// A value failed to parse as the requested type.
    BadValue {
        /// The offending flag name.
        key: String,
        /// The raw value supplied.
        value: String,
        /// What the caller asked the value to parse as.
        wanted: &'static str,
    },
    /// Flags that were supplied but never consumed (typos).
    UnknownArgs(Vec<String>),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingSubcommand => write!(f, "missing subcommand"),
            CliError::MissingValue(k) => write!(f, "flag --{k} needs a value"),
            CliError::BadValue { key, value, wanted } => {
                write!(f, "--{key} {value}: expected {wanted}")
            }
            CliError::UnknownArgs(ks) => write!(f, "unknown arguments: {ks:?}"),
        }
    }
}
impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of tokens (excluding the program name).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args, CliError> {
        let mut it = tokens.into_iter().peekable();
        let subcommand = it.next().ok_or(CliError::MissingSubcommand)?;
        let mut opts = BTreeMap::new();
        let mut switches = Vec::new();
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| CliError::UnknownArgs(vec![tok.clone()]))?
                .to_string();
            // a flag followed by another flag (or nothing) is a switch
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    opts.insert(key, it.next().unwrap());
                }
                _ => switches.push(key),
            }
        }
        Ok(Args { subcommand, opts, switches, consumed: Default::default() })
    }

    /// Parse the process arguments (skipping the program name).
    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Boolean switch (`--verbose`).
    pub fn switch(&self, key: &str) -> bool {
        self.mark(key);
        self.switches.iter().any(|s| s == key)
    }

    /// `usize` option with default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.to_string(),
                value: v.to_string(),
                wanted: "unsigned integer",
            }),
        }
    }

    /// `u64` option with default.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.to_string(),
                value: v.to_string(),
                wanted: "unsigned integer",
            }),
        }
    }

    /// `f32` option with default.
    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.to_string(),
                value: v.to_string(),
                wanted: "float",
            }),
        }
    }

    /// `f64` option with default.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.to_string(),
                value: v.to_string(),
                wanted: "float",
            }),
        }
    }

    /// Comma-separated list of usize (`--dims 100,200,300`).
    pub fn get_usize_list(&self, key: &str) -> Result<Option<Vec<usize>>, CliError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|tok| tok.trim().parse::<usize>())
                .collect::<Result<Vec<_>, _>>()
                .map(Some)
                .map_err(|_| CliError::BadValue {
                    key: key.to_string(),
                    value: v.to_string(),
                    wanted: "comma-separated unsigned integers",
                }),
        }
    }

    /// Fail if any provided option was never consumed (catches typos).
    pub fn finish(&self) -> Result<(), CliError> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<String> = self
            .opts
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !consumed.contains(k))
            .cloned()
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError::UnknownArgs(unknown))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--epochs", "5", "--algo", "fastertucker"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get_usize("epochs", 0).unwrap(), 5);
        assert_eq!(a.get("algo"), Some("fastertucker"));
    }

    #[test]
    fn switches_without_values() {
        let a = parse(&["train", "--verbose", "--epochs", "3"]);
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
        assert_eq!(a.get_usize("epochs", 0).unwrap(), 3);
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["gen", "--out", "x.bin", "--force"]);
        assert!(a.switch("force"));
        assert_eq!(a.get("out"), Some("x.bin"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["bench"]);
        assert_eq!(a.get_usize("epochs", 7).unwrap(), 7);
        assert_eq!(a.get_f32("lr", 0.01).unwrap(), 0.01);
        assert_eq!(a.get_or("algo", "fastertucker"), "fastertucker");
    }

    #[test]
    fn f64_values_parse() {
        let a = parse(&["train", "--min-delta", "0.0025"]);
        assert_eq!(a.get_f64("min-delta", 0.0).unwrap(), 0.0025);
        assert_eq!(a.get_f64("missing", 1.5).unwrap(), 1.5);
        let b = parse(&["train", "--min-delta", "xyz"]);
        assert!(matches!(b.get_f64("min-delta", 0.0), Err(CliError::BadValue { .. })));
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["train", "--epochs", "five"]);
        assert!(matches!(a.get_usize("epochs", 0), Err(CliError::BadValue { .. })));
    }

    #[test]
    fn usize_list() {
        let a = parse(&["gen", "--dims", "10, 20,30"]);
        assert_eq!(a.get_usize_list("dims").unwrap().unwrap(), vec![10, 20, 30]);
        assert_eq!(a.get_usize_list("missing").unwrap(), None);
    }

    #[test]
    fn missing_subcommand() {
        assert_eq!(
            Args::parse(std::iter::empty::<String>()).unwrap_err(),
            CliError::MissingSubcommand
        );
    }

    #[test]
    fn unknown_args_detected_by_finish() {
        let a = parse(&["train", "--epohcs", "5"]);
        let _ = a.get_usize("epochs", 1); // program never reads "epohcs"
        assert!(matches!(a.finish(), Err(CliError::UnknownArgs(_))));
    }

    #[test]
    fn finish_ok_when_all_consumed() {
        let a = parse(&["train", "--epochs", "5"]);
        let _ = a.get_usize("epochs", 1);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn non_flag_token_is_error() {
        assert!(Args::parse(["train".to_string(), "oops".to_string()]).is_err());
    }
}
