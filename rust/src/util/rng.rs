//! Deterministic pseudo-random number generation.
//!
//! The repo builds offline (no `rand` crate), so we ship a small,
//! well-understood generator: **xoshiro256\*\*** seeded through SplitMix64,
//! the same construction the `rand_xoshiro` crate uses. All experiment
//! entropy flows through this module so every table/figure is exactly
//! reproducible from a seed.

/// SplitMix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator. `Clone` is cheap; cloning then `jump`-ing gives
/// independent streams for parallel workers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for worker `k` (seed-domain separation).
    pub fn fork(&self, k: u64) -> Self {
        let mut sm = self.s[0] ^ self.s[2] ^ k.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, no modulo bias).
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal_f32(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-300 {
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Zipf-distributed integer in `[0, n)` with exponent `s` using inverse
    /// CDF over a precomputed table is too large for big `n`; instead use
    /// rejection-inversion (Hörmann & Derflinger). Good enough for data
    /// generation; exactness validated statistically in tests.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n >= 1);
        if n == 1 {
            return 0;
        }
        if s <= 0.0 {
            return self.next_below(n);
        }
        // Rejection-inversion sampling of Zipf(s) on {1..n}.
        let nf = n as f64;
        let q = s;
        let h = |x: f64| -> f64 {
            if (1.0 - q).abs() < 1e-12 {
                (1.0 + x).ln()
            } else {
                ((1.0 + x).powf(1.0 - q) - 1.0) / (1.0 - q)
            }
        };
        let h_inv = |x: f64| -> f64 {
            if (1.0 - q).abs() < 1e-12 {
                x.exp() - 1.0
            } else {
                (1.0 + x * (1.0 - q)).powf(1.0 / (1.0 - q)) - 1.0
            }
        };
        let hx0 = h(0.5) - 1.0;
        let hn = h(nf - 0.5);
        loop {
            let u = hx0 + self.next_f64() * (hn - hx0);
            let x = h_inv(u);
            let k = (x + 0.5).floor().max(0.0).min(nf - 1.0);
            // acceptance test
            if k - x <= (1.0f64).exp() / (1.0 + k).powf(q) - 1.0
                || u >= h(k + 0.5) - (1.0 + k).powf(-q)
            {
                return k as usize;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_streams_are_independent() {
        let base = Rng::new(99);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 10_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.next_below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal_f32() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(13);
        let n = 1000;
        let mut counts = vec![0usize; n];
        for _ in 0..50_000 {
            let k = r.zipf(n, 1.1);
            assert!(k < n);
            counts[k] += 1;
        }
        // head should dominate tail under a power law
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[n - 100..].iter().sum();
        assert!(head > tail * 3, "head={head} tail={tail}");
    }

    #[test]
    fn zipf_s_zero_is_uniformish() {
        let mut r = Rng::new(17);
        let mut counts = vec![0usize; 8];
        for _ in 0..16_000 {
            counts[r.zipf(8, 0.0)] += 1;
        }
        for &c in &counts {
            assert!((1500..2600).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn permutation_covers_all() {
        let mut r = Rng::new(31);
        let p = r.permutation(64);
        let mut seen = vec![false; 64];
        for &i in &p {
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
