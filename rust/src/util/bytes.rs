//! Chunked little-endian slice IO.
//!
//! The checkpoint (`model::ModelState::save`) and tensor
//! (`tensor::io`) binary formats are flat streams of `u32`/`f32`
//! values. Writing them one 4-byte `write_all` per value costs a
//! `BufWriter` borrow-check and branch per scalar — measurable on
//! million-parameter checkpoints. These helpers convert whole slices
//! through a bounded scratch buffer, so the syscall/branch cost is per
//! ~64 KiB chunk instead of per value while the on-disk byte layout
//! stays identical.

use std::io::{Read, Result, Write};

/// Values converted per chunk (× 4 bytes = 64 KiB scratch).
const CHUNK: usize = 16 * 1024;

/// Write a `f32` slice as little-endian bytes.
pub fn write_f32s<W: Write>(w: &mut W, values: &[f32]) -> Result<()> {
    let mut buf = vec![0u8; CHUNK.min(values.len()) * 4];
    for chunk in values.chunks(CHUNK) {
        let bytes = &mut buf[..chunk.len() * 4];
        for (i, v) in chunk.iter().enumerate() {
            bytes[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        w.write_all(bytes)?;
    }
    Ok(())
}

/// Fill a `f32` slice from little-endian bytes.
pub fn read_f32s<R: Read>(r: &mut R, values: &mut [f32]) -> Result<()> {
    let mut buf = vec![0u8; CHUNK.min(values.len()) * 4];
    for chunk in values.chunks_mut(CHUNK) {
        let bytes = &mut buf[..chunk.len() * 4];
        r.read_exact(bytes)?;
        for (i, v) in chunk.iter_mut().enumerate() {
            *v = f32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
        }
    }
    Ok(())
}

/// Write a `u32` slice as little-endian bytes.
pub fn write_u32s<W: Write>(w: &mut W, values: &[u32]) -> Result<()> {
    let mut buf = vec![0u8; CHUNK.min(values.len()) * 4];
    for chunk in values.chunks(CHUNK) {
        let bytes = &mut buf[..chunk.len() * 4];
        for (i, v) in chunk.iter().enumerate() {
            bytes[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        w.write_all(bytes)?;
    }
    Ok(())
}

/// Fill a `u32` slice from little-endian bytes.
pub fn read_u32s<R: Read>(r: &mut R, values: &mut [u32]) -> Result<()> {
    let mut buf = vec![0u8; CHUNK.min(values.len()) * 4];
    for chunk in values.chunks_mut(CHUNK) {
        let bytes = &mut buf[..chunk.len() * 4];
        r.read_exact(bytes)?;
        for (i, v) in chunk.iter_mut().enumerate() {
            *v = u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn f32_roundtrip_exact_bits() {
        let src: Vec<f32> = (0..40_000)
            .map(|i| (i as f32).sin() * 1e3 + i as f32 * 1e-3)
            .collect();
        let mut bytes = Vec::new();
        write_f32s(&mut bytes, &src).unwrap();
        assert_eq!(bytes.len(), src.len() * 4);
        let mut back = vec![0f32; src.len()];
        read_f32s(&mut Cursor::new(&bytes), &mut back).unwrap();
        for (a, b) in src.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn u32_roundtrip() {
        let src: Vec<u32> = (0..CHUNK as u32 * 2 + 7).map(|i| i.wrapping_mul(2654435761)).collect();
        let mut bytes = Vec::new();
        write_u32s(&mut bytes, &src).unwrap();
        let mut back = vec![0u32; src.len()];
        read_u32s(&mut Cursor::new(&bytes), &mut back).unwrap();
        assert_eq!(src, back);
    }

    #[test]
    fn layout_matches_per_value_writes() {
        // the chunked writer must emit the exact byte stream the old
        // one-value-at-a-time loop produced (format compatibility)
        let src = [1.5f32, -0.25, 3.25e7, f32::MIN_POSITIVE];
        let mut chunked = Vec::new();
        write_f32s(&mut chunked, &src).unwrap();
        let mut scalar = Vec::new();
        for v in src {
            scalar.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(chunked, scalar);
    }

    #[test]
    fn empty_slices_are_noops() {
        let mut bytes = Vec::new();
        write_f32s(&mut bytes, &[]).unwrap();
        write_u32s(&mut bytes, &[]).unwrap();
        assert!(bytes.is_empty());
        read_f32s(&mut Cursor::new(&bytes), &mut []).unwrap();
        read_u32s(&mut Cursor::new(&bytes), &mut []).unwrap();
    }

    #[test]
    fn truncated_stream_errors() {
        let mut bytes = Vec::new();
        write_f32s(&mut bytes, &[1.0, 2.0]).unwrap();
        let mut back = vec![0f32; 3];
        assert!(read_f32s(&mut Cursor::new(&bytes), &mut back).is_err());
    }
}
