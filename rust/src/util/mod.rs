//! Substrate utilities built in-repo (the offline environment ships no
//! third-party crates beyond `xla`/`anyhow`): a counter-based PRNG, a JSON
//! reader/writer, a CLI argument parser, wall-clock timers, and a tiny
//! property-testing framework used by the test suite.

pub mod rng;
pub mod json;
pub mod cli;
pub mod timer;
pub mod proptest;
pub mod bytes;
pub mod bitset;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

/// Human-readable large-number formatting (`1234567` → `"1.23M"`).
pub fn human_count(n: u64) -> String {
    const UNITS: [(&str, u64); 4] =
        [("G", 1_000_000_000), ("M", 1_000_000), ("K", 1_000), ("", 1)];
    for (suffix, scale) in UNITS {
        if n >= scale && scale > 1 {
            return format!("{:.2}{}", n as f64 / scale as f64, suffix);
        }
    }
    format!("{n}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_exact_and_remainder() {
        assert_eq!(ceil_div(10, 5), 2);
        assert_eq!(ceil_div(11, 5), 3);
        assert_eq!(ceil_div(1, 5), 1);
        assert_eq!(ceil_div(0, 5), 0);
    }

    #[test]
    fn round_up_multiples() {
        assert_eq!(round_up(7, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_up(0, 8), 0);
    }

    #[test]
    fn human_count_scales() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(1_500), "1.50K");
        assert_eq!(human_count(2_500_000), "2.50M");
        assert_eq!(human_count(3_000_000_000), "3.00G");
    }
}
