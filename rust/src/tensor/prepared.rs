//! **PreparedStorage** — layer 2 of `Dataset → PreparedStorage → Session`.
//!
//! The paper's speed claim rests on *preparing reusable structures once and
//! streaming epochs over them* (§III): the B-CSF rotations and the element
//! traversal order are staging costs, paid before epoch 0, never on the
//! epoch path. `PreparedStorage` owns every such structure for one
//! algorithm — the shuffled COO traversal and, for the B-CSF variants, the
//! per-mode rotations — chooses the matching [`ChainStrategy`], and
//! implements [`SparseStorage`] directly, so a `Session` holds exactly one
//! owned storage for its whole lifetime instead of re-boxing adapters on
//! every factor/core pass.
//!
//! Two invariants make staging observable:
//!
//! * [`PrepStats`] splits the build cost (shuffle vs B-CSF) from the sweep
//!   cost, the separation the paper's Table V reports.
//! * [`PrepStats::builds`] counts heavy builds. It is set to 1 in
//!   [`PreparedStorage::prepare`] and nothing else increments it —
//!   `bench::experiments` asserts it stays 1 across a multi-epoch run,
//!   which is precisely the "no per-pass repartition" guarantee.

use crate::algo::engine::{BlockSink, ChainStrategy, SparseStorage};
use crate::algo::Algo;
use crate::config::TrainConfig;
use crate::tensor::bcsf::{self, BalanceStats, BcsfTensor};
use crate::sched::Executor;
use crate::tensor::coo::{self, CooTensor};
use crate::util::timer::Timer;
use anyhow::{bail, Result};

/// Staging-cost accounting: what was built before epoch 0 and how long it
/// took, separated from epoch sweep time (paper Table V reports
/// preparation and iteration separately).
#[derive(Clone, Debug, Default)]
pub struct PrepStats {
    /// Seconds spent shuffling the COO element order (computed **once**
    /// and shared by every mode rotation).
    pub shuffle_seconds: f64,
    /// Wall seconds spent building the per-mode B-CSF rotations (0 for
    /// the COO layouts). With `stage_workers > 1` the builds overlap, so
    /// this is what the caller actually waits.
    pub bcsf_seconds: f64,
    /// Summed per-build seconds across all mode rotations — the CPU-side
    /// cost. `bcsf_cpu_seconds / bcsf_seconds` approximates the staging
    /// parallel efficiency; the two are equal for a serial build.
    pub bcsf_cpu_seconds: f64,
    /// Staging workers the build ran with (resolved, never 0).
    pub stage_workers: usize,
    /// Seconds spent refreshing the per-mode `C^(n)` reuse tables across
    /// all passes so far. Accumulated by the session *after* each pass —
    /// refresh is epoch-path work, so it is deliberately **not** part of
    /// `total_seconds` (which freezes once staging is done).
    pub refresh_seconds: f64,
    /// Total staging seconds (shuffle + B-CSF + bookkeeping).
    pub total_seconds: f64,
    /// How many times the heavy structures were built. A session builds its
    /// storage exactly once *per residency*; epochs and passes never bump
    /// it — only a registry eviction followed by a transparent rebuild does
    /// (`tests/registry_serving.rs` asserts exactly that).
    pub builds: usize,
    /// Approximate heap bytes the built structures occupy — the charge a
    /// `SessionRegistry` eviction budget accounts this storage at.
    pub resident_bytes: usize,
}

/// Which concrete layout walks the non-zeros.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Layout {
    /// COO element blocks (FastTucker, FasterTucker_COO).
    Coo,
    /// B-CSF with fiber-shared groups (full FasterTucker).
    BcsfShared,
    /// B-CSF traversal without sharing (Table V ablation row).
    BcsfPerElement,
}

/// The owned, once-built `(storage, chain)` instantiation for one
/// FastTucker-family algorithm. Implements [`SparseStorage`], so the epoch
/// engine consumes it directly, pass after pass, epoch after epoch.
pub struct PreparedStorage {
    /// Shuffled training data — the COO traversal order for the COO
    /// layouts, and the evaluation/self-sample source for every layout.
    coo: CooTensor,
    /// Per-mode B-CSF rotations (`rotations[n]` has leaf mode `n`); only
    /// built for the B-CSF layouts.
    bcsf: Option<Vec<BcsfTensor>>,
    layout: Layout,
    chain: ChainStrategy,
    block_nnz: usize,
    /// Per-mode chain-mode lists, materialized once at prepare time so
    /// every pass borrows instead of allocating.
    chain_modes: Vec<Vec<usize>>,
    prep: PrepStats,
}

impl PreparedStorage {
    /// Build every reusable structure for `algo` exactly once. Fails for
    /// the full-core baselines, which keep their own loops and structures.
    ///
    /// # Examples
    ///
    /// ```
    /// use fastertucker::algo::Algo;
    /// use fastertucker::config::TrainConfig;
    /// use fastertucker::tensor::coo::CooTensor;
    /// use fastertucker::tensor::prepared::PreparedStorage;
    ///
    /// let mut t = CooTensor::new(vec![4, 3, 2]);
    /// t.push(&[0, 0, 0], 1.0);
    /// t.push(&[1, 2, 1], 2.0);
    /// let cfg = TrainConfig {
    ///     order: 3, dims: vec![4, 3, 2], j: 2, r: 2, ..TrainConfig::default()
    /// };
    /// let p = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
    /// assert_eq!(p.prep().builds, 1);
    /// assert!(p.resident_bytes() > 0);
    /// assert!(PreparedStorage::prepare(Algo::CuTucker, &cfg, &t).is_err());
    /// ```
    pub fn prepare(
        algo: Algo,
        cfg: &TrainConfig,
        train: &CooTensor,
    ) -> Result<PreparedStorage> {
        let Some(chain) = ChainStrategy::for_algo(algo) else {
            bail!("{} does not run on the epoch engine", algo.name());
        };
        let layout = match algo {
            Algo::FastTucker | Algo::FasterTuckerCoo => Layout::Coo,
            Algo::FasterTuckerBcsf => Layout::BcsfPerElement,
            Algo::FasterTucker => Layout::BcsfShared,
            Algo::CuTucker | Algo::PTucker => unreachable!("rejected above"),
        };
        let stage_workers = cfg.effective_stage_workers();
        let total = Timer::start();
        // one up-front shuffle so COO SGD sees a random element order, as
        // the paper's random sampling sets do; the permutation is computed
        // once here and shared by every mode rotation below (the B-CSF
        // builds re-sort from the pristine input, so they never need it)
        let t = Timer::start();
        let coo = train.training_shuffle(cfg.seed);
        let shuffle_seconds = t.seconds();
        let t = Timer::start();
        let mut bcsf_cpu_seconds = 0.0;
        let bcsf = match layout {
            Layout::Coo => None,
            Layout::BcsfShared | Layout::BcsfPerElement => {
                // per-mode rotations are independent pure functions of the
                // pristine input, so they fan out on a transient staging
                // pool; each build's own fiber-run split further divides
                // the leftover worker budget
                let split = crate::util::ceil_div(
                    stage_workers,
                    cfg.order.min(stage_workers),
                );
                let mut slots: Vec<Option<(BcsfTensor, f64)>> =
                    (0..cfg.order).map(|_| None).collect();
                let build = |n: usize, slot: &mut Option<(BcsfTensor, f64)>| {
                    let t = Timer::start();
                    let b = BcsfTensor::build_with_workers(
                        train,
                        n,
                        cfg.fiber_threshold,
                        cfg.block_nnz,
                        split,
                    );
                    *slot = Some((b, t.seconds()));
                };
                if stage_workers > 1 && cfg.order > 1 {
                    Executor::new(stage_workers)
                        .run_indexed(cfg.order, &mut slots, build);
                } else {
                    for (n, slot) in slots.iter_mut().enumerate() {
                        build(n, slot);
                    }
                }
                let mut rotations = Vec::with_capacity(cfg.order);
                for slot in slots {
                    let (b, seconds) = slot.expect("every mode built");
                    bcsf_cpu_seconds += seconds;
                    rotations.push(b);
                }
                Some(rotations)
            }
        };
        let bcsf_seconds = t.seconds();
        let chain_modes: Vec<Vec<usize>> = if let Some(rot) = &bcsf {
            (0..cfg.order)
                .map(|n| rot[n].csf.mode_order[..cfg.order - 1].to_vec())
                .collect()
        } else {
            (0..cfg.order)
                .map(|n| (0..cfg.order).filter(|&m| m != n).collect())
                .collect()
        };
        let resident_bytes = coo.heap_bytes()
            + bcsf
                .as_deref()
                .map_or(0, |v| v.iter().map(BcsfTensor::heap_bytes).sum());
        Ok(PreparedStorage {
            coo,
            bcsf,
            layout,
            chain,
            block_nnz: cfg.block_nnz.max(1),
            chain_modes,
            prep: PrepStats {
                shuffle_seconds,
                bcsf_seconds,
                bcsf_cpu_seconds,
                stage_workers,
                refresh_seconds: 0.0,
                total_seconds: total.seconds(),
                builds: 1,
                resident_bytes,
            },
        })
    }

    /// Approximate heap bytes of the owned structures (shuffled traversal
    /// copy + B-CSF rotations) — what evicting this storage frees.
    pub fn resident_bytes(&self) -> usize {
        self.prep.resident_bytes
    }

    /// The chain strategy paired with this storage.
    pub fn chain(&self) -> ChainStrategy {
        self.chain
    }

    /// The shuffled training tensor (evaluation and self-sampling source).
    pub fn coo(&self) -> &CooTensor {
        &self.coo
    }

    /// Staging-cost accounting.
    pub fn prep(&self) -> &PrepStats {
        &self.prep
    }

    /// B-CSF balance statistics (B-CSF layouts only).
    pub fn balance_stats(&self) -> Option<Vec<BalanceStats>> {
        self.bcsf
            .as_ref()
            .map(|v| v.iter().map(|b| b.stats.clone()).collect())
    }

    /// The mode-`n` B-CSF rotation (B-CSF layouts only).
    #[inline]
    fn rotation(&self, n: usize) -> &BcsfTensor {
        &self.bcsf.as_deref().expect("bcsf built")[n]
    }
}

/// `SparseStorage` over the owned, once-built structures. The layout
/// `match` below is the engine's **single remaining dispatch site** — one
/// predictable branch per storage call at block granularity; inside each
/// arm the walk and the sink monomorphize together.
impl SparseStorage for PreparedStorage {
    fn num_blocks(&self, n: usize) -> usize {
        match self.layout {
            Layout::Coo => coo::coo_num_blocks(self.coo.nnz(), self.block_nnz),
            Layout::BcsfShared | Layout::BcsfPerElement => {
                self.rotation(n).num_blocks()
            }
        }
    }

    fn nnz(&self, n: usize) -> usize {
        match self.layout {
            Layout::Coo => self.coo.nnz(),
            Layout::BcsfShared | Layout::BcsfPerElement => self.rotation(n).nnz(),
        }
    }

    fn block_weight(&self, n: usize, b: usize) -> usize {
        match self.layout {
            Layout::Coo => coo::coo_block_weight(self.coo.nnz(), self.block_nnz, b),
            Layout::BcsfShared | Layout::BcsfPerElement => {
                self.rotation(n).block_nnz_of(b)
            }
        }
    }

    fn chain_modes(&self, n: usize) -> &[usize] {
        &self.chain_modes[n]
    }

    fn drive_block<S: BlockSink>(&self, n: usize, b: usize, sink: &mut S) {
        match self.layout {
            Layout::Coo => {
                coo::drive_coo_block(&self.coo, self.block_nnz, n, b, sink)
            }
            Layout::BcsfShared => bcsf::drive_shared_block(self.rotation(n), b, sink),
            Layout::BcsfPerElement => {
                bcsf::drive_per_element_block(self.rotation(n), b, sink)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::tensor::bcsf::BcsfShared;

    fn cfg_for(t: &CooTensor) -> TrainConfig {
        TrainConfig {
            order: t.order(),
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            workers: 1,
            block_nnz: 512,
            fiber_threshold: 32,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn prepare_maps_algo_to_storage_and_chain() {
        let t = recommender(&RecommenderSpec::tiny(), 61);
        let cfg = cfg_for(&t);
        for (algo, chain, has_bcsf) in [
            (Algo::FastTucker, ChainStrategy::OnTheFly, false),
            (Algo::FasterTuckerCoo, ChainStrategy::Tables, false),
            (Algo::FasterTuckerBcsf, ChainStrategy::Tables, true),
            (Algo::FasterTucker, ChainStrategy::TablesPrefixCached, true),
        ] {
            let p = PreparedStorage::prepare(algo, &cfg, &t).unwrap();
            assert_eq!(p.chain(), chain, "{}", algo.name());
            assert_eq!(p.balance_stats().is_some(), has_bcsf, "{}", algo.name());
            assert_eq!(p.prep().builds, 1);
            assert!(p.prep().total_seconds >= 0.0);
        }
        for algo in [Algo::CuTucker, Algo::PTucker] {
            assert!(PreparedStorage::prepare(algo, &cfg, &t).is_err());
        }
    }

    #[test]
    fn prepared_storage_agrees_with_direct_adapters() {
        let t = recommender(&RecommenderSpec::tiny(), 62);
        let cfg = cfg_for(&t);
        let p = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        let bcsf: Vec<BcsfTensor> = (0..t.order())
            .map(|n| BcsfTensor::build(&t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect();
        let direct = BcsfShared::new(&bcsf);
        for n in 0..t.order() {
            assert_eq!(p.num_blocks(n), direct.num_blocks(n));
            assert_eq!(p.nnz(n), direct.nnz(n));
            assert_eq!(p.chain_modes(n), direct.chain_modes(n));
        }
    }

    #[test]
    fn prepared_coo_streams_every_nnz() {
        struct Count(usize);
        impl BlockSink for Count {
            fn group(&mut self, _coords: &[u32]) {}
            fn leaves(&mut self, rows: &[u32], vals: &[f32]) {
                assert_eq!(rows.len(), vals.len());
                self.0 += rows.len();
            }
        }
        let t = recommender(&RecommenderSpec::tiny(), 63);
        let cfg = cfg_for(&t);
        let p = PreparedStorage::prepare(Algo::FasterTuckerCoo, &cfg, &t).unwrap();
        for n in 0..t.order() {
            let mut c = Count(0);
            for b in 0..p.num_blocks(n) {
                p.drive_block(n, b, &mut c);
            }
            assert_eq!(c.0, t.nnz());
        }
    }

    #[test]
    fn resident_bytes_account_the_built_structures() {
        let t = recommender(&RecommenderSpec::tiny(), 66);
        let cfg = cfg_for(&t);
        let coo_only = PreparedStorage::prepare(Algo::FastTucker, &cfg, &t).unwrap();
        let with_bcsf = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        // at least the shuffled COO copy: nnz × (order u32 indices + f32)
        assert!(coo_only.resident_bytes() >= t.nnz() * 4 * (t.order() + 1));
        // the B-CSF rotations dominate the charge
        assert!(with_bcsf.resident_bytes() > coo_only.resident_bytes());
        assert_eq!(with_bcsf.prep().resident_bytes, with_bcsf.resident_bytes());
    }

    #[test]
    fn parallel_staging_is_bit_identical_to_serial() {
        #[derive(Default, PartialEq, Debug)]
        struct Trace {
            groups: Vec<Vec<u32>>,
            rows: Vec<u32>,
            vals: Vec<f32>,
        }
        impl BlockSink for Trace {
            fn group(&mut self, coords: &[u32]) {
                self.groups.push(coords.to_vec());
            }
            fn leaves(&mut self, rows: &[u32], vals: &[f32]) {
                self.rows.extend_from_slice(rows);
                self.vals.extend_from_slice(vals);
            }
        }
        let t = recommender(&RecommenderSpec::tiny(), 65);
        let mut cfg = cfg_for(&t);
        cfg.stage_workers = 1;
        let serial = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        cfg.stage_workers = 4;
        let par = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        assert_eq!(serial.prep().stage_workers, 1);
        assert_eq!(par.prep().stage_workers, 4);
        assert_eq!(par.coo().canonical_elements(), serial.coo().canonical_elements());
        for n in 0..t.order() {
            assert_eq!(par.num_blocks(n), serial.num_blocks(n));
            assert_eq!(par.chain_modes(n), serial.chain_modes(n));
            for b in 0..serial.num_blocks(n) {
                let (mut a, mut bb) = (Trace::default(), Trace::default());
                serial.drive_block(n, b, &mut a);
                par.drive_block(n, b, &mut bb);
                assert_eq!(a, bb, "mode {n} block {b}");
            }
        }
    }

    #[test]
    fn shuffle_is_part_of_staging_and_deterministic() {
        let t = recommender(&RecommenderSpec::tiny(), 64);
        let cfg = cfg_for(&t);
        let a = PreparedStorage::prepare(Algo::FastTucker, &cfg, &t).unwrap();
        let b = PreparedStorage::prepare(Algo::FastTucker, &cfg, &t).unwrap();
        assert_eq!(a.coo().index(0), b.coo().index(0));
        assert_eq!(a.coo().canonical_elements(), t.canonical_elements());
    }
}
