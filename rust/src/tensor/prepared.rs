//! **PreparedStorage** — layer 2 of `Dataset → PreparedStorage → Session`.
//!
//! The paper's speed claim rests on *preparing reusable structures once and
//! streaming epochs over them* (§III): the B-CSF rotations and the element
//! traversal order are staging costs, paid before epoch 0, never on the
//! epoch path. `PreparedStorage` owns every such structure for one
//! algorithm — the shuffled COO traversal and, for the B-CSF variants, the
//! per-mode rotations — chooses the matching [`ChainStrategy`], and
//! implements [`SparseStorage`] directly, so a `Session` holds exactly one
//! owned storage for its whole lifetime instead of re-boxing adapters on
//! every factor/core pass.
//!
//! Two invariants make staging observable:
//!
//! * [`PrepStats`] splits the build cost (shuffle vs B-CSF) from the sweep
//!   cost, the separation the paper's Table V reports.
//! * [`PrepStats::builds`] counts heavy builds. It is set to 1 in
//!   [`PreparedStorage::prepare`] and nothing else increments it —
//!   `bench::experiments` asserts it stays 1 across a multi-epoch run,
//!   which is precisely the "no per-pass repartition" guarantee.

use crate::algo::engine::{BlockSink, ChainStrategy, SparseStorage};
use crate::algo::Algo;
use crate::config::TrainConfig;
use crate::tensor::bcsf::{self, BalanceStats, BcsfTensor};
use crate::sched::topo::{self, Topology, WorkerHome};
use crate::sched::Executor;
use crate::tensor::coo::{self, CooTensor};
use crate::tensor::io as tensor_io;
use crate::util::timer::Timer;
use anyhow::{bail, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Mutex, RwLock};

/// Staging-cost accounting: what was built before epoch 0 and how long it
/// took, separated from epoch sweep time (paper Table V reports
/// preparation and iteration separately).
#[derive(Clone, Debug, Default)]
pub struct PrepStats {
    /// Seconds spent shuffling the COO element order (computed **once**
    /// and shared by every mode rotation).
    pub shuffle_seconds: f64,
    /// Wall seconds spent building the per-mode B-CSF rotations (0 for
    /// the COO layouts). With `stage_workers > 1` the builds overlap, so
    /// this is what the caller actually waits.
    pub bcsf_seconds: f64,
    /// Summed per-build seconds across all mode rotations — the CPU-side
    /// cost. `bcsf_cpu_seconds / bcsf_seconds` approximates the staging
    /// parallel efficiency; the two are equal for a serial build.
    pub bcsf_cpu_seconds: f64,
    /// Staging workers the build ran with (resolved, never 0).
    pub stage_workers: usize,
    /// Seconds spent refreshing the per-mode `C^(n)` reuse tables across
    /// all passes so far. Accumulated by the session *after* each pass —
    /// refresh is epoch-path work, so it is deliberately **not** part of
    /// `total_seconds` (which freezes once staging is done).
    pub refresh_seconds: f64,
    /// Total staging seconds (shuffle + B-CSF + bookkeeping).
    pub total_seconds: f64,
    /// How many times the heavy structures were built. A session builds its
    /// storage exactly once *per residency*; epochs and passes never bump
    /// it — only a registry eviction followed by a transparent rebuild does
    /// (`tests/registry_serving.rs` asserts exactly that).
    pub builds: usize,
    /// Approximate heap bytes the built structures occupy — the charge a
    /// `SessionRegistry` eviction budget accounts this storage at. For
    /// budget-capped staging this is capped at the budget: spilled
    /// rotations page in and out, so the full unbounded sum never resides.
    pub resident_bytes: usize,
    /// Peak bytes resident during staging. Equals `resident_bytes` for
    /// unbounded staging; for budget-capped staging it is the shuffled
    /// traversal plus the single largest rotation (modes build serially
    /// and spill between builds), which is also the minimum feasible
    /// budget. [`PreparedStorage::peak_resident_bytes`] reports the live
    /// high-water mark including training-time page-ins.
    pub peak_resident_bytes: usize,
    /// How many B-CSF blocks, summed across mode rotations, an incremental
    /// [`PreparedStorage::restage`] carried over bitwise-unchanged from
    /// the previous residency (the clean prefix ahead of the first
    /// delta-touched element). 0 for a cold [`PreparedStorage::prepare`].
    pub blocks_reused: usize,
    /// B-CSF blocks actually (re)built: every block for a cold prepare of
    /// a B-CSF layout, only the delta-dirtied suffix for an incremental
    /// restage.
    pub blocks_rebuilt: usize,
    /// The NUMA node each mode rotation's staging worker was bound to —
    /// `stage_nodes[n]` is the node mode `n`'s B-CSF block arrays were
    /// allocated (first-touched) on. Empty for COO layouts, serial or
    /// budget-capped staging, and single-node topologies, where no
    /// binding happens.
    pub stage_nodes: Vec<usize>,
}

/// Which concrete layout walks the non-zeros.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Layout {
    /// COO element blocks (FastTucker, FasterTucker_COO).
    Coo,
    /// B-CSF with fiber-shared groups (full FasterTucker).
    BcsfShared,
    /// B-CSF traversal without sharing (Table V ablation row).
    BcsfPerElement,
}

/// Always-resident metadata of one spilled rotation — answers every
/// engine query (block counts, weights, nnz) except the block drive
/// itself, so planning never forces a page-in.
struct RotationMeta {
    nnz: usize,
    heap_bytes: usize,
    block_sizes: Vec<u32>,
    stats: BalanceStats,
}

struct PageAcct {
    /// Rotation bytes currently resident (the COO charge is constant and
    /// accounted outside).
    resident: usize,
    /// High-water mark of `resident` — seeded with the staging-phase peak
    /// (the largest single rotation).
    peak: usize,
}

/// Budget-capped residency for the per-mode B-CSF rotations: every
/// rotation lives in a spill file, slots page in on demand under
/// `rot_budget`, and paging in one mode evicts others as needed. The
/// epoch engine drives exactly one mode's blocks between barriers, so
/// evicted modes are never mid-drive; bitwise output is unaffected
/// because the spill round-trip is bit-exact.
struct PagedRotations {
    slots: Vec<RwLock<Option<BcsfTensor>>>,
    meta: Vec<RotationMeta>,
    paths: Vec<PathBuf>,
    /// Bytes available to resident rotations (budget minus the COO charge).
    rot_budget: usize,
    acct: Mutex<PageAcct>,
}

impl PagedRotations {
    /// Run `f` with mode `n`'s rotation resident, paging it in first if
    /// needed. Concurrent callers for the same mode serialize on the slot
    /// lock; the read guard is held for the whole drive so an eviction
    /// sweep cannot pull the tensor out from under `f`.
    fn with_rotation<R>(&self, n: usize, f: impl FnOnce(&BcsfTensor) -> R) -> R {
        loop {
            let guard = self.slots[n].read().expect("rotation slot lock");
            if let Some(t) = guard.as_ref() {
                return f(t);
            }
            drop(guard);
            self.page_in(n);
        }
    }

    fn page_in(&self, n: usize) {
        let mut slot = self.slots[n].write().expect("rotation slot lock");
        if slot.is_some() {
            return; // raced with another page-in of the same mode
        }
        let need = self.meta[n].heap_bytes;
        {
            let mut acct = self.acct.lock().expect("paging accounting lock");
            if acct.resident + need > self.rot_budget {
                for m in 0..self.slots.len() {
                    if m == n || acct.resident + need <= self.rot_budget {
                        continue;
                    }
                    // try_write: a mode someone is actively driving or
                    // paging is skipped; the engine's per-mode barrier
                    // makes that window transient
                    if let Ok(mut other) = self.slots[m].try_write() {
                        if other.take().is_some() {
                            acct.resident -= self.meta[m].heap_bytes;
                        }
                    }
                }
            }
            acct.resident += need;
            acct.peak = acct.peak.max(acct.resident);
        }
        let t = tensor_io::read_bcsf_spill(&self.paths[n])
            .expect("spill readback (file written earlier by this storage)");
        *slot = Some(t);
    }
}

impl Drop for PagedRotations {
    fn drop(&mut self) {
        for p in &self.paths {
            std::fs::remove_file(p).ok();
        }
    }
}

/// Process-unique spill file names (a registry can stage many storages
/// concurrently; an eviction-rebuild cycle must not collide with itself).
static SPILL_COUNTER: AtomicU64 = AtomicU64::new(0);

fn spill_path(mode: usize) -> PathBuf {
    let c = SPILL_COUNTER.fetch_add(1, AtomicOrdering::Relaxed);
    std::env::temp_dir().join(format!(
        "ft_spill_{}_{}_m{}.bcsf",
        std::process::id(),
        c,
        mode
    ))
}

/// The owned, once-built `(storage, chain)` instantiation for one
/// FastTucker-family algorithm. Implements [`SparseStorage`], so the epoch
/// engine consumes it directly, pass after pass, epoch after epoch.
pub struct PreparedStorage {
    /// Shuffled training data — the COO traversal order for the COO
    /// layouts, and the evaluation/self-sample source for every layout.
    coo: CooTensor,
    /// Per-mode B-CSF rotations (`rotations[n]` has leaf mode `n`); only
    /// built for the B-CSF layouts staged without a byte budget.
    bcsf: Option<Vec<BcsfTensor>>,
    /// Budget-capped residency (B-CSF layouts with
    /// `stage_budget_bytes > 0`): rotations spill to disk and page in on
    /// demand. Mutually exclusive with `bcsf`.
    paged: Option<PagedRotations>,
    /// The algorithm this storage was prepared for — what an incremental
    /// [`PreparedStorage::restage`] re-prepares as.
    algo: Algo,
    layout: Layout,
    chain: ChainStrategy,
    block_nnz: usize,
    /// Per-mode chain-mode lists, materialized once at prepare time so
    /// every pass borrows instead of allocating.
    chain_modes: Vec<Vec<usize>>,
    prep: PrepStats,
}

impl PreparedStorage {
    /// Build every reusable structure for `algo` exactly once. Fails for
    /// the full-core baselines, which keep their own loops and structures.
    ///
    /// # Examples
    ///
    /// ```
    /// use fastertucker::algo::Algo;
    /// use fastertucker::config::TrainConfig;
    /// use fastertucker::tensor::coo::CooTensor;
    /// use fastertucker::tensor::prepared::PreparedStorage;
    ///
    /// let mut t = CooTensor::new(vec![4, 3, 2]);
    /// t.push(&[0, 0, 0], 1.0);
    /// t.push(&[1, 2, 1], 2.0);
    /// let cfg = TrainConfig {
    ///     order: 3, dims: vec![4, 3, 2], j: 2, r: 2, ..TrainConfig::default()
    /// };
    /// let p = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
    /// assert_eq!(p.prep().builds, 1);
    /// assert!(p.resident_bytes() > 0);
    /// assert!(PreparedStorage::prepare(Algo::CuTucker, &cfg, &t).is_err());
    /// ```
    pub fn prepare(
        algo: Algo,
        cfg: &TrainConfig,
        train: &CooTensor,
    ) -> Result<PreparedStorage> {
        let Some(chain) = ChainStrategy::for_algo(algo) else {
            bail!("{} does not run on the epoch engine", algo.name());
        };
        let layout = match algo {
            Algo::FastTucker | Algo::FasterTuckerCoo => Layout::Coo,
            Algo::FasterTuckerBcsf => Layout::BcsfPerElement,
            Algo::FasterTucker => Layout::BcsfShared,
            Algo::CuTucker | Algo::PTucker => unreachable!("rejected above"),
        };
        let stage_workers = cfg.effective_stage_workers();
        let budget = cfg.stage_budget_bytes;
        let total = Timer::start();
        // one up-front shuffle so COO SGD sees a random element order, as
        // the paper's random sampling sets do; the permutation is computed
        // once here and shared by every mode rotation below (the B-CSF
        // builds re-sort from the pristine input, so they never need it)
        let t = Timer::start();
        let coo = train.training_shuffle(cfg.seed);
        let shuffle_seconds = t.seconds();
        let coo_bytes = coo.heap_bytes();
        if budget > 0 && coo_bytes > budget {
            bail!(
                "stage budget of {budget} bytes is below the shuffled COO \
                 traversal alone ({coo_bytes} bytes); nothing can be staged"
            );
        }
        let t = Timer::start();
        let mut bcsf_cpu_seconds = 0.0;
        let mut bcsf = None;
        let mut paged = None;
        let mut stage_nodes: Vec<usize> = Vec::new();
        match layout {
            Layout::Coo => {}
            Layout::BcsfShared | Layout::BcsfPerElement if budget > 0 => {
                // budget-capped staging: build the rotations one mode at a
                // time with the full staging pool inside each build (the
                // build is bit-identical at any worker count), spill each
                // to disk, and release it before the next — peak residency
                // is the traversal plus one rotation, regardless of order
                let mut meta = Vec::with_capacity(cfg.order);
                let mut paths: Vec<PathBuf> = Vec::with_capacity(cfg.order);
                let mut max_rot = 0usize;
                let mut spilled = Ok(());
                for n in 0..cfg.order {
                    let tb = Timer::start();
                    let b = BcsfTensor::build_with_workers(
                        train,
                        n,
                        cfg.fiber_threshold,
                        cfg.block_nnz,
                        stage_workers,
                    );
                    bcsf_cpu_seconds += tb.seconds();
                    let bytes = b.heap_bytes();
                    max_rot = max_rot.max(bytes);
                    let path = spill_path(n);
                    if let Err(e) = tensor_io::write_bcsf_spill(&b, &path) {
                        std::fs::remove_file(&path).ok();
                        spilled = Err(e);
                        break;
                    }
                    meta.push(RotationMeta {
                        nnz: b.nnz(),
                        heap_bytes: bytes,
                        block_sizes: b.block_sizes.clone(),
                        stats: b.stats.clone(),
                    });
                    paths.push(path);
                    // `b` drops here: released before the next mode builds
                }
                if let Err(e) = spilled {
                    for p in &paths {
                        std::fs::remove_file(p).ok();
                    }
                    return Err(e.context("spilling a staged B-CSF rotation"));
                }
                if coo_bytes + max_rot > budget {
                    for p in &paths {
                        std::fs::remove_file(p).ok();
                    }
                    bail!(
                        "stage budget of {budget} bytes is infeasible: the \
                         COO traversal ({coo_bytes} bytes) plus the largest \
                         rotation ({max_rot} bytes) needs at least {} bytes",
                        coo_bytes + max_rot
                    );
                }
                paged = Some(PagedRotations {
                    slots: (0..cfg.order).map(|_| RwLock::new(None)).collect(),
                    meta,
                    paths,
                    rot_budget: budget - coo_bytes,
                    acct: Mutex::new(PageAcct {
                        resident: 0,
                        peak: max_rot,
                    }),
                });
            }
            Layout::BcsfShared | Layout::BcsfPerElement => {
                // per-mode rotations are independent pure functions of the
                // pristine input, so they fan out on a transient staging
                // pool; each build's own fiber-run split further divides
                // the leftover worker budget
                let split = crate::util::ceil_div(
                    stage_workers,
                    cfg.order.min(stage_workers),
                );
                let parallel = stage_workers > 1 && cfg.order > 1;
                let homes = stage_mode_homes(cfg, parallel);
                if let Some(h) = &homes {
                    stage_nodes = h.iter().map(|x| x.node).collect();
                }
                let mut slots: Vec<Option<(BcsfTensor, f64)>> =
                    (0..cfg.order).map(|_| None).collect();
                let build = |n: usize, slot: &mut Option<(BcsfTensor, f64)>| {
                    // bind this staging worker to mode n's home first, so
                    // the rotation's block arrays are allocated
                    // (first-touched) on the node that will drive them
                    if let Some(h) = &homes {
                        topo::bind_worker(Some(&h[n]));
                    }
                    let t = Timer::start();
                    let b = BcsfTensor::build_with_workers(
                        train,
                        n,
                        cfg.fiber_threshold,
                        cfg.block_nnz,
                        split,
                    );
                    *slot = Some((b, t.seconds()));
                };
                if parallel {
                    Executor::new(stage_workers)
                        .run_indexed(cfg.order, &mut slots, build);
                } else {
                    for (n, slot) in slots.iter_mut().enumerate() {
                        build(n, slot);
                    }
                }
                let mut rotations = Vec::with_capacity(cfg.order);
                for slot in slots {
                    let (b, seconds) = slot.expect("every mode built");
                    bcsf_cpu_seconds += seconds;
                    rotations.push(b);
                }
                bcsf = Some(rotations);
            }
        }
        let bcsf_seconds = t.seconds();
        // The B-CSF rotation for leaf mode n always sorts by
        // ((n+1)%N, ..., (n+N-1)%N, n), so the chain modes follow from the
        // leaf alone — no need to touch (possibly spilled) rotations.
        let chain_modes: Vec<Vec<usize>> = match layout {
            Layout::Coo => (0..cfg.order)
                .map(|n| (0..cfg.order).filter(|&m| m != n).collect())
                .collect(),
            Layout::BcsfShared | Layout::BcsfPerElement => (0..cfg.order)
                .map(|n| (1..cfg.order).map(|k| (n + k) % cfg.order).collect())
                .collect(),
        };
        let unbounded_bytes = coo_bytes
            + bcsf
                .as_deref()
                .map_or(0, |v| v.iter().map(BcsfTensor::heap_bytes).sum())
            + paged
                .as_ref()
                .map_or(0, |p: &PagedRotations| {
                    p.meta.iter().map(|m| m.heap_bytes).sum()
                });
        let resident_bytes = if budget > 0 {
            unbounded_bytes.min(budget)
        } else {
            unbounded_bytes
        };
        let peak_resident_bytes = match &paged {
            Some(p) => coo_bytes + p.acct.lock().expect("acct").peak,
            None => resident_bytes,
        };
        let blocks_rebuilt = if let Some(rot) = &bcsf {
            rot.iter().map(BcsfTensor::num_blocks).sum()
        } else if let Some(p) = &paged {
            p.meta.iter().map(|m| m.block_sizes.len()).sum()
        } else {
            0
        };
        Ok(PreparedStorage {
            coo,
            bcsf,
            paged,
            algo,
            layout,
            chain,
            block_nnz: cfg.block_nnz.max(1),
            chain_modes,
            prep: PrepStats {
                shuffle_seconds,
                bcsf_seconds,
                bcsf_cpu_seconds,
                stage_workers,
                refresh_seconds: 0.0,
                total_seconds: total.seconds(),
                builds: 1,
                resident_bytes,
                peak_resident_bytes,
                blocks_reused: 0,
                blocks_rebuilt,
                stage_nodes,
            },
        })
    }

    /// Incrementally re-stage for `concat = base ∪ delta`, where `self`
    /// was prepared over the base tensor, by merging `delta` into each
    /// existing rotation instead of re-sorting the full input per mode.
    ///
    /// The result is **bitwise identical** to
    /// `PreparedStorage::prepare(self.algo, cfg, concat)`: a cold B-CSF
    /// build stable-sorts the pristine input, so duplicate coordinates
    /// fold base-order-first then delta-order — exactly the order the
    /// merge reproduces from the previous rotation's already-folded values
    /// plus the delta elements in delta order. `cfg.dims` must already
    /// reflect any mode growth (`concat.dims()`).
    ///
    /// Budget-capped (paged) and COO storages gain nothing from the merge
    /// and fall back to a full [`PreparedStorage::prepare`] over `concat`.
    ///
    /// The returned stats report `builds: 1` plus the split of B-CSF
    /// blocks carried over bitwise-unchanged ([`PrepStats::blocks_reused`])
    /// versus rebuilt ([`PrepStats::blocks_rebuilt`]); the session folds
    /// these into its lifetime counters.
    pub fn restage(
        &self,
        cfg: &TrainConfig,
        concat: &CooTensor,
        delta: &CooTensor,
    ) -> Result<PreparedStorage> {
        assert_eq!(
            concat.nnz(),
            self.coo.nnz() + delta.nnz(),
            "concat must be base plus delta"
        );
        let Some(prev) = self.bcsf.as_deref() else {
            // COO layouts re-shuffle anyway; paged storages would have to
            // page every rotation in just to merge — a cold prepare has
            // the same peak residency and stays on the budgeted path
            return Self::prepare(self.algo, cfg, concat);
        };
        let stage_workers = cfg.effective_stage_workers();
        let total = Timer::start();
        let t = Timer::start();
        let coo = concat.training_shuffle(cfg.seed);
        let shuffle_seconds = t.seconds();
        let t = Timer::start();
        let split =
            crate::util::ceil_div(stage_workers, cfg.order.min(stage_workers));
        let parallel = stage_workers > 1 && cfg.order > 1;
        let homes = stage_mode_homes(cfg, parallel);
        let stage_nodes: Vec<usize> = homes
            .as_deref()
            .map(|h| h.iter().map(|x| x.node).collect())
            .unwrap_or_default();
        let mut slots: Vec<Option<(BcsfTensor, usize, f64)>> =
            (0..cfg.order).map(|_| None).collect();
        let grown_dims = concat.dims().to_vec();
        let build = |n: usize, slot: &mut Option<(BcsfTensor, usize, f64)>| {
            // same placement as a cold prepare: the rebuilt rotation's
            // arrays first-touch on mode n's home node
            if let Some(h) = &homes {
                topo::bind_worker(Some(&h[n]));
            }
            let t = Timer::start();
            let (merged, first_touched) =
                merge_rotation_delta(&prev[n], delta, grown_dims.clone());
            let b = BcsfTensor::build_with_workers(
                &merged,
                n,
                cfg.fiber_threshold,
                cfg.block_nnz,
                split,
            );
            *slot = Some((b, first_touched, t.seconds()));
        };
        if parallel {
            Executor::new(stage_workers).run_indexed(cfg.order, &mut slots, build);
        } else {
            for (n, slot) in slots.iter_mut().enumerate() {
                build(n, slot);
            }
        }
        let mut bcsf_cpu_seconds = 0.0;
        let mut rotations = Vec::with_capacity(cfg.order);
        let mut blocks_reused = 0usize;
        let mut blocks_rebuilt = 0usize;
        for slot in slots {
            let (b, first_touched, seconds) = slot.expect("every mode merged");
            bcsf_cpu_seconds += seconds;
            // a block whose element range ends at or before the first
            // delta-touched element is the bitwise-identical prefix of the
            // previous rotation (same sorted elements, same fiber splits,
            // same greedy packing) — count it as carried over
            let mut cum = 0usize;
            for bi in 0..b.num_blocks() {
                cum += b.block_nnz_of(bi);
                if cum <= first_touched {
                    blocks_reused += 1;
                } else {
                    blocks_rebuilt += 1;
                }
            }
            rotations.push(b);
        }
        let bcsf_seconds = t.seconds();
        let resident_bytes = coo.heap_bytes()
            + rotations.iter().map(BcsfTensor::heap_bytes).sum::<usize>();
        Ok(PreparedStorage {
            coo,
            bcsf: Some(rotations),
            paged: None,
            algo: self.algo,
            layout: self.layout,
            chain: self.chain,
            block_nnz: cfg.block_nnz.max(1),
            chain_modes: self.chain_modes.clone(),
            prep: PrepStats {
                shuffle_seconds,
                bcsf_seconds,
                bcsf_cpu_seconds,
                stage_workers,
                refresh_seconds: 0.0,
                total_seconds: total.seconds(),
                builds: 1,
                resident_bytes,
                peak_resident_bytes: resident_bytes,
                blocks_reused,
                blocks_rebuilt,
                stage_nodes,
            },
        })
    }

    /// Approximate heap bytes of the owned structures (shuffled traversal
    /// copy + B-CSF rotations) — what evicting this storage frees. For
    /// budget-capped staging this is capped at the budget.
    pub fn resident_bytes(&self) -> usize {
        self.prep.resident_bytes
    }

    /// High-water mark of resident bytes, including training-time page-ins
    /// for budget-capped staging. For unbounded staging this equals
    /// [`PreparedStorage::resident_bytes`]. Never exceeds the configured
    /// `stage_budget_bytes` when one was set.
    pub fn peak_resident_bytes(&self) -> usize {
        match &self.paged {
            Some(p) => {
                self.coo.heap_bytes() + p.acct.lock().expect("acct").peak
            }
            None => self.prep.peak_resident_bytes,
        }
    }

    /// Smallest `stage_budget_bytes` that can stage this dataset with this
    /// layout: the shuffled COO traversal plus the single largest rotation
    /// (modes build serially under a budget, so only one rotation is ever
    /// resident during staging).
    pub fn min_stage_budget_bytes(&self) -> usize {
        let max_rot = if let Some(rot) = self.bcsf.as_deref() {
            rot.iter().map(BcsfTensor::heap_bytes).max().unwrap_or(0)
        } else if let Some(p) = &self.paged {
            p.meta.iter().map(|m| m.heap_bytes).max().unwrap_or(0)
        } else {
            0
        };
        self.coo.heap_bytes() + max_rot
    }

    /// The chain strategy paired with this storage.
    pub fn chain(&self) -> ChainStrategy {
        self.chain
    }

    /// The shuffled training tensor (evaluation and self-sampling source).
    pub fn coo(&self) -> &CooTensor {
        &self.coo
    }

    /// Staging-cost accounting.
    pub fn prep(&self) -> &PrepStats {
        &self.prep
    }

    /// B-CSF balance statistics (B-CSF layouts only). Served from the
    /// always-resident metadata for budget-capped staging — no page-in.
    pub fn balance_stats(&self) -> Option<Vec<BalanceStats>> {
        if let Some(v) = self.bcsf.as_ref() {
            return Some(v.iter().map(|b| b.stats.clone()).collect());
        }
        self.paged
            .as_ref()
            .map(|p| p.meta.iter().map(|m| m.stats.clone()).collect())
    }

    /// Run `f` against the mode-`n` B-CSF rotation (B-CSF layouts only),
    /// paging it in first under budget-capped staging.
    #[inline]
    fn with_rotation<R>(&self, n: usize, f: impl FnOnce(&BcsfTensor) -> R) -> R {
        if let Some(rot) = self.bcsf.as_deref() {
            return f(&rot[n]);
        }
        self.paged
            .as_ref()
            .expect("B-CSF layout has rotations or pages")
            .with_rotation(n, f)
    }

    /// The always-resident per-block nnz table for mode `n`.
    #[inline]
    fn block_sizes(&self, n: usize) -> &[u32] {
        if let Some(rot) = self.bcsf.as_deref() {
            return &rot[n].block_sizes;
        }
        &self
            .paged
            .as_ref()
            .expect("B-CSF layout has rotations or pages")
            .meta[n]
            .block_sizes
    }

    /// nnz of the mode-`n` rotation without forcing a page-in.
    #[inline]
    fn rotation_nnz(&self, n: usize) -> usize {
        if let Some(rot) = self.bcsf.as_deref() {
            return rot[n].nnz();
        }
        self.paged
            .as_ref()
            .expect("B-CSF layout has rotations or pages")
            .meta[n]
            .nnz
    }
}

/// Memory-hierarchy homes for the per-mode staging fan-out: mode `n`'s
/// rotation is built — and its block arrays first-touched — by a worker
/// bound to `homes[n]` (node-balanced via [`Topology::assign_homes`]).
/// `None` when staging is serial (binding would rebind the *caller*
/// thread) or the topology has a single node (nothing to place).
fn stage_mode_homes(cfg: &TrainConfig, parallel: bool) -> Option<Vec<WorkerHome>> {
    if !parallel {
        return None;
    }
    let topo = Topology::detect(cfg.numa);
    if topo.nodes() <= 1 {
        return None;
    }
    Some(topo.assign_homes(cfg.order))
}

/// Merge `delta` into the element sequence of one existing B-CSF rotation,
/// producing the COO input a cold build over `base ∪ delta` would sort to
/// for that rotation's `mode_order` — already in sorted order — plus the
/// index of the first element the delta touched (`usize::MAX` if none,
/// i.e. an empty delta).
///
/// Correctness of the folded values: `CsfTensor::build_with_order` merges
/// duplicate coordinates with a stable sort over the *input* order, folding
/// left to right. For the concatenated input that order is "base elements
/// first (in base order), then delta elements (in delta order)". The
/// previous rotation's `to_coo()` value at a coordinate *is* the fold of
/// the base elements in base order, so appending the delta values after it
/// reproduces the cold fold exactly — and a rebuild from the merged,
/// already-folded sequence adds nothing further.
fn merge_rotation_delta(
    prev: &BcsfTensor,
    delta: &CooTensor,
    grown_dims: Vec<usize>,
) -> (CooTensor, usize) {
    let mode_order = &prev.csf.mode_order;
    let prev_coo = prev.csf.to_coo();
    let perm = delta.sorted_perm(mode_order);
    let lex = |a: &[u32], b: &[u32]| -> std::cmp::Ordering {
        for &m in mode_order {
            match a[m].cmp(&b[m]) {
                std::cmp::Ordering::Equal => {}
                o => return o,
            }
        }
        std::cmp::Ordering::Equal
    };
    let pn = prev_coo.nnz();
    let dn = delta.nnz();
    let mut out = CooTensor::with_capacity(grown_dims, pn + dn);
    let mut first_touched = usize::MAX;
    let (mut pi, mut di) = (0usize, 0usize);
    while pi < pn || di < dn {
        let take_prev = if pi == pn {
            false
        } else if di == dn {
            true
        } else {
            // ties take the previous element: base order precedes delta
            // order in the concatenated input
            lex(prev_coo.index(pi), delta.index(perm[di] as usize))
                != std::cmp::Ordering::Greater
        };
        if take_prev {
            let idx = prev_coo.index(pi).to_vec();
            let mut v = prev_coo.value(pi);
            pi += 1;
            // fold delta duplicates of this coordinate onto the base value
            while di < dn {
                let e = perm[di] as usize;
                if lex(&idx, delta.index(e)) != std::cmp::Ordering::Equal {
                    break;
                }
                v += delta.value(e);
                first_touched = first_touched.min(out.nnz());
                di += 1;
            }
            out.push(&idx, v);
        } else {
            let e = perm[di] as usize;
            let idx = delta.index(e).to_vec();
            let mut v = delta.value(e);
            di += 1;
            while di < dn {
                let e2 = perm[di] as usize;
                if lex(&idx, delta.index(e2)) != std::cmp::Ordering::Equal {
                    break;
                }
                v += delta.value(e2);
                di += 1;
            }
            first_touched = first_touched.min(out.nnz());
            out.push(&idx, v);
        }
    }
    (out, first_touched)
}

/// `SparseStorage` over the owned, once-built structures. The layout
/// `match` below is the engine's **single remaining dispatch site** — one
/// predictable branch per storage call at block granularity; inside each
/// arm the walk and the sink monomorphize together.
impl SparseStorage for PreparedStorage {
    fn num_blocks(&self, n: usize) -> usize {
        match self.layout {
            Layout::Coo => coo::coo_num_blocks(self.coo.nnz(), self.block_nnz),
            Layout::BcsfShared | Layout::BcsfPerElement => {
                self.block_sizes(n).len()
            }
        }
    }

    fn nnz(&self, n: usize) -> usize {
        match self.layout {
            Layout::Coo => self.coo.nnz(),
            Layout::BcsfShared | Layout::BcsfPerElement => self.rotation_nnz(n),
        }
    }

    fn block_weight(&self, n: usize, b: usize) -> usize {
        match self.layout {
            Layout::Coo => coo::coo_block_weight(self.coo.nnz(), self.block_nnz, b),
            Layout::BcsfShared | Layout::BcsfPerElement => {
                self.block_sizes(n)[b] as usize
            }
        }
    }

    fn chain_modes(&self, n: usize) -> &[usize] {
        &self.chain_modes[n]
    }

    fn drive_block<S: BlockSink>(&self, n: usize, b: usize, sink: &mut S) {
        match self.layout {
            Layout::Coo => {
                coo::drive_coo_block(&self.coo, self.block_nnz, n, b, sink)
            }
            Layout::BcsfShared => {
                self.with_rotation(n, |t| bcsf::drive_shared_block(t, b, sink))
            }
            Layout::BcsfPerElement => self
                .with_rotation(n, |t| bcsf::drive_per_element_block(t, b, sink)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{recommender, RecommenderSpec};
    use crate::tensor::bcsf::BcsfShared;

    fn cfg_for(t: &CooTensor) -> TrainConfig {
        TrainConfig {
            order: t.order(),
            dims: t.dims().to_vec(),
            j: 8,
            r: 4,
            workers: 1,
            block_nnz: 512,
            fiber_threshold: 32,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn prepare_maps_algo_to_storage_and_chain() {
        let t = recommender(&RecommenderSpec::tiny(), 61);
        let cfg = cfg_for(&t);
        for (algo, chain, has_bcsf) in [
            (Algo::FastTucker, ChainStrategy::OnTheFly, false),
            (Algo::FasterTuckerCoo, ChainStrategy::Tables, false),
            (Algo::FasterTuckerBcsf, ChainStrategy::Tables, true),
            (Algo::FasterTucker, ChainStrategy::TablesPrefixCached, true),
        ] {
            let p = PreparedStorage::prepare(algo, &cfg, &t).unwrap();
            assert_eq!(p.chain(), chain, "{}", algo.name());
            assert_eq!(p.balance_stats().is_some(), has_bcsf, "{}", algo.name());
            assert_eq!(p.prep().builds, 1);
            assert!(p.prep().total_seconds >= 0.0);
        }
        for algo in [Algo::CuTucker, Algo::PTucker] {
            assert!(PreparedStorage::prepare(algo, &cfg, &t).is_err());
        }
    }

    #[test]
    fn prepared_storage_agrees_with_direct_adapters() {
        let t = recommender(&RecommenderSpec::tiny(), 62);
        let cfg = cfg_for(&t);
        let p = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        let bcsf: Vec<BcsfTensor> = (0..t.order())
            .map(|n| BcsfTensor::build(&t, n, cfg.fiber_threshold, cfg.block_nnz))
            .collect();
        let direct = BcsfShared::new(&bcsf);
        for n in 0..t.order() {
            assert_eq!(p.num_blocks(n), direct.num_blocks(n));
            assert_eq!(p.nnz(n), direct.nnz(n));
            assert_eq!(p.chain_modes(n), direct.chain_modes(n));
        }
    }

    #[test]
    fn prepared_coo_streams_every_nnz() {
        struct Count(usize);
        impl BlockSink for Count {
            fn group(&mut self, _coords: &[u32]) {}
            fn leaves(&mut self, rows: &[u32], vals: &[f32]) {
                assert_eq!(rows.len(), vals.len());
                self.0 += rows.len();
            }
        }
        let t = recommender(&RecommenderSpec::tiny(), 63);
        let cfg = cfg_for(&t);
        let p = PreparedStorage::prepare(Algo::FasterTuckerCoo, &cfg, &t).unwrap();
        for n in 0..t.order() {
            let mut c = Count(0);
            for b in 0..p.num_blocks(n) {
                p.drive_block(n, b, &mut c);
            }
            assert_eq!(c.0, t.nnz());
        }
    }

    #[test]
    fn resident_bytes_account_the_built_structures() {
        let t = recommender(&RecommenderSpec::tiny(), 66);
        let cfg = cfg_for(&t);
        let coo_only = PreparedStorage::prepare(Algo::FastTucker, &cfg, &t).unwrap();
        let with_bcsf = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        // at least the shuffled COO copy: nnz × (order u32 indices + f32)
        assert!(coo_only.resident_bytes() >= t.nnz() * 4 * (t.order() + 1));
        // the B-CSF rotations dominate the charge
        assert!(with_bcsf.resident_bytes() > coo_only.resident_bytes());
        assert_eq!(with_bcsf.prep().resident_bytes, with_bcsf.resident_bytes());
    }

    #[test]
    fn parallel_staging_is_bit_identical_to_serial() {
        #[derive(Default, PartialEq, Debug)]
        struct Trace {
            groups: Vec<Vec<u32>>,
            rows: Vec<u32>,
            vals: Vec<f32>,
        }
        impl BlockSink for Trace {
            fn group(&mut self, coords: &[u32]) {
                self.groups.push(coords.to_vec());
            }
            fn leaves(&mut self, rows: &[u32], vals: &[f32]) {
                self.rows.extend_from_slice(rows);
                self.vals.extend_from_slice(vals);
            }
        }
        let t = recommender(&RecommenderSpec::tiny(), 65);
        let mut cfg = cfg_for(&t);
        cfg.stage_workers = 1;
        let serial = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        cfg.stage_workers = 4;
        let par = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        assert_eq!(serial.prep().stage_workers, 1);
        assert_eq!(par.prep().stage_workers, 4);
        assert_eq!(par.coo().canonical_elements(), serial.coo().canonical_elements());
        for n in 0..t.order() {
            assert_eq!(par.num_blocks(n), serial.num_blocks(n));
            assert_eq!(par.chain_modes(n), serial.chain_modes(n));
            for b in 0..serial.num_blocks(n) {
                let (mut a, mut bb) = (Trace::default(), Trace::default());
                serial.drive_block(n, b, &mut a);
                par.drive_block(n, b, &mut bb);
                assert_eq!(a, bb, "mode {n} block {b}");
            }
        }
    }

    /// Block drive transcript with bit-exact values — `f32` equality
    /// would conflate `-0.0`/`0.0`, so compare raw bits.
    #[derive(Default, PartialEq, Debug)]
    struct BitTrace {
        groups: Vec<Vec<u32>>,
        rows: Vec<u32>,
        val_bits: Vec<u32>,
    }
    impl BlockSink for BitTrace {
        fn group(&mut self, coords: &[u32]) {
            self.groups.push(coords.to_vec());
        }
        fn leaves(&mut self, rows: &[u32], vals: &[f32]) {
            self.rows.extend_from_slice(rows);
            self.val_bits.extend(vals.iter().map(|v| v.to_bits()));
        }
    }

    fn assert_blocks_bitwise(a: &PreparedStorage, b: &PreparedStorage, what: &str) {
        let order = a.coo().order();
        for n in 0..order {
            assert_eq!(a.num_blocks(n), b.num_blocks(n), "{what}: mode {n}");
            assert_eq!(a.nnz(n), b.nnz(n), "{what}: mode {n}");
            assert_eq!(a.chain_modes(n), b.chain_modes(n), "{what}: mode {n}");
            for blk in 0..a.num_blocks(n) {
                assert_eq!(a.block_weight(n, blk), b.block_weight(n, blk));
                let (mut ta, mut tb) = (BitTrace::default(), BitTrace::default());
                a.drive_block(n, blk, &mut ta);
                b.drive_block(n, blk, &mut tb);
                assert_eq!(ta, tb, "{what}: mode {n} block {blk}");
            }
        }
    }

    #[test]
    fn budgeted_staging_is_bitwise_unbounded_at_any_budget() {
        let t = recommender(&RecommenderSpec::tiny(), 67);
        let cfg = cfg_for(&t);
        let unbounded =
            PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        let min = unbounded.min_stage_budget_bytes();
        let total = unbounded.resident_bytes();
        assert!(min < total, "several rotations: paging must be exercised");
        for budget in [total, ((total + min) / 2).max(min), min] {
            let mut c = cfg.clone();
            c.stage_budget_bytes = budget;
            let p = PreparedStorage::prepare(Algo::FasterTucker, &c, &t).unwrap();
            assert!(
                p.prep().peak_resident_bytes <= budget,
                "staging peak {} within budget {budget}",
                p.prep().peak_resident_bytes
            );
            assert!(p.resident_bytes() <= budget);
            assert_eq!(
                p.coo().canonical_elements(),
                unbounded.coo().canonical_elements()
            );
            assert!(p.balance_stats().is_some());
            // driving every block of every mode forces page-in/eviction
            // cycles at the tight budgets — output must not notice
            assert_blocks_bitwise(&p, &unbounded, &format!("budget {budget}"));
            assert!(
                p.peak_resident_bytes() <= budget,
                "live peak {} within budget {budget} after full drives",
                p.peak_resident_bytes()
            );
        }
        // one byte below the feasible minimum must refuse to stage
        let mut c = cfg.clone();
        c.stage_budget_bytes = min - 1;
        assert!(PreparedStorage::prepare(Algo::FasterTucker, &c, &t).is_err());
    }

    #[test]
    fn restage_is_bitwise_cold_prepare_of_concat() {
        let base = recommender(&RecommenderSpec::tiny(), 68);
        let cfg = cfg_for(&base);
        let prepared =
            PreparedStorage::prepare(Algo::FasterTucker, &cfg, &base).unwrap();
        // delta: the same coordinate twice (multiplicity three with the
        // base element), plus brand-new rows growing mode 0 by five
        let mut dims = base.dims().to_vec();
        dims[0] += 5;
        let mut delta = CooTensor::new(dims.clone());
        let c0 = base.index(0).to_vec();
        delta.push(&c0, 0.25);
        delta.push(&c0, -1.5);
        for g in 0..3u32 {
            let mut c = base.index((g as usize + 1) % base.nnz()).to_vec();
            c[0] = (base.dims()[0] + g as usize) as u32;
            delta.push(&c, 0.5 + g as f32);
        }
        let mut concat =
            CooTensor::with_capacity(dims.clone(), base.nnz() + delta.nnz());
        for e in 0..base.nnz() {
            concat.push(base.index(e), base.value(e));
        }
        for e in 0..delta.nnz() {
            concat.push(delta.index(e), delta.value(e));
        }
        let mut cfg2 = cfg.clone();
        cfg2.dims = dims.clone();
        let cold =
            PreparedStorage::prepare(Algo::FasterTucker, &cfg2, &concat).unwrap();
        let warm = prepared.restage(&cfg2, &concat, &delta).unwrap();
        assert_eq!(warm.coo().indices_flat(), cold.coo().indices_flat());
        let wb: Vec<u32> = warm.coo().values().iter().map(|v| v.to_bits()).collect();
        let cb: Vec<u32> = cold.coo().values().iter().map(|v| v.to_bits()).collect();
        assert_eq!(wb, cb, "shuffled traversal values");
        assert_blocks_bitwise(&warm, &cold, "restage vs cold");
        let p = warm.prep();
        assert_eq!(p.builds, 1);
        let total_blocks: usize =
            (0..base.order()).map(|n| warm.num_blocks(n)).sum();
        assert_eq!(p.blocks_reused + p.blocks_rebuilt, total_blocks);
        assert!(p.blocks_rebuilt >= 1, "the delta dirtied at least one block");
        assert_eq!(cold.prep().blocks_reused, 0);
        assert_eq!(cold.prep().blocks_rebuilt, total_blocks);
    }

    /// Node-bound parallel staging records where each rotation was
    /// first-touched but never perturbs the built bits.
    #[test]
    fn node_bound_staging_is_bitwise_blind_staging() {
        use crate::config::NumaMode;
        let t = recommender(&RecommenderSpec::tiny(), 69);
        let mut cfg = cfg_for(&t);
        cfg.stage_workers = 4;
        cfg.numa = NumaMode::Off;
        let blind = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        assert!(blind.prep().stage_nodes.is_empty(), "off: no binding");
        cfg.numa = NumaMode::Force(2);
        let homed = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        let nodes = &homed.prep().stage_nodes;
        assert_eq!(nodes.len(), t.order(), "one home per mode rotation");
        assert!(nodes.iter().any(|&n| n == 0) && nodes.iter().any(|&n| n == 1));
        assert_blocks_bitwise(&homed, &blind, "homed vs blind staging");
        // serial staging never binds (it would rebind the caller thread)
        cfg.stage_workers = 1;
        let serial = PreparedStorage::prepare(Algo::FasterTucker, &cfg, &t).unwrap();
        assert!(serial.prep().stage_nodes.is_empty());
        assert_blocks_bitwise(&serial, &blind, "serial staging under numa cfg");
    }

    #[test]
    fn shuffle_is_part_of_staging_and_deterministic() {
        let t = recommender(&RecommenderSpec::tiny(), 64);
        let cfg = cfg_for(&t);
        let a = PreparedStorage::prepare(Algo::FastTucker, &cfg, &t).unwrap();
        let b = PreparedStorage::prepare(Algo::FastTucker, &cfg, &t).unwrap();
        assert_eq!(a.coo().index(0), b.coo().index(0));
        assert_eq!(a.coo().canonical_elements(), t.canonical_elements());
    }
}
