//! Balanced CSF (B-CSF) — the load-balanced storage cuFasterTucker uses
//! (paper §IV-A, after Nisa et al. "Load-balanced sparse MTTKRP on GPUs").
//!
//! Real tensors are power-law: a few fibers hold most of the non-zeros, so
//! assigning whole fibers to workers starves some and drowns others. B-CSF:
//!
//! 1. **Sub-fiber split** — any fiber longer than `fiber_threshold` is cut
//!    into sub-fibers of at most that many leaves (each sub-fiber recomputes
//!    the shared intermediate; the paper calls this the "slightly increased
//!    computation" traded for balance).
//! 2. **Blocking** — sub-fibers are packed, in traversal order, into blocks
//!    of ~`block_nnz` non-zeros. A block is the work unit a worker claims
//!    (the paper's sub-tensor per thread-group).

use super::coo::CooTensor;
use super::csf::CsfTensor;
use crate::algo::engine::{BlockSink, SparseStorage};

/// One schedulable sub-fiber: a contiguous leaf range of one CSF fiber.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Task {
    /// Fiber id in the underlying CSF.
    pub fiber: u32,
    /// Leaf range start (absolute offset into the CSF leaf arrays).
    pub start: u32,
    /// Leaf range end (exclusive).
    pub end: u32,
}

impl Task {
    /// Non-zeros in this sub-fiber.
    #[inline]
    pub fn len(&self) -> usize {
        (self.end - self.start) as usize
    }
    /// Whether the sub-fiber holds no non-zeros.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.end == self.start
    }
}

/// Load-balance accounting, reported by benches and asserted by tests.
#[derive(Clone, Debug, Default)]
pub struct BalanceStats {
    /// Fibers in the underlying CSF.
    pub num_fibers: usize,
    /// Sub-fibers after the threshold split.
    pub num_tasks: usize,
    /// Blocks after packing.
    pub num_blocks: usize,
    /// Longest original fiber (pre-split).
    pub max_fiber_len: usize,
    /// Heaviest block in non-zeros.
    pub max_block_nnz: usize,
    /// Lightest block in non-zeros.
    pub min_block_nnz: usize,
    /// Mean block size in non-zeros.
    pub mean_block_nnz: f64,
    /// Coefficient of variation of block sizes (stddev/mean).
    pub block_cv: f64,
}

/// Balanced-CSF tensor: a [`CsfTensor`] plus the sub-fiber task list, the
/// per-fiber path table, and the block partition workers iterate over.
#[derive(Clone, Debug)]
pub struct BcsfTensor {
    /// The underlying CSF tree (leaf level = update mode).
    pub csf: CsfTensor,
    /// Sub-fibers in CSF traversal order.
    pub tasks: Vec<Task>,
    /// `fiber_paths[f*(N-1)..]` = internal coordinates of fiber `f` in
    /// `csf.mode_order[0..N-1]` order.
    pub fiber_paths: Vec<u32>,
    /// Task ranges, one per block: `blocks[b] = (task_lo, task_hi)`.
    pub blocks: Vec<(u32, u32)>,
    /// Measured non-zeros per block, aligned with `blocks` — the weights
    /// `ShardPlan`'s LPT packing and the claimed-nnz accounting read.
    pub block_sizes: Vec<u32>,
    /// The sub-fiber split bound this tensor was built with.
    pub fiber_threshold: usize,
    /// Load-balance accounting of the split + packing.
    pub stats: BalanceStats,
}

/// Default fiber split threshold — the paper sets 128 ("considered to have
/// the best performance").
pub const DEFAULT_FIBER_THRESHOLD: usize = 128;
/// Default block size target in non-zeros.
pub const DEFAULT_BLOCK_NNZ: usize = 8192;

impl BcsfTensor {
    /// Build from COO with the leaf (update) mode and balancing parameters.
    pub fn build(
        coo: &CooTensor,
        leaf_mode: usize,
        fiber_threshold: usize,
        block_nnz: usize,
    ) -> BcsfTensor {
        let csf = CsfTensor::build(coo, leaf_mode);
        Self::from_csf(csf, fiber_threshold, block_nnz)
    }

    /// Build with paper defaults (threshold 128).
    pub fn build_default(coo: &CooTensor, leaf_mode: usize) -> BcsfTensor {
        Self::build(coo, leaf_mode, DEFAULT_FIBER_THRESHOLD, DEFAULT_BLOCK_NNZ)
    }

    /// [`BcsfTensor::build`] with the sub-fiber split fanned out over
    /// `workers` threads (see [`BcsfTensor::from_csf_with_workers`]).
    /// Bit-identical to the serial build at any worker count.
    pub fn build_with_workers(
        coo: &CooTensor,
        leaf_mode: usize,
        fiber_threshold: usize,
        block_nnz: usize,
        workers: usize,
    ) -> BcsfTensor {
        let csf = CsfTensor::build(coo, leaf_mode);
        Self::from_csf_with_workers(csf, fiber_threshold, block_nnz, workers)
    }

    /// Split + block an already-built CSF tree.
    pub fn from_csf(csf: CsfTensor, fiber_threshold: usize, block_nnz: usize) -> BcsfTensor {
        Self::from_csf_with_workers(csf, fiber_threshold, block_nnz, 1)
    }

    /// [`BcsfTensor::from_csf`] with the sub-fiber split fanned out over
    /// `workers` threads. The fiber index space — already sorted by the
    /// CSF build — is cut into contiguous runs, each worker splits its run
    /// into threshold-bounded tasks independently, and the per-run task
    /// lists concatenate back in fiber order: the result is **bit-identical
    /// to the serial split** for every worker count, because a fiber's
    /// tasks depend on nothing outside that fiber. The block packing that
    /// follows is a cheap sequential prefix scan and stays serial.
    pub fn from_csf_with_workers(
        csf: CsfTensor,
        fiber_threshold: usize,
        block_nnz: usize,
        workers: usize,
    ) -> BcsfTensor {
        assert!(fiber_threshold > 0);
        assert!(block_nnz > 0);
        let fiber_paths = csf.fiber_paths();
        let nf = csf.num_fibers();

        // 1. sub-fiber split, over contiguous sorted fiber runs
        let split_run = |f_lo: usize, f_hi: usize| -> (Vec<Task>, usize) {
            let mut tasks = Vec::with_capacity(f_hi - f_lo);
            let mut max_fiber_len = 0usize;
            for f in f_lo..f_hi {
                let (s, e) = csf.fiber_range(f);
                max_fiber_len = max_fiber_len.max(e - s);
                let mut lo = s;
                while lo < e {
                    let hi = (lo + fiber_threshold).min(e);
                    tasks.push(Task {
                        fiber: f as u32,
                        start: lo as u32,
                        end: hi as u32,
                    });
                    lo = hi;
                }
            }
            (tasks, max_fiber_len)
        };
        let lanes = workers.min(nf).max(1);
        let (mut tasks, mut max_fiber_len) = (Vec::new(), 0usize);
        if lanes <= 1 {
            (tasks, max_fiber_len) = split_run(0, nf);
        } else {
            let run = crate::util::ceil_div(nf, lanes);
            let parts: Vec<(Vec<Task>, usize)> = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..lanes)
                    .map(|w| {
                        let split_run = &split_run;
                        let (lo, hi) = (w * run, ((w + 1) * run).min(nf));
                        scope.spawn(move || split_run(lo, hi))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("split worker")).collect()
            });
            tasks.reserve(parts.iter().map(|p| p.0.len()).sum());
            for (part, part_max) in parts {
                tasks.extend(part);
                max_fiber_len = max_fiber_len.max(part_max);
            }
        }

        // 2. pack tasks into blocks of ~block_nnz non-zeros
        let mut blocks = Vec::new();
        let mut lo = 0usize;
        let mut acc = 0usize;
        for (t, task) in tasks.iter().enumerate() {
            acc += task.len();
            if acc >= block_nnz {
                blocks.push((lo as u32, (t + 1) as u32));
                lo = t + 1;
                acc = 0;
            }
        }
        if lo < tasks.len() {
            blocks.push((lo as u32, tasks.len() as u32));
        }

        let block_sizes: Vec<u32> = blocks
            .iter()
            .map(|&(lo, hi)| {
                tasks[lo as usize..hi as usize]
                    .iter()
                    .map(Task::len)
                    .sum::<usize>() as u32
            })
            .collect();
        let stats = Self::compute_stats(&csf, tasks.len(), &block_sizes, max_fiber_len);
        BcsfTensor {
            csf,
            tasks,
            fiber_paths,
            blocks,
            block_sizes,
            fiber_threshold,
            stats,
        }
    }

    fn compute_stats(
        csf: &CsfTensor,
        num_tasks: usize,
        block_sizes_u32: &[u32],
        max_fiber_len: usize,
    ) -> BalanceStats {
        let block_sizes: Vec<usize> =
            block_sizes_u32.iter().map(|&s| s as usize).collect();
        let nb = block_sizes.len().max(1);
        let mean = block_sizes.iter().sum::<usize>() as f64 / nb as f64;
        let var = block_sizes
            .iter()
            .map(|&s| (s as f64 - mean).powi(2))
            .sum::<f64>()
            / nb as f64;
        BalanceStats {
            num_fibers: csf.num_fibers(),
            num_tasks,
            num_blocks: block_sizes.len(),
            max_fiber_len,
            max_block_nnz: block_sizes.iter().copied().max().unwrap_or(0),
            min_block_nnz: block_sizes.iter().copied().min().unwrap_or(0),
            mean_block_nnz: mean,
            block_cv: if mean > 0.0 { var.sqrt() / mean } else { 0.0 },
        }
    }

    /// Number of modes N.
    #[inline]
    pub fn order(&self) -> usize {
        self.csf.order()
    }

    /// Stored non-zeros (after CSF duplicate merging).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.csf.nnz()
    }

    /// Schedulable blocks (the units workers claim).
    #[inline]
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Approximate heap footprint: the CSF tree plus the task list, fiber
    /// paths, and block partition — what evicting this rotation frees.
    pub fn heap_bytes(&self) -> usize {
        self.csf.heap_bytes()
            + self.tasks.capacity() * std::mem::size_of::<Task>()
            + self.fiber_paths.capacity() * 4
            + self.blocks.capacity() * std::mem::size_of::<(u32, u32)>()
            + self.block_sizes.capacity() * 4
    }

    /// Tasks of block `b`.
    #[inline]
    pub fn block_tasks(&self, b: usize) -> &[Task] {
        let (lo, hi) = self.blocks[b];
        &self.tasks[lo as usize..hi as usize]
    }

    /// Measured non-zeros in block `b`.
    #[inline]
    pub fn block_nnz_of(&self, b: usize) -> usize {
        self.block_sizes[b] as usize
    }

    /// Path (internal coordinates) of fiber `f`.
    #[inline]
    pub fn fiber_path(&self, f: u32) -> &[u32] {
        let plen = self.order() - 1;
        &self.fiber_paths[f as usize * plen..(f as usize + 1) * plen]
    }

    /// Leaf coordinates + values of a task (sub-fiber).
    #[inline]
    pub fn task_leaves(&self, t: &Task) -> (&[u32], &[f32]) {
        let n = self.order();
        let (s, e) = (t.start as usize, t.end as usize);
        (&self.csf.level_idx[n - 1][s..e], &self.csf.values[s..e])
    }

    /// Invariants beyond the CSF's own: tasks tile fibers exactly, respect
    /// the threshold, blocks tile tasks exactly.
    pub fn validate(&self) -> Result<(), String> {
        self.csf.validate()?;
        let mut covered = 0usize;
        let mut prev_fiber = None::<u32>;
        let mut expected_next = 0u32;
        for task in &self.tasks {
            if task.is_empty() {
                return Err("empty task".into());
            }
            if task.len() > self.fiber_threshold {
                return Err(format!(
                    "task longer than threshold: {} > {}",
                    task.len(),
                    self.fiber_threshold
                ));
            }
            let (fs, fe) = self.csf.fiber_range(task.fiber as usize);
            if (task.start as usize) < fs || (task.end as usize) > fe {
                return Err("task outside its fiber".into());
            }
            if prev_fiber == Some(task.fiber) {
                if task.start != expected_next {
                    return Err("gap between sub-fibers".into());
                }
            } else if task.start as usize != fs {
                return Err("first sub-fiber does not start at fiber start".into());
            }
            expected_next = task.end;
            prev_fiber = Some(task.fiber);
            covered += task.len();
        }
        if covered != self.nnz() {
            return Err(format!("tasks cover {} of {} nnz", covered, self.nnz()));
        }
        let mut t_cursor = 0u32;
        for &(lo, hi) in &self.blocks {
            if lo != t_cursor || hi <= lo {
                return Err("blocks do not tile tasks".into());
            }
            t_cursor = hi;
        }
        if t_cursor as usize != self.tasks.len() {
            return Err("blocks do not cover all tasks".into());
        }
        if self.block_sizes.len() != self.blocks.len() {
            return Err("block_sizes misaligned with blocks".into());
        }
        for (b, &(lo, hi)) in self.blocks.iter().enumerate() {
            let measured: usize =
                self.tasks[lo as usize..hi as usize].iter().map(Task::len).sum();
            if measured != self.block_sizes[b] as usize {
                return Err(format!(
                    "block {b}: stored size {} != measured {measured}",
                    self.block_sizes[b]
                ));
            }
        }
        Ok(())
    }
}

/// Epoch-engine storage adapter over the per-mode B-CSF rotations with
/// **fiber-shared** streaming (full cuFasterTucker, paper §III-B):
/// [`BlockSink::group`] fires once per run of tasks on the same fiber, so the
/// chain products `v` and the invariant `w = B^(n) v` are computed once and
/// shared by every leaf of the (sub-)fiber.
///
/// `rotations[n]` must be the rotation whose leaf (update) mode is `n`.
pub struct BcsfShared<'a> {
    rotations: &'a [BcsfTensor],
}

impl<'a> BcsfShared<'a> {
    /// Adapter over per-mode rotations (`rotations[n]` has leaf mode `n`).
    pub fn new(rotations: &'a [BcsfTensor]) -> BcsfShared<'a> {
        BcsfShared { rotations }
    }
}

/// Epoch-engine storage adapter for the paper's "cuFasterTucker_B-CSF"
/// ablation (Table V row 3): identical traversal order to [`BcsfShared`] —
/// so it inherits B-CSF's locality and balance — but [`BlockSink::group`]
/// fires for **every** leaf, forcing `v`/`w` recomputation per non-zero and
/// isolating the benefit of the shared invariant intermediates.
pub struct BcsfPerElement<'a> {
    rotations: &'a [BcsfTensor],
}

impl<'a> BcsfPerElement<'a> {
    /// Adapter over per-mode rotations (`rotations[n]` has leaf mode `n`).
    pub fn new(rotations: &'a [BcsfTensor]) -> BcsfPerElement<'a> {
        BcsfPerElement { rotations }
    }
}

fn bcsf_chain_modes(t: &BcsfTensor, n: usize) -> &[usize] {
    debug_assert_eq!(t.csf.leaf_mode(), n);
    &t.csf.mode_order[..t.order() - 1]
}

/// Stream block `b` of rotation `t` with **fiber-shared** groups: one
/// [`BlockSink::group`] per run of tasks on the same fiber, then each
/// sub-fiber's leaves as one contiguous slice pair straight out of the CSF
/// arrays — zero per-element work in the walker. Shared by [`BcsfShared`]
/// and [`crate::tensor::prepared::PreparedStorage`].
pub(crate) fn drive_shared_block<S: BlockSink>(t: &BcsfTensor, b: usize, sink: &mut S) {
    let mut prev_fiber = u32::MAX;
    let mut first = true;
    for task in t.block_tasks(b) {
        if first || task.fiber != prev_fiber {
            sink.group(t.fiber_path(task.fiber));
            prev_fiber = task.fiber;
            first = false;
        }
        let (leaf_idx, leaf_vals) = t.task_leaves(task);
        sink.leaves(leaf_idx, leaf_vals);
    }
}

/// Stream block `b` of rotation `t` with **per-element** groups (Table V
/// ablation): same traversal order, but every leaf re-announces its group
/// and arrives as a one-element run, forcing `v`/`w` recomputation.
pub(crate) fn drive_per_element_block<S: BlockSink>(
    t: &BcsfTensor,
    b: usize,
    sink: &mut S,
) {
    for task in t.block_tasks(b) {
        let path = t.fiber_path(task.fiber);
        let (leaf_idx, leaf_vals) = t.task_leaves(task);
        for k in 0..leaf_idx.len() {
            // per-element group announcement = per-element recomputation
            sink.group(path);
            sink.leaves(&leaf_idx[k..k + 1], &leaf_vals[k..k + 1]);
        }
    }
}

impl SparseStorage for BcsfShared<'_> {
    fn num_blocks(&self, n: usize) -> usize {
        self.rotations[n].num_blocks()
    }

    fn nnz(&self, n: usize) -> usize {
        self.rotations[n].nnz()
    }

    fn block_weight(&self, n: usize, b: usize) -> usize {
        self.rotations[n].block_nnz_of(b)
    }

    fn chain_modes(&self, n: usize) -> &[usize] {
        bcsf_chain_modes(&self.rotations[n], n)
    }

    fn drive_block<S: BlockSink>(&self, n: usize, b: usize, sink: &mut S) {
        drive_shared_block(&self.rotations[n], b, sink);
    }
}

impl SparseStorage for BcsfPerElement<'_> {
    fn num_blocks(&self, n: usize) -> usize {
        self.rotations[n].num_blocks()
    }

    fn nnz(&self, n: usize) -> usize {
        self.rotations[n].nnz()
    }

    fn block_weight(&self, n: usize, b: usize) -> usize {
        self.rotations[n].block_nnz_of(b)
    }

    fn chain_modes(&self, n: usize) -> &[usize] {
        bcsf_chain_modes(&self.rotations[n], n)
    }

    fn drive_block<S: BlockSink>(&self, n: usize, b: usize, sink: &mut S) {
        drive_per_element_block(&self.rotations[n], b, sink);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn power_law_tensor(nnz: usize, seed: u64) -> CooTensor {
        let mut rng = Rng::new(seed);
        let mut t = CooTensor::new(vec![50, 40, 30]);
        for _ in 0..nnz {
            let c = [
                rng.zipf(50, 1.2) as u32,
                rng.zipf(40, 1.1) as u32,
                rng.next_below(30) as u32,
            ];
            t.push(&c, rng.uniform_f32(1.0, 5.0));
        }
        t
    }

    #[test]
    fn tasks_respect_threshold() {
        let coo = power_law_tensor(5000, 1);
        let b = BcsfTensor::build(&coo, 2, 16, 256);
        b.validate().unwrap();
        assert!(b.tasks.iter().all(|t| t.len() <= 16));
    }

    #[test]
    fn element_set_preserved() {
        let coo = power_law_tensor(2000, 2);
        let b = BcsfTensor::build(&coo, 0, 8, 128);
        // CSF merges duplicate coordinates by summing, so compare against the
        // deduplicated input.
        let dedup = CsfTensor::build(&coo, 0).to_coo();
        assert_eq!(
            dedup.canonical_elements(),
            b.csf.to_coo().canonical_elements()
        );
    }

    #[test]
    fn blocks_cover_all_nnz_once() {
        let coo = power_law_tensor(3000, 3);
        let b = BcsfTensor::build(&coo, 1, 32, 512);
        b.validate().unwrap();
        let total: usize = (0..b.num_blocks())
            .map(|blk| b.block_tasks(blk).iter().map(Task::len).sum::<usize>())
            .sum();
        assert_eq!(total, b.nnz());
    }

    #[test]
    fn balance_improves_with_splitting() {
        let coo = power_law_tensor(20_000, 4);
        // tiny threshold → finely split → small blocks near target
        let balanced = BcsfTensor::build(&coo, 2, 8, 512);
        // huge threshold → whole fibers → lumpier blocks
        let lumpy = BcsfTensor::build(&coo, 2, usize::MAX >> 1, 512);
        assert!(balanced.stats.max_block_nnz <= 512 + 8);
        assert!(balanced.stats.block_cv <= lumpy.stats.block_cv + 1e-9);
    }

    #[test]
    fn block_max_bounded_by_target_plus_threshold() {
        let coo = power_law_tensor(10_000, 5);
        let thr = 64;
        let target = 1024;
        let b = BcsfTensor::build(&coo, 0, thr, target);
        // greedy close: a block closes as soon as it reaches target, so it
        // can overshoot by at most one task (≤ threshold)
        assert!(b.stats.max_block_nnz <= target + thr);
    }

    #[test]
    fn stats_consistency() {
        let coo = power_law_tensor(4000, 6);
        let b = BcsfTensor::build(&coo, 1, 128, 1024);
        assert_eq!(b.stats.num_tasks, b.tasks.len());
        assert_eq!(b.stats.num_blocks, b.blocks.len());
        assert!(b.stats.min_block_nnz <= b.stats.max_block_nnz);
        assert!(b.stats.mean_block_nnz > 0.0);
    }

    #[test]
    fn fiber_path_lookup_consistent_with_csf() {
        let coo = power_law_tensor(1000, 7);
        let b = BcsfTensor::build(&coo, 2, 128, 1024);
        let paths = b.csf.fiber_paths();
        let plen = b.order() - 1;
        for f in 0..b.csf.num_fibers() {
            assert_eq!(b.fiber_path(f as u32), &paths[f * plen..(f + 1) * plen]);
        }
    }

    #[test]
    fn parallel_split_is_bit_identical_to_serial() {
        let coo = power_law_tensor(8000, 8);
        for mode in 0..3 {
            let serial = BcsfTensor::build(&coo, mode, 16, 512);
            for workers in [2, 3, 5, 64] {
                let par =
                    BcsfTensor::build_with_workers(&coo, mode, 16, 512, workers);
                par.validate().unwrap();
                assert_eq!(par.tasks, serial.tasks, "mode {mode} ×{workers}");
                assert_eq!(par.blocks, serial.blocks);
                assert_eq!(par.block_sizes, serial.block_sizes);
                assert_eq!(par.fiber_paths, serial.fiber_paths);
                assert_eq!(par.stats.max_fiber_len, serial.stats.max_fiber_len);
            }
        }
    }

    #[test]
    fn single_fiber_tensor() {
        // all elements in one fiber along mode 1
        let mut t = CooTensor::new(vec![2, 100]);
        for i in 0..100u32 {
            t.push(&[1, i], 1.0);
        }
        let b = BcsfTensor::build(&t, 1, 10, 25);
        b.validate().unwrap();
        assert_eq!(b.csf.num_fibers(), 1);
        assert_eq!(b.tasks.len(), 10);
        assert!(b.num_blocks() >= 4);
    }
}
