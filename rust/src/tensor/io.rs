//! Sparse tensor IO: a compact binary format plus FROSTT-style text.
//!
//! Binary layout (little-endian):
//! ```text
//! magic  "FTNS"          4 bytes
//! version u32            currently 1
//! order   u32
//! dims    u64 × order
//! nnz     u64
//! indices u32 × nnz × order   (element-major)
//! values  f32 × nnz
//! ```
//!
//! Text format: one non-zero per line, `i_1 i_2 .. i_N value`, whitespace
//! separated; `#` comments; `one_based` toggles FROSTT's 1-based indices.

use super::coo::CooTensor;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"FTNS";
const VERSION: u32 = 1;

/// Write a COO tensor in the binary format.
pub fn write_binary(tensor: &CooTensor, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(tensor.order() as u32).to_le_bytes())?;
    for &d in tensor.dims() {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    w.write_all(&(tensor.nnz() as u64).to_le_bytes())?;
    for &i in tensor.indices_flat() {
        w.write_all(&i.to_le_bytes())?;
    }
    for &v in tensor.values() {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read a binary tensor written by [`write_binary`].
pub fn read_binary(path: &Path) -> Result<CooTensor> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("truncated header")?;
    if &magic != MAGIC {
        bail!("bad magic: not a FTNS tensor file");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported version {version}");
    }
    let order = read_u32(&mut r)? as usize;
    if order == 0 || order > 64 {
        bail!("implausible order {order}");
    }
    let mut dims = Vec::with_capacity(order);
    for _ in 0..order {
        dims.push(read_u64(&mut r)? as usize);
    }
    let nnz = read_u64(&mut r)? as usize;
    // sanity-check the claimed nnz against the actual file size before
    // allocating (a hostile header must not drive a huge allocation)
    let file_len = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
    let needed = (nnz as u64)
        .checked_mul(order as u64 * 4 + 4)
        .ok_or_else(|| anyhow::anyhow!("claimed nnz overflows"))?;
    if needed > file_len {
        bail!(
            "file too small for claimed nnz {} (needs {} bytes, file has {})",
            nnz,
            needed,
            file_len
        );
    }
    let mut tensor = CooTensor::with_capacity(dims, nnz);
    let mut coords = vec![0u32; order];
    for _ in 0..nnz {
        for c in coords.iter_mut() {
            *c = read_u32(&mut r)?;
        }
        // value comes later in the stream layout; read after all indices
        // NOTE: layout stores all indices then all values, so buffer indices.
        tensor.push_unchecked(&coords, 0.0);
    }
    // now the values block
    for e in 0..nnz {
        let v = read_f32(&mut r)?;
        tensor.set_value(e, v);
    }
    tensor
        .validate()
        .map_err(|e| anyhow::anyhow!("invalid tensor data: {e}"))?;
    Ok(tensor)
}

/// Write FROSTT-style text.
pub fn write_text(tensor: &CooTensor, path: &Path, one_based: bool) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    let off = if one_based { 1 } else { 0 };
    writeln!(w, "# fastertucker tensor: dims {:?}", tensor.dims())?;
    for (coords, v) in tensor.iter() {
        for &c in coords {
            write!(w, "{} ", c + off)?;
        }
        writeln!(w, "{v}")?;
    }
    w.flush()?;
    Ok(())
}

/// Read FROSTT-style text; dims are inferred as max index + 1 unless given.
pub fn read_text(path: &Path, dims: Option<Vec<usize>>, one_based: bool) -> Result<CooTensor> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let r = BufReader::new(f);
    let off: i64 = if one_based { 1 } else { 0 };
    let mut rows: Vec<(Vec<u32>, f32)> = Vec::new();
    let mut order: Option<usize> = None;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks.len() < 2 {
            bail!("line {}: need at least one index and a value", lineno + 1);
        }
        let n = toks.len() - 1;
        match order {
            None => order = Some(n),
            Some(o) if o != n => {
                bail!("line {}: inconsistent order {} vs {}", lineno + 1, n, o)
            }
            _ => {}
        }
        let mut coords = Vec::with_capacity(n);
        for t in &toks[..n] {
            let raw: i64 = t
                .parse()
                .with_context(|| format!("line {}: bad index '{}'", lineno + 1, t))?;
            let idx = raw - off;
            if idx < 0 {
                bail!("line {}: negative index after base adjustment", lineno + 1);
            }
            coords.push(idx as u32);
        }
        let v: f32 = toks[n]
            .parse()
            .with_context(|| format!("line {}: bad value '{}'", lineno + 1, toks[n]))?;
        rows.push((coords, v));
    }
    let order = order.unwrap_or_else(|| dims.as_ref().map(|d| d.len()).unwrap_or(1));
    let dims = match dims {
        Some(d) => {
            if d.len() != order {
                bail!("given dims order {} != data order {}", d.len(), order);
            }
            d
        }
        None => {
            let mut d = vec![0usize; order];
            for (coords, _) in &rows {
                for (k, &c) in coords.iter().enumerate() {
                    d[k] = d[k].max(c as usize + 1);
                }
            }
            d.iter_mut().for_each(|x| *x = (*x).max(1));
            d
        }
    };
    let mut tensor = CooTensor::with_capacity(dims, rows.len());
    for (coords, v) in rows {
        tensor.push(&coords, v);
    }
    Ok(tensor)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("truncated file")?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("truncated file")?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("truncated file")?;
    Ok(f32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ft_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{}", std::process::id(), name))
    }

    fn random_tensor(seed: u64) -> CooTensor {
        let mut rng = Rng::new(seed);
        let mut t = CooTensor::new(vec![20, 30, 10]);
        for _ in 0..500 {
            let c = [
                rng.next_below(20) as u32,
                rng.next_below(30) as u32,
                rng.next_below(10) as u32,
            ];
            t.push(&c, rng.uniform_f32(-5.0, 5.0));
        }
        t
    }

    #[test]
    fn binary_roundtrip() {
        let t = random_tensor(1);
        let p = tmpfile("bin_roundtrip.ftns");
        write_binary(&t, &p).unwrap();
        let t2 = read_binary(&p).unwrap();
        assert_eq!(t.dims(), t2.dims());
        assert_eq!(t.canonical_elements(), t2.canonical_elements());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let p = tmpfile("bad_magic.ftns");
        std::fs::write(&p, b"NOPE00000000").unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn binary_rejects_truncation() {
        let t = random_tensor(2);
        let p = tmpfile("trunc.ftns");
        write_binary(&t, &p).unwrap();
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..data.len() / 2]).unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_roundtrip_zero_based() {
        let t = random_tensor(3);
        let p = tmpfile("text0.tns");
        write_text(&t, &p, false).unwrap();
        let t2 = read_text(&p, Some(t.dims().to_vec()), false).unwrap();
        // text loses some float precision via decimal printing; compare coords
        let a = t.canonical_elements();
        let b = t2.canonical_elements();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.0, y.0);
            assert!((x.1 - y.1).abs() < 1e-4);
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_roundtrip_one_based() {
        let t = random_tensor(4);
        let p = tmpfile("text1.tns");
        write_text(&t, &p, true).unwrap();
        let t2 = read_text(&p, None, true).unwrap();
        assert_eq!(
            t.canonical_elements().len(),
            t2.canonical_elements().len()
        );
        // inferred dims must bound all indices
        for (c, _) in t2.iter() {
            for (k, &i) in c.iter().enumerate() {
                assert!((i as usize) < t2.dims()[k]);
            }
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_rejects_ragged_lines() {
        let p = tmpfile("ragged.tns");
        std::fs::write(&p, "1 2 3 1.0\n1 2 1.0\n").unwrap();
        assert!(read_text(&p, None, false).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn text_skips_comments_and_blank() {
        let p = tmpfile("comments.tns");
        std::fs::write(&p, "# header\n\n0 1 2.5\n").unwrap();
        let t = read_text(&p, None, false).unwrap();
        assert_eq!(t.nnz(), 1);
        assert_eq!(t.value(0), 2.5);
        std::fs::remove_file(p).ok();
    }
}
